"""L2 model tests: shapes, pallas-vs-ref equivalence, optimizer, learning."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model

CFG = model.TINY


def _rand_tokens(rng, cfg, batch=None):
    b = batch or cfg.batch
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.seq + 1)), jnp.int32)


def test_param_bookkeeping_consistent():
    names = model.param_names(CFG)
    shapes = model.param_shapes(CFG)
    params = model.init_params(CFG)
    assert len(names) == len(shapes) == len(params)
    for p, s in zip(params, shapes):
        assert p.shape == tuple(s)
    assert model.num_params(CFG) == sum(int(np.prod(s)) for s in shapes)


def test_forward_shapes():
    rng = np.random.default_rng(0)
    params = model.init_params(CFG)
    toks = _rand_tokens(rng, CFG)
    logits = model.forward(CFG, params, toks[:, :-1])
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)


def test_pallas_and_ref_losses_agree():
    rng = np.random.default_rng(1)
    params = model.init_params(CFG)
    toks = _rand_tokens(rng, CFG)
    l_pallas = model.loss_fn(CFG, params, toks, use_pallas=True)
    l_ref = model.loss_fn(CFG, params, toks, use_pallas=False)
    np.testing.assert_allclose(l_pallas, l_ref, rtol=1e-5, atol=1e-5)


def test_pallas_and_ref_gradients_agree():
    rng = np.random.default_rng(2)
    params = model.init_params(CFG)
    toks = _rand_tokens(rng, CFG)
    gp = jax.grad(lambda p: model.loss_fn(CFG, p, toks, use_pallas=True))(params)
    gr = jax.grad(lambda p: model.loss_fn(CFG, p, toks, use_pallas=False))(params)
    for a, b, name in zip(gp, gr, model.param_names(CFG)):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=1e-5, err_msg=name)


def test_initial_loss_near_uniform():
    """Fresh model ≈ uniform predictor: loss ≈ log(vocab)."""
    rng = np.random.default_rng(3)
    params = model.init_params(CFG)
    toks = _rand_tokens(rng, CFG)
    loss = float(model.loss_fn(CFG, params, toks))
    assert abs(loss - np.log(CFG.vocab)) < 0.5


def test_adamw_matches_manual_formula():
    cfg = CFG
    rng = np.random.default_rng(4)
    p = [jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))]
    g = [jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))]
    m = [jnp.zeros((4, 4), jnp.float32)]
    v = [jnp.zeros((4, 4), jnp.float32)]
    new_p, new_m, new_v = model.adamw_update(cfg, p, g, m, v, 1.0)
    b1, b2 = cfg.betas
    m1 = (1 - b1) * np.asarray(g[0])
    v1 = (1 - b2) * np.asarray(g[0]) ** 2
    mhat = m1 / (1 - b1)
    vhat = v1 / (1 - b2)
    upd = mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * np.asarray(p[0])
    np.testing.assert_allclose(new_m[0], m1, rtol=1e-6)
    np.testing.assert_allclose(new_v[0], v1, rtol=1e-6)
    np.testing.assert_allclose(new_p[0], np.asarray(p[0]) - cfg.lr * upd, rtol=1e-5)


def test_train_step_output_arity():
    rng = np.random.default_rng(5)
    params = model.init_params(CFG)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    toks = _rand_tokens(rng, CFG)
    out = model.train_step(CFG, params, m, v, toks, 1.0)
    assert len(out) == 1 + 3 * len(params)
    assert out[0].shape == ()


def test_loss_decreases_on_learnable_data():
    """~30 steps on a fixed repetitive batch must cut the loss sharply."""
    rng = np.random.default_rng(6)
    params = model.init_params(CFG)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    pattern = np.tile(np.arange(16, dtype=np.int32), CFG.seq // 16 + 2)
    toks = jnp.asarray(
        np.stack([pattern[i : i + CFG.seq + 1] for i in range(CFG.batch)]), jnp.int32
    )
    step_fn = jax.jit(lambda p, m, v, t, s: model.train_step(CFG, p, m, v, t, s))
    first = None
    n = len(params)
    for i in range(30):
        out = step_fn(params, m, v, toks, float(i + 1))
        loss = float(out[0])
        if first is None:
            first = loss
        params = list(out[1 : 1 + n])
        m = list(out[1 + n : 1 + 2 * n])
        v = list(out[1 + 2 * n :])
    assert loss < first * 0.5, (first, loss)


def test_specs_match_init():
    p_specs, tok_spec, step_spec = model.make_specs(CFG)
    params = model.init_params(CFG)
    for spec, p in zip(p_specs, params):
        assert spec.shape == p.shape and spec.dtype == p.dtype
    assert tok_spec.shape == (CFG.batch, CFG.seq + 1)
    assert step_spec.shape == ()
