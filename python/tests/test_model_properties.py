"""Hypothesis property sweeps over the L2 model across GPT-2 configs."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model


@st.composite
def small_configs(draw):
    d_model = draw(st.sampled_from([32, 64, 128]))
    n_head = draw(st.sampled_from([1, 2, 4]))
    return model.GPT2Config(
        vocab=draw(st.sampled_from([64, 128, 256])),
        seq=draw(st.sampled_from([32, 64])),
        d_model=d_model,
        n_head=n_head,
        n_layer=draw(st.integers(1, 2)),
        batch=draw(st.integers(1, 2)),
    )


@settings(max_examples=5, deadline=None)
@given(cfg=small_configs(), seed=st.integers(0, 2**31 - 1))
def test_forward_is_finite_and_shaped(cfg, seed):
    rng = np.random.default_rng(seed)
    params = model.init_params(cfg, seed=seed % 997)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)
    logits = model.forward(cfg, params, toks)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@settings(max_examples=4, deadline=None)
@given(cfg=small_configs(), seed=st.integers(0, 2**31 - 1))
def test_loss_near_uniform_and_grads_match_param_shapes(cfg, seed):
    rng = np.random.default_rng(seed)
    params = model.init_params(cfg, seed=1)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq + 1)), jnp.int32
    )
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(cfg, p, toks))(params)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.isfinite(g).all())


@settings(max_examples=4, deadline=None)
@given(cfg=small_configs())
def test_causality_of_the_full_model(cfg):
    """Changing future tokens must not change earlier logits."""
    rng = np.random.default_rng(0)
    params = model.init_params(cfg, seed=2)
    toks = np.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), np.int32)
    l1 = model.forward(cfg, params, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % cfg.vocab
    l2 = model.forward(cfg, params, jnp.asarray(toks2))
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)
