"""AOT lowering sanity: HLO text well-formed, metadata consistent.

The full rust round-trip (load + compile + execute + numerics) is covered by
rust integration tests (rust/tests/runtime_roundtrip.rs); here we check the
python side of the contract.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_wellformed(tmp_path):
    spec = jax.ShapeDtypeStruct((model.N_CFG, 8), jnp.float32)
    lay = jax.ShapeDtypeStruct((model.N_LAYER, 8), jnp.float32)
    lowered = jax.jit(model.cost_eval_graph).lower(spec, lay)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # fixed AOT shapes visible in the entry signature
    assert f"f32[{model.N_CFG},8]" in text
    assert f"f32[{model.N_LAYER},8]" in text


def test_train_step_lowering_param_count():
    cfg = model.TINY
    p_specs, tok_spec, step_spec = model.make_specs(cfg)
    lowered = jax.jit(
        lambda p, m, v, t, s: model.train_step(cfg, p, m, v, t, s)
    ).lower(p_specs, p_specs, p_specs, tok_spec, step_spec)
    text = aot.to_hlo_text(lowered)
    n = len(p_specs)
    # params + m + v + tokens + step ("parameter(i)" also appears in nested
    # computations, so check the max entry index, not the count)
    import re

    max_idx = max(int(m) for m in re.findall(r"parameter\((\d+)\)", text))
    assert max_idx == 3 * n + 2 - 1


def test_full_aot_run(tmp_path):
    """Run the real entry point end to end into a temp dir."""
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--gpt2-configs", "tiny"]
    try:
        aot.main()
    finally:
        sys.argv = argv

    meta = json.load(open(tmp_path / "meta.json"))
    assert meta["cost_eval"]["n_cfg"] == model.N_CFG
    g = meta["gpt2_tiny"]
    assert g["num_params"] == model.num_params(model.TINY)
    assert len(g["param_names"]) == len(g["param_shapes"])

    init = np.fromfile(tmp_path / "gpt2_tiny_init.bin", dtype=np.float32)
    assert init.size == g["num_params"]
    # init blob must reproduce init_params exactly, in flatten order
    want = np.concatenate(
        [np.asarray(p, np.float32).ravel() for p in model.init_params(model.TINY)]
    )
    np.testing.assert_array_equal(init, want)

    for name in (
        "cost_eval.hlo.txt",
        "cost_eval_ref.hlo.txt",
        "gpt2_tiny_train.hlo.txt",
        "gpt2_tiny_eval.hlo.txt",
    ):
        text = open(tmp_path / name).read()
        assert "ENTRY" in text, name
