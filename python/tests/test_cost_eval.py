"""Pallas cost kernel vs pure-jnp oracle: hypothesis shape/value sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cost_eval, ref

BC = cost_eval.BLOCK_CFG


def _run_pair(configs, layers):
    got = np.asarray(cost_eval.cost_eval(jnp.asarray(configs), jnp.asarray(layers)))
    want = np.asarray(ref.cost_eval_ref(jnp.asarray(configs), jnp.asarray(layers)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    return got


def _random_inputs(rng, n_cfg, n_layer):
    configs = np.empty((n_cfg, ref.CFG_W), np.float32)
    configs[:, ref.CFG_MACS] = rng.uniform(1, 1e5, n_cfg)
    configs[:, ref.CFG_ONCHIP_BW] = rng.uniform(1, 1e4, n_cfg)
    configs[:, ref.CFG_OFFCHIP_BW] = rng.uniform(1, 1e3, n_cfg)
    configs[:, ref.CFG_LOCAL_MEM] = rng.uniform(1e3, 1e7, n_cfg)
    configs[:, ref.CFG_E_MAC] = rng.uniform(0.1, 4.0, n_cfg)
    configs[:, ref.CFG_E_ONCHIP] = rng.uniform(0.1, 10.0, n_cfg)
    configs[:, ref.CFG_E_OFFCHIP] = rng.uniform(10.0, 200.0, n_cfg)
    configs[:, ref.CFG_RESERVED] = 0.0
    layers = np.empty((n_layer, ref.LAY_W), np.float32)
    layers[:, ref.LAY_FLOPS] = rng.uniform(0, 1e9, n_layer)
    layers[:, ref.LAY_ONCHIP_BYTES] = rng.uniform(0, 1e7, n_layer)
    layers[:, ref.LAY_OFFCHIP_BYTES] = rng.uniform(0, 1e6, n_layer)
    layers[:, ref.LAY_PARALLELISM] = rng.uniform(1, 1e5, n_layer)
    layers[:, ref.LAY_WORKING_SET] = rng.uniform(0, 1e7, n_layer)
    layers[:, ref.LAY_WEIGHT_BYTES] = rng.uniform(0, 1e6, n_layer)
    layers[:, 6:] = 0.0
    return configs, layers


@settings(max_examples=12, deadline=None)
@given(
    n_cfg_blocks=st.integers(1, 3),
    n_layer=st.sampled_from([1, 7, 64, 200]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_across_shapes(n_cfg_blocks, n_layer, seed):
    rng = np.random.default_rng(seed)
    configs, layers = _random_inputs(rng, n_cfg_blocks * BC, n_layer)
    _run_pair(configs, layers)


def test_zero_layer_rows_are_benign():
    rng = np.random.default_rng(0)
    configs, layers = _random_inputs(rng, BC, 32)
    padded = np.concatenate([layers, np.zeros((32, ref.LAY_W), np.float32)])
    a = _run_pair(configs, layers)
    b = _run_pair(configs, padded)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_util_bounded():
    rng = np.random.default_rng(1)
    configs, layers = _random_inputs(rng, BC, 50)
    out = _run_pair(configs, layers)
    assert (out[:, ref.OUT_UTIL] >= 0).all() and (out[:, ref.OUT_UTIL] <= 1).all()


def test_more_macs_never_slower():
    """Monotonicity: scaling MACs up cannot increase cycles."""
    rng = np.random.default_rng(2)
    configs, layers = _random_inputs(rng, BC, 50)
    faster = configs.copy()
    faster[:, ref.CFG_MACS] *= 4.0
    a = _run_pair(configs, layers)
    b = _run_pair(faster, layers)
    assert (b[:, ref.OUT_CYCLES] <= a[:, ref.OUT_CYCLES] * (1 + 1e-6)).all()


def test_spill_only_when_working_set_exceeds_mem():
    rng = np.random.default_rng(3)
    configs, layers = _random_inputs(rng, BC, 50)
    configs[:, ref.CFG_LOCAL_MEM] = 1e9  # everything fits
    out = _run_pair(configs, layers)
    np.testing.assert_allclose(out[:, ref.OUT_SPILL], 0.0, atol=1e-6)


def test_memory_bound_config_hits_bandwidth_roof():
    """With huge MAC count, cycles are exactly the memory roofline."""
    configs = np.zeros((BC, ref.CFG_W), np.float32)
    configs[:, ref.CFG_MACS] = 1e9
    configs[:, ref.CFG_ONCHIP_BW] = 100.0
    configs[:, ref.CFG_OFFCHIP_BW] = 10.0
    configs[:, ref.CFG_LOCAL_MEM] = 1e9
    layers = np.zeros((4, ref.LAY_W), np.float32)
    layers[:, ref.LAY_FLOPS] = 1e3
    layers[:, ref.LAY_PARALLELISM] = 1e9
    layers[:, ref.LAY_ONCHIP_BYTES] = 1e4
    layers[:, ref.LAY_OFFCHIP_BYTES] = 1e3
    out = _run_pair(configs, layers)
    want = 4 * max(1e4 / 100.0, 1e3 / 10.0)
    np.testing.assert_allclose(out[:, ref.OUT_CYCLES], want, rtol=1e-5)


def test_rejects_unaligned_config_count():
    with pytest.raises(AssertionError):
        cost_eval.cost_eval(
            jnp.zeros((BC + 1, ref.CFG_W)), jnp.zeros((4, ref.LAY_W))
        )
