"""Flash-attention Pallas kernels (fwd + custom-vjp bwd) vs jnp oracle."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref


def _rand_qkv(rng, seq, d):
    return tuple(
        jnp.asarray(rng.normal(size=(seq, d)).astype(np.float32)) for _ in range(3)
    )


@settings(max_examples=10, deadline=None)
@given(
    seq=st.sampled_from([32, 64, 96, 128]),
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_forward_matches_ref(seq, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, seq, d)
    got = attention.flash_attention(q, k, v, causal=causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(
    seq=st.sampled_from([32, 64]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_gradients_match_ref(seq, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, seq, d)

    def loss_pallas(q, k, v):
        return jnp.sum(jnp.tanh(attention.flash_attention(q, k, v, causal=causal)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(ref.attention_ref(q, k, v, causal=causal)))

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_block_sizes_do_not_change_result():
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, 128, 32)
    outs = [
        attention.flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv)
        for bq, bkv in [(16, 16), (32, 64), (64, 32), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_causal_ignores_future_tokens():
    """Perturbing future k/v rows must not change earlier outputs."""
    rng = np.random.default_rng(1)
    q, k, v = _rand_qkv(rng, 64, 16)
    o1 = attention.flash_attention(q, k, v, causal=True)
    k2 = k.at[48:].set(rng.normal(size=(16, 16)).astype(np.float32))
    v2 = v.at[48:].set(rng.normal(size=(16, 16)).astype(np.float32))
    o2 = attention.flash_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(o1[:48], o2[:48], rtol=1e-6, atol=1e-6)


def test_mha_matches_ref():
    rng = np.random.default_rng(2)
    q, k, v = (
        jnp.asarray(rng.normal(size=(4, 64, 16)).astype(np.float32)) for _ in range(3)
    )
    got = attention.mha(q, k, v, causal=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_softmax_rows_sum_to_one_via_uniform_v():
    """With v = all-ones, attention output must be exactly ones."""
    rng = np.random.default_rng(3)
    q, k, _ = _rand_qkv(rng, 64, 32)
    v = jnp.ones((64, 32), jnp.float32)
    o = attention.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(o, 1.0, rtol=1e-5, atol=1e-5)
