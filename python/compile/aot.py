"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py and its README.

Artifacts (under ``artifacts/``):
  cost_eval.hlo.txt       Pallas roofline kernel, fixed [N_CFG, N_LAYER]
  cost_eval_ref.hlo.txt   pure-jnp twin (runtime self-check / ablation)
  gpt2_<cfg>_train.hlo.txt  full training step (loss + params + adam state)
  gpt2_<cfg>_eval.hlo.txt   loss-only forward
  meta.json               shapes + parameter ordering for the rust side

Usage: ``python -m compile.aot --out-dir ../artifacts`` (what `make
artifacts` runs). Python never runs again after this.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cost_eval(out_dir: str, meta: dict) -> None:
    cfg_spec = jax.ShapeDtypeStruct((model.N_CFG, 8), jnp.float32)
    lay_spec = jax.ShapeDtypeStruct((model.N_LAYER, 8), jnp.float32)
    for name, fn in (
        ("cost_eval", model.cost_eval_graph),
        ("cost_eval_ref", model.cost_eval_ref_graph),
    ):
        lowered = jax.jit(fn).lower(cfg_spec, lay_spec)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {path}")
    meta["cost_eval"] = {
        "n_cfg": model.N_CFG,
        "n_layer": model.N_LAYER,
        "cfg_w": 8,
        "lay_w": 8,
        "out_w": 4,
    }


def lower_gpt2(out_dir: str, cfg_name: str, meta: dict) -> None:
    cfg = model.CONFIGS[cfg_name]
    p_specs, tok_spec, step_spec = model.make_specs(cfg)

    train = lambda p, m, v, t, s: model.train_step(cfg, p, m, v, t, s)
    lowered = jax.jit(train).lower(p_specs, p_specs, p_specs, tok_spec, step_spec)
    path = os.path.join(out_dir, f"gpt2_{cfg_name}_train.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    ev = lambda p, t: model.eval_step(cfg, p, t)
    lowered = jax.jit(ev).lower(p_specs, tok_spec)
    path = os.path.join(out_dir, f"gpt2_{cfg_name}_eval.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # Initial parameter values, flat f32 blobs in flatten order, so rust can
    # bootstrap training without any python at runtime.
    import numpy as np

    params = model.init_params(cfg, seed=0)
    init_path = os.path.join(out_dir, f"gpt2_{cfg_name}_init.bin")
    with open(init_path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype=np.float32).tobytes())
    print(f"wrote {init_path}")

    meta[f"gpt2_{cfg_name}"] = {
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "d_model": cfg.d_model,
        "n_head": cfg.n_head,
        "n_layer": cfg.n_layer,
        "mlp_ratio": cfg.mlp_ratio,
        "batch": cfg.batch,
        "lr": cfg.lr,
        "num_params": model.num_params(cfg),
        "param_names": model.param_names(cfg),
        "param_shapes": [list(s) for s in model.param_shapes(cfg)],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--gpt2-configs",
        default="tiny",
        help="comma-separated subset of: " + ",".join(model.CONFIGS),
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta: dict = {}
    lower_cost_eval(args.out_dir, meta)
    for cfg_name in args.gpt2_configs.split(","):
        if cfg_name:
            lower_gpt2(args.out_dir, cfg_name, meta)

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
