"""MONET build-time python package: L1 Pallas kernels + L2 JAX graphs + AOT.

Never imported at runtime — `make artifacts` runs `compile.aot` once and the
rust binary consumes the HLO text it emits.
"""
