"""L1 Pallas kernels (interpret=True) and their pure-jnp oracles."""

from . import attention, cost_eval, ref  # noqa: F401
