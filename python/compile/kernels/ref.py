"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth used by pytest/hypothesis: every Pallas kernel in
this package must match its oracle to float tolerance across shape sweeps.
They are also used directly by `model.py` when building the non-Pallas
reference lowering (useful for debugging the AOT path).

Descriptor layouts are shared with the rust side (rust/src/dse/prefilter.rs)
and with `cost_eval.py`; change them in lockstep.
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Batched roofline cost model (DSE pre-filter)
# ---------------------------------------------------------------------------

# Config descriptor columns (CFG_W = 8)
CFG_MACS = 0  # peak MAC/cycle of the whole accelerator
CFG_ONCHIP_BW = 1  # on-chip bandwidth, bytes/cycle
CFG_OFFCHIP_BW = 2  # off-chip bandwidth, bytes/cycle
CFG_LOCAL_MEM = 3  # local (on-chip) memory, bytes
CFG_E_MAC = 4  # energy per MAC, pJ
CFG_E_ONCHIP = 5  # energy per on-chip byte, pJ
CFG_E_OFFCHIP = 6  # energy per off-chip byte, pJ
CFG_RESERVED = 7
CFG_W = 8

# Layer descriptor columns (LAY_W = 8)
LAY_FLOPS = 0  # 2 x multiply-accumulate count
LAY_ONCHIP_BYTES = 1  # compulsory on-chip traffic
LAY_OFFCHIP_BYTES = 2  # compulsory off-chip traffic
LAY_PARALLELISM = 3  # max MACs exploitable per cycle by this layer
LAY_WORKING_SET = 4  # bytes that must be resident while computing
LAY_WEIGHT_BYTES = 5  # parameter bytes (used for spill modelling)
LAY_RESERVED6 = 6
LAY_RESERVED7 = 7
LAY_W = 8

# Output columns (OUT_W = 4)
OUT_CYCLES = 0
OUT_ENERGY = 1  # pJ
OUT_UTIL = 2  # average MAC-array utilisation in [0, 1]
OUT_SPILL = 3  # total spill bytes (off-chip overflow traffic)
OUT_W = 4

_EPS = 1e-6


def cost_eval_ref(configs: jnp.ndarray, layers: jnp.ndarray) -> jnp.ndarray:
    """Roofline cost of every layer on every config, reduced per config.

    configs: f32[n_cfg, CFG_W]
    layers:  f32[n_layer, LAY_W]
    returns: f32[n_cfg, OUT_W]

    Per (config c, layer l):
      eff_macs      = min(macs_c, parallelism_l)
      compute_cyc   = flops_l / (2 * eff_macs)
      spill_bytes   = 2 * max(0, working_set_l - local_mem_c)
      offchip_bytes = offchip_l + spill_bytes
      mem_cyc       = max(onchip_l / onchip_bw_c, offchip_bytes / offchip_bw_c)
      cycles        = max(compute_cyc, mem_cyc)
      energy        = flops_l/2 * e_mac + onchip_l * e_onchip
                      + offchip_bytes * e_offchip

    The per-config reduction serialises layers (sum of cycles/energy): this is
    the optimistic lower bound the detailed scheduler refines, and exactly the
    quantity the DSE pre-filter needs for pruning.
    """
    c = configs[:, None, :]  # [n_cfg, 1, CFG_W]
    l = layers[None, :, :]  # [1, n_layer, LAY_W]

    macs = jnp.maximum(c[..., CFG_MACS], _EPS)
    eff_macs = jnp.minimum(macs, jnp.maximum(l[..., LAY_PARALLELISM], 1.0))
    flops = l[..., LAY_FLOPS]
    compute_cyc = flops / (2.0 * eff_macs)

    spill = 2.0 * jnp.maximum(0.0, l[..., LAY_WORKING_SET] - c[..., CFG_LOCAL_MEM])
    offchip = l[..., LAY_OFFCHIP_BYTES] + spill
    onchip = l[..., LAY_ONCHIP_BYTES]
    mem_cyc = jnp.maximum(
        onchip / jnp.maximum(c[..., CFG_ONCHIP_BW], _EPS),
        offchip / jnp.maximum(c[..., CFG_OFFCHIP_BW], _EPS),
    )
    cycles = jnp.maximum(compute_cyc, mem_cyc)  # [n_cfg, n_layer]

    energy = (
        0.5 * flops * c[..., CFG_E_MAC]
        + onchip * c[..., CFG_E_ONCHIP]
        + offchip * c[..., CFG_E_OFFCHIP]
    )

    total_cyc = jnp.sum(cycles, axis=1)
    total_energy = jnp.sum(energy, axis=1)
    total_spill = jnp.sum(spill, axis=1)
    total_flops = jnp.sum(flops, axis=1)
    util = (0.5 * total_flops) / (
        jnp.maximum(configs[:, CFG_MACS], _EPS) * jnp.maximum(total_cyc, _EPS)
    )
    util = jnp.clip(util, 0.0, 1.0)

    return jnp.stack([total_cyc, total_energy, util, total_spill], axis=1)


# ---------------------------------------------------------------------------
# Attention (flash-attention oracle)
# ---------------------------------------------------------------------------


def attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True
) -> jnp.ndarray:
    """Plain softmax attention. q,k,v: f32[seq, d] -> f32[seq, d]."""
    seq = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = (q @ k.T) * scale
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    weights = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights @ v


def mha_ref(q, k, v, *, causal: bool = True):
    """Multi-head wrapper: q,k,v f32[heads, seq, d] -> f32[heads, seq, d]."""
    import jax

    return jax.vmap(lambda a, b, c: attention_ref(a, b, c, causal=causal))(q, k, v)
