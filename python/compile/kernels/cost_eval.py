"""L1 Pallas kernel: batched roofline cost evaluation for the DSE pre-filter.

The DSE sweeps in MONET (Figs 1, 8, 9) evaluate thousands of hardware
configurations against training graphs with hundreds of nodes. Before the
detailed layer-fused scheduler runs, a roofline pre-filter scores every
(config, layer) pair and prunes configurations that cannot be competitive.
That scoring is a dense, regular computation — this kernel.

Tiling: the grid iterates over blocks of BLOCK_CFG configurations. Each grid
step holds one (BLOCK_CFG, CFG_W) config panel, the full (n_layer, LAY_W)
layer descriptor matrix, and a (BLOCK_CFG, n_layer) scratch panel in VMEM.
On a real TPU the VMEM footprint per step is

    BLOCK_CFG*CFG_W*4 + n_layer*LAY_W*4 + ~4*BLOCK_CFG*n_layer*4 bytes
    = 128*8*4 + 1024*8*4 + 4*128*1024*4  ≈ 2.1 MiB   « 16 MiB VMEM

so the block shape leaves headroom for double buffering. The arithmetic is
elementwise + row reductions (VPU work, no MXU), so the roofline is the
HBM→VMEM stream of the config panels; BLOCK_CFG=128 amortises the layer
matrix reload across 128 configs per step.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the rust runtime can run
the AOT artifact. See DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_CFG = 128

_EPS = 1e-6


def _cost_kernel(cfg_ref, lay_ref, out_ref):
    """One grid step: score a (BLOCK_CFG, CFG_W) config panel vs all layers."""
    cfg = cfg_ref[...]  # [BC, CFG_W]
    lay = lay_ref[...]  # [NL, LAY_W]

    # Broadcast panels: c_* are [BC, 1], l_* are [1, NL].
    def c(col):
        return cfg[:, col][:, None]

    def l(col):
        return lay[:, col][None, :]

    macs = jnp.maximum(c(ref.CFG_MACS), _EPS)
    eff_macs = jnp.minimum(macs, jnp.maximum(l(ref.LAY_PARALLELISM), 1.0))
    flops = l(ref.LAY_FLOPS)
    compute_cyc = flops / (2.0 * eff_macs)

    spill = 2.0 * jnp.maximum(0.0, l(ref.LAY_WORKING_SET) - c(ref.CFG_LOCAL_MEM))
    offchip = l(ref.LAY_OFFCHIP_BYTES) + spill
    onchip = l(ref.LAY_ONCHIP_BYTES)
    mem_cyc = jnp.maximum(
        onchip / jnp.maximum(c(ref.CFG_ONCHIP_BW), _EPS),
        offchip / jnp.maximum(c(ref.CFG_OFFCHIP_BW), _EPS),
    )
    cycles = jnp.maximum(compute_cyc, mem_cyc)  # [BC, NL]

    energy = (
        0.5 * flops * c(ref.CFG_E_MAC)
        + onchip * c(ref.CFG_E_ONCHIP)
        + offchip * c(ref.CFG_E_OFFCHIP)
    )

    total_cyc = jnp.sum(cycles, axis=1)  # [BC]
    total_energy = jnp.sum(energy, axis=1)
    total_spill = jnp.sum(spill, axis=1)
    total_flops = jnp.sum(jnp.broadcast_to(flops, cycles.shape), axis=1)
    util = (0.5 * total_flops) / (
        jnp.maximum(cfg[:, ref.CFG_MACS], _EPS) * jnp.maximum(total_cyc, _EPS)
    )
    util = jnp.clip(util, 0.0, 1.0)

    out_ref[...] = jnp.stack([total_cyc, total_energy, util, total_spill], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cost_eval(configs: jnp.ndarray, layers: jnp.ndarray, *, interpret: bool = True):
    """Pallas-tiled version of :func:`ref.cost_eval_ref`.

    configs: f32[n_cfg, CFG_W] — n_cfg must be a multiple of BLOCK_CFG
             (the AOT wrapper and the rust caller pad with benign rows).
    layers:  f32[n_layer, LAY_W] — zero rows are benign (0 flops, 0 bytes).
    returns: f32[n_cfg, OUT_W]
    """
    n_cfg, cfg_w = configs.shape
    n_layer, lay_w = layers.shape
    assert cfg_w == ref.CFG_W and lay_w == ref.LAY_W
    assert n_cfg % BLOCK_CFG == 0, f"n_cfg={n_cfg} must be a multiple of {BLOCK_CFG}"

    grid = (n_cfg // BLOCK_CFG,)
    return pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_CFG, ref.CFG_W), lambda i: (i, 0)),
            pl.BlockSpec((n_layer, ref.LAY_W), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_CFG, ref.OUT_W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cfg, ref.OUT_W), jnp.float32),
        interpret=interpret,
    )(configs, layers)
