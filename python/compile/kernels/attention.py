"""L1 Pallas kernels: tiled causal flash-attention, forward AND backward.

Used by the L2 GPT-2 training step (model.py). The paper motivates
layer-fused scheduling with FlashAttention (§II-C2): fusing the softmax with
the two matmuls so the (seq, seq) score matrix never materialises off-chip.
These kernels are exactly that fusion, expressed in the TPU idiom:

  * grids walk query blocks (fwd, dQ) or key/value blocks (dK/dV);
    BlockSpec stages the per-step panel HBM→VMEM (the threadblock/
    shared-memory schedule of the CUDA original, re-thought for the VMEM
    scratchpad),
  * the complementary operand streams through VMEM in block-row panels
    inside a fori_loop,
  * online-softmax accumulators (m, l, acc) live in fp32,
  * matmuls are MXU-shaped: (BLOCK, d) @ (d, BLOCK) panels.

Training support follows FlashAttention-2: the forward kernel additionally
emits the per-row log-sum-exp (lse); the backward pass *recomputes* the
attention probabilities blockwise from (q, k, lse) instead of storing the
(seq, seq) matrix — the same memory-vs-recompute trade the paper studies as
activation checkpointing (§V-B), here at kernel granularity.

VMEM per fwd grid step ≈ (BLOCK_Q + 2·seq)·d·4 + BLOCK_Q·BLOCK_KV·4 bytes;
for seq=1024, d=128, blocks of 128 that is ~1.2 MiB — comfortable in a
16 MiB VMEM with double buffering.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the rust runtime can run
the AOT artifact (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_KV = 32

_NEG_INF = -1e30
INTERPRET = True  # flipped only by TPU builds; CPU PJRT requires interpret


def _mask(s, q_blk, kv_blk, block_q, block_kv):
    q_idx = q_blk * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0
    )
    kv_idx = kv_blk * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1
    )
    return jnp.where(q_idx >= kv_idx, s, _NEG_INF)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_kv, causal):
    block_q, d = q_ref.shape
    seq = k_ref.shape[0]
    q_blk = pl.program_id(0)

    q = q_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)

    n_kv = seq // block_kv
    if causal:
        # kv blocks strictly after this q block contribute nothing
        n_kv_live = jnp.minimum(
            n_kv, (q_blk * block_q + block_q + block_kv - 1) // block_kv
        )
    else:
        n_kv_live = n_kv

    def body(kv_blk, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(kv_blk * block_kv, block_kv), :]
        v = v_ref[pl.ds(kv_blk * block_kv, block_kv), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            s = _mask(s, q_blk, kv_blk, block_q, block_kv)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv_live, body, (m0, l0, acc0))
    o_ref[...] = acc / jnp.maximum(l, 1e-30)[:, None]
    lse_ref[...] = m + jnp.log(jnp.maximum(l, 1e-30))


def _flash_fwd(q, k, v, *, causal, block_q, block_kv, interpret):
    seq, d = q.shape
    kernel = functools.partial(_fwd_kernel, block_kv=block_kv, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(seq // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((seq, d), jnp.float32),
            jax.ShapeDtypeStruct((seq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward (FlashAttention-2 style: recompute P blockwise from q, k, lse)
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, delta_ref, lse_ref, dq_ref, *, block_kv, causal
):
    block_q, d = q_ref.shape
    seq = k_ref.shape[0]
    q_blk = pl.program_id(0)

    q = q_ref[...]
    do = do_ref[...]
    delta = delta_ref[...]  # rowsum(dO * O), [block_q]
    lse = lse_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    n_kv = seq // block_kv
    if causal:
        n_kv_live = jnp.minimum(
            n_kv, (q_blk * block_q + block_q + block_kv - 1) // block_kv
        )
    else:
        n_kv_live = n_kv

    def body(kv_blk, dq):
        k = k_ref[pl.ds(kv_blk * block_kv, block_kv), :]
        v = v_ref[pl.ds(kv_blk * block_kv, block_kv), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            s = _mask(s, q_blk, kv_blk, block_q, block_kv)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jnp.dot(ds, k, preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, n_kv_live, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = dq


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, delta_ref, lse_ref, dk_ref, dv_ref,
    *, block_q, causal
):
    block_kv, d = k_ref.shape
    seq = q_ref.shape[0]
    kv_blk = pl.program_id(0)

    k = k_ref[...]
    v = v_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    n_q = seq // block_q
    if causal:
        # q blocks strictly before this kv block see nothing of it
        first_q = (kv_blk * block_kv) // block_q
    else:
        first_q = 0

    def body(q_blk, carry):
        dk, dv = carry
        q = q_ref[pl.ds(q_blk * block_q, block_q), :]
        do = do_ref[pl.ds(q_blk * block_q, block_q), :]
        delta = delta_ref[pl.ds(q_blk * block_q, block_q)]
        lse = lse_ref[pl.ds(q_blk * block_q, block_q)]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            s = _mask(s, q_blk, kv_blk, block_q, block_kv)
        p = jnp.exp(s - lse[:, None])  # [BQ, BKV]
        dv_new = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_new = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
        return dk_new, dv_new

    zero = jnp.zeros((block_kv, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(first_q, n_q, body, (zero, zero))
    dk_ref[...] = dk
    dv_ref[...] = dv


def _flash_bwd(q, k, v, o, lse, do, *, causal, block_q, block_kv, interpret):
    seq, d = q.shape
    delta = jnp.sum(do * o, axis=-1)  # [seq]

    dq_kernel = functools.partial(_bwd_dq_kernel, block_kv=block_kv, causal=causal)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(seq // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq, d), jnp.float32),
        interpret=interpret,
    )(q, k, v, do, delta, lse)

    dkv_kernel = functools.partial(_bwd_dkv_kernel, block_q=block_q, causal=causal)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(seq // block_kv,),
        in_specs=[
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((block_kv, d), lambda i: (i, 0)),
            pl.BlockSpec((block_kv, d), lambda i: (i, 0)),
            pl.BlockSpec((seq, d), lambda i: (0, 0)),
            pl.BlockSpec((seq,), lambda i: (0,)),
            pl.BlockSpec((seq,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_kv, d), lambda i: (i, 0)),
            pl.BlockSpec((block_kv, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((seq, d), jnp.float32),
            jax.ShapeDtypeStruct((seq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, delta, lse)

    return dq, dk, dv


# ---------------------------------------------------------------------------
# Differentiable public entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, block_q, block_kv, interpret):
    o, _ = _flash_fwd(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=interpret,
    )
    return o


def _flash_attention_fwd(q, k, v, causal, block_q, block_kv, interpret):
    o, lse = _flash_fwd(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _flash_attention_bwd(causal, block_q, block_kv, interpret, res, do):
    q, k, v, o, lse = res
    return _flash_bwd(
        q, k, v, o, lse, do,
        causal=causal, block_q=block_q, block_kv=block_kv, interpret=interpret,
    )


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Single-head flash attention. q,k,v: f32[seq, d] -> f32[seq, d].

    seq must be divisible by both block sizes (the L2 model guarantees it).
    Differentiable via the FlashAttention-2-style backward kernels above.
    """
    seq, d = q.shape
    assert k.shape == (seq, d) and v.shape == (seq, d)
    block_q = min(block_q, seq)
    block_kv = min(block_kv, seq)
    assert seq % block_q == 0 and seq % block_kv == 0
    return _flash_attention(q, k, v, causal, block_q, block_kv, interpret)


def mha(q, k, v, *, causal: bool = True, interpret: bool = INTERPRET):
    """Multi-head flash attention: f32[heads, seq, d] -> f32[heads, seq, d]."""
    fn = functools.partial(flash_attention, causal=causal, interpret=interpret)
    return jax.vmap(fn)(q, k, v)
