"""L2: JAX compute graphs that are AOT-lowered to HLO for the rust runtime.

Two graphs live here:

1. ``cost_eval_graph`` — the batched roofline cost model used by the rust
   DSE pre-filter (wraps the L1 Pallas kernel ``kernels.cost_eval``).

2. A tiny GPT-2 (the paper's §IV-B workload, scaled to the CPU testbed) with
   a full training step: forward, backward (jax.grad) and an AdamW update.
   Attention uses the L1 Pallas flash-attention kernel, so the layer-fusion
   the paper cites (FlashAttention, §II-C2) is physically present in the
   lowered HLO. The rust e2e driver (examples/e2e_train.rs) executes this
   artifact for a few hundred steps on a synthetic byte corpus and logs the
   loss curve — proving L1→L2→L3 compose.

Everything here is build-time only. ``aot.py`` lowers these functions once;
rust never imports python.

Parameter convention: params / adam-m / adam-v are *lists* of f32 arrays.
JAX flattens lists in order, so the HLO entry takes parameters in exactly
the order of ``param_names(cfg)``; ``aot.py`` writes that order (with
shapes) to ``artifacts/meta.json`` for the rust side.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import cost_eval as cost_kernel
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Cost-model graph (DSE pre-filter)
# ---------------------------------------------------------------------------

# Fixed AOT shapes: rust pads config batches to N_CFG rows and the layer
# matrix to N_LAYER rows (zero layer rows are benign; padded config rows are
# discarded by the caller).
N_CFG = 256
N_LAYER = 1024


def cost_eval_graph(configs: jnp.ndarray, layers: jnp.ndarray):
    """returns (f32[N_CFG, OUT_W],) — tuple for the AOT contract."""
    return (cost_kernel.cost_eval(configs, layers),)


def cost_eval_ref_graph(configs: jnp.ndarray, layers: jnp.ndarray):
    """Pure-jnp twin of ``cost_eval_graph`` (debug/ablation artifact)."""
    return (kref.cost_eval_ref(configs, layers),)


# ---------------------------------------------------------------------------
# Tiny GPT-2
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GPT2Config:
    vocab: int = 256  # byte-level
    seq: int = 64  # tokens per sample (training window)
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 2
    mlp_ratio: int = 4
    batch: int = 8
    lr: float = 3e-3
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


TINY = GPT2Config()
# A larger config for throughput experiments (still CPU-tractable).
SMALL = GPT2Config(vocab=512, seq=128, d_model=256, n_head=8, n_layer=4, batch=8)

CONFIGS = {"tiny": TINY, "small": SMALL}


def param_names(cfg: GPT2Config) -> List[str]:
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layer):
        names += [
            f"h{i}.ln1.g",
            f"h{i}.ln1.b",
            f"h{i}.attn.wqkv",
            f"h{i}.attn.bqkv",
            f"h{i}.attn.wo",
            f"h{i}.attn.bo",
            f"h{i}.ln2.g",
            f"h{i}.ln2.b",
            f"h{i}.mlp.wfc",
            f"h{i}.mlp.bfc",
            f"h{i}.mlp.wproj",
            f"h{i}.mlp.bproj",
        ]
    names += ["lnf.g", "lnf.b"]
    return names


def param_shapes(cfg: GPT2Config) -> List[Tuple[int, ...]]:
    d, dm = cfg.d_model, cfg.mlp_ratio * cfg.d_model
    shapes: List[Tuple[int, ...]] = [(cfg.vocab, d), (cfg.seq, d)]
    for _ in range(cfg.n_layer):
        shapes += [
            (d,),
            (d,),
            (d, 3 * d),
            (3 * d,),
            (d, d),
            (d,),
            (d,),
            (d,),
            (d, dm),
            (dm,),
            (dm, d),
            (d,),
        ]
    shapes += [(d,), (d,)]
    return shapes


def init_params(cfg: GPT2Config, seed: int = 0) -> List[jnp.ndarray]:
    """GPT-2-style init: N(0, 0.02) for matrices, zeros/ones for LN+bias."""
    key = jax.random.PRNGKey(seed)
    params: List[jnp.ndarray] = []
    for name, shape in zip(param_names(cfg), param_shapes(cfg)):
        if name.endswith(".g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", "bqkv", "bo", "bfc", "bproj")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            key, sub = jax.random.split(key)
            scale = 0.02
            if name.endswith("wproj") or name.endswith("wo"):
                # residual-branch scaling a la GPT-2
                scale = 0.02 / float(jnp.sqrt(2.0 * cfg.n_layer))
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


def _block(cfg: GPT2Config, x, p, base, use_pallas: bool):
    """One transformer block. x: [B, S, D]. p: full param list."""
    ln1 = _layer_norm(x, p[base + 0], p[base + 1])
    qkv = ln1 @ p[base + 2] + p[base + 3]  # [B, S, 3D]
    b, s, _ = qkv.shape
    h, dh = cfg.n_head, cfg.d_head
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [B, S, D] -> [B, H, S, dh]
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    if use_pallas:
        o = jax.vmap(lambda a, b_, c: attn_kernel.mha(a, b_, c, causal=True))(q, k, v)
    else:
        o = jax.vmap(lambda a, b_, c: kref.mha_ref(a, b_, c, causal=True))(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
    x = x + o @ p[base + 4] + p[base + 5]

    ln2 = _layer_norm(x, p[base + 6], p[base + 7])
    hmid = _gelu(ln2 @ p[base + 8] + p[base + 9])
    x = x + hmid @ p[base + 10] + p[base + 11]
    return x


def forward(cfg: GPT2Config, params: List[jnp.ndarray], tokens, use_pallas=True):
    """tokens: i32[B, S] -> logits f32[B, S, vocab] (tied embedding head)."""
    tok_emb, pos_emb = params[0], params[1]
    x = tok_emb[tokens] + pos_emb[None, : tokens.shape[1], :]
    base = 2
    for _ in range(cfg.n_layer):
        x = _block(cfg, x, params, base, use_pallas)
        base += 12
    x = _layer_norm(x, params[base], params[base + 1])
    return x @ tok_emb.T


def loss_fn(cfg: GPT2Config, params, tokens, use_pallas=True):
    """tokens: i32[B, S+1]; next-token cross entropy averaged over B*S."""
    x, y = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, x, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def adamw_update(cfg: GPT2Config, params, grads, m, v, step):
    """AdamW with bias correction; step is the 1-based f32 step counter."""
    b1, b2 = cfg.betas
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (GPT-2 convention)
            upd = upd + cfg.weight_decay * p
        new_p.append(p - cfg.lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def train_step(cfg: GPT2Config, params, m, v, tokens, step, use_pallas=True):
    """One full training iteration.

    returns (loss f32[], new_params..., new_m..., new_v...) as one flat tuple
    — the AOT contract consumed by rust/src/runtime/gpt2.rs.
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, use_pallas)
    )(params)
    new_p, new_m, new_v = adamw_update(cfg, params, grads, m, v, step)
    return tuple([loss] + new_p + new_m + new_v)


def eval_step(cfg: GPT2Config, params, tokens, use_pallas=True):
    """Loss only (no update) — used for model-vs-measured validation runs."""
    return (loss_fn(cfg, params, tokens, use_pallas),)


def make_specs(cfg: GPT2Config):
    """ShapeDtypeStructs for lowering train_step."""
    p_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes(cfg)]
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    step_spec = jax.ShapeDtypeStruct((), jnp.float32)
    return p_specs, tok_spec, step_spec


def num_params(cfg: GPT2Config) -> int:
    total = 0
    for s in param_shapes(cfg):
        n = 1
        for d in s:
            n *= d
        total += n
    return total
