//! `cargo bench --bench bench_runtime` — the AOT/PJRT hot paths: cost
//! kernel execution (the DSE pre-filter), its native-rust twin, and the
//! tiny-GPT-2 training step. Requires `make artifacts`.

use std::time::Instant;

use monet::dse::{accel_to_cfg, graph_to_layers};
use monet::hardware::presets::EdgeTpuParams;
use monet::runtime::{cost_eval_native, Corpus, CostKernel, Gpt2Runner, Runtime};
use monet::workload::models::resnet18;

fn bench(name: &str, reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{name:<52} {:>9.2} ms   ({:.0}/s)", per * 1e3, 1.0 / per);
    per
}

fn main() {
    println!("== MONET runtime (AOT/PJRT) benchmarks ==\n");
    if !std::path::Path::new("artifacts/meta.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        // default build compiles the stub client, which cannot execute
        // artifacts even when they exist — skip, matching runtime_roundtrip
        Err(e) => {
            println!("PJRT runtime unavailable ({e}); skipping");
            return;
        }
    };
    println!("platform: {}\n", rt.platform());

    let g = resnet18(1, 32, 10);
    let layers = graph_to_layers(&g);
    let cfgs: Vec<_> = EdgeTpuParams::space_strided(40)
        .into_iter()
        .map(|p| accel_to_cfg(&p.build()))
        .collect();
    println!("cost-kernel inputs: {} configs x {} layers", cfgs.len(), layers.len());

    let kernel = CostKernel::load(&rt).expect("load");
    let hlo = bench("prefilter: AOT Pallas kernel via PJRT", 20, || {
        let _ = kernel.eval(&cfgs, &layers).unwrap();
    });
    let nat = bench("prefilter: native rust twin", 20, || {
        let _ = cost_eval_native(&cfgs, &layers);
    });
    println!(
        "    HLO-vs-native ratio: {:.2}x ({} (cfg,layer) pairs/s via PJRT)\n",
        hlo / nat,
        (cfgs.len() * layers.len()) as f64 / hlo
    );

    let mut runner = Gpt2Runner::load(&rt, "tiny").expect("gpt2 artifacts");
    let meta = runner.meta.clone();
    let mut corpus = Corpus::synthetic(meta.vocab, 16384, 1);
    let tokens = corpus.next_batch(meta.batch, meta.seq + 1);
    bench("gpt2-tiny: full train step (fwd+bwd+adam)", 20, || {
        let _ = runner.step(&tokens).unwrap();
    });
    bench("gpt2-tiny: eval step (loss only)", 20, || {
        let _ = runner.eval_loss(&tokens).unwrap();
    });

    println!("\nbench_runtime done");
}
