//! `cargo bench --bench bench_serve` — throughput of the `monet serve`
//! daemon: one in-process server on an ephemeral loopback port, driven
//! over real TCP by the same one-exchange-per-connection protocol the
//! CLI smoke test uses. Measures the cold first query (resident cache
//! empty), the warm steady state, and scaling under 1/4/8 concurrent
//! clients. Emits `BENCH_serve.json` (uploaded as a CI artifact
//! alongside `BENCH_eval.json` and `BENCH_dse.json`) so serving
//! regressions are visible across PRs.
//!
//! Every response is asserted byte-identical to the first — cache
//! warmth and client concurrency may change throughput, never a byte.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use monet::serve::{ServeConfig, Server};

/// The benchmark query: the homogeneous-cluster family, small enough to
/// answer in well under a second warm, large enough to exercise the
/// engine + cache path rather than HTTP overhead alone.
const QUERY: &str = r#"{"family":"cluster","devices":4,"batch":4,"workload":"resnet18"}"#;

fn ask(addr: SocketAddr) -> String {
    let mut s = TcpStream::connect(addr).expect("connect to daemon");
    write!(
        s,
        "POST /query HTTP/1.1\r\nHost: monet\r\nContent-Length: {}\r\n\r\n{QUERY}",
        QUERY.len()
    )
    .expect("send query");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    assert!(raw.starts_with("HTTP/1.1 200"), "query failed: {raw}");
    raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).expect("response body")
}

/// Drive `clients` concurrent client threads, `per_client` queries each
/// (serial per client, like real callers); returns (total, secs).
fn drive(addr: SocketAddr, reference: &str, clients: usize, per_client: usize) -> (usize, f64) {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            s.spawn(move || {
                for _ in 0..per_client {
                    ask(addr);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    // one post-drive check per load level: still bit-identical
    assert_eq!(ask(addr), reference, "concurrency changed the answer");
    (clients * per_client, secs)
}

fn main() {
    println!("== MONET serve daemon throughput (cold vs warm, concurrent clients) ==\n");
    let server = Server::bind(ServeConfig { serve_workers: 4, ..Default::default() })
        .expect("bind daemon");
    let addr = server.local_addr();
    let daemon = std::thread::spawn(move || server.run().expect("serve loop"));

    // cold: the resident cache is empty, every group cost is computed
    let t0 = Instant::now();
    let reference = ask(addr);
    let cold_secs = t0.elapsed().as_secs_f64();
    let cold_qps = 1.0 / cold_secs;

    // warm steady state, single client
    const WARM_QUERIES: usize = 8;
    let t1 = Instant::now();
    for _ in 0..WARM_QUERIES {
        assert_eq!(ask(addr), reference, "warmth changed the answer");
    }
    let warm_secs = t1.elapsed().as_secs_f64();
    let warm_qps = WARM_QUERIES as f64 / warm_secs;
    assert!(
        warm_qps > cold_qps,
        "warm queries/sec ({warm_qps:.2}) must beat cold ({cold_qps:.2}) — the resident cache is the point of the daemon"
    );

    println!("{:<10} {:>10} {:>12} {:>14}", "phase", "queries", "secs", "queries/s");
    println!("{:<10} {:>10} {:>12.3} {:>14.2}", "cold", 1, cold_secs, cold_qps);
    println!("{:<10} {:>10} {:>12.3} {:>14.2}", "warm", WARM_QUERIES, warm_secs, warm_qps);

    // warm scaling under concurrent clients
    const PER_CLIENT: usize = 4;
    let mut client_json: Vec<String> = vec![];
    for clients in [1usize, 4, 8] {
        let (queries, secs) = drive(addr, &reference, clients, PER_CLIENT);
        let qps = queries as f64 / secs;
        println!("{:<10} {:>10} {:>12.3} {:>14.2}", format!("c{clients}"), queries, secs, qps);
        client_json.push(format!(
            "    \"c{}\": {{\n      \"clients\": {},\n      \"queries\": {},\n      \"secs\": {:.3},\n      \"queries_per_sec\": {:.2}\n    }}",
            clients, clients, queries, secs, qps
        ));
    }

    // graceful shutdown: drain, persist (no cache_dir here — a no-op),
    // join — the daemon must exit cleanly under bench load too
    let mut s = TcpStream::connect(addr).expect("connect for shutdown");
    write!(s, "POST /shutdown HTTP/1.1\r\nHost: monet\r\nContent-Length: 0\r\n\r\n")
        .expect("send shutdown");
    let mut raw = String::new();
    s.read_to_string(&mut raw).ok();
    daemon.join().expect("daemon thread");

    let json = format!(
        "{{\n  \"bench\": \"serve_daemon_throughput\",\n  \"harness\": \"monet serve (resident cache, bounded queue, {} query workers)\",\n  \"cold\": {{\n    \"secs\": {:.3},\n    \"queries_per_sec\": {:.2}\n  }},\n  \"warm\": {{\n    \"queries\": {},\n    \"secs\": {:.3},\n    \"queries_per_sec\": {:.2},\n    \"speedup_vs_cold\": {:.2}\n  }},\n  \"clients\": {{\n{}\n  }}\n}}\n",
        4,
        cold_secs,
        cold_qps,
        WARM_QUERIES,
        warm_secs,
        warm_qps,
        warm_qps / cold_qps,
        client_json.join(",\n")
    );
    std::fs::write("BENCH_serve.json", &json).expect("writing BENCH_serve.json");
    println!("\n    -> BENCH_serve.json written");
    println!("\nbench_serve done");
}
