//! `cargo bench --bench bench_figures` — one end-to-end timing per paper
//! table/figure target: how long each experiment takes to regenerate, plus
//! the headline numbers it produces. (criterion is not vendored offline;
//! this is a harness=false bench with manual timing — median of N runs.)

use std::time::Instant;

use monet::figures;
use monet::ga::GaConfig;

fn timed<T>(name: &str, reps: usize, mut f: impl FnMut() -> T) -> T {
    let mut times = Vec::with_capacity(reps);
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        out = Some(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let med = times[times.len() / 2];
    println!("{name:<42} {:>10.3} s (median of {reps})", med);
    out.unwrap()
}

fn main() {
    println!("== MONET figure-regeneration benchmarks ==\n");

    let sweep = timed("fig1/fig8: Edge-TPU sweep (stride 20)", 3, || {
        figures::fig1_fig8_edge_sweep(20, None, |_, _| {})
    });
    println!("    {} rows", sweep.rows.len());

    let bd = timed("fig3: ResNet-50 memory breakdown", 3, || {
        figures::fig3_memory_breakdown(None)
    });
    println!(
        "    batch8 activations {:.2} GiB",
        bd[1].activation_bytes as f64 / (1u64 << 30) as f64
    );

    let f9 = timed("fig9: FuseMax sweep (stride 8)", 3, || {
        figures::fig9_fusemax_sweep(8, None, |_, _| {})
    });
    println!("    {} rows", f9.rows.len());

    let f10 = timed("fig10: fusion strategies (Base..Limit8)", 3, || {
        figures::fig10_fusion_strategies(None)
    });
    let best = f10
        .iter()
        .filter(|r| r.strategy.starts_with("Limit"))
        .min_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles))
        .unwrap();
    println!("    best: {} @ {:.3e} cycles", best.strategy, best.latency_cycles);

    let f11 = timed("fig11: checkpoint linearity probe", 3, || {
        figures::fig11_checkpoint_linearity(None)
    });
    let (gl, ge) = figures::linearity_gap(&f11);
    println!("    non-additivity: lat {:.1}%, energy {:.1}%", gl * 100.0, ge * 100.0);

    let ga = GaConfig { population: 16, generations: 10, ..Default::default() };
    let (front, _) = timed("fig12: NSGA-II checkpointing (16x10)", 1, || {
        figures::fig12_checkpoint_ga(&ga, None)
    });
    if let Some(best) = front
        .iter()
        .filter(|r| r.latency_overhead < 0.05)
        .map(|r| r.memory_saving)
        .max_by(|a, b| a.total_cmp(b))
    {
        println!("    best ≤5%-overhead saving: {:.0}%", best * 100.0);
    }

    println!("\nbench_figures done");
}
