//! `cargo bench --bench bench_dse` — throughput of the unified
//! `dse::engine` harness across the three sweep families (single-device
//! accelerator points, homogeneous cluster deployments, heterogeneous
//! stage placements), cold cache vs warm-persisted cache, plus the
//! `ga-cluster` deployment GA on a 256-device pool (front hypervolume
//! proxy vs the block-fallback baseline, and the fraction of the
//! enumerable space visited). Emits
//! `BENCH_dse.json` (uploaded as a CI artifact alongside
//! `BENCH_eval.json`) so engine/harness overhead regressions are visible
//! across PRs.

use std::path::PathBuf;
use std::time::Instant;

use monet::autodiff::{build_training_graph, TrainOptions, TrainingGraph};
use monet::dse::{
    ga_cluster_search, run_cluster_sweep, run_cluster_sweep_outcome, run_hetero_sweep,
    run_sweep_outcome, run_sweep_stats, ClusterRow, ClusterSpace, DesignPoint, SweepConfig,
};
use monet::ga::{pareto_rank0, DeploymentGenome, GaConfig};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{DeviceClass, HeteroCluster, LinkTier};
use monet::workload::models::{mlp, resnet18};
use monet::workload::op::Optimizer;

struct FamilyResult {
    name: &'static str,
    points: usize,
    cold_secs: f64,
    warm_secs: f64,
}

impl FamilyResult {
    fn cold_pps(&self) -> f64 {
        self.points as f64 / self.cold_secs
    }

    fn warm_pps(&self) -> f64 {
        self.points as f64 / self.warm_secs
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("monet_bench_dse_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Run `sweep(cfg)` twice against one persisted cache dir — cold (fills
/// and persists the snapshot) then warm (replays it) — returning the
/// family's throughput record.
fn time_family(
    name: &'static str,
    points: usize,
    sweep: impl Fn(&SweepConfig) -> usize,
    mapping: MappingConfig,
) -> FamilyResult {
    let dir = tmp_dir(name);
    let cfg = SweepConfig { mapping, cache_dir: Some(dir.clone()), ..Default::default() };
    let t0 = Instant::now();
    let rows_cold = sweep(&cfg);
    let cold_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let rows_warm = sweep(&cfg);
    let warm_secs = t1.elapsed().as_secs_f64();
    assert_eq!(rows_cold, rows_warm, "{name}: warm run changed the row count");
    std::fs::remove_dir_all(&dir).ok();
    FamilyResult { name, points, cold_secs, warm_secs }
}

fn main() {
    println!("== MONET dse::engine throughput (cold vs warm-persisted cache) ==\n");
    let mut results: Vec<FamilyResult> = vec![];

    // single-device accelerator sweep (the fig1 family, strided small)
    {
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(
            &fwd,
            TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
        );
        let points = DesignPoint::edge_space(300);
        let n = points.len();
        results.push(time_family(
            "edge_sweep",
            n,
            |cfg| run_sweep_stats(&points, &fwd, &tg.graph, cfg, |_, _| {}).0.len(),
            MappingConfig::edge_tpu_default(),
        ));
    }

    // homogeneous cluster deployments (the fig5 family)
    {
        let space = ClusterSpace {
            device_counts: vec![1, 2, 4],
            tiers: LinkTier::all().to_vec(),
            microbatches: vec![2, 4],
        };
        let points = space.enumerate();
        let n = points.len();
        let accel = EdgeTpuParams::baseline().build();
        results.push(time_family(
            "cluster_sweep",
            n,
            |cfg| {
                run_cluster_sweep(
                    &points,
                    8,
                    &monet::figures::cluster_resnet18_builder,
                    &accel,
                    cfg,
                    |_, _| {},
                )
                .0
                .len()
            },
            MappingConfig::edge_tpu_default(),
        ));
    }

    // heterogeneous stage placements (the cluster --device-classes family)
    {
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let points = ClusterSpace::enumerate_hetero(&hc, &[2]);
        let n = points.len();
        results.push(time_family(
            "hetero_sweep",
            n,
            |cfg| {
                run_hetero_sweep(
                    &points,
                    &hc,
                    4,
                    &monet::figures::cluster_resnet18_builder,
                    cfg,
                    |_, _| {},
                )
                .0
                .len()
            },
            MappingConfig::edge_tpu_default(),
        ));
    }

    // crash-safety overhead: the same single-device sweep journaled to a
    // --run-dir (journaled = evaluate + per-point checksummed append;
    // replay = --resume over the complete journal, zero evaluations)
    let (journal_points, journaled_secs, replay_secs) = {
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(
            &fwd,
            TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
        );
        let points = DesignPoint::edge_space(300);
        let dir = tmp_dir("journal");
        let cfg = |resume: bool| SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            run_dir: Some(dir.clone()),
            resume,
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(false), |_, _| {})
            .expect("journaled sweep");
        let journaled_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let replay = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(true), |_, _| {})
            .expect("resumed sweep");
        let replay_secs = t1.elapsed().as_secs_f64();
        assert_eq!(out.rows.len(), replay.rows.len(), "replay changed the row count");
        assert_eq!(replay.resumed, points.len(), "resume evaluated instead of replaying");
        std::fs::remove_dir_all(&dir).ok();
        (points.len(), journaled_secs, replay_secs)
    };

    // bound-based front pruning (ROADMAP item 5): the tiny-GPT-2 cluster
    // deployment space, full enumeration vs pruned — the front must be
    // bit-identical while a large fraction of the space never schedules
    let (pruned_points, pruned_skipped, pruned_json) = {
        let space = ClusterSpace {
            device_counts: vec![4, 8],
            tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
            microbatches: vec![2, 4],
        };
        let points = space.enumerate();
        let accel = EdgeTpuParams::baseline().build();
        let cfg = |prune: bool| SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            prune,
            ..Default::default()
        };
        let t0 = Instant::now();
        let full = run_cluster_sweep_outcome(
            &points,
            4,
            &monet::figures::cluster_gpt2_builder,
            &accel,
            &cfg(false),
            |_, _| {},
        )
        .expect("full cluster sweep");
        let full_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let pruned = run_cluster_sweep_outcome(
            &points,
            4,
            &monet::figures::cluster_gpt2_builder,
            &accel,
            &cfg(true),
            |_, _| {},
        )
        .expect("pruned cluster sweep");
        let pruned_secs = t1.elapsed().as_secs_f64();
        let front_key = |rows: &[ClusterRow]| -> Vec<(u64, u64, u64, usize)> {
            let objs: Vec<Vec<f64>> = rows.iter().map(|r| r.objectives().to_vec()).collect();
            pareto_rank0(&objs)
                .into_iter()
                .map(|i| {
                    let r = &rows[i];
                    (
                        r.latency_cycles.to_bits(),
                        r.energy_pj.to_bits(),
                        r.per_device_mem_bytes,
                        r.devices,
                    )
                })
                .collect()
        };
        let identical = front_key(&full.rows) == front_key(&pruned.rows);
        assert!(identical, "pruning moved the gpt2 cluster front");
        let json = format!(
            "  \"pruned\": {{\n    \"points\": {},\n    \"skipped\": {},\n    \"skipped_fraction\": {:.4},\n    \"points_per_sec_full\": {:.2},\n    \"points_per_sec_pruned\": {:.2},\n    \"speedup\": {:.3},\n    \"front_identical\": {}\n  }},\n",
            points.len(),
            pruned.skipped.len(),
            pruned.skipped.len() as f64 / points.len().max(1) as f64,
            points.len() as f64 / full_secs,
            points.len() as f64 / pruned_secs,
            full_secs / pruned_secs.max(1e-300),
            identical
        );
        (points.len(), pruned.skipped.len(), json)
    };

    // past-the-wall deployment GA (the ga-cluster family): front quality
    // vs the block-fallback baseline on a 256-device pool, plus how small
    // a fraction of the enumerable space the search visits
    let (ga_evaluated, ga_enumerated, ga_secs, ga_json) = {
        fn tiny_builder(batch: usize) -> TrainingGraph {
            build_training_graph(&mlp(batch.max(1), 8, 16, 2, 4), TrainOptions::default())
        }
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 128),
            (DeviceClass::server(), 64),
            (DeviceClass::datacenter(), 64),
        ]);
        let ga: GaConfig<DeploymentGenome> =
            GaConfig { population: 16, generations: 6, ..Default::default() };
        let cfg = SweepConfig { mapping: MappingConfig::edge_tpu_default(), ..Default::default() };
        let t0 = Instant::now();
        let out = ga_cluster_search(&hc, &[2], 4, &tiny_builder, "tiny-mlp", &ga, &cfg, |_, _| {});
        let secs = t0.elapsed().as_secs_f64();
        assert!(out.failures.is_empty(), "{:?}", out.failures);

        // hypervolume proxy: sum of per-point dominated boxes against the
        // reference point 1.1^d after max-normalizing each objective over
        // both fronts (overlap overcounted — a proxy, comparable between
        // the two fronts since they share the normalization)
        let objs = |rows: &[ClusterRow]| -> Vec<Vec<f64>> {
            rows.iter().map(|r| r.objectives().to_vec()).collect()
        };
        let ga_o = objs(&out.rows);
        let fb_o = objs(&out.fallback_front);
        let dims = ga_o.first().map_or(0, |o| o.len());
        let mut maxs = vec![f64::MIN; dims];
        for o in ga_o.iter().chain(&fb_o) {
            for (m, v) in maxs.iter_mut().zip(o) {
                *m = m.max(*v);
            }
        }
        let hv = |front: &[Vec<f64>]| -> f64 {
            front
                .iter()
                .map(|o| {
                    o.iter()
                        .zip(&maxs)
                        .map(|(v, m)| (1.1 - v / m.max(1e-300)).max(0.0))
                        .product::<f64>()
                })
                .sum()
        };
        let (hv_ga, hv_fb) = (hv(&ga_o), hv(&fb_o));
        let json = format!(
            "  \"ga_cluster\": {{\n    \"pool_devices\": {},\n    \"enumerable_points\": {},\n    \"points_evaluated\": {},\n    \"evaluated_fraction\": {:.6},\n    \"front_points\": {},\n    \"fallback_front_points\": {},\n    \"hv_proxy_front\": {:.6},\n    \"hv_proxy_fallback\": {:.6},\n    \"hv_gain\": {:.4},\n    \"secs\": {:.3}\n  }},\n",
            hc.total_devices(),
            out.enumerated,
            out.evaluated,
            out.evaluated as f64 / out.enumerated.max(1) as f64,
            out.rows.len(),
            out.fallback_front.len(),
            hv_ga,
            hv_fb,
            hv_ga / hv_fb.max(1e-300),
            secs
        );
        (out.evaluated, out.enumerated, secs, json)
    };

    println!(
        "{:<16} {:>8} {:>12} {:>12} {:>14} {:>14}",
        "family", "points", "cold (s)", "warm (s)", "cold pts/s", "warm pts/s"
    );
    for r in &results {
        println!(
            "{:<16} {:>8} {:>12.3} {:>12.3} {:>14.1} {:>14.1}",
            r.name,
            r.points,
            r.cold_secs,
            r.warm_secs,
            r.cold_pps(),
            r.warm_pps()
        );
    }

    let families_json: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "    \"{}\": {{\n      \"points\": {},\n      \"points_per_sec_cold\": {:.2},\n      \"points_per_sec_warm\": {:.2},\n      \"warm_speedup\": {:.3}\n    }}",
                r.name,
                r.points,
                r.cold_pps(),
                r.warm_pps(),
                r.cold_secs / r.warm_secs
            )
        })
        .collect();
    println!(
        "{:<16} {:>8} {:>12.3} {:>12.3}   (journaled sweep vs full --resume replay)",
        "run_journal", journal_points, journaled_secs, replay_secs
    );
    println!(
        "{:<16} {:>8} {:>12}              ({} of {} points bound-pruned, front bit-identical)",
        "pruned", pruned_points, "", pruned_skipped, pruned_points
    );
    println!(
        "{:<16} {:>8} {:>12.3}              ({} of {} enumerable points visited, {:.2}%)",
        "ga_cluster",
        ga_evaluated,
        ga_secs,
        ga_evaluated,
        ga_enumerated,
        ga_evaluated as f64 / ga_enumerated.max(1) as f64 * 100.0
    );
    let journal_json = format!(
        "  \"journal\": {{\n    \"points\": {},\n    \"points_per_sec_journaled\": {:.2},\n    \"points_per_sec_replay\": {:.2}\n  }},\n",
        journal_points,
        journal_points as f64 / journaled_secs,
        journal_points as f64 / replay_secs
    );
    let json = format!(
        "{{\n  \"bench\": \"dse_engine_throughput\",\n  \"harness\": \"dse::engine (one generic worker pool + cache lifecycle for every sweep family)\",\n{}{}{}  \"families\": {{\n{}\n  }}\n}}\n",
        journal_json,
        pruned_json,
        ga_json,
        families_json.join(",\n")
    );
    std::fs::write("BENCH_dse.json", &json).expect("writing BENCH_dse.json");
    println!("\n    -> BENCH_dse.json written");
    println!("\nbench_dse done");
}
