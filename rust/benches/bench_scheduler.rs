//! `cargo bench --bench bench_scheduler` — microbenchmarks of the L3 hot
//! paths: per-design-point evaluation throughput (the DSE inner loop),
//! autodiff, fusion solving, scheduling, and GA generation cost. These are
//! the §Perf numbers tracked in EXPERIMENTS.md.

use std::time::Instant;

use monet::autodiff::{build_training_graph, TrainOptions};
use monet::dse::{evaluate_point, DesignPoint, SweepConfig};
use monet::fusion::{enumerate_candidates, fuse, fuse_greedy, FusionConstraints};
use monet::ga::{CheckpointProblem, GaConfig};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::scheduler::{schedule, Partition};
use monet::workload::models::{gpt2, resnet18, Gpt2Config};
use monet::workload::op::Optimizer;

fn bench(name: &str, reps: usize, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    let (val, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "µs")
    };
    println!("{name:<52} {val:>9.2} {unit}   ({:.0}/s)", 1.0 / per);
    per
}

fn main() {
    println!("== MONET L3 hot-path benchmarks ==\n");

    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let fc = FusionConstraints::default();

    bench("autodiff: resnet18 training-graph build", 200, || {
        let _ = build_training_graph(&fwd, TrainOptions::default());
    });

    bench("fusion: candidate enumeration (resnet18 train)", 50, || {
        let _ = enumerate_candidates(&tg.graph, &fc);
    });

    bench("fusion: greedy partition (resnet18 train)", 200, || {
        let _ = fuse_greedy(&tg.graph, &fc);
    });

    bench("fusion: exact-cover solve (resnet18 train)", 20, || {
        let _ = fuse(&tg.graph, &fc);
    });

    let p_sing = Partition::singletons(&tg.graph);
    bench("schedule: resnet18 train, singletons", 500, || {
        let _ = schedule(&tg.graph, &p_sing, &accel, &mapping);
    });

    let p_fused = fuse_greedy(&tg.graph, &fc);
    bench("schedule: resnet18 train, greedy-fused", 500, || {
        let _ = schedule(&tg.graph, &p_fused, &accel, &mapping);
    });

    let cfg = SweepConfig { mapping, ..Default::default() };
    let pt = DesignPoint::edge_space(1)[0];
    let per_pt = bench("dse: evaluate_point (fwd+train, fuse+schedule)", 200, || {
        let _ = evaluate_point(0, &pt, &fwd, &tg.graph, &cfg);
    });
    let parts = monet::dse::SweepPartitions::prepare(&fwd, &tg.graph, &cfg);
    let per_pt2 = bench("dse: evaluate_point_prepared (hoisted fusion)", 400, || {
        let _ = monet::dse::evaluate_point_prepared(0, &pt, &fwd, &tg.graph, &parts, &cfg);
    });
    println!(
        "    -> sweep inner loop speedup {:.1}x; full Table II ~ {:.0} s",
        per_pt / per_pt2,
        per_pt2 * 10_000.0
    );
    println!(
        "    → full Table II space (10 000 points) ≈ {:.0} s on this core",
        per_pt * 10_000.0
    );

    let g2 = gpt2(Gpt2Config::tiny());
    let tg2 = build_training_graph(
        &g2,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let fpt = DesignPoint::fusemax_space(1)[0];
    bench("dse: evaluate_point gpt2-tiny on fusemax", 200, || {
        let _ = evaluate_point(0, &fpt, &g2, &tg2.graph, &cfg);
    });

    let problem = CheckpointProblem::new(&tg, &accel, MappingConfig::edge_tpu_default(), fc);
    bench("ga: one checkpoint-plan evaluation", 100, || {
        let plan = monet::autodiff::CheckpointPlan::recompute_set(
            problem.candidates.iter().step_by(3).copied(),
        );
        let _ = problem.evaluate(&plan);
    });

    bench("ga: one NSGA-II generation (pop 16)", 3, || {
        let _ = problem.optimize(&GaConfig {
            population: 16,
            generations: 1,
            ..Default::default()
        });
    });

    println!("\nbench_scheduler done");
}
