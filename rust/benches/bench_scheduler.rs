//! `cargo bench --bench bench_scheduler` — microbenchmarks of the L3 hot
//! paths: per-design-point evaluation throughput (the DSE inner loop),
//! autodiff, fusion solving, scheduling, and GA generation cost. These are
//! the §Perf numbers tracked in EXPERIMENTS.md.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use monet::autodiff::{
    apply_checkpointing, build_training_graph, stored_activation_bytes, TrainOptions,
    TrainingGraph,
};
use monet::dse::{
    evaluate_point, ClusterScratch, ClusterSpace, DesignPoint, Evaluate, HeteroEval, SweepConfig,
};
use monet::fusion::{enumerate_candidates, fuse, fuse_greedy, FusionConstraints};
use monet::ga::{
    nsga2, nsga2_problem, CheckpointProblem, DeploymentGenome, DeploymentProblem, GaConfig,
    Genome, Objectives,
};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{DeviceClass, HeteroCluster};
use monet::scheduler::{schedule, Partition};
use monet::workload::models::{gpt2, mlp, resnet18, Gpt2Config};
use monet::workload::op::Optimizer;

fn bench(name: &str, reps: usize, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    let (val, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "µs")
    };
    println!("{name:<52} {val:>9.2} {unit}   ({:.0}/s)", 1.0 / per);
    per
}

fn main() {
    println!("== MONET L3 hot-path benchmarks ==\n");

    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let fc = FusionConstraints::default();

    bench("autodiff: resnet18 training-graph build", 200, || {
        let _ = build_training_graph(&fwd, TrainOptions::default());
    });

    bench("fusion: candidate enumeration (resnet18 train)", 50, || {
        let _ = enumerate_candidates(&tg.graph, &fc);
    });

    bench("fusion: greedy partition (resnet18 train)", 200, || {
        let _ = fuse_greedy(&tg.graph, &fc);
    });

    bench("fusion: exact-cover solve (resnet18 train)", 20, || {
        let _ = fuse(&tg.graph, &fc);
    });

    let p_sing = Partition::singletons(&tg.graph);
    bench("schedule: resnet18 train, singletons", 500, || {
        let _ = schedule(&tg.graph, &p_sing, &accel, &mapping);
    });

    let p_fused = fuse_greedy(&tg.graph, &fc);
    bench("schedule: resnet18 train, greedy-fused", 500, || {
        let _ = schedule(&tg.graph, &p_fused, &accel, &mapping);
    });

    let cfg = SweepConfig { mapping, ..Default::default() };
    let pt = DesignPoint::edge_space(1)[0];
    let per_pt = bench("dse: evaluate_point (fwd+train, fuse+schedule)", 200, || {
        let _ = evaluate_point(0, &pt, &fwd, &tg.graph, &cfg);
    });
    let parts = monet::dse::SweepPartitions::prepare(&fwd, &tg.graph, &cfg);
    let per_pt2 = bench("dse: evaluate_point_prepared (hoisted fusion)", 400, || {
        let _ = monet::dse::evaluate_point_prepared(0, &pt, &fwd, &tg.graph, &parts, &cfg);
    });
    println!(
        "    -> sweep inner loop speedup {:.1}x; full Table II ~ {:.0} s",
        per_pt / per_pt2,
        per_pt2 * 10_000.0
    );
    println!(
        "    → full Table II space (10 000 points) ≈ {:.0} s on this core",
        per_pt * 10_000.0
    );

    let g2 = gpt2(Gpt2Config::tiny());
    let tg2 = build_training_graph(
        &g2,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let fpt = DesignPoint::fusemax_space(1)[0];
    bench("dse: evaluate_point gpt2-tiny on fusemax", 200, || {
        let _ = evaluate_point(0, &fpt, &g2, &tg2.graph, &cfg);
    });

    let problem = CheckpointProblem::new(&tg, &accel, MappingConfig::edge_tpu_default(), fc);
    bench("ga: one checkpoint-plan evaluation", 100, || {
        let plan = monet::autodiff::CheckpointPlan::recompute_set(
            problem.candidates.iter().step_by(3).copied(),
        );
        let _ = problem.evaluate(&plan);
    });

    bench("ga: one NSGA-II generation (pop 16)", 3, || {
        let _ = problem.optimize(&GaConfig {
            population: 16,
            generations: 1,
            ..Default::default()
        });
    });

    // ---- GA evaluation throughput: uncached-serial vs memoized/parallel
    // (the headline number of the memoized-evaluation PR; trajectory
    // tracked across PRs via BENCH_eval.json) ----
    println!();
    let ga_pop = 32usize;
    let ga_gens = 20usize;
    let evals = (ga_pop * (ga_gens + 1)) as f64;
    // fresh problem so the cold run starts with genuinely empty caches
    // (the micro-benches above already warmed `problem`'s)
    let ga_problem =
        CheckpointProblem::new(&tg, &accel, MappingConfig::edge_tpu_default(), FusionConstraints::default());
    let width = ga_problem.candidates.len();

    // serial baseline: full checkpoint→fuse→schedule per genome with no
    // cost cache and no transform cache, one worker. (nsga2's built-in
    // genome memo still dedupes exact-duplicate genomes — it cannot be
    // disabled — so this baseline is *faster* than the true pre-memoization
    // pipeline and the speedups below are conservative.)
    let eval_uncached = |genome: &Genome| -> Objectives {
        let plan = ga_problem.genome_to_plan(genome);
        let g = apply_checkpointing(&tg, &plan);
        let part = fuse_greedy(&g, &FusionConstraints::default());
        let r = schedule(&g, &part, &accel, &mapping);
        let stored = stored_activation_bytes(&tg, &plan) / 2;
        vec![r.latency_cycles, r.energy_pj, stored as f64]
    };
    // memoized path: the CheckpointProblem transform + cost caches
    let eval_cached = |genome: &Genome| -> Objectives {
        let plan = ga_problem.genome_to_plan(genome);
        let (lat, en, mem) = ga_problem.evaluate(&plan);
        vec![lat, en, mem as f64]
    };
    let serial_cfg =
        GaConfig { population: ga_pop, generations: ga_gens, workers: 1, ..Default::default() };
    let par_cfg = GaConfig { population: ga_pop, generations: ga_gens, ..Default::default() };

    let t0 = Instant::now();
    let base_front = nsga2(width, &serial_cfg, &eval_uncached);
    let base_secs = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let cold_front = nsga2(width, &par_cfg, &eval_cached);
    let cold_secs = t1.elapsed().as_secs_f64();

    // warm: caches primed by the cold run, same seed → same genome stream
    let t2 = Instant::now();
    let warm_front = nsga2(width, &par_cfg, &eval_cached);
    let warm_secs = t2.elapsed().as_secs_f64();

    let key = |f: &[monet::ga::Individual]| -> Vec<(Genome, Vec<u64>)> {
        f.iter()
            .map(|i| {
                (i.genome.clone(), i.objectives.iter().map(|o| o.to_bits()).collect())
            })
            .collect()
    };
    let fronts_identical = key(&base_front) == key(&cold_front) && key(&base_front) == key(&warm_front);
    assert!(fronts_identical, "memoized GA diverged from the serial uncached-pipeline baseline");

    let stats = ga_problem.cache_stats();
    for (name, secs) in [
        ("ga-eval: pop32x20gens serial, pipeline uncached", base_secs),
        ("ga-eval: pop32x20gens cold caches, parallel", cold_secs),
        ("ga-eval: pop32x20gens warm caches, parallel", warm_secs),
    ] {
        println!("{name:<52} {:>9.2} ms   ({:.0} genomes/s)", secs * 1e3, evals / secs);
    }
    println!(
        "    -> speedup vs baseline: cold {:.1}x, warm {:.1}x; cache {} hits / {} misses; fronts identical: {}",
        base_secs / cold_secs,
        base_secs / warm_secs,
        stats.hits,
        stats.misses,
        fronts_identical
    );

    // ---- incremental GA re-evaluation (ROADMAP item 5): deployment-genome
    // throughput, a cold `ClusterScratch` per genome (full re-evaluation)
    // vs warm scratches recycled through a pool (a mutant re-costs only
    // the stage schedules it changed) — objectives bit-identical ----
    println!();
    let hc = HeteroCluster::new(vec![
        (DeviceClass::edge(), 4),
        (DeviceClass::server(), 4),
        (DeviceClass::datacenter(), 4),
    ]);
    fn stage_builder(batch: usize) -> TrainingGraph {
        build_training_graph(&mlp(batch.max(1), 16, 32, 3, 8), TrainOptions::default())
    }
    let builder: &(dyn Fn(usize) -> TrainingGraph + Sync) = &stage_builder;
    let heval = HeteroEval {
        hc: &hc,
        full_batch: 8,
        builder,
        mapping: MappingConfig::edge_tpu_default(),
    };
    let dproblem = DeploymentProblem { hc: &hc, microbatches: vec![2, 4] };
    let dga: GaConfig<DeploymentGenome> =
        GaConfig { population: 16, generations: 8, ..Default::default() };
    let devals = (dga.population * (dga.generations + 1)) as f64;

    let eval_full = |g: &DeploymentGenome| {
        let p = ClusterSpace::genome_to_hetero(g);
        let mut scratch = heval.scratch();
        heval.evaluate(0, &p, None, &mut scratch)[0].objectives().to_vec()
    };
    let mut memo_full: HashMap<DeploymentGenome, Objectives> = HashMap::new();
    let t3 = Instant::now();
    let (pop_full, _) = nsga2_problem(&dproblem, &dga, eval_full, &mut memo_full, None, |_| {});
    let full_secs = t3.elapsed().as_secs_f64();

    let pool: Mutex<Vec<ClusterScratch>> = Mutex::new(Vec::new());
    let eval_inc = |g: &DeploymentGenome| {
        let p = ClusterSpace::genome_to_hetero(g);
        let mut scratch =
            pool.lock().ok().and_then(|mut v| v.pop()).unwrap_or_else(|| heval.scratch());
        let objs = heval.evaluate(0, &p, None, &mut scratch)[0].objectives().to_vec();
        if let Ok(mut v) = pool.lock() {
            v.push(scratch);
        }
        objs
    };
    let mut memo_inc: HashMap<DeploymentGenome, Objectives> = HashMap::new();
    let t4 = Instant::now();
    let (pop_inc, _) = nsga2_problem(&dproblem, &dga, eval_inc, &mut memo_inc, None, |_| {});
    let inc_secs = t4.elapsed().as_secs_f64();

    let dkey = |f: &[monet::ga::Individual<DeploymentGenome>]| -> Vec<(DeploymentGenome, Vec<u64>)> {
        f.iter()
            .map(|i| (i.genome.clone(), i.objectives.iter().map(|o| o.to_bits()).collect()))
            .collect()
    };
    let objectives_identical = dkey(&pop_full) == dkey(&pop_inc);
    assert!(objectives_identical, "incremental GA diverged from full re-evaluation");
    for (name, secs) in [
        ("ga-eval: deployment pop16x8gens, cold scratch/genome", full_secs),
        ("ga-eval: deployment pop16x8gens, pooled warm scratch", inc_secs),
    ] {
        println!("{name:<52} {:>9.2} ms   ({:.0} genomes/s)", secs * 1e3, devals / secs);
    }
    println!(
        "    -> incremental speedup {:.1}x; objectives identical: {}",
        full_secs / inc_secs,
        objectives_identical
    );

    let incremental_json = format!(
        "  \"incremental\": {{\n    \"pool_devices\": {},\n    \"population\": {},\n    \"generations\": {},\n    \"genomes_per_sec_full\": {:.2},\n    \"genomes_per_sec_incremental\": {:.2},\n    \"speedup\": {:.3},\n    \"objectives_identical\": {}\n  }}",
        hc.total_devices(),
        dga.population,
        dga.generations,
        devals / full_secs,
        devals / inc_secs,
        full_secs / inc_secs.max(1e-300),
        objectives_identical
    );

    let json = format!(
        "{{\n  \"bench\": \"ga_eval_throughput\",\n  \"workload\": \"resnet18(1,32,10) training, Adam, EdgeTPU baseline\",\n  \"baseline\": \"serial, pipeline uncached (nsga2 genome memo active -> speedups are conservative)\",\n  \"population\": {ga_pop},\n  \"generations\": {ga_gens},\n  \"evaluations\": {},\n  \"genomes_per_sec_baseline\": {:.2},\n  \"genomes_per_sec_cold_cache\": {:.2},\n  \"genomes_per_sec_warm_cache\": {:.2},\n  \"speedup_cold\": {:.3},\n  \"speedup_warm\": {:.3},\n  \"cache_hits\": {},\n  \"cache_misses\": {},\n  \"fronts_identical\": {},\n{}\n}}\n",
        evals as u64,
        evals / base_secs,
        evals / cold_secs,
        evals / warm_secs,
        base_secs / cold_secs,
        base_secs / warm_secs,
        stats.hits,
        stats.misses,
        fronts_identical,
        incremental_json
    );
    std::fs::write("BENCH_eval.json", &json).expect("writing BENCH_eval.json");
    println!("    -> BENCH_eval.json written");

    println!("\nbench_scheduler done");
}
