//! Integration pins for the unified `dse::engine` harness: every sweep
//! family (single-device accelerator points, homogeneous cluster
//! deployments, heterogeneous stage placements) must produce rows
//! **bit-identical** to a serial, cache-free reference loop — the exact
//! per-point math the pre-engine bespoke harnesses ran — at every worker
//! count and cache setting (off / cold / warm-persisted /
//! capacity-bounded). A final test pins the engine-owned cache-flag
//! semantics (`--no-cache` wins, `--cache-dir` persists, `--cache-cap`
//! bounds) uniformly across the families, so no command can drift.

use std::path::PathBuf;

use monet::autodiff::{build_training_graph, TrainOptions};
use monet::dse::{
    evaluate_point_cached, run_cluster_sweep, run_hetero_sweep, run_sweep_stats, ClusterRow,
    ClusterSpace, DesignPoint, SweepConfig, SweepPartitions, SweepRow,
};
use monet::eval::{persist, CacheStats};
use monet::figures::cluster_resnet18_builder;
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{
    model_strategy_cached, model_strategy_hetero, DeviceClass, HeteroCluster, LinkTier,
};
use monet::workload::models::resnet18;
use monet::workload::op::Optimizer;

fn sweep_rows_bit_eq(expect: &[SweepRow], got: &[SweepRow], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: row count");
    for (a, b) in expect.iter().zip(got) {
        assert_eq!(a.index, b.index, "{what}: index");
        assert_eq!(a.label, b.label, "{what}: label");
        assert_eq!(a.mode, b.mode, "{what}: mode");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits(), "{what}: latency");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{what}: energy");
        assert_eq!(a.peak_dram_bytes, b.peak_dram_bytes, "{what}: peak dram");
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{what}: utilization");
    }
}

fn cluster_rows_bit_eq(expect: &[ClusterRow], got: &[ClusterRow], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: row count");
    for (a, b) in expect.iter().zip(got) {
        assert_eq!(a.index, b.index, "{what}: index");
        assert_eq!(a.label, b.label, "{what}: label");
        assert_eq!(a.placement, b.placement, "{what}: placement");
        assert_eq!(a.tier, b.tier, "{what}: tier");
        assert_eq!(a.devices, b.devices, "{what}: devices");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits(), "{what}: latency");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{what}: energy");
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes, "{what}: mem");
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits(), "{what}: comm");
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("monet_dse_engine_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// The property-style engine matrix on the single-device space: 1/2/8
/// workers × {cache off, cold, bounded, warm-persisted}, every cell
/// bit-identical to the serial cache-free reference (the pre-engine
/// harness's exact per-point math).
#[test]
fn single_device_sweep_matches_the_serial_reference_everywhere() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
    );
    let points = DesignPoint::edge_space(3000);
    assert!(points.len() >= 2);
    let base = SweepConfig { workers: 1, ..Default::default() };
    let parts = SweepPartitions::prepare(&fwd, &tg.graph, &base);
    let reference: Vec<SweepRow> = points
        .iter()
        .enumerate()
        .flat_map(|(i, p)| evaluate_point_cached(i, p, &fwd, &tg.graph, &parts, &base, None))
        .collect();

    for workers in [1usize, 2, 8] {
        for (use_cache, cache_cap) in [(false, 0usize), (true, 0), (true, 16)] {
            let cfg = SweepConfig { workers, use_cache, cache_cap, ..Default::default() };
            let (rows, stats) = run_sweep_stats(&points, &fwd, &tg.graph, &cfg, |_, _| {});
            let what = format!("workers={workers} use_cache={use_cache} cap={cache_cap}");
            sweep_rows_bit_eq(&reference, &rows, &what);
            if use_cache {
                assert!(stats.hits + stats.misses > 0, "{what}: cache never consulted");
                if cache_cap > 0 {
                    assert!(stats.entries <= cache_cap, "{what}: cap exceeded: {stats:?}");
                }
            } else {
                assert_eq!(stats, CacheStats::default(), "{what}: no-cache must not count");
            }
        }
        // warm-persisted: the second run replays the snapshot bit for bit
        let dir = tmp_dir(&format!("sweep_w{workers}"));
        let cfg =
            SweepConfig { workers, cache_dir: Some(dir.clone()), ..Default::default() };
        let (first, _) = run_sweep_stats(&points, &fwd, &tg.graph, &cfg, |_, _| {});
        let (second, s2) = run_sweep_stats(&points, &fwd, &tg.graph, &cfg, |_, _| {});
        sweep_rows_bit_eq(&reference, &first, "cold persisted");
        sweep_rows_bit_eq(&reference, &second, "warm persisted");
        assert_eq!(s2.misses, 0, "warm run recomputed group costs: {s2:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Golden pin for the homogeneous cluster family: engine output ≡ the
/// serial reference built directly from `model_strategy_cached` (what
/// the retired bespoke pool computed per point), across workers and
/// cache settings.
#[test]
fn cluster_sweep_matches_the_serial_reference_everywhere() {
    let space = ClusterSpace {
        device_counts: vec![1, 2],
        tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
        microbatches: vec![2],
    };
    let points = space.enumerate();
    assert!(points.len() >= 6);
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let full_batch = 4usize;
    let reference: Vec<ClusterRow> = points
        .iter()
        .enumerate()
        .map(|(index, p)| {
            let r = model_strategy_cached(
                p.strategy(),
                full_batch,
                &cluster_resnet18_builder,
                &accel,
                &mapping,
                &p.cluster(),
                None,
            );
            ClusterRow {
                index,
                label: p.label(),
                devices: r.devices,
                tier: p.tier,
                dp: p.dp,
                pp: p.pp,
                microbatches: p.microbatches,
                tp: p.tp,
                placement: String::new(),
                latency_cycles: r.latency_cycles,
                energy_pj: r.energy_pj,
                per_device_mem_bytes: r.per_device_mem_bytes,
                comm_bytes: r.comm_bytes,
            }
        })
        .collect();

    let dir = tmp_dir("cluster");
    for workers in [1usize, 4] {
        for (use_cache, cache_dir, cache_cap) in [
            (false, None, 0usize),
            (true, None, 0),
            (true, None, 24),
            (true, Some(dir.clone()), 0),
        ] {
            let what = format!(
                "workers={workers} use_cache={use_cache} dir={} cap={cache_cap}",
                cache_dir.is_some()
            );
            let cfg = SweepConfig {
                mapping,
                workers,
                use_cache,
                cache_dir,
                cache_cap,
                ..Default::default()
            };
            let (rows, stats) = run_cluster_sweep(
                &points,
                full_batch,
                &cluster_resnet18_builder,
                &accel,
                &cfg,
                |_, _| {},
            );
            cluster_rows_bit_eq(&reference, &rows, &what);
            if !use_cache {
                assert_eq!(stats, CacheStats::default(), "{what}");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Golden pin for the heterogeneous stage-placement family: engine
/// output ≡ the serial reference built directly from
/// `model_strategy_hetero`, across workers and cache settings — the
/// per-worker stage-cuts memo the engine adds must be invisible in the
/// rows.
#[test]
fn hetero_sweep_matches_the_serial_reference_everywhere() {
    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 1), (DeviceClass::datacenter(), 1)]);
    let points = ClusterSpace::enumerate_hetero(&hc, &[2]);
    assert!(points.len() >= 4);
    let mapping = MappingConfig::edge_tpu_default();
    let full_batch = 4usize;
    let reference: Vec<ClusterRow> = points
        .iter()
        .enumerate()
        .map(|(index, p)| {
            let r = model_strategy_hetero(
                p,
                full_batch,
                &cluster_resnet18_builder,
                &mapping,
                &hc,
                None,
            );
            ClusterRow {
                index,
                label: p.label(&hc),
                devices: r.devices,
                tier: hc.bottleneck_tier(&p.placement),
                dp: p.dp,
                pp: p.pp,
                microbatches: p.microbatches,
                tp: p.tp,
                placement: p.placement_names(&hc),
                latency_cycles: r.latency_cycles,
                energy_pj: r.energy_pj,
                per_device_mem_bytes: r.per_device_mem_bytes,
                comm_bytes: r.comm_bytes,
            }
        })
        .collect();

    let dir = tmp_dir("hetero");
    for workers in [1usize, 4] {
        for (use_cache, cache_dir, cache_cap) in [
            (false, None, 0usize),
            (true, None, 0),
            (true, None, 24),
            (true, Some(dir.clone()), 0),
        ] {
            let what = format!(
                "workers={workers} use_cache={use_cache} dir={} cap={cache_cap}",
                cache_dir.is_some()
            );
            let cfg = SweepConfig {
                mapping,
                workers,
                use_cache,
                cache_dir,
                cache_cap,
                ..Default::default()
            };
            let (rows, stats) = run_hetero_sweep(
                &points,
                &hc,
                full_batch,
                &cluster_resnet18_builder,
                &cfg,
                |_, _| {},
            );
            cluster_rows_bit_eq(&reference, &rows, &what);
            if !use_cache {
                assert_eq!(stats, CacheStats::default(), "{what}");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The engine-owned cache-flag semantics, pinned uniformly across all
/// three sweep families (the ISSUE 5 flag audit): `--no-cache` wins over
/// `--cache-dir` (nothing loaded, counted or **written**), `--cache-dir`
/// persists a snapshot that makes the restarted run recompute nothing,
/// and `--cache-cap` bounds the entry count.
#[test]
fn cache_flag_semantics_are_uniform_across_all_sweep_families() {
    type Family = (&'static str, Box<dyn Fn(&SweepConfig) -> CacheStats>);
    let families: Vec<Family> = vec![
        (
            "single-device",
            Box::new(|cfg: &SweepConfig| {
                let fwd = resnet18(1, 32, 10);
                let tg = build_training_graph(
                    &fwd,
                    TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
                );
                let points = DesignPoint::edge_space(4000);
                run_sweep_stats(&points, &fwd, &tg.graph, cfg, |_, _| {}).1
            }),
        ),
        (
            "cluster",
            Box::new(|cfg: &SweepConfig| {
                let space = ClusterSpace {
                    device_counts: vec![2],
                    tiers: vec![LinkTier::Edge],
                    microbatches: vec![2],
                };
                let accel = EdgeTpuParams::baseline().build();
                run_cluster_sweep(
                    &space.enumerate(),
                    4,
                    &cluster_resnet18_builder,
                    &accel,
                    cfg,
                    |_, _| {},
                )
                .1
            }),
        ),
        (
            "hetero",
            Box::new(|cfg: &SweepConfig| {
                let hc = HeteroCluster::new(vec![
                    (DeviceClass::edge(), 1),
                    (DeviceClass::datacenter(), 1),
                ]);
                let points = ClusterSpace::enumerate_hetero(&hc, &[2]);
                run_hetero_sweep(&points, &hc, 4, &cluster_resnet18_builder, cfg, |_, _| {}).1
            }),
        ),
    ];

    let mapping = MappingConfig::edge_tpu_default();
    for (name, run) in &families {
        // `--no-cache` wins over `--cache-dir`: zero counters AND no
        // snapshot on disk afterwards
        let dir = tmp_dir(&format!("flags_nocache_{name}"));
        let stats = run(&SweepConfig {
            mapping,
            workers: 2,
            use_cache: false,
            cache_dir: Some(dir.clone()),
            ..Default::default()
        });
        assert_eq!(stats, CacheStats::default(), "{name}: --no-cache must zero the counters");
        assert!(
            !dir.join(persist::COST_SNAPSHOT_FILE).exists(),
            "{name}: --no-cache wrote a snapshot despite winning over --cache-dir"
        );
        std::fs::remove_dir_all(&dir).ok();

        // `--cache-dir` persists: a snapshot exists and the restarted
        // run recomputes nothing
        let dir = tmp_dir(&format!("flags_dir_{name}"));
        let cfg = SweepConfig {
            mapping,
            workers: 2,
            cache_dir: Some(dir.clone()),
            ..Default::default()
        };
        let cold = run(&cfg);
        assert!(cold.misses > 0, "{name}: cold run must compute something");
        assert!(
            dir.join(persist::COST_SNAPSHOT_FILE).exists(),
            "{name}: --cache-dir produced no snapshot"
        );
        let warm = run(&cfg);
        assert_eq!(warm.misses, 0, "{name}: warm restart recomputed: {warm:?}");
        assert_eq!(cold.entries, warm.entries, "{name}: entry sets must match");
        std::fs::remove_dir_all(&dir).ok();

        // `--cache-cap` bounds the cache on every family
        let stats = run(&SweepConfig {
            mapping,
            workers: 2,
            cache_cap: 8,
            ..Default::default()
        });
        assert!(stats.entries <= 8, "{name}: cap ignored: {stats:?}");
        assert!(stats.evictions > 0, "{name}: cap 8 never evicted: {stats:?}");
    }
}
