//! Crash-safety pins for the robustness layer, driven by the
//! deterministic fault-injection harness (`monet::util::fault`):
//!
//! * **resume ≡ uninterrupted** — for every sweep family (single-device,
//!   homogeneous cluster, heterogeneous placement) and for the GA, a run
//!   killed at *any* journal record boundary (and mid-record: torn tails
//!   truncate) resumes to rows/fronts bit-identical to a run that was
//!   never interrupted;
//! * **panic isolation** — an injected per-point panic becomes one
//!   `PointFailure`, every other point still evaluates, and the failure
//!   itself is journaled so a resume replays it instead of re-panicking;
//! * **cache-lifecycle degradation** — an injected snapshot byte-flip is
//!   rejected + quarantined on the next run (counted in `CacheStats`)
//!   without changing a row; an injected transient write failure is
//!   retried (counted) and the snapshot still lands.
//!
//! Tests that install a `FaultPlan` mutate process-global hooks, and the
//! journal/snapshot writers consult those globals — so **every** test in
//! this binary serializes behind `FAULT_LOCK` (the CI job additionally
//! runs this binary with `--test-threads=1`).

use std::path::PathBuf;
use std::sync::Mutex;

use monet::autodiff::{build_training_graph, TrainOptions, TrainingGraph};
use monet::dse::journal::{GA_JOURNAL_FILE, RUN_JOURNAL_FILE};
use monet::dse::{
    ga_cluster_search, journal_record_bounds, run_cluster_sweep_outcome, run_hetero_sweep_outcome,
    run_sweep_outcome, ClusterRow, ClusterSpace, DesignPoint, SweepConfig, SweepRow,
};
use monet::eval::persist;
use monet::figures::{cluster_gpt2_builder, cluster_resnet18_builder};
use monet::fusion::FusionConstraints;
use monet::ga::{pareto_rank0, CheckpointProblem, CheckpointSolution, DeploymentGenome, GaConfig};
use monet::hardware::accelerator::Accelerator;
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{DeviceClass, HeteroCluster, LinkTier};
use monet::util::fault::{self, FaultPlan};
use monet::workload::graph::Graph;
use monet::workload::models::{mlp, resnet18};
use monet::workload::op::Optimizer;

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Clears the global fault plan even when the test body panics, so one
/// failing assertion cannot corrupt the rest of the binary.
struct PlanGuard;
impl Drop for PlanGuard {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn install(plan: FaultPlan) -> PlanGuard {
    fault::install(plan);
    PlanGuard
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("monet_fault_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn sweep_rows_bit_eq(expect: &[SweepRow], got: &[SweepRow], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: row count");
    for (a, b) in expect.iter().zip(got) {
        assert_eq!(a.index, b.index, "{what}: index");
        assert_eq!(a.label, b.label, "{what}: label");
        assert_eq!(a.mode, b.mode, "{what}: mode");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits(), "{what}: latency");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{what}: energy");
        assert_eq!(a.peak_dram_bytes, b.peak_dram_bytes, "{what}: peak dram");
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{what}: utilization");
    }
}

fn cluster_rows_bit_eq(expect: &[ClusterRow], got: &[ClusterRow], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: row count");
    for (a, b) in expect.iter().zip(got) {
        assert_eq!(a.index, b.index, "{what}: index");
        assert_eq!(a.label, b.label, "{what}: label");
        assert_eq!(a.placement, b.placement, "{what}: placement");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits(), "{what}: latency");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{what}: energy");
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes, "{what}: mem");
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits(), "{what}: comm");
    }
}

fn edge_fixture() -> (Graph, TrainingGraph, Vec<DesignPoint>) {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
    );
    let points = DesignPoint::edge_space(3000);
    assert!(points.len() >= 2);
    (fwd, tg, points)
}

/// Single-device family: journaling is invisible in the rows, and a run
/// killed at **every** record boundary — plus mid-record, exercising
/// torn-tail truncation — resumes bit-identically to the uninterrupted
/// run, replaying exactly the surviving records.
#[test]
fn edge_sweep_resumes_bit_identically_at_every_record_boundary() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (fwd, tg, points) = edge_fixture();
    let dir = tmp_dir("edge_resume");
    let cfg = |run: bool, resume: bool| SweepConfig {
        workers: 2,
        run_dir: run.then(|| dir.clone()),
        resume,
        ..Default::default()
    };

    let plain = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(false, false), |_, _| {})
        .expect("unjournaled run");
    let full = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(true, false), |_, _| {})
        .expect("journaled run");
    assert!(full.is_clean(), "{:?}", full.failures);
    assert_eq!(full.resumed, 0);
    sweep_rows_bit_eq(&plain.rows, &full.rows, "journaling changed rows");

    let jpath = dir.join(RUN_JOURNAL_FILE);
    let complete = std::fs::read(&jpath).expect("journal missing");
    let bounds = journal_record_bounds(&jpath).expect("journal unreadable");
    assert_eq!(bounds.len(), points.len() + 1, "one journal record per point");

    for (k, &cut) in bounds.iter().enumerate() {
        std::fs::write(&jpath, &complete[..cut as usize]).unwrap();
        let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(true, true), |_, _| {})
            .expect("resumed run");
        assert_eq!(out.resumed, k, "cut at record boundary {k}: replay count");
        sweep_rows_bit_eq(&full.rows, &out.rows, &format!("resume from boundary {k}"));
    }

    // torn tail: a cut strictly inside a record truncates back to the
    // last good boundary and resumes from there
    let mid = bounds[1] + 3;
    assert!(mid < *bounds.last().unwrap(), "space too small for a torn cut");
    std::fs::write(&jpath, &complete[..mid as usize]).unwrap();
    let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(true, true), |_, _| {})
        .expect("torn resume");
    assert_eq!(out.resumed, 1, "torn tail must truncate to the last good record");
    sweep_rows_bit_eq(&full.rows, &out.rows, "resume from torn tail");
    std::fs::remove_dir_all(&dir).ok();
}

/// Cluster families (homogeneous device×tier×strategy grid and
/// heterogeneous stage placements): same resume-at-every-boundary
/// bit-identity as the single-device family.
#[test]
fn cluster_and_hetero_sweeps_resume_bit_identically() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let full_batch = 4usize;

    // homogeneous
    let space = ClusterSpace {
        device_counts: vec![1, 2],
        tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
        microbatches: vec![2],
    };
    let points = space.enumerate();
    assert!(points.len() >= 6);
    let dir = tmp_dir("cluster_resume");
    let cfg = |run: bool, resume: bool| SweepConfig {
        mapping,
        workers: 2,
        run_dir: run.then(|| dir.clone()),
        resume,
        ..Default::default()
    };
    let full = run_cluster_sweep_outcome(
        &points,
        full_batch,
        &cluster_resnet18_builder,
        &accel,
        &cfg(true, false),
        |_, _| {},
    )
    .expect("cluster run");
    assert!(full.is_clean(), "{:?}", full.failures);
    let jpath = dir.join(RUN_JOURNAL_FILE);
    let complete = std::fs::read(&jpath).unwrap();
    let bounds = journal_record_bounds(&jpath).unwrap();
    assert_eq!(bounds.len(), points.len() + 1);
    for (k, &cut) in bounds.iter().enumerate() {
        std::fs::write(&jpath, &complete[..cut as usize]).unwrap();
        let out = run_cluster_sweep_outcome(
            &points,
            full_batch,
            &cluster_resnet18_builder,
            &accel,
            &cfg(true, true),
            |_, _| {},
        )
        .expect("cluster resume");
        assert_eq!(out.resumed, k, "cluster boundary {k}");
        cluster_rows_bit_eq(&full.rows, &out.rows, &format!("cluster resume {k}"));
    }
    std::fs::remove_dir_all(&dir).ok();

    // heterogeneous
    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 1), (DeviceClass::datacenter(), 1)]);
    let hpoints = ClusterSpace::enumerate_hetero(&hc, &[2]);
    assert!(hpoints.len() >= 4);
    let hdir = tmp_dir("hetero_resume");
    let hcfg = |resume: bool| SweepConfig {
        mapping,
        workers: 2,
        run_dir: Some(hdir.clone()),
        resume,
        ..Default::default()
    };
    let hfull = run_hetero_sweep_outcome(
        &hpoints,
        &hc,
        full_batch,
        &cluster_resnet18_builder,
        &hcfg(false),
        |_, _| {},
    )
    .expect("hetero run");
    assert!(hfull.is_clean(), "{:?}", hfull.failures);
    let hjpath = hdir.join(RUN_JOURNAL_FILE);
    let hcomplete = std::fs::read(&hjpath).unwrap();
    let hbounds = journal_record_bounds(&hjpath).unwrap();
    assert_eq!(hbounds.len(), hpoints.len() + 1);
    for (k, &cut) in hbounds.iter().enumerate() {
        std::fs::write(&hjpath, &hcomplete[..cut as usize]).unwrap();
        let out = run_hetero_sweep_outcome(
            &hpoints,
            &hc,
            full_batch,
            &cluster_resnet18_builder,
            &hcfg(true),
            |_, _| {},
        )
        .expect("hetero resume");
        assert_eq!(out.resumed, k, "hetero boundary {k}");
        cluster_rows_bit_eq(&hfull.rows, &out.rows, &format!("hetero resume {k}"));
    }
    std::fs::remove_dir_all(&hdir).ok();
}

/// Bound-pruned journaled runs stay crash-safe: with pruning on, every
/// point still lands exactly one journal record — evaluated row or
/// `Skipped` — and a run killed at **every** record boundary (cuts land
/// between skip records too, since skips are journaled in bound order
/// interleaved with evaluations) resumes to a 4-objective rank-0 front
/// bit-identical to both the uninterrupted pruned run and the full
/// unpruned enumeration. Only fronts are compared: a resume may
/// legitimately skip *more* points than the run it replays (the
/// replayed rows hand it a stronger incumbent before the remainder is
/// bounded), so row sets can differ while the front cannot.
#[test]
fn pruned_runs_resume_to_the_same_front_at_every_record_boundary() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let space = ClusterSpace {
        device_counts: vec![4, 8],
        tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
        microbatches: vec![2, 4],
    };
    let points = space.enumerate();
    let accel = EdgeTpuParams::baseline().build();
    let full_batch = 4usize;
    let dir = tmp_dir("pruned_resume");
    let cache = tmp_dir("pruned_resume_cache");
    let cfg = |run: bool, resume: bool| SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        workers: 2,
        prune: true,
        run_dir: run.then(|| dir.clone()),
        resume,
        cache_dir: Some(cache.clone()),
        ..Default::default()
    };
    let front_key = |rows: &[ClusterRow]| -> Vec<(String, u64, u64, u64, usize)> {
        let objs: Vec<Vec<f64>> = rows.iter().map(|r| r.objectives().to_vec()).collect();
        pareto_rank0(&objs)
            .into_iter()
            .map(|i| {
                let r = &rows[i];
                (
                    r.label.clone(),
                    r.latency_cycles.to_bits(),
                    r.energy_pj.to_bits(),
                    r.per_device_mem_bytes,
                    r.devices,
                )
            })
            .collect()
    };

    let unpruned = run_cluster_sweep_outcome(
        &points,
        full_batch,
        &cluster_gpt2_builder,
        &accel,
        &SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            workers: 2,
            cache_dir: Some(cache.clone()),
            ..Default::default()
        },
        |_, _| {},
    )
    .expect("unpruned reference");
    let full = run_cluster_sweep_outcome(
        &points,
        full_batch,
        &cluster_gpt2_builder,
        &accel,
        &cfg(true, false),
        |_, _| {},
    )
    .expect("pruned journaled run");
    assert!(full.is_clean(), "{:?}", full.failures);
    assert!(!full.skipped.is_empty(), "pruning never skipped — no Skipped records to cut at");
    assert_eq!(front_key(&unpruned.rows), front_key(&full.rows), "pruning moved the front");

    let jpath = dir.join(RUN_JOURNAL_FILE);
    let complete = std::fs::read(&jpath).expect("journal missing");
    let bounds = journal_record_bounds(&jpath).expect("journal unreadable");
    assert_eq!(
        bounds.len(),
        points.len() + 1,
        "every point must land one record, skipped points included"
    );
    let reference_front = front_key(&full.rows);
    for (k, &cut) in bounds.iter().enumerate() {
        std::fs::write(&jpath, &complete[..cut as usize]).unwrap();
        let out = run_cluster_sweep_outcome(
            &points,
            full_batch,
            &cluster_gpt2_builder,
            &accel,
            &cfg(true, true),
            |_, _| {},
        )
        .expect("pruned resume");
        assert!(out.is_clean(), "boundary {k}: {:?}", out.failures);
        assert_eq!(out.resumed, k, "boundary {k}: skip records must replay as resumed too");
        assert_eq!(
            out.rows.len() + out.skipped.len(),
            points.len(),
            "boundary {k}: every point accounted for"
        );
        assert_eq!(
            reference_front,
            front_key(&out.rows),
            "boundary {k}: resumed pruned front diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&cache).ok();
}

/// An injected panic on one point must not take down the sweep: the
/// point becomes a `PointFailure` carrying the panic message, every
/// other point's rows are bit-identical to a clean run, the failure is
/// journaled, and a resume (fault cleared) replays the failure rather
/// than re-evaluating or forgetting the point.
#[test]
fn injected_panic_is_isolated_journaled_and_replayed_on_resume() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (fwd, tg, points) = edge_fixture();
    let clean = run_sweep_outcome(
        &points,
        &fwd,
        &tg.graph,
        &SweepConfig { workers: 2, ..Default::default() },
        |_, _| {},
    )
    .expect("clean run");

    let k = 1usize;
    let dir = tmp_dir("panic_isolation");
    let cfg = |resume: bool| SweepConfig {
        workers: 2,
        run_dir: Some(dir.clone()),
        resume,
        ..Default::default()
    };
    let faulted = {
        let _plan = install(FaultPlan { panic_on_point: Some(k), ..Default::default() });
        run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(false), |_, _| {})
            .expect("faulted run must still complete")
    };
    assert_eq!(faulted.failures.len(), 1, "{:?}", faulted.failures);
    assert_eq!(faulted.failures[0].index, k);
    assert!(
        faulted.failures[0].diagnostic.contains("injected fault"),
        "diagnostic lost: {:?}",
        faulted.failures[0]
    );
    assert!(!faulted.failures[0].point_id.is_empty());
    // every surviving point is bit-identical to the clean run
    let expect: Vec<SweepRow> = clean.rows.iter().filter(|r| r.index != k).cloned().collect();
    sweep_rows_bit_eq(&expect, &faulted.rows, "panic isolation rows");

    // the journal holds one record per point — the failure included —
    // and a resume replays everything, panicking nowhere
    let bounds = journal_record_bounds(&dir.join(RUN_JOURNAL_FILE)).unwrap();
    assert_eq!(bounds.len(), points.len() + 1, "failed point must be journaled too");
    let resumed = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg(true), |_, _| {})
        .expect("resume after failure");
    assert_eq!(resumed.resumed, points.len());
    assert_eq!(resumed.failures, faulted.failures, "failure must replay, not vanish");
    sweep_rows_bit_eq(&faulted.rows, &resumed.rows, "resume after failure");
    std::fs::remove_dir_all(&dir).ok();
}

/// GA family: the per-generation checkpoint journal makes the
/// checkpointing search resumable from **every** generation boundary,
/// and each resume reproduces the uninterrupted front bit for bit.
#[test]
fn ga_front_resumes_bit_identically_from_every_generation_boundary() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let tg = build_training_graph(
        &mlp(1, 32, 64, 3, 10),
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel: Accelerator = EdgeTpuParams::baseline().build();
    let p = CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::default(),
        FusionConstraints::default(),
    );
    let ga = GaConfig { population: 8, generations: 3, workers: 1, ..Default::default() };
    let key = |v: &[CheckpointSolution]| {
        v.iter()
            .map(|s| {
                (
                    s.plan.clone(),
                    s.latency_cycles.to_bits(),
                    s.energy_pj.to_bits(),
                    s.stored_bytes_fp16,
                )
            })
            .collect::<Vec<_>>()
    };

    let dir = tmp_dir("ga_resume");
    let full = p.optimize_journaled(&ga, &dir, false);
    let jpath = dir.join(GA_JOURNAL_FILE);
    let complete = std::fs::read(&jpath).expect("GA journal missing");
    let bounds = journal_record_bounds(&jpath).unwrap();
    // one checkpoint after the initial evaluation + one per generation
    assert_eq!(bounds.len(), ga.generations + 2, "checkpoint cadence");

    for (g, &cut) in bounds.iter().enumerate() {
        std::fs::write(&jpath, &complete[..cut as usize]).unwrap();
        let resumed = p.optimize_journaled(&ga, &dir, true);
        assert_eq!(key(&full), key(&resumed), "GA resume from checkpoint {g} diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Deployment-genome GA family (`ga-cluster`): the same per-generation
/// checkpoint journal covers the cluster deployment search, on top of
/// the point journal covering its block-fallback backbone — so a run
/// killed at any GA generation boundary resumes to a final front (and
/// fallback baseline) bit-identical to the uninterrupted run. A cut
/// back to the bare journal header degrades to a fresh GA run over the
/// replayed backbone, still bit-identical.
#[test]
fn ga_cluster_front_resumes_bit_identically_from_every_generation_boundary() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fn tiny_builder(batch: usize) -> TrainingGraph {
        build_training_graph(&mlp(batch.max(1), 8, 16, 2, 4), TrainOptions::default())
    }
    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::datacenter(), 2)]);
    let ga: GaConfig<DeploymentGenome> =
        GaConfig { population: 8, generations: 3, workers: 2, ..Default::default() };
    let dir = tmp_dir("ga_cluster_resume");
    let cfg = |resume: bool| SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        workers: 2,
        run_dir: Some(dir.clone()),
        resume,
        ..Default::default()
    };

    let full =
        ga_cluster_search(&hc, &[2], 4, &tiny_builder, "tiny-mlp", &ga, &cfg(false), |_, _| {});
    assert!(full.failures.is_empty(), "{:?}", full.failures);
    assert!(!full.rows.is_empty() && !full.fallback_front.is_empty());

    let jpath = dir.join(GA_JOURNAL_FILE);
    let complete = std::fs::read(&jpath).expect("GA journal missing");
    let bounds = journal_record_bounds(&jpath).unwrap();
    // one checkpoint after the initial evaluation + one per generation
    assert_eq!(bounds.len(), ga.generations + 2, "checkpoint cadence");

    for (g, &cut) in bounds.iter().enumerate() {
        std::fs::write(&jpath, &complete[..cut as usize]).unwrap();
        let resumed =
            ga_cluster_search(&hc, &[2], 4, &tiny_builder, "tiny-mlp", &ga, &cfg(true), |_, _| {});
        assert!(resumed.resumed > 0, "backbone journal must replay (boundary {g})");
        assert_eq!(resumed.ga_resumed, g > 0, "checkpoint presence at boundary {g}");
        cluster_rows_bit_eq(&full.rows, &resumed.rows, &format!("ga-cluster front, boundary {g}"));
        cluster_rows_bit_eq(
            &full.fallback_front,
            &resumed.fallback_front,
            &format!("ga-cluster fallback front, boundary {g}"),
        );
        if g == bounds.len() - 1 {
            // the final checkpoint carries the whole surviving population:
            // nothing is re-evaluated
            assert_eq!(resumed.stats.evaluated, 0, "resume at the final boundary re-evaluated");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A snapshot corrupted on disk (injected byte-flip during the write)
/// must be rejected and quarantined on the next run — counted in
/// `CacheStats`, rows untouched — and the run then writes a fresh valid
/// snapshot that warm-loads cleanly afterwards.
#[test]
fn corrupt_snapshot_is_quarantined_and_the_run_recovers() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (fwd, tg, points) = edge_fixture();
    let dir = tmp_dir("snapshot_flip");
    let cfg = SweepConfig { workers: 2, cache_dir: Some(dir.clone()), ..Default::default() };
    let reference = run_sweep_outcome(
        &points,
        &fwd,
        &tg.graph,
        &SweepConfig { workers: 2, ..Default::default() },
        |_, _| {},
    )
    .expect("reference run");

    {
        let _plan = install(FaultPlan { flip_byte: Some(1234), ..Default::default() });
        run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |_, _| {}).expect("corrupting run");
    }
    assert!(dir.join(persist::COST_SNAPSHOT_FILE).exists(), "snapshot never written");

    let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |_, _| {})
        .expect("run over corrupt snapshot");
    assert!(out.cache.snapshots_rejected >= 1, "rejection uncounted: {:?}", out.cache);
    assert!(out.cache.snapshots_quarantined >= 1, "quarantine uncounted: {:?}", out.cache);
    let sidecar = dir.join(format!("{}.corrupt", persist::COST_SNAPSHOT_FILE));
    assert!(sidecar.exists(), "corrupt snapshot must be quarantined, not deleted");
    sweep_rows_bit_eq(&reference.rows, &out.rows, "rows after snapshot loss");

    // the run above re-persisted a valid snapshot: the next run is warm
    let warm = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |_, _| {})
        .expect("warm run");
    assert_eq!(warm.cache.misses, 0, "recovered snapshot did not warm-load: {:?}", warm.cache);
    assert_eq!(warm.cache.snapshots_rejected, 0, "{:?}", warm.cache);
    sweep_rows_bit_eq(&reference.rows, &warm.rows, "rows after recovery");
    std::fs::remove_dir_all(&dir).ok();
}

/// A transient IO failure on the snapshot write (injected: the first
/// write fails) must be retried with backoff — counted in
/// `CacheStats::io_retries` — and the snapshot still lands.
#[test]
fn transient_snapshot_write_failure_is_retried() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (fwd, tg, points) = edge_fixture();
    let dir = tmp_dir("write_retry");
    let cfg = SweepConfig { workers: 2, cache_dir: Some(dir.clone()), ..Default::default() };
    let out = {
        let _plan = install(FaultPlan { fail_write: Some(1), ..Default::default() });
        run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |_, _| {})
            .expect("run with failing first write")
    };
    assert!(out.is_clean(), "{:?}", out.failures);
    assert!(out.cache.io_retries >= 1, "retry uncounted: {:?}", out.cache);
    assert!(dir.join(persist::COST_SNAPSHOT_FILE).exists(), "retry never landed the snapshot");

    // and the retried snapshot is valid: the next run warm-loads it
    let warm = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |_, _| {})
        .expect("warm run");
    assert_eq!(warm.cache.misses, 0, "retried snapshot did not warm-load: {:?}", warm.cache);
    sweep_rows_bit_eq(&out.rows, &warm.rows, "rows across retried persist");
    std::fs::remove_dir_all(&dir).ok();
}

/// Concurrent snapshot writers to one `--cache-dir` — the serve daemon's
/// periodic checkpoint racing its shutdown persist, or two processes'
/// threads — must never publish a torn file. Each writer stages to a
/// unique tmp name (pid + per-process sequence, not pid alone: that
/// collides across threads) and publishes with an atomic rename, so the
/// surviving snapshot is exactly ONE writer's complete content, and no
/// tmp litter outlives the race.
#[test]
fn concurrent_snapshot_writers_never_publish_a_torn_file() {
    let _g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = tmp_dir("concurrent_persist");
    std::fs::create_dir_all(&dir).unwrap();
    const WRITERS: usize = 8;
    const KEYS: u128 = 64;
    const ROUNDS: usize = 10;
    // same key set per writer, writer-identifying values: a mixed file
    // would either fail validation or show two writers' values
    let caches: Vec<monet::eval::CostCache> = (0..WRITERS)
        .map(|t| {
            let c = monet::eval::CostCache::new();
            for k in 0..KEYS {
                c.insert_loaded(
                    k,
                    monet::cost::NodeCost {
                        cycles: (t as f64) * 1000.0 + k as f64,
                        ..Default::default()
                    },
                );
            }
            c
        })
        .collect();
    std::thread::scope(|s| {
        for c in &caches {
            let dir = dir.clone();
            s.spawn(move || {
                for _ in 0..ROUNDS {
                    persist::save_cost_cache(c, &dir).expect("save under contention");
                }
            });
        }
    });
    let loaded = persist::load_cost_cache(&dir, 0)
        .expect("published snapshot must load intact — a torn file would be rejected");
    let mut entries = loaded.export_entries();
    entries.sort_by_key(|(k, _)| *k);
    assert_eq!(entries.len(), KEYS as usize, "snapshot lost entries");
    let winner = (entries[0].1.cycles / 1000.0).floor() as usize;
    assert!(winner < WRITERS, "snapshot value from no writer: {}", entries[0].1.cycles);
    for (k, cost) in &entries {
        assert_eq!(
            cost.cycles,
            (winner as f64) * 1000.0 + *k as f64,
            "snapshot mixes two writers' content (key {k})"
        );
    }
    let litter: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.contains(".tmp."))
        .collect();
    assert!(litter.is_empty(), "atomic publish left tmp litter: {litter:?}");
    std::fs::remove_dir_all(&dir).ok();
}
