//! Integration pins for `monet serve`, the DSE-as-a-service daemon:
//!
//! * **bit-identity** — a query answered by the warm daemon (even under
//!   concurrent clients) is byte-identical to the same query run as a
//!   one-shot CLI command, for every design-space family;
//! * **robustness** — malformed requests get structured 4xx JSON errors,
//!   never a panic, and the daemon keeps serving afterwards;
//! * **observability** — repeated identical queries hit the resident
//!   cache (hits strictly grow, misses/entries stay put) without ever
//!   changing an answer;
//! * **pollable jobs** — `POST /jobs` + `GET /jobs/<id>` converge to the
//!   same answer as the blocking path, with progress that lands on
//!   done == total;
//! * **snapshot lifecycle** — graceful shutdown persists the cache
//!   snapshot, and a second daemon warm-loads it into pure hits.
//!
//! Each test boots its own daemon on an ephemeral loopback port, so the
//! binary is safe under the default parallel test runner.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use monet::eval::persist;
use monet::serve::{one_shot, OneShotOpts, ServeConfig, Server};
use monet::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("monet_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Minimal HTTP/1.1 client for the daemon's one-exchange-per-connection
/// protocol. Returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect to daemon");
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: monet\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("send request");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|w| w.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn boot(cfg: ServeConfig) -> (SocketAddr, thread::JoinHandle<()>) {
    let server = Server::bind(cfg).expect("bind daemon");
    let addr = server.local_addr();
    let handle = thread::spawn(move || server.run().expect("serve loop"));
    (addr, handle)
}

fn shutdown(addr: SocketAddr, handle: thread::JoinHandle<()>) {
    let (status, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200, "graceful shutdown must be acknowledged");
    handle.join().expect("daemon thread");
}

fn stat_f64(stats_body: &str, group: &str, key: &str) -> f64 {
    let j = Json::parse(stats_body).expect("stats body is JSON");
    match group {
        "" => j.get(key).and_then(|v| v.as_f64()),
        g => j.get(g).and_then(|c| c.get(key)).and_then(|v| v.as_f64()),
    }
    .unwrap_or_else(|| panic!("stats missing {group}/{key}: {stats_body}"))
}

/// One small query per design-space family — the whole serving surface.
const FAMILY_QUERIES: [&str; 4] = [
    r#"{"family":"sweep","stride":1500}"#,
    r#"{"family":"cluster","devices":2,"batch":2,"workload":"resnet18"}"#,
    r#"{"family":"hetero","device_classes":"edge:1,datacenter:1","batch":2,"microbatches":[2],"workload":"resnet18"}"#,
    r#"{"family":"ga-cluster","device_classes":"edge:2,datacenter:1","batch":2,"microbatches":[2],"workload":"resnet18","pop":4,"gens":2,"seed":7}"#,
];

/// The non-negotiable serving bar: for every family, the warm daemon —
/// answering all four families *concurrently*, twice — returns exactly
/// the bytes of the one-shot CLI path. Cache warmth may change speed,
/// never a byte.
#[test]
fn warm_daemon_answers_bit_identical_to_one_shot_for_every_family() {
    let opts = OneShotOpts { use_cache: true, cache_dir: None, cache_cap: 0 };
    let expected: Vec<String> = FAMILY_QUERIES
        .iter()
        .map(|q| one_shot(q, &opts).expect("one-shot reference run"))
        .collect();

    let (addr, handle) = boot(ServeConfig { serve_workers: 4, ..Default::default() });
    let ask_all = || -> Vec<(u16, String)> {
        let clients: Vec<_> = FAMILY_QUERIES
            .iter()
            .copied()
            .map(|q| thread::spawn(move || http(addr, "POST", "/query", q)))
            .collect();
        clients.into_iter().map(|c| c.join().expect("client thread")).collect()
    };

    for pass in ["cold", "warm"] {
        for (i, (status, body)) in ask_all().into_iter().enumerate() {
            assert_eq!(status, 200, "[{pass}] family {i}: {body}");
            assert_eq!(
                body, expected[i],
                "[{pass}] family query {i} drifted from the one-shot answer"
            );
        }
    }
    shutdown(addr, handle);
}

/// The `"prune"` key (default `true`) is part of the query surface:
/// bound-based pruning may only skip dominated rows, so for the
/// front-only cluster and hetero responses a pruned answer must be
/// byte-identical to an unpruned one — and the pruned daemon must stay
/// bit-identical to the one-shot `monet query` path.
#[test]
fn prune_key_never_changes_a_front_and_daemon_matches_one_shot() {
    let opts = OneShotOpts { use_cache: true, cache_dir: None, cache_cap: 0 };
    let (addr, handle) = boot(ServeConfig::default());
    for base in [
        r#""family":"cluster","devices":2,"batch":2,"workload":"resnet18""#,
        r#""family":"hetero","device_classes":"edge:1,datacenter:1","batch":2,"microbatches":[2],"workload":"resnet18""#,
    ] {
        let pruned = format!("{{{base},\"prune\":true}}");
        let full = format!("{{{base},\"prune\":false}}");
        let (status, pruned_daemon) = http(addr, "POST", "/query", &pruned);
        assert_eq!(status, 200, "pruned: {pruned_daemon}");
        let (status, full_daemon) = http(addr, "POST", "/query", &full);
        assert_eq!(status, 200, "unpruned: {full_daemon}");
        assert_eq!(pruned_daemon, full_daemon, "pruning changed a front for {{{base}}}");
        let reference = one_shot(&pruned, &opts).expect("one-shot pruned reference");
        assert_eq!(pruned_daemon, reference, "pruned daemon drifted from one-shot for {{{base}}}");
    }
    // a non-boolean prune is a structured 400, and the daemon survives it
    let (status, resp) = http(addr, "POST", "/query", r#"{"family":"sweep","prune":1}"#);
    assert_eq!(status, 400, "bad prune type: {resp}");
    assert_eq!(http(addr, "GET", "/healthz", "").0, 200);
    shutdown(addr, handle);
}

/// Arbitrary client input is a structured JSON error with the right
/// status — never a panic — and the daemon keeps serving afterwards.
#[test]
fn malformed_requests_get_structured_errors_never_panics() {
    let (addr, handle) = boot(ServeConfig::default());
    let bad_bodies = [
        "{not json",
        "[1,2,3]",
        r#"{"stride":20}"#,
        r#"{"family":"warp"}"#,
        r#"{"family":"sweep","stride":0}"#,
        r#"{"family":"sweep","strid":20}"#,
        r#"{"family":"cluster","devices":1000000000}"#,
        r#"{"family":"cluster","workload":"alexnet"}"#,
        r#"{"family":"hetero"}"#,
        r#"{"family":"hetero","device_classes":"edge:0"}"#,
        r#"{"family":"ga-cluster","device_classes":"edge:2","pop":1}"#,
        r#"{"family":"ga-cluster","device_classes":"edge:2","microbatches":[]}"#,
    ];
    for body in bad_bodies {
        let (status, resp) = http(addr, "POST", "/query", body);
        assert_eq!(status, 400, "case {body:?} → {resp}");
        let j = Json::parse(&resp).expect("error body must be JSON");
        let msg = j.get("error").and_then(|e| e.get("message")).and_then(|m| m.as_str());
        assert!(msg.is_some_and(|m| !m.is_empty()), "no error message in {resp}");
    }
    assert_eq!(http(addr, "GET", "/nope", "").0, 404);
    assert_eq!(http(addr, "DELETE", "/healthz", "").0, 405);
    assert_eq!(http(addr, "GET", "/query", "").0, 405);
    // after all that abuse the daemon still answers
    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert!(body.contains("ok"), "unhealthy after bad input: {body}");
    shutdown(addr, handle);
}

/// Repeating one identical query warms the resident cache: hits grow
/// strictly, misses and entries freeze after the first pass, and the
/// answer never changes by a byte.
#[test]
fn cache_stats_grow_monotonically_across_repeated_identical_queries() {
    let (addr, handle) = boot(ServeConfig::default());
    let q = r#"{"family":"cluster","devices":2,"batch":2,"workload":"resnet18"}"#;
    let stats = |label: &str| -> (f64, f64, f64) {
        let (status, body) = http(addr, "GET", "/stats", "");
        assert_eq!(status, 200, "{label}: {body}");
        (
            stat_f64(&body, "cache", "hits"),
            stat_f64(&body, "cache", "misses"),
            stat_f64(&body, "cache", "entries"),
        )
    };

    let mut first_answer: Option<String> = None;
    let mut prev = stats("before any query");
    for round in 0..3 {
        let (status, body) = http(addr, "POST", "/query", q);
        assert_eq!(status, 200, "round {round}: {body}");
        match &first_answer {
            None => first_answer = Some(body),
            Some(a) => assert_eq!(a, &body, "cache warmth changed the answer (round {round})"),
        }
        let cur = stats("after query");
        assert!(cur.0 >= prev.0 && cur.1 >= prev.1, "counters went backwards");
        if round > 0 {
            assert!(cur.0 > prev.0, "round {round}: an identical query must hit the warm cache");
            assert_eq!(cur.1, prev.1, "round {round}: a fully warm query must add no misses");
            assert_eq!(cur.2, prev.2, "round {round}: a fully warm query must add no entries");
        }
        prev = cur;
    }
    let (_, body) = http(addr, "GET", "/stats", "");
    assert_eq!(stat_f64(&body, "", "queries_done"), 3.0, "queries_done miscounted");
    shutdown(addr, handle);
}

/// The pollable path (`POST /jobs`, `GET /jobs/<id>`) converges to the
/// same answer as the blocking path, reports progress that lands on
/// done == total, and 404s unknown job ids.
#[test]
fn pollable_jobs_match_the_sync_answer_and_report_progress() {
    let (addr, handle) = boot(ServeConfig::default());
    let q = FAMILY_QUERIES[3]; // the GA family — what /jobs exists for
    let (status, sync_body) = http(addr, "POST", "/query", q);
    assert_eq!(status, 200, "sync reference: {sync_body}");

    let (status, accept) = http(addr, "POST", "/jobs", q);
    assert_eq!(status, 202, "job submit: {accept}");
    let j = Json::parse(&accept).expect("accept body is JSON");
    let poll = j.get("poll").and_then(|p| p.as_str()).expect("accept carries a poll path").to_string();

    let mut done_body = None;
    for _ in 0..600 {
        let (status, body) = http(addr, "GET", &poll, "");
        assert_eq!(status, 200, "poll: {body}");
        let j = Json::parse(&body).expect("poll body is JSON");
        match j.get("status").and_then(|s| s.as_str()) {
            Some("done") => {
                done_body = Some(body);
                break;
            }
            Some("queued" | "running") => thread::sleep(Duration::from_millis(100)),
            other => panic!("bad job status {other:?} in {body}"),
        }
    }
    let done_body = done_body.expect("job never finished within 60s");
    let j = Json::parse(&done_body).unwrap();
    let total = j.get("total").and_then(|v| v.as_f64()).unwrap();
    let done = j.get("done").and_then(|v| v.as_f64()).unwrap();
    assert!(total > 0.0 && done == total, "progress must land on done == total: {done_body}");
    // the nested result is the same JSON value the sync path returned
    // (Display is deterministic, so comparing renderings compares values)
    let job_result = j.get("result").expect("done job carries its result");
    let sync_value = Json::parse(&sync_body).unwrap();
    assert_eq!(
        format!("{job_result}"),
        format!("{sync_value}"),
        "job answer drifted from the sync answer"
    );
    assert_eq!(http(addr, "GET", "/jobs/999999", "").0, 404);
    shutdown(addr, handle);
}

/// Graceful shutdown is the persist point: with `checkpoint_every: 0`
/// nothing touches disk while serving, the snapshot lands on shutdown,
/// and a second daemon warm-loads it into pure hits — answering
/// bit-identically to the first.
#[test]
fn graceful_shutdown_persists_the_snapshot_and_a_second_daemon_warm_loads_it() {
    let dir = tmp_dir("daemon_snapshot");
    let cfg = ServeConfig {
        cache_dir: Some(dir.clone()),
        checkpoint_every: 0,
        ..Default::default()
    };
    let q = r#"{"family":"cluster","devices":2,"batch":2,"workload":"resnet18"}"#;

    let (addr, handle) = boot(cfg.clone());
    let (status, first) = http(addr, "POST", "/query", q);
    assert_eq!(status, 200, "first daemon: {first}");
    assert!(
        !dir.join(persist::COST_SNAPSHOT_FILE).exists(),
        "checkpoint_every=0 must not persist while serving"
    );
    shutdown(addr, handle);
    assert!(
        dir.join(persist::COST_SNAPSHOT_FILE).exists(),
        "graceful shutdown must persist the snapshot"
    );
    let snapshot = monet::eval::load_cost_cache(&dir, 0).expect("persisted snapshot loads");
    assert!(snapshot.stats().entries > 0, "snapshot must carry the resident entries");

    let (addr2, handle2) = boot(cfg);
    let (status, second) = http(addr2, "POST", "/query", q);
    assert_eq!(status, 200, "second daemon: {second}");
    assert_eq!(first, second, "warm-loaded daemon answer drifted from the cold one");
    let (_, stats) = http(addr2, "GET", "/stats", "");
    assert!(
        stat_f64(&stats, "cache", "hits") > 0.0,
        "the warm-loaded snapshot produced no hits: {stats}"
    );
    shutdown(addr2, handle2);
    std::fs::remove_dir_all(&dir).ok();
}
