//! Fixture suite for `monet-audit` (src/audit/, docs/AUDIT.md): every
//! rule family is proven on a known-bad fixture — failing with the right
//! rule id at the right file:line — plus a clean fixture that passes,
//! the tampered-manifest rejection, the `--bless` refusal at an
//! unchanged contract version, and the repo tip pinned audit-clean
//! against the checked-in `ci/contract_fingerprints.json`.

use std::fs;
use std::path::{Path, PathBuf};

use monet::audit::fingerprint::{self, Region, RegionSpec};
use monet::audit::{
    default_config, run_audit, AuditConfig, Finding, ItemSpec, RequiredScope, Rule, SourceTree,
};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("monet_audit_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(d.join("src")).unwrap();
    d
}

/// Write fixture sources (`rel` is relative to `<root>/`, e.g.
/// `src/lib.rs`) into a fresh temp root.
fn fixture(tag: &str, files: &[(&str, &str)]) -> PathBuf {
    let root = tmp_dir(tag);
    for (rel, text) in files {
        let p = root.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(p, text).unwrap();
    }
    root
}

fn active(findings: &[Finding]) -> Vec<&Finding> {
    findings.iter().filter(|f| f.is_active()).collect()
}

/// A one-region config over `src/lib.rs` with its own version const.
fn tiny_cfg() -> AuditConfig {
    AuditConfig {
        regions: vec![Region::new(
            "fixture.cost",
            "src/lib.rs",
            RegionSpec::Fns(vec!["node_cost".to_string()]),
        )],
        version_file: "src/lib.rs".to_string(),
        version_const: "CACHE_CONTRACT_VERSION".to_string(),
        required_scopes: vec![],
        module_allow: vec![],
    }
}

const LIB_V1: &str = "pub const CACHE_CONTRACT_VERSION: u32 = 1;\n\
                      pub fn node_cost(x: u64) -> u64 { x * 3 + 1 }\n";

#[test]
fn unbumped_contract_edit_is_cv01_at_the_region() {
    let root = fixture("cv01", &[("src/lib.rs", LIB_V1)]);
    let manifest = root.join("manifest.json");
    let cfg = tiny_cfg();

    let tree = SourceTree::load(&root).unwrap();
    fingerprint::bless(&tree, &cfg, &manifest).unwrap();
    assert!(active(&run_audit(&root, &cfg, &manifest).unwrap()).is_empty());

    // change the formula without bumping the version
    fs::write(
        root.join("src/lib.rs"),
        "pub const CACHE_CONTRACT_VERSION: u32 = 1;\n\
         pub fn node_cost(x: u64) -> u64 { x * 4 + 1 }\n",
    )
    .unwrap();
    let findings = run_audit(&root, &cfg, &manifest).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Cv01);
    assert_eq!(act[0].file, Path::new("src/lib.rs"));
    assert_eq!(act[0].line, 2, "CV01 must point at the changed region");
    assert!(act[0].message.contains("fixture.cost"));
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn doc_and_test_edits_never_trip_cv01() {
    let root = fixture("cv_docs", &[("src/lib.rs", LIB_V1)]);
    let manifest = root.join("manifest.json");
    let cfg = tiny_cfg();
    let tree = SourceTree::load(&root).unwrap();
    fingerprint::bless(&tree, &cfg, &manifest).unwrap();

    // comments, whitespace and `mod tests` additions are fingerprint-inert
    fs::write(
        root.join("src/lib.rs"),
        "pub const CACHE_CONTRACT_VERSION: u32 = 1;\n\
         /// documented now\n\
         pub fn node_cost(x: u64) -> u64 {\n    x * 3 + 1 // affine\n}\n\
         #[cfg(test)]\nmod tests { fn node_cost() {} }\n",
    )
    .unwrap();
    let findings = run_audit(&root, &cfg, &manifest).unwrap();
    assert!(active(&findings).is_empty(), "{findings:?}");
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn version_bump_with_stale_manifest_is_cv04_then_bless_clears() {
    let root = fixture("cv04", &[("src/lib.rs", LIB_V1)]);
    let manifest = root.join("manifest.json");
    let cfg = tiny_cfg();
    fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest).unwrap();

    // legit change: new formula AND a version bump — but manifest is stale
    fs::write(
        root.join("src/lib.rs"),
        "pub const CACHE_CONTRACT_VERSION: u32 = 2;\n\
         pub fn node_cost(x: u64) -> u64 { x * 5 }\n",
    )
    .unwrap();
    let findings = run_audit(&root, &cfg, &manifest).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Cv04);
    assert_eq!(act[0].line, 1, "CV04 points at the version const");

    // the documented workflow: bless after the bump, then check is clean
    fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest).unwrap();
    assert!(active(&run_audit(&root, &cfg, &manifest).unwrap()).is_empty());
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn bless_refuses_at_unchanged_version() {
    let root = fixture("bless_refuse", &[("src/lib.rs", LIB_V1)]);
    let manifest = root.join("manifest.json");
    let cfg = tiny_cfg();
    fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest).unwrap();

    fs::write(
        root.join("src/lib.rs"),
        "pub const CACHE_CONTRACT_VERSION: u32 = 1;\n\
         pub fn node_cost(x: u64) -> u64 { x }\n",
    )
    .unwrap();
    let err = fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest)
        .expect_err("bless at an unchanged version must refuse");
    assert!(err.contains("refusing"), "{err}");
    assert!(err.contains("fixture.cost"), "{err}");
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn tampered_manifest_is_cv02() {
    let root = fixture("cv02", &[("src/lib.rs", LIB_V1)]);
    let manifest = root.join("manifest.json");
    let cfg = tiny_cfg();
    fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest).unwrap();

    // flip one fingerprint nibble by hand — checksum catches it
    let text = fs::read_to_string(&manifest).unwrap();
    let pos = text.find("\"fixture.cost\":\"").unwrap() + "\"fixture.cost\":\"".len();
    let mut bytes = text.into_bytes();
    bytes[pos] = if bytes[pos] == b'0' { b'1' } else { b'0' };
    fs::write(&manifest, bytes).unwrap();

    let findings = run_audit(&root, &cfg, &manifest).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Cv02);
    assert!(act[0].message.contains("checksum"), "{}", act[0].message);

    // and bless refuses to silently overwrite the tampered file
    let err = fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest)
        .expect_err("bless over a tampered manifest must refuse");
    assert!(err.contains("invalid manifest"), "{err}");
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn missing_manifest_is_cv02() {
    let root = fixture("cv02_missing", &[("src/lib.rs", LIB_V1)]);
    let cfg = tiny_cfg();
    let findings = run_audit(&root, &cfg, &root.join("absent.json")).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Cv02);
    assert!(act[0].message.contains("--bless"), "{}", act[0].message);
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn unresolvable_region_is_cv03() {
    let root = fixture("cv03", &[("src/lib.rs", "pub fn other() {}")]);
    let mut cfg = tiny_cfg(); // names node_cost, which does not exist here
    cfg.version_file = String::new();
    let (_, findings) =
        fingerprint::compute(&SourceTree::load(&root).unwrap(), &cfg);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, Rule::Cv03);
    let _ = fs::remove_dir_all(&root);
}

/// A purity/determinism-only config (no regions → no manifest needed).
fn lint_cfg(required: Vec<RequiredScope>) -> AuditConfig {
    AuditConfig { required_scopes: required, ..Default::default() }
}

#[test]
fn impure_evaluate_impl_is_pu01_at_the_call() {
    let src = "\
use std::time::Instant;
// audit:pure
impl Evaluate for SweepEval {
    fn evaluate(&self, p: u64) -> u64 {
        let t = Instant::now();
        p + t.elapsed().as_nanos() as u64
    }
}
";
    let root = fixture("pu01", &[("src/lib.rs", src)]);
    let findings = run_audit(&root, &lint_cfg(vec![]), &root.join("m.json")).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Pu01);
    assert_eq!(act[0].file, Path::new("src/lib.rs"));
    assert_eq!(act[0].line, 5, "PU01 points at the Instant::now call");
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn missing_required_marker_is_pu02() {
    let root = fixture(
        "pu02",
        &[("src/lib.rs", "pub fn answer(q: u64) -> u64 { q }")],
    );
    let cfg = lint_cfg(vec![RequiredScope {
        file: "src/lib.rs".into(),
        item: ItemSpec::Fn("answer".into()),
    }]);
    let findings = run_audit(&root, &cfg, &root.join("m.json")).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Pu02);
    assert_eq!(act[0].line, 1);
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn partial_cmp_sort_is_dt01() {
    let src = "\
pub fn rank(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
";
    let root = fixture("dt01", &[("src/lib.rs", src)]);
    let findings = run_audit(&root, &lint_cfg(vec![]), &root.join("m.json")).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Dt01);
    assert_eq!(act[0].line, 2);
    assert!(act[0].message.contains("total_cmp"), "{}", act[0].message);
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn hashmap_order_leak_is_dt02_and_sorting_suppresses() {
    let src = "\
use std::collections::HashMap;
pub fn rows(m: &HashMap<u32, u32>) -> Vec<u32> {
    let mut out = Vec::new();
    for (_, v) in m.iter() {
        out.push(*v);
    }
    out
}
pub fn rows_sorted(m: &HashMap<u32, u32>) -> Vec<u32> {
    let mut v: Vec<u32> = m.values().copied().collect();
    v.sort_unstable();
    v
}
";
    let root = fixture("dt02", &[("src/lib.rs", src)]);
    let findings = run_audit(&root, &lint_cfg(vec![]), &root.join("m.json")).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Dt02);
    assert_eq!(act[0].line, 4, "only the unsorted iteration is flagged");
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn allow_marker_waives_with_reason_echoed_and_stale_allow_is_au01() {
    let src = "\
use std::collections::HashMap;
pub fn count(m: &HashMap<u32, u32>) -> u64 {
    let mut n = 0u64;
    // audit:allow(DT02): accumulation is a commutative integer sum
    for (_, v) in m.iter() {
        n += *v as u64;
    }
    n
}
// audit:allow(DT01): nothing here to waive
pub fn untouched() {}
";
    let root = fixture("allow", &[("src/lib.rs", src)]);
    let findings = run_audit(&root, &lint_cfg(vec![]), &root.join("m.json")).unwrap();
    let waived: Vec<&Finding> = findings.iter().filter(|f| !f.is_active()).collect();
    assert_eq!(waived.len(), 1, "{findings:?}");
    assert_eq!(waived[0].rule, Rule::Dt02);
    assert_eq!(
        waived[0].allowed.as_deref(),
        Some("accumulation is a commutative integer sum"),
        "the allow reason must be carried on the finding"
    );
    let act = active(&findings);
    assert_eq!(act.len(), 1, "{act:?}");
    assert_eq!(act[0].rule, Rule::Au01, "stale allow must be flagged");
    assert_eq!(act[0].line, 10);
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn malformed_markers_are_au01() {
    let src = "\
// audit:allow(DT02)
pub fn a() {}
// audit:allow(XX99): made-up rule
pub fn b() {}
// audit:allow(CV01): not waivable inline
pub fn c() {}
// audit:frobnicate
pub fn d() {}
";
    let root = fixture("au01", &[("src/lib.rs", src)]);
    let findings = run_audit(&root, &lint_cfg(vec![]), &root.join("m.json")).unwrap();
    let act = active(&findings);
    assert_eq!(act.len(), 4, "{act:?}");
    assert!(act.iter().all(|f| f.rule == Rule::Au01));
    assert_eq!(
        act.iter().map(|f| f.line).collect::<Vec<_>>(),
        vec![1, 3, 5, 7]
    );
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn clean_fixture_passes() {
    let src = "\
use std::collections::BTreeMap;
// audit:pure
pub fn node_cost(x: u64, weights: &BTreeMap<u64, u64>) -> u64 {
    weights.iter().map(|(k, v)| k * v).sum::<u64>() + x
}
";
    let root = fixture(
        "clean",
        &[("src/lib.rs", &format!("pub const CACHE_CONTRACT_VERSION: u32 = 1;\n{src}"))],
    );
    let manifest = root.join("manifest.json");
    let cfg = tiny_cfg();
    fingerprint::bless(&SourceTree::load(&root).unwrap(), &cfg, &manifest).unwrap();
    let findings = run_audit(&root, &cfg, &manifest).unwrap();
    assert!(active(&findings).is_empty(), "{findings:?}");
    let _ = fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------- repo tip

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn checked_in_manifest() -> PathBuf {
    repo_root().join("../ci/contract_fingerprints.json")
}

/// The acceptance bar: `monet-audit --check` exits 0 on the repo tip.
/// Every finding must be waived with a documented reason.
#[test]
fn repo_tip_is_audit_clean() {
    let findings =
        run_audit(&repo_root(), &default_config(), &checked_in_manifest()).unwrap();
    let act = active(&findings);
    assert!(
        act.is_empty(),
        "repo tip has active audit findings:\n{}",
        act.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
    for f in findings.iter().filter(|f| !f.is_active()) {
        assert!(
            f.allowed.as_deref().is_some_and(|r| !r.is_empty()),
            "waived finding without a reason: {f}"
        );
    }
}

/// The checked-in manifest must be exactly what `--bless` regenerates at
/// the current contract version — catches both drift and a stale bless.
#[test]
fn checked_in_manifest_matches_a_fresh_bless() {
    let tree = SourceTree::load(&repo_root()).unwrap();
    let cfg = default_config();
    let dir = tmp_dir("fresh_bless");
    let fresh = dir.join("manifest.json");
    fingerprint::bless(&tree, &cfg, &fresh).unwrap();
    let fresh_text = fs::read_to_string(&fresh).unwrap();
    let pinned = fs::read_to_string(checked_in_manifest()).unwrap();
    assert_eq!(
        fresh_text, pinned,
        "ci/contract_fingerprints.json is out of date — after a legitimate \
         CACHE_CONTRACT_VERSION bump, run `cargo run --bin monet_audit -- --bless`"
    );
    let _ = fs::remove_dir_all(&dir);
}

/// Bless→check round-trip over the real tree with a throwaway manifest:
/// the tool is self-consistent end-to-end regardless of the pinned file.
#[test]
fn bless_check_round_trip_on_repo_tree() {
    let dir = tmp_dir("round_trip");
    let manifest = dir.join("manifest.json");
    let tree = SourceTree::load(&repo_root()).unwrap();
    let cfg = default_config();
    let msg = fingerprint::bless(&tree, &cfg, &manifest).unwrap();
    assert!(msg.contains("created manifest"), "{msg}");
    let findings = fingerprint::check(&tree, &cfg, &manifest);
    assert!(findings.is_empty(), "{findings:?}");
    let _ = fs::remove_dir_all(&dir);
}
