//! Runtime round-trip tests: python-AOT HLO artifacts executed through the
//! rust PJRT client, cross-validated against the native rust cost model
//! and against known training behaviour. These tests need `make artifacts`
//! to have run; they are skipped (with a note) when artifacts are missing
//! so `cargo test` stays green on a fresh checkout.

use monet::dse::{accel_to_cfg, graph_to_layers};
use monet::hardware::presets::EdgeTpuParams;
use monet::runtime::{cost_eval_native, Corpus, CostKernel, Gpt2Runner, Runtime};
use monet::workload::models::resnet18;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/meta.json").exists() {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping runtime test");
        return None;
    }
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        // default (no-`pjrt`) builds compile the stub client whose
        // constructor always fails — artifacts present or not, there is
        // nothing to round-trip against, so skip rather than panic
        #[cfg(not(feature = "pjrt"))]
        Err(e) => {
            eprintln!("NOTE: PJRT runtime unavailable ({e}); skipping runtime test");
            None
        }
        // a real pjrt build with artifacts present must fail loudly: an
        // init error here is a regression, not a missing-artifact skip
        #[cfg(feature = "pjrt")]
        Err(e) => panic!("PJRT client init failed with artifacts present: {e}"),
    }
}

#[test]
fn cost_kernel_hlo_matches_native_model() {
    let Some(rt) = runtime() else { return };
    let kernel = CostKernel::load(&rt).expect("load cost_eval artifact");
    let g = resnet18(1, 32, 10);
    let layers = graph_to_layers(&g);
    let cfgs: Vec<_> = EdgeTpuParams::space_strided(61)
        .into_iter()
        .map(|p| accel_to_cfg(&p.build()))
        .collect();
    let hlo = kernel.eval(&cfgs, &layers).expect("kernel exec");
    let native = cost_eval_native(&cfgs, &layers);
    assert_eq!(hlo.len(), native.len());
    for (a, b) in hlo.iter().zip(&native) {
        let rel = (a.cycles - b.cycles).abs() / b.cycles.max(1.0);
        assert!(rel < 1e-4, "cycles diverge: {} vs {}", a.cycles, b.cycles);
        let rel_e = (a.energy_pj - b.energy_pj).abs() / b.energy_pj.max(1.0);
        assert!(rel_e < 1e-3, "energy diverges: {} vs {}", a.energy_pj, b.energy_pj);
        assert!((a.utilization - b.utilization).abs() < 1e-4);
    }
}

#[test]
fn pallas_and_ref_cost_artifacts_agree() {
    // the interpret-mode Pallas lowering and the pure-jnp lowering of the
    // same math must agree when run through PJRT
    let Some(rt) = runtime() else { return };
    let pallas = CostKernel::load(&rt).unwrap();
    let refk = CostKernel::load_ref(&rt).unwrap();
    let g = resnet18(1, 32, 10);
    let layers = graph_to_layers(&g);
    let cfgs: Vec<_> = EdgeTpuParams::space_strided(977)
        .into_iter()
        .map(|p| accel_to_cfg(&p.build()))
        .collect();
    let a = pallas.eval(&cfgs, &layers).unwrap();
    let b = refk.eval(&cfgs, &layers).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!(((x.cycles - y.cycles) / y.cycles.max(1.0)).abs() < 1e-5);
    }
}

#[test]
fn gpt2_first_loss_is_near_uniform() {
    // fresh model ≈ uniform predictor → loss ≈ ln(vocab) = ln(256) ≈ 5.55
    let Some(rt) = runtime() else { return };
    let runner = Gpt2Runner::load(&rt, "tiny").expect("load gpt2 artifacts");
    let m = runner.meta.clone();
    let mut corpus = Corpus::synthetic(m.vocab, 8192, 3);
    let tokens = corpus.next_batch(m.batch, m.seq + 1);
    let loss = runner.eval_loss(&tokens).expect("eval");
    let expect = (m.vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 0.6,
        "initial loss {loss} far from ln(vocab)={expect}"
    );
}

#[test]
fn gpt2_training_reduces_loss_through_aot_stack() {
    let Some(rt) = runtime() else { return };
    let mut runner = Gpt2Runner::load(&rt, "tiny").unwrap();
    let m = runner.meta.clone();
    let mut corpus = Corpus::synthetic(m.vocab, 16384, 9);
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..25 {
        let tokens = corpus.next_batch(m.batch, m.seq + 1);
        last = runner.step(&tokens).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.85,
        "25 steps should cut loss ≥15%: {first} → {last}"
    );
    assert_eq!(runner.step_count, 25);
}

#[test]
fn gpt2_eval_is_side_effect_free() {
    let Some(rt) = runtime() else { return };
    let runner = Gpt2Runner::load(&rt, "tiny").unwrap();
    let m = runner.meta.clone();
    let mut corpus = Corpus::synthetic(m.vocab, 8192, 5);
    let tokens = corpus.next_batch(m.batch, m.seq + 1);
    let a = runner.eval_loss(&tokens).unwrap();
    let b = runner.eval_loss(&tokens).unwrap();
    assert_eq!(a, b, "eval must not mutate parameters");
}
