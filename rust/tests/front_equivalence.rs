//! The front-equivalence harness pinning ROADMAP item 5 (bound-based
//! front pruning + incremental GA re-evaluation):
//!
//! * **pruned ≡ full fronts** — for every sweep family (single-device
//!   accelerator points, homogeneous cluster deployments, heterogeneous
//!   stage placements, the past-the-wall deployment GA), a run with
//!   bound-based pruning enabled produces a rank-0 Pareto front
//!   **bit-identical** to the full enumeration, at every worker count
//!   and cache temperature — pruning may only elide rows that are
//!   strictly dominated by a returned row;
//! * **surviving rows are untouched** — pruning must not change what
//!   gets computed (or cached) for the points it does not skip: every
//!   surviving row is bit-identical to the same point's row in the full
//!   run;
//! * **the skip set is deterministic** — the same points are skipped at
//!   1, 2 and 8 workers, cold or warm cache;
//! * **incremental ≡ full GA evaluation** — recycling warm
//!   `ClusterScratch` memos across genomes (the `ga-cluster` fast path)
//!   is bit-identical to evaluating every genome with a cold scratch, at
//!   **every generation boundary** (RNG state, population genomes and
//!   objective bits), not just in the final front.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use monet::autodiff::{build_training_graph, TrainOptions, TrainingGraph};
use monet::dse::{
    ga_cluster_search, pareto_front, run_cluster_sweep_outcome, run_hetero_sweep_outcome,
    run_sweep_outcome, ClusterRow, ClusterScratch, ClusterSpace, DesignPoint, Evaluate, HeteroEval,
    Mode, SweepConfig, SweepRow,
};
use monet::figures::{cluster_gpt2_builder, cluster_resnet18_builder};
use monet::ga::{
    nsga2_problem, pareto_rank0, DeploymentGenome, DeploymentProblem, GaCheckpoint, GaConfig,
};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{DeviceClass, HeteroCluster, LinkTier};
use monet::workload::models::{mlp, resnet18};
use monet::workload::op::Optimizer;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("monet_front_eq_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn sweep_rows_bit_eq(expect: &[SweepRow], got: &[SweepRow], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: row count");
    for (a, b) in expect.iter().zip(got) {
        assert_eq!(a.index, b.index, "{what}: index");
        assert_eq!(a.label, b.label, "{what}: label");
        assert_eq!(a.mode, b.mode, "{what}: mode");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits(), "{what}: latency");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{what}: energy");
        assert_eq!(a.peak_dram_bytes, b.peak_dram_bytes, "{what}: peak dram");
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{what}: utilization");
    }
}

fn cluster_rows_bit_eq(expect: &[ClusterRow], got: &[ClusterRow], what: &str) {
    assert_eq!(expect.len(), got.len(), "{what}: row count");
    for (a, b) in expect.iter().zip(got) {
        assert_eq!(a.index, b.index, "{what}: index");
        assert_eq!(a.label, b.label, "{what}: label");
        assert_eq!(a.placement, b.placement, "{what}: placement");
        assert_eq!(a.tier, b.tier, "{what}: tier");
        assert_eq!(a.devices, b.devices, "{what}: devices");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits(), "{what}: latency");
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{what}: energy");
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes, "{what}: mem");
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits(), "{what}: comm");
    }
}

/// Rank-0 front of a cluster-row set under the 4-objective dominance
/// set, as rows in `pareto_rank0`'s deterministic order.
fn rank0_rows(rows: &[ClusterRow]) -> Vec<ClusterRow> {
    let objs: Vec<Vec<f64>> = rows.iter().map(|r| r.objectives().to_vec()).collect();
    pareto_rank0(&objs).into_iter().map(|i| rows[i].clone()).collect()
}

fn mode_idx(m: Mode) -> usize {
    match m {
        Mode::Inference => 0,
        Mode::Training => 1,
    }
}

/// Per-mode 2-objective Pareto fronts of a single-device sweep (the
/// fronts `fig1`/`fig8` report), as rows in `pareto_front`'s order.
fn mode_fronts(rows: &[SweepRow]) -> Vec<Vec<SweepRow>> {
    [Mode::Inference, Mode::Training]
        .iter()
        .map(|&m| {
            let sub: Vec<SweepRow> = rows.iter().filter(|r| r.mode == m).cloned().collect();
            pareto_front(&sub).into_iter().map(|i| sub[i].clone()).collect()
        })
        .collect()
}

/// Single-device family: pruning thins the row set but the per-mode
/// Pareto fronts are bit-identical to the full enumeration, every
/// surviving row is bit-identical to the full run's row for the same
/// point, and the skip set is the same at every worker count and cache
/// temperature.
#[test]
fn pruned_single_device_fronts_are_bit_identical_per_mode() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
    );
    let points = DesignPoint::edge_space(3000);
    assert!(points.len() >= 2);

    let dir = tmp_dir("sweep");
    let full = run_sweep_outcome(
        &points,
        &fwd,
        &tg.graph,
        &SweepConfig { workers: 2, cache_dir: Some(dir.clone()), ..Default::default() },
        |_, _| {},
    )
    .expect("full sweep");
    assert!(full.is_clean(), "{:?}", full.failures);
    assert!(full.skipped.is_empty(), "prune off must never skip");
    let full_fronts = mode_fronts(&full.rows);
    let full_by_key: HashMap<(usize, usize), &SweepRow> =
        full.rows.iter().map(|r| ((r.index, mode_idx(r.mode)), r)).collect();

    let mut skip_set: Option<Vec<usize>> = None;
    // the full run above persisted a snapshot into `dir`, so the
    // cache_dir cells run warm; the `None` cells run on a cold
    // in-memory cache
    for workers in [1usize, 2, 8] {
        for cache_dir in [None, Some(dir.clone())] {
            let what = format!("sweep workers={workers} warm={}", cache_dir.is_some());
            let cfg = SweepConfig { workers, prune: true, cache_dir, ..Default::default() };
            let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |_, _| {})
                .expect("pruned sweep");
            assert!(out.is_clean(), "{what}: {:?}", out.failures);
            assert_eq!(
                out.rows.len() + 2 * out.skipped.len(),
                full.rows.len(),
                "{what}: rows + skipped points must account for the space"
            );
            for r in &out.rows {
                let reference = full_by_key
                    .get(&(r.index, mode_idx(r.mode)))
                    .unwrap_or_else(|| panic!("{what}: row for unknown point {}", r.index));
                sweep_rows_bit_eq(
                    std::slice::from_ref(*reference),
                    std::slice::from_ref(r),
                    &format!("{what}: surviving point {}", r.index),
                );
            }
            let got_fronts = mode_fronts(&out.rows);
            assert_eq!(full_fronts.len(), got_fronts.len(), "{what}: mode count");
            for (m, (e, g)) in full_fronts.iter().zip(&got_fronts).enumerate() {
                sweep_rows_bit_eq(e, g, &format!("{what}: mode-{m} Pareto front"));
            }
            match &skip_set {
                None => skip_set = Some(out.skipped.clone()),
                Some(s) => assert_eq!(s, &out.skipped, "{what}: skip set not deterministic"),
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Homogeneous cluster family on the tiny-GPT-2 deployment space — the
/// ROADMAP item 5 acceptance workload: the pruned run must skip at
/// least 30% of the space while the 4-objective rank-0 front stays
/// bit-identical to the full enumeration, across worker counts and
/// cache temperatures.
#[test]
fn pruned_gpt2_cluster_front_is_bit_identical_and_skips_a_third_of_the_space() {
    let space = ClusterSpace {
        device_counts: vec![4, 8],
        tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
        microbatches: vec![2, 4],
    };
    let points = space.enumerate();
    assert!(points.len() >= 10);
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let full_batch = 4usize;

    let dir = tmp_dir("cluster_gpt2");
    let full = run_cluster_sweep_outcome(
        &points,
        full_batch,
        &cluster_gpt2_builder,
        &accel,
        &SweepConfig { mapping, workers: 2, cache_dir: Some(dir.clone()), ..Default::default() },
        |_, _| {},
    )
    .expect("full cluster sweep");
    assert!(full.is_clean(), "{:?}", full.failures);
    assert!(full.skipped.is_empty(), "prune off must never skip");
    let full_front = rank0_rows(&full.rows);
    assert!(!full_front.is_empty());
    let full_by_index: HashMap<usize, &ClusterRow> =
        full.rows.iter().map(|r| (r.index, r)).collect();

    let mut skip_set: Option<Vec<usize>> = None;
    for (workers, cache_dir) in
        [(1usize, Some(dir.clone())), (2, Some(dir.clone())), (8, Some(dir.clone())), (8, None)]
    {
        let what = format!("gpt2 cluster workers={workers} warm={}", cache_dir.is_some());
        let cfg = SweepConfig { mapping, workers, prune: true, cache_dir, ..Default::default() };
        let out = run_cluster_sweep_outcome(
            &points,
            full_batch,
            &cluster_gpt2_builder,
            &accel,
            &cfg,
            |_, _| {},
        )
        .expect("pruned cluster sweep");
        assert!(out.is_clean(), "{what}: {:?}", out.failures);
        assert_eq!(out.rows.len() + out.skipped.len(), points.len(), "{what}: accounting");
        // the acceptance bar: the roofline bound retires >=30% of the
        // tiny-GPT-2 deployment space without scheduling it
        assert!(
            out.skipped.len() * 10 >= points.len() * 3,
            "{what}: skipped only {}/{} points (<30%)",
            out.skipped.len(),
            points.len()
        );
        for r in &out.rows {
            let reference = full_by_index
                .get(&r.index)
                .unwrap_or_else(|| panic!("{what}: row for unknown point {}", r.index));
            cluster_rows_bit_eq(
                std::slice::from_ref(*reference),
                std::slice::from_ref(r),
                &format!("{what}: surviving point {}", r.index),
            );
        }
        cluster_rows_bit_eq(
            &full_front,
            &rank0_rows(&out.rows),
            &format!("{what}: rank-0 front"),
        );
        match &skip_set {
            None => skip_set = Some(out.skipped.clone()),
            Some(s) => assert_eq!(s, &out.skipped, "{what}: skip set not deterministic"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Heterogeneous stage-placement family: same contract on the mixed
/// edge+datacenter pool — front bit-identity, surviving-row
/// bit-identity, deterministic skips.
#[test]
fn pruned_hetero_front_is_bit_identical_on_the_mixed_pool() {
    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::datacenter(), 2)]);
    let points = ClusterSpace::enumerate_hetero(&hc, &[2]);
    assert!(points.len() >= 4);
    let mapping = MappingConfig::edge_tpu_default();
    let full_batch = 4usize;

    let full = run_hetero_sweep_outcome(
        &points,
        &hc,
        full_batch,
        &cluster_resnet18_builder,
        &SweepConfig { mapping, workers: 2, ..Default::default() },
        |_, _| {},
    )
    .expect("full hetero sweep");
    assert!(full.is_clean(), "{:?}", full.failures);
    assert!(full.skipped.is_empty(), "prune off must never skip");
    let full_front = rank0_rows(&full.rows);
    let full_by_index: HashMap<usize, &ClusterRow> =
        full.rows.iter().map(|r| (r.index, r)).collect();

    let mut skip_set: Option<Vec<usize>> = None;
    for workers in [1usize, 2, 8] {
        let what = format!("hetero workers={workers}");
        let cfg = SweepConfig { mapping, workers, prune: true, ..Default::default() };
        let out = run_hetero_sweep_outcome(
            &points,
            &hc,
            full_batch,
            &cluster_resnet18_builder,
            &cfg,
            |_, _| {},
        )
        .expect("pruned hetero sweep");
        assert!(out.is_clean(), "{what}: {:?}", out.failures);
        assert_eq!(out.rows.len() + out.skipped.len(), points.len(), "{what}: accounting");
        for r in &out.rows {
            let reference = full_by_index
                .get(&r.index)
                .unwrap_or_else(|| panic!("{what}: row for unknown point {}", r.index));
            cluster_rows_bit_eq(
                std::slice::from_ref(*reference),
                std::slice::from_ref(r),
                &format!("{what}: surviving point {}", r.index),
            );
        }
        cluster_rows_bit_eq(
            &full_front,
            &rank0_rows(&out.rows),
            &format!("{what}: rank-0 front"),
        );
        match &skip_set {
            None => skip_set = Some(out.skipped.clone()),
            Some(s) => assert_eq!(s, &out.skipped, "{what}: skip set not deterministic"),
        }
    }
}

fn tiny_mlp_builder(batch: usize) -> TrainingGraph {
    build_training_graph(&mlp(batch.max(1), 8, 16, 2, 4), TrainOptions::default())
}

/// `ga-cluster` family: pruning the journaled backbone sweep must not
/// move the reported front or the block-fallback baseline by a bit —
/// skipped backbone rows are strictly dominated, so the rank-0 union
/// front and the GA's warm-start seeds are unchanged.
#[test]
fn pruned_ga_cluster_search_reports_the_same_front_and_baseline() {
    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::datacenter(), 2)]);
    let ga: GaConfig<DeploymentGenome> =
        GaConfig { population: 8, generations: 3, workers: 2, ..Default::default() };
    let cfg = |prune: bool| SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        workers: 2,
        prune,
        ..Default::default()
    };

    let full = ga_cluster_search(&hc, &[2], 4, &tiny_mlp_builder, "tiny-mlp", &ga, &cfg(false), |_, _| {});
    assert!(full.failures.is_empty(), "{:?}", full.failures);
    assert_eq!(full.skipped, 0, "prune off must never skip");

    let pruned = ga_cluster_search(&hc, &[2], 4, &tiny_mlp_builder, "tiny-mlp", &ga, &cfg(true), |_, _| {});
    assert!(pruned.failures.is_empty(), "{:?}", pruned.failures);
    cluster_rows_bit_eq(&full.rows, &pruned.rows, "ga-cluster rank-0 front");
    cluster_rows_bit_eq(&full.fallback_front, &pruned.fallback_front, "ga-cluster fallback front");
    assert!(
        pruned.evaluated <= full.evaluated,
        "pruning must not evaluate more points ({} > {})",
        pruned.evaluated,
        full.evaluated
    );
}

fn checkpoint_key(
    cps: &[GaCheckpoint<DeploymentGenome>],
) -> Vec<(usize, [u64; 4], Vec<(DeploymentGenome, Vec<u64>)>)> {
    cps.iter()
        .map(|cp| {
            (
                cp.generation,
                cp.rng,
                cp.population
                    .iter()
                    .map(|(g, o)| (g.clone(), o.iter().map(|v| v.to_bits()).collect()))
                    .collect(),
            )
        })
        .collect()
}

/// The incremental-evaluation half of ROADMAP item 5: the `ga-cluster`
/// eval closure recycles `ClusterScratch`es (training-graph memo,
/// balanced stage cuts, per-stage `StageEval` rows) through a pool, so
/// a mutant genome re-costs only the stage schedules it changed. A warm
/// memo must be bit-identical to a cold one — pinned here by running
/// NSGA-II twice over the same problem, once with a cold scratch per
/// genome and once with the pooled warm scratches, and comparing every
/// generation checkpoint (RNG state, population genomes, objective
/// bits) plus the final population.
#[test]
fn incremental_ga_evaluation_is_bit_identical_to_cold_scratch_evaluation() {
    let hc = HeteroCluster::new(vec![
        (DeviceClass::edge(), 2),
        (DeviceClass::server(), 2),
        (DeviceClass::datacenter(), 2),
    ]);
    let builder: &(dyn Fn(usize) -> TrainingGraph + Sync) = &tiny_mlp_builder;
    let heval = HeteroEval {
        hc: &hc,
        full_batch: 4,
        builder,
        mapping: MappingConfig::edge_tpu_default(),
    };
    let problem = DeploymentProblem { hc: &hc, microbatches: vec![2] };

    for workers in [1usize, 2] {
        let ga: GaConfig<DeploymentGenome> =
            GaConfig { population: 8, generations: 4, workers, ..Default::default() };

        // reference: every genome pays for a cold scratch
        let eval_cold = |g: &DeploymentGenome| {
            let p = ClusterSpace::genome_to_hetero(g);
            let mut scratch = heval.scratch();
            heval.evaluate(0, &p, None, &mut scratch)[0].objectives().to_vec()
        };
        let mut memo_cold = HashMap::new();
        let mut cps_cold: Vec<GaCheckpoint<DeploymentGenome>> = vec![];
        let (pop_cold, _) =
            nsga2_problem(&problem, &ga, eval_cold, &mut memo_cold, None, |cp| {
                cps_cold.push(cp.clone())
            });

        // incremental: warm scratches recycled through a pool, exactly
        // as `dse::search::ga_cluster_search` does
        let pool: Mutex<Vec<ClusterScratch>> = Mutex::new(Vec::new());
        let eval_warm = |g: &DeploymentGenome| {
            let p = ClusterSpace::genome_to_hetero(g);
            let mut scratch =
                pool.lock().ok().and_then(|mut v| v.pop()).unwrap_or_else(|| heval.scratch());
            let objs = heval.evaluate(0, &p, None, &mut scratch)[0].objectives().to_vec();
            if let Ok(mut v) = pool.lock() {
                v.push(scratch);
            }
            objs
        };
        let mut memo_warm = HashMap::new();
        let mut cps_warm: Vec<GaCheckpoint<DeploymentGenome>> = vec![];
        let (pop_warm, _) =
            nsga2_problem(&problem, &ga, eval_warm, &mut memo_warm, None, |cp| {
                cps_warm.push(cp.clone())
            });

        // the scratches really were recycled: far fewer scratches than
        // evaluations were ever built
        let pooled = pool.lock().unwrap().len();
        assert!(
            pooled <= workers.max(1) * 2 + 1,
            "workers={workers}: pool grew to {pooled} scratches — nothing was recycled"
        );

        assert_eq!(
            cps_cold.len(),
            ga.generations + 1,
            "workers={workers}: checkpoint cadence (init + one per generation)"
        );
        assert_eq!(
            checkpoint_key(&cps_cold),
            checkpoint_key(&cps_warm),
            "workers={workers}: a generation boundary diverged between cold and warm scratches"
        );
        assert_eq!(pop_cold.len(), pop_warm.len(), "workers={workers}: final population size");
        for (a, b) in pop_cold.iter().zip(&pop_warm) {
            assert_eq!(a.genome, b.genome, "workers={workers}: final population genome");
            let (oa, ob): (Vec<u64>, Vec<u64>) = (
                a.objectives.iter().map(|v| v.to_bits()).collect(),
                b.objectives.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(oa, ob, "workers={workers}: final population objectives");
        }
    }
}
