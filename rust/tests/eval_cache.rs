//! Integration tests for the memoized, parallel evaluation engine
//! (`eval::CostCache` + parallel NSGA-II): cached and uncached pipelines
//! must be *bit-identical* on real training graphs, and GA results must be
//! independent of the worker count. These pin the `eval` module's
//! cache-key soundness contract (see `src/eval/mod.rs`).

use monet::autodiff::{
    apply_checkpointing, build_training_graph, checkpoint_candidates, CheckpointPlan,
    TrainOptions,
};
use monet::eval::{persist, CacheStats, CostCache};
use monet::fusion::{fuse_greedy, FusionConstraints};
use monet::ga::{CheckpointProblem, GaConfig};
use monet::hardware::presets::{EdgeTpuParams, FuseMaxParams};
use monet::mapping::MappingConfig;
use monet::scheduler::{schedule, schedule_with_cache, Partition, ScheduleResult};
use monet::util::proptest::{check, BitMask, UsizeIn};
use monet::workload::models::{gpt2, mlp, resnet18, Gpt2Config};
use monet::workload::op::Optimizer;

/// Bit-level equality of everything a `ScheduleResult` reports.
fn bit_identical(a: &ScheduleResult, b: &ScheduleResult) -> bool {
    a.latency_cycles.to_bits() == b.latency_cycles.to_bits()
        && a.energy_pj.to_bits() == b.energy_pj.to_bits()
        && a.peak_dram_bytes == b.peak_dram_bytes
        && a.offchip_bytes.to_bits() == b.offchip_bytes.to_bits()
        && a.n_groups == b.n_groups
        && a.core_busy.len() == b.core_busy.len()
        && a
            .core_busy
            .iter()
            .zip(&b.core_busy)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && a
            .phase_busy
            .iter()
            .zip(&b.phase_busy)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_cached_schedule_bit_identical_resnet18_training() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let cands = checkpoint_candidates(&tg);
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    // one cache across every case: entries written by one plan's schedule
    // must stay valid for structurally-equal groups of every other plan
    let cache = CostCache::new();
    check(6, &BitMask { width: cands.len(), p: 0.3 }, |mask| {
        let plan = CheckpointPlan::recompute_set(
            cands.iter().zip(mask).filter(|(_, &bit)| bit).map(|(&n, _)| n),
        );
        let g = apply_checkpointing(&tg, &plan);
        let p = fuse_greedy(&g, &FusionConstraints::default());
        let plain = schedule(&g, &p, &accel, &mapping);
        let cached = schedule_with_cache(&g, &p, &accel, &mapping, Some(&cache));
        bit_identical(&plain, &cached)
    });
    let s = cache.stats();
    assert!(s.hits > 0, "cross-plan cache sharing never hit: {s:?}");
}

#[test]
fn prop_cached_schedule_bit_identical_gpt2_training_across_accelerators() {
    let fwd = gpt2(Gpt2Config::tiny());
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let greedy = fuse_greedy(&tg.graph, &FusionConstraints::default());
    let singles = Partition::singletons(&tg.graph);
    let space = FuseMaxParams::space_strided(97);
    let mapping = MappingConfig::fusemax_default();
    let cache = CostCache::new();
    check(6, &UsizeIn(0, space.len() - 1), |&i| {
        let accel = space[i].build();
        [&greedy, &singles].iter().all(|&p| {
            let plain = schedule(&tg.graph, p, &accel, &mapping);
            let cached = schedule_with_cache(&tg.graph, p, &accel, &mapping, Some(&cache));
            bit_identical(&plain, &cached)
        })
    });
    assert!(cache.stats().hits > 0);
}

#[test]
fn checkpoint_ga_identical_across_1_4_8_workers() {
    let fwd = mlp(1, 32, 64, 3, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let run = |workers: usize| {
        let problem = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let ga = GaConfig { population: 12, generations: 6, workers, ..Default::default() };
        problem
            .optimize(&ga)
            .into_iter()
            .map(|s| {
                (
                    s.plan,
                    s.latency_cycles.to_bits(),
                    s.energy_pj.to_bits(),
                    s.stored_bytes_fp16,
                )
            })
            .collect::<Vec<_>>()
    };
    let serial = run(1);
    assert!(!serial.is_empty());
    assert_eq!(serial, run(4), "4-worker GA diverged from serial");
    assert_eq!(serial, run(8), "8-worker GA diverged from serial");
}

#[test]
fn cluster_sweep_cached_and_uncached_agree_bitwise() {
    // the cluster DSE's inner stage schedules ride the same cost cache as
    // the single-device sweeps; sharing entries across DP/PP/TP
    // factorizations and link tiers must never change a single bit of any
    // row (the eval soundness contract, extended to deployment points)
    use monet::dse::{run_cluster_sweep, ClusterSpace, SweepConfig};
    use monet::parallelism::LinkTier;

    let space = ClusterSpace {
        device_counts: vec![1, 2, 4],
        tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
        microbatches: vec![2],
    };
    let points = space.enumerate();
    assert!(points.len() >= 12);
    let accel = EdgeTpuParams::baseline().build();
    let run = |use_cache: bool| {
        run_cluster_sweep(
            &points,
            8,
            &monet::figures::cluster_resnet18_builder,
            &accel,
            &SweepConfig {
                mapping: MappingConfig::edge_tpu_default(),
                use_cache,
                workers: 4,
                ..Default::default()
            },
            |_, _| {},
        )
    };
    let (cached, stats) = run(true);
    let (plain, no_stats) = run(false);
    assert!(
        stats.hits > 0,
        "factorizations sharing stage shapes never hit the cache: {stats:?}"
    );
    assert_eq!(no_stats, CacheStats::default());
    assert_eq!(cached.len(), plain.len());
    for (a, b) in cached.iter().zip(&plain) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes);
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits());
    }
}

#[test]
fn hetero_sweep_cached_and_uncached_agree_bitwise() {
    // the heterogeneous cluster DSE threads stage placements through the
    // same cost cache — per-class accelerators key their own entries via
    // the structural core-class hash, and sharing them across placements
    // and factorizations must never change a single bit of any row
    use monet::dse::{run_hetero_sweep, ClusterSpace, SweepConfig};
    use monet::parallelism::{DeviceClass, HeteroCluster};

    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::datacenter(), 2)]);
    let points = ClusterSpace::enumerate_hetero(&hc, &[2]);
    assert!(points.iter().any(|p| p.is_mixed()), "space must contain mixed placements");
    let run = |use_cache: bool| {
        run_hetero_sweep(
            &points,
            &hc,
            4,
            &monet::figures::cluster_resnet18_builder,
            &SweepConfig {
                mapping: MappingConfig::edge_tpu_default(),
                use_cache,
                workers: 4,
                ..Default::default()
            },
            |_, _| {},
        )
    };
    let (cached, stats) = run(true);
    let (plain, no_stats) = run(false);
    assert!(stats.hits > 0, "placements sharing stage shapes never hit the cache: {stats:?}");
    assert_eq!(no_stats, CacheStats::default());
    assert_eq!(cached.len(), plain.len());
    for (a, b) in cached.iter().zip(&plain) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes);
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits());
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("monet_eval_cache_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn persisted_hetero_sweep_is_bit_identical_and_warm_on_restart() {
    // a heterogeneous sweep restarted against its own snapshot recomputes
    // nothing and replays every row bit for bit — the persistence
    // lifecycle extended to placement-keyed entries (stale snapshots from
    // older contracts are rejected wholesale by the persist-layer tests)
    use monet::dse::{run_hetero_sweep, ClusterSpace, SweepConfig};
    use monet::parallelism::{DeviceClass, HeteroCluster};

    let dir = tmp_dir("hetero");
    let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 1), (DeviceClass::datacenter(), 1)]);
    let points = ClusterSpace::enumerate_hetero(&hc, &[2]);
    let cfg = SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        workers: 2,
        cache_dir: Some(dir.clone()),
        ..Default::default()
    };
    let run = || {
        run_hetero_sweep(
            &points,
            &hc,
            4,
            &monet::figures::cluster_resnet18_builder,
            &cfg,
            |_, _| {},
        )
    };
    let (r1, _s1) = run();
    let (r2, s2) = run();
    assert_eq!(s2.misses, 0, "warm hetero run recomputed group costs: {s2:?}");
    assert_eq!(r1.len(), r2.len());
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn evicting_cache_is_bit_identical_and_bounded() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let p = fuse_greedy(&tg.graph, &FusionConstraints::default());
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let plain = schedule(&tg.graph, &p, &accel, &mapping);
    // a capacity this small evicts constantly on a training graph — the
    // CLOCK policy may only ever cost re-computation, never correctness
    let cache = CostCache::with_capacity(32);
    let first = schedule_with_cache(&tg.graph, &p, &accel, &mapping, Some(&cache));
    let second = schedule_with_cache(&tg.graph, &p, &accel, &mapping, Some(&cache));
    assert!(bit_identical(&plain, &first), "evicting cache diverged (first run)");
    assert!(bit_identical(&plain, &second), "evicting cache diverged (second run)");
    let s = cache.stats();
    assert!(s.evictions > 0, "capacity 32 never evicted on a training graph: {s:?}");
    assert!(s.entries <= 32, "CLOCK exceeded its bound: {s:?}");
}

#[test]
fn persisted_cache_round_trip_is_bit_identical_and_all_hits() {
    let dir = tmp_dir("roundtrip");
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let p = fuse_greedy(&tg.graph, &FusionConstraints::default());
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();

    // cold process: open (no snapshot yet), fill, persist
    let cold_cache = persist::open_cost_cache(Some(&dir), 0);
    assert_eq!(cold_cache.stats().entries, 0);
    let cold = schedule_with_cache(&tg.graph, &p, &accel, &mapping, Some(&cold_cache));
    persist::save_cost_cache(&cold_cache, &dir).unwrap();

    // "restarted" process: warm-load and re-run — bit-identical, zero
    // recomputation
    let warm_cache = persist::load_cost_cache(&dir, 0).expect("snapshot must load");
    assert_eq!(warm_cache.stats().entries, cold_cache.stats().entries);
    let warm = schedule_with_cache(&tg.graph, &p, &accel, &mapping, Some(&warm_cache));
    assert!(bit_identical(&cold, &warm), "warm-loaded cache diverged from cold run");
    let ws = warm_cache.stats();
    assert_eq!(ws.misses, 0, "warm-loaded cache recomputed group costs: {ws:?}");
    assert!(ws.hits > 0);

    // a warm load into a *bounded* cache still reproduces the run exactly
    let bounded = persist::load_cost_cache(&dir, 32).expect("bounded load");
    assert!(bounded.stats().entries <= 32);
    let br = schedule_with_cache(&tg.graph, &p, &accel, &mapping, Some(&bounded));
    assert!(bit_identical(&cold, &br), "bounded warm cache diverged");

    // corruption is rejected wholesale, never half-loaded
    let path = dir.join(persist::COST_SNAPSHOT_FILE);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    assert!(persist::load_cost_cache(&dir, 0).is_none());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ga_warm_start_round_trips_and_resumes() {
    let dir = tmp_dir("ga_warm");
    let fwd = mlp(1, 32, 64, 3, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let ga = GaConfig { population: 10, generations: 3, workers: 2, ..Default::default() };

    let problem = CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::default(),
        FusionConstraints::default(),
    );
    let front = problem.optimize_persistent(&ga, &dir);
    assert!(!front.is_empty());

    // the persisted warm-start holds exactly the front as seeds, plus a
    // non-empty memo, under this problem's structural key
    let key = problem.warm_key();
    let width = problem.candidates.len();
    let warm = persist::load_ga_warmstart(&dir, key, width).expect("warm-start file");
    assert_eq!(warm.seeds.len(), front.len());
    assert!(!warm.memo.is_empty());
    for (sol, seed) in front.iter().zip(&warm.seeds) {
        assert_eq!(&problem.plan_to_genome(&sol.plan), seed);
    }
    // a different problem key or width must never warm-start from it
    assert!(persist::load_ga_warmstart(&dir, key ^ 1, width).is_none());
    assert!(persist::load_ga_warmstart(&dir, key, width + 1).is_none());

    // the key must separate same-topology, different-shape workloads:
    // this mlp has identical node/edge/candidate counts but a wider
    // hidden layer — replaying the memo's objective values against it
    // would silently corrupt the front
    let fwd_wide = mlp(1, 32, 128, 3, 10);
    let tg_wide = build_training_graph(
        &fwd_wide,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let problem_wide = CheckpointProblem::new(
        &tg_wide,
        &accel,
        MappingConfig::default(),
        FusionConstraints::default(),
    );
    assert_eq!(problem_wide.candidates.len(), width, "test premise: same genome width");
    assert_ne!(problem_wide.warm_key(), key, "layer shapes must be part of the warm key");

    // a restarted run resumes: every previous front point is already in
    // its memo, so re-optimizing returns a front at least as good on the
    // anchor plan, and completes without recomputing the seeds
    let problem2 = CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::default(),
        FusionConstraints::default(),
    );
    assert_eq!(problem2.warm_key(), key, "warm key must be stable across instances");
    let front2 = problem2.optimize_persistent(&ga, &dir);
    assert!(!front2.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warm_problem_reevaluates_known_plans_from_cache() {
    // an NSGA-II run followed by re-evaluation of its own front: every
    // transform is memoized, so the second pass adds no misses beyond the
    // schedule-level lookups (which all hit)
    let fwd = mlp(1, 32, 64, 3, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let problem = CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::default(),
        FusionConstraints::default(),
    );
    let ga = GaConfig { population: 10, generations: 4, workers: 2, ..Default::default() };
    let front = problem.optimize(&ga);
    let warm_before = problem.cache_stats();
    for sol in &front {
        let (lat, en, mem) = problem.evaluate(&sol.plan);
        assert_eq!(lat.to_bits(), sol.latency_cycles.to_bits());
        assert_eq!(en.to_bits(), sol.energy_pj.to_bits());
        assert_eq!(mem, sol.stored_bytes_fp16);
    }
    let warm_after = problem.cache_stats();
    assert_eq!(
        warm_before.misses, warm_after.misses,
        "re-evaluating known plans must not recompute any group cost"
    );
    assert!(warm_after.hits > warm_before.hits);
}
