//! Integration tests for the memoized, parallel evaluation engine
//! (`eval::CostCache` + parallel NSGA-II): cached and uncached pipelines
//! must be *bit-identical* on real training graphs, and GA results must be
//! independent of the worker count. These pin the `eval` module's
//! cache-key soundness contract (see `src/eval/mod.rs`).

use monet::autodiff::{
    apply_checkpointing, build_training_graph, checkpoint_candidates, CheckpointPlan,
    TrainOptions,
};
use monet::eval::CostCache;
use monet::fusion::{fuse_greedy, FusionConstraints};
use monet::ga::{CheckpointProblem, GaConfig};
use monet::hardware::presets::{EdgeTpuParams, FuseMaxParams};
use monet::mapping::MappingConfig;
use monet::scheduler::{schedule, schedule_with_cache, Partition, ScheduleResult};
use monet::util::proptest::{check, BitMask, UsizeIn};
use monet::workload::models::{gpt2, mlp, resnet18, Gpt2Config};
use monet::workload::op::Optimizer;

/// Bit-level equality of everything a `ScheduleResult` reports.
fn bit_identical(a: &ScheduleResult, b: &ScheduleResult) -> bool {
    a.latency_cycles.to_bits() == b.latency_cycles.to_bits()
        && a.energy_pj.to_bits() == b.energy_pj.to_bits()
        && a.peak_dram_bytes == b.peak_dram_bytes
        && a.offchip_bytes.to_bits() == b.offchip_bytes.to_bits()
        && a.n_groups == b.n_groups
        && a.core_busy.len() == b.core_busy.len()
        && a
            .core_busy
            .iter()
            .zip(&b.core_busy)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && a
            .phase_busy
            .iter()
            .zip(&b.phase_busy)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_cached_schedule_bit_identical_resnet18_training() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let cands = checkpoint_candidates(&tg);
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    // one cache across every case: entries written by one plan's schedule
    // must stay valid for structurally-equal groups of every other plan
    let cache = CostCache::new();
    check(6, &BitMask { width: cands.len(), p: 0.3 }, |mask| {
        let plan = CheckpointPlan::recompute_set(
            cands.iter().zip(mask).filter(|(_, &bit)| bit).map(|(&n, _)| n),
        );
        let g = apply_checkpointing(&tg, &plan);
        let p = fuse_greedy(&g, &FusionConstraints::default());
        let plain = schedule(&g, &p, &accel, &mapping);
        let cached = schedule_with_cache(&g, &p, &accel, &mapping, Some(&cache));
        bit_identical(&plain, &cached)
    });
    let s = cache.stats();
    assert!(s.hits > 0, "cross-plan cache sharing never hit: {s:?}");
}

#[test]
fn prop_cached_schedule_bit_identical_gpt2_training_across_accelerators() {
    let fwd = gpt2(Gpt2Config::tiny());
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let greedy = fuse_greedy(&tg.graph, &FusionConstraints::default());
    let singles = Partition::singletons(&tg.graph);
    let space = FuseMaxParams::space_strided(97);
    let mapping = MappingConfig::fusemax_default();
    let cache = CostCache::new();
    check(6, &UsizeIn(0, space.len() - 1), |&i| {
        let accel = space[i].build();
        [&greedy, &singles].iter().all(|&p| {
            let plain = schedule(&tg.graph, p, &accel, &mapping);
            let cached = schedule_with_cache(&tg.graph, p, &accel, &mapping, Some(&cache));
            bit_identical(&plain, &cached)
        })
    });
    assert!(cache.stats().hits > 0);
}

#[test]
fn checkpoint_ga_identical_across_1_4_8_workers() {
    let fwd = mlp(1, 32, 64, 3, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let run = |workers: usize| {
        let problem = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let ga = GaConfig { population: 12, generations: 6, workers, ..Default::default() };
        problem
            .optimize(&ga)
            .into_iter()
            .map(|s| {
                (
                    s.plan,
                    s.latency_cycles.to_bits(),
                    s.energy_pj.to_bits(),
                    s.stored_bytes_fp16,
                )
            })
            .collect::<Vec<_>>()
    };
    let serial = run(1);
    assert!(!serial.is_empty());
    assert_eq!(serial, run(4), "4-worker GA diverged from serial");
    assert_eq!(serial, run(8), "8-worker GA diverged from serial");
}

#[test]
fn warm_problem_reevaluates_known_plans_from_cache() {
    // an NSGA-II run followed by re-evaluation of its own front: every
    // transform is memoized, so the second pass adds no misses beyond the
    // schedule-level lookups (which all hit)
    let fwd = mlp(1, 32, 64, 3, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let problem = CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::default(),
        FusionConstraints::default(),
    );
    let ga = GaConfig { population: 10, generations: 4, workers: 2, ..Default::default() };
    let front = problem.optimize(&ga);
    let warm_before = problem.cache_stats();
    for sol in &front {
        let (lat, en, mem) = problem.evaluate(&sol.plan);
        assert_eq!(lat.to_bits(), sol.latency_cycles.to_bits());
        assert_eq!(en.to_bits(), sol.energy_pj.to_bits());
        assert_eq!(mem, sol.stored_bytes_fp16);
    }
    let warm_after = problem.cache_stats();
    assert_eq!(
        warm_before.misses, warm_after.misses,
        "re-evaluating known plans must not recompute any group cost"
    );
    assert!(warm_after.hits > warm_before.hits);
}
