//! Regression tests for the scheduler's memory/energy accounting fixes:
//!
//! * peak-DRAM lifetimes are per *tensor*, not per edge — a tensor with k
//!   consumers is one allocation, freed at its last consumer;
//! * inter-group transfer energy is charged only when producer and
//!   consumer actually land on different cores;
//! * sink outputs stay in DRAM instead of paying bus/global traffic;
//! * one NaN objective cannot abort a GA run or a sweep's Pareto scan.
//!
//! The hand-built graphs are small enough that the expected numbers are
//! computable by hand, so each test pins an exact oracle that the pre-fix
//! accounting violates.

use monet::ga::{nsga2, GaConfig};
use monet::hardware::accelerator::{Accelerator, Interconnect};
use monet::hardware::core::{Core, Dataflow};
use monet::hardware::energy::E_IDLE_PJ_PER_CYCLE;
use monet::mapping::MappingConfig;
use monet::scheduler::{schedule, Partition};
use monet::workload::graph::Graph;
use monet::workload::op::{EltwiseKind, OpKind, Phase};

fn relu(elems: u64) -> OpKind {
    OpKind::Eltwise { kind: EltwiseKind::Relu, elems, arity: 1 }
}

/// A minimal HDA with `n` identical SIMD cores and no global buffer.
fn simd_accel(n: usize) -> Accelerator {
    let cores = (0..n)
        .map(|id| Core {
            id,
            name: format!("pe{id}"),
            dataflow: Dataflow::Simd { lanes: 64 },
            local_mem_bytes: 1 << 20,
            regfile_bytes: 16 << 10,
            onchip_bw: 128.0,
        })
        .collect();
    Accelerator {
        name: format!("{n}core"),
        cores,
        interconnect: Interconnect { link_bw: 64.0, link_energy_pj: 0.8 },
        global_buffer_bytes: 0,
        global_buffer_bw: 0.0,
        offchip_bw: 64.0,
        clock_ghz: 1.0,
    }
}

/// Idle energy the scheduler adds on top of per-group energies.
fn idle_energy(latency: f64, n_cores: usize) -> f64 {
    E_IDLE_PJ_PER_CYCLE * latency * n_cores as f64
}

#[test]
fn multi_consumer_tensor_peaks_at_one_allocation() {
    // a --(1000B)--> {b, c, d}: one tensor, three consumer groups. The
    // exact oracle: peak DRAM = 1000 bytes, live from a's finish to the
    // last consumer's finish. The pre-fix per-edge accounting allocated
    // it once per edge and peaked at 3000.
    let mut g = Graph::new();
    let a = g.add_node("a", relu(256), Phase::Forward);
    for i in 0..3 {
        let c = g.add_node(format!("c{i}"), relu(256), Phase::Forward);
        g.add_edge(a, c, 1000);
    }
    let p = Partition::singletons(&g);
    let r = schedule(&g, &p, &simd_accel(4), &MappingConfig::default());
    assert_eq!(r.peak_dram_bytes, 1000, "multi-consumer tensor must be one allocation");
}

#[test]
fn chained_tensors_overlap_exactly_where_lifetimes_overlap() {
    // a -> b -> c, distinct tensor sizes: on one core the groups run
    // sequentially, so a's tensor (alive until b finishes) and b's tensor
    // (allocated when b finishes) never coexist *except* at the tie
    // instant, where frees sort first. Exact oracle: max(1000, 600).
    let mut g = Graph::new();
    let a = g.add_node("a", relu(256), Phase::Forward);
    let b = g.add_node("b", relu(256), Phase::Forward);
    let c = g.add_node("c", relu(256), Phase::Forward);
    g.add_edge(a, b, 1000);
    g.add_edge(b, c, 600);
    let p = Partition::singletons(&g);
    let r = schedule(&g, &p, &simd_accel(1), &MappingConfig::default());
    assert_eq!(r.peak_dram_bytes, 1000);
}

#[test]
fn same_core_chain_pays_no_link_energy() {
    // one core: every group lands on it, so the producer→consumer tensor
    // never crosses the bus and the schedule's energy must be exactly
    // sum(group energies) + idle — no inter-group transfer term. Pre-fix,
    // every cross-group edge was charged link energy unconditionally.
    let mut g = Graph::new();
    let a = g.add_node("a", relu(4096), Phase::Forward);
    let b = g.add_node("b", relu(4096), Phase::Forward);
    g.add_edge(a, b, 16384);
    let p = Partition::singletons(&g);
    let accel = simd_accel(1);
    let r = schedule(&g, &p, &accel, &MappingConfig::default());
    let group_energy: f64 = r.timeline.iter().map(|t| t.energy_pj).sum();
    let expected = group_energy + idle_energy(r.latency_cycles, accel.cores.len());
    let err = (r.energy_pj - expected).abs();
    assert!(
        err <= 1e-9 * expected.max(1.0),
        "same-core chain charged transfer energy: total {} vs expected {expected}",
        r.energy_pj
    );
}

#[test]
fn cross_core_transfer_energy_is_charged_exactly_once() {
    // two cores: the consumer lands on the idle second core (earliest-
    // free tie-break), so exactly one 16384-byte tensor crosses the bus.
    let bytes = 16384u64;
    let mut g = Graph::new();
    let a = g.add_node("a", relu(4096), Phase::Forward);
    let b = g.add_node("b", relu(4096), Phase::Forward);
    g.add_edge(a, b, bytes);
    let p = Partition::singletons(&g);
    let accel = simd_accel(2);
    let r = schedule(&g, &p, &accel, &MappingConfig::default());
    let cores: std::collections::HashSet<usize> =
        r.timeline.iter().map(|t| t.core).collect();
    assert_eq!(cores.len(), 2, "test premise: the two groups use two cores");
    let group_energy: f64 = r.timeline.iter().map(|t| t.energy_pj).sum();
    let expected = group_energy
        + idle_energy(r.latency_cycles, accel.cores.len())
        + bytes as f64 * accel.interconnect.link_energy_pj;
    let err = (r.energy_pj - expected).abs();
    assert!(
        err <= 1e-9 * expected.max(1.0),
        "cross-core transfer mischarged: total {} vs expected {expected}",
        r.energy_pj
    );
}

#[test]
fn shared_tensor_into_one_consumer_group_crosses_the_bus_once() {
    // a --(16384B)--> {c1, c2} with c1,c2 fused into ONE remote group:
    // exactly one tensor crosses the bus, so exactly one transfer is
    // charged — the per-edge aggregation double-charged it (the same
    // fan-out duplication the peak-DRAM fix removes)
    let bytes = 16384u64;
    let mut g = Graph::new();
    let a = g.add_node("a", relu(4096), Phase::Forward);
    let c1 = g.add_node("c1", relu(4096), Phase::Forward);
    let c2 = g.add_node("c2", relu(4096), Phase::Forward);
    g.add_edge(a, c1, bytes);
    g.add_edge(a, c2, bytes);
    let p = Partition::from_groups(vec![vec![a], vec![c1, c2]]);
    p.validate(&g).unwrap();
    let accel = simd_accel(2);
    let r = schedule(&g, &p, &accel, &MappingConfig::default());
    let cores: std::collections::HashSet<usize> =
        r.timeline.iter().map(|t| t.core).collect();
    assert_eq!(cores.len(), 2, "test premise: producer and consumer group on different cores");
    let group_energy: f64 = r.timeline.iter().map(|t| t.energy_pj).sum();
    let expected = group_energy
        + idle_energy(r.latency_cycles, accel.cores.len())
        + bytes as f64 * accel.interconnect.link_energy_pj;
    let err = (r.energy_pj - expected).abs();
    assert!(
        err <= 1e-9 * expected.max(1.0),
        "shared tensor double-charged: total {} vs expected {expected}",
        r.energy_pj
    );
}

#[test]
fn sink_heavy_graph_offchip_traffic_is_consistent() {
    // a sink's output goes to DRAM, so its bytes appear in offchip
    // traffic; fusing the chain into one group must not increase either
    // offchip bytes or energy (the sink fix keeps sink outputs off the
    // bus in both partitions).
    let mut g = Graph::new();
    let a = g.add_node("a", relu(4096), Phase::Forward);
    let b = g.add_node("b", relu(4096), Phase::Forward);
    g.add_edge(a, b, 16384);
    let accel = simd_accel(2);
    let singles = schedule(&g, &Partition::singletons(&g), &accel, &MappingConfig::default());
    let fused_p = Partition::from_groups(vec![vec![a, b]]);
    fused_p.validate(&g).unwrap();
    let fused = schedule(&g, &fused_p, &accel, &MappingConfig::default());
    assert!(fused.offchip_bytes <= singles.offchip_bytes);
    assert!(fused.energy_pj < singles.energy_pj);
}

#[test]
fn nan_objective_ga_smoke() {
    // a degenerate objective (NaN for one genome family) must not abort
    // the run — pre-fix, the crowding-distance and elitist sorts panicked
    // on `partial_cmp(..).unwrap()`
    let front = nsga2(
        12,
        &GaConfig { population: 16, generations: 10, workers: 2, ..Default::default() },
        |g| {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            let poisoned = if g[0] && g[1] { f64::NAN } else { 12.0 - ones };
            vec![ones, poisoned]
        },
    );
    assert!(!front.is_empty(), "GA must survive NaN objectives");
}
