//! Integration tests: full MONET pipelines (workload → autodiff →
//! checkpoint → fusion → schedule) at reduced sizes, asserting the paper's
//! qualitative claims end to end.

use monet::autodiff::{
    apply_checkpointing, build_training_graph, checkpoint_candidates, CheckpointPlan,
    TrainOptions,
};
use monet::dse::{run_sweep, DesignPoint, Mode, SweepConfig};
use monet::figures;
use monet::fusion::{fuse, fuse_greedy, fuse_manual_conv_bn_relu, FusionConstraints};
use monet::ga::GaConfig;
use monet::hardware::presets::{EdgeTpuParams, FuseMaxParams};
use monet::mapping::MappingConfig;
use monet::scheduler::{schedule, Partition};
use monet::workload::models::{gpt2, mlp, resnet18, resnet50, Gpt2Config};
use monet::workload::op::{Optimizer, Phase};

#[test]
fn full_pipeline_resnet18_training() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let p = fuse(&tg.graph, &FusionConstraints::default());
    let r = schedule(&tg.graph, &p, &accel, &mapping);
    assert!(r.latency_cycles > 0.0 && r.energy_pj > 0.0);
    assert!(r.utilization() > 0.0 && r.utilization() <= 1.0);
    // conservation: every group scheduled exactly once
    assert_eq!(r.timeline.len(), p.len());
}

#[test]
fn training_strictly_dominates_inference_cost() {
    // on every accelerator in a strided space, training > inference in both
    // latency and energy (it does ~3x the MACs and holds activations)
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(&fwd, TrainOptions::default());
    let rows = run_sweep(
        &DesignPoint::edge_space(997),
        &fwd,
        &tg.graph,
        &SweepConfig::default(),
        |_, _| {},
    );
    for pair in rows.chunks(2) {
        assert_eq!(pair[0].mode, Mode::Inference);
        assert!(pair[1].latency_cycles > pair[0].latency_cycles);
        assert!(pair[1].energy_pj > pair[0].energy_pj);
        assert!(pair[1].peak_dram_bytes >= pair[0].peak_dram_bytes);
    }
}

#[test]
fn fig10_pipeline_solver_beats_manual_mostly() {
    let rows = figures::fig10_fusion_strategies(None);
    let manual = rows.iter().find(|r| r.strategy == "Manual").unwrap();
    let base = rows.iter().find(|r| r.strategy == "Base").unwrap();
    // manual fusion already beats base (sanity of the baseline itself)
    assert!(manual.energy_pj < base.energy_pj);
    // at least one solver limit beats manual on both metrics ("most of the
    // time" in the paper; the best limit must win here)
    let wins = rows
        .iter()
        .filter(|r| r.strategy.starts_with("Limit"))
        .filter(|r| r.latency_cycles <= manual.latency_cycles && r.energy_pj <= manual.energy_pj)
        .count();
    assert!(wins >= 1, "no solver limit beats manual fusion");
}

#[test]
fn checkpointing_pipeline_memory_latency_tradeoff() {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let fc = FusionConstraints::default();
    let cands = checkpoint_candidates(&tg);

    let eval = |plan: &CheckpointPlan| {
        let g = apply_checkpointing(&tg, plan);
        let p = fuse_greedy(&g, &fc);
        let r = schedule(&g, &p, &accel, &mapping);
        (r.latency_cycles, r.energy_pj)
    };
    let (lat0, _) = eval(&CheckpointPlan::save_all());
    let all = CheckpointPlan::recompute_set(cands.iter().copied());
    let (lat1, _) = eval(&all);
    // recompute-everything must add recompute work (more MACs → more time)
    assert!(lat1 > lat0, "recompute-all should cost latency: {lat1} !> {lat0}");
}

#[test]
fn ga_front_contains_low_overhead_high_saving_point() {
    // miniature Fig 12 on the CIFAR graph (fast): the GA must find a point
    // with >30% activation-memory saving at <10% latency overhead
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let problem = monet::ga::CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::edge_tpu_default(),
        FusionConstraints::default(),
    );
    let (base_lat, _, _) = problem.evaluate(&CheckpointPlan::save_all());
    let front = problem.optimize(&GaConfig { population: 16, generations: 8, ..Default::default() });
    assert!(!front.is_empty());
    let ok = front
        .iter()
        .any(|s| s.memory_saving > 0.3 && s.latency_cycles < base_lat * 1.10);
    assert!(ok, "no >30% saving at <10% latency overhead found");
}

#[test]
fn gpt2_fusemax_pipeline() {
    let cfg = Gpt2Config::tiny();
    let fwd = gpt2(cfg);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = FuseMaxParams::baseline().build();
    let mapping = MappingConfig::fusemax_default();
    let p = fuse_greedy(&tg.graph, &FusionConstraints::default());
    let r = schedule(&tg.graph, &p, &accel, &mapping);
    assert!(r.latency_cycles > 0.0);
    // both cores of the 2-core HDA must be used (pipeline parallelism)
    let busy_cores = r.core_busy.iter().filter(|&&b| b > 0.0).count();
    assert_eq!(busy_cores, 2, "pipeline parallelism unused");
}

#[test]
fn fig9_distribution_more_concentrated_than_fig1() {
    // paper §IV-B: regular workload × regular hardware → tighter spread.
    // Prime strides so the subsample doesn't alias with the cartesian axis
    // periods (stride 400 would fix U/L/mem/RF and only vary PE count).
    let edge = figures::fig1_fig8_edge_sweep(397, None, |_, _| {});
    let fmx = figures::fig9_fusemax_sweep(97, None, |_, _| {});
    // The concentration shows on the energy axis: FuseMax energy is nearly
    // invariant across configs (regular workload, all traffic through the
    // shared buffer), while Edge-TPU energy spans decades. Latency on
    // FuseMax still spreads along the off-chip-bandwidth axis — which is
    // exactly the sensitivity Fig 9's colour coding highlights.
    let spread = |rows: &[monet::dse::SweepRow]| {
        let en: Vec<f64> = rows.iter().map(|r| r.energy_pj.log10()).collect();
        monet::util::stats::stddev(&en)
    };
    let (einf, _) = figures::split_modes(&edge.rows);
    let (finf, _) = figures::split_modes(&fmx.rows);
    assert!(
        spread(&finf) < spread(&einf) / 2.0,
        "fusemax energy spread {} not ≪ edge energy spread {}",
        spread(&finf),
        spread(&einf)
    );
}

#[test]
fn resnet50_memory_matches_published_scale() {
    // well-known numbers: ResNet-50 FP32 params ≈ 100 MB; batch-8 224²
    // activations are GB-scale (the Fig 3 story)
    let bd = figures::fig3_memory_breakdown(None);
    let b8 = &bd[1];
    // PyTorch's measured bars (Fig 3) include cuDNN workspace and allocator
    // fragmentation on top of the analytic tensor bytes we model, so our
    // bound is the analytic floor of the same story: batch-8 activations in
    // the high hundreds of MiB, dominating the breakdown.
    assert!(
        b8.activation_bytes > 500 << 20,
        "batch-8 activations should exceed 500 MiB"
    );
    assert!(b8.total() < 20 * (1 << 30) as u64, "total should stay below 20 GiB");
}

#[test]
fn recompute_phase_nodes_only_from_checkpointing() {
    let fwd = mlp(1, 16, 32, 2, 8);
    let tg = build_training_graph(&fwd, TrainOptions::default());
    assert!(tg.graph.nodes.iter().all(|n| n.phase != Phase::Recompute));
    let cands = checkpoint_candidates(&tg);
    let g = apply_checkpointing(&tg, &CheckpointPlan::recompute_set([cands[0]]));
    assert!(g.nodes.iter().any(|n| n.phase == Phase::Recompute));
}

#[test]
fn manual_fusion_matches_known_group_structure() {
    let g = resnet18(1, 32, 10);
    let p = fuse_manual_conv_bn_relu(&g);
    // 20 convs each lead a group; stem group has conv+bn+relu
    let conv_led = p
        .groups
        .iter()
        .filter(|grp| g.node(grp[0]).kind.is_conv())
        .count();
    assert_eq!(conv_led, 20);
}

#[test]
fn resnet50_batch_sweep_scales_linearly_in_macs() {
    let g1 = resnet50(1, 224, 1000);
    let g4 = resnet50(4, 224, 1000);
    assert_eq!(g4.total_macs(None), 4 * g1.total_macs(None));
}
