//! Property-based tests over coordinator invariants, using the in-repo
//! mini-proptest (util::proptest; the proptest crate is not vendored in
//! this offline environment — see DESIGN.md §Substitutions). Each property
//! runs across dozens of seeded random cases with shrinking on failure.

use std::collections::HashSet;

use monet::autodiff::{
    apply_checkpointing, build_training_graph, checkpoint_candidates,
    stored_activation_bytes, CheckpointPlan, TrainOptions, TrainingGraph,
};
use monet::dse::{
    run_cluster_sweep_outcome, run_sweep, ClusterEval, ClusterRow, ClusterSpace, DesignPoint,
    Evaluate, SweepConfig, SweepEval, SweepPartitions,
};
use monet::fusion::{enumerate_candidates, fuse_greedy, solve_exact_cover, FusionConstraints};
use monet::ga::{dominates, nsga2, pareto_rank0, GaConfig};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::LinkTier;
use monet::scheduler::{schedule, Partition};
use monet::util::proptest::{check, BitMask, Gen, UsizeIn};
use monet::util::rng::Rng;
use monet::workload::graph::Graph;
use monet::workload::models::{mlp, resnet18};
use monet::workload::op::Optimizer;

/// Generator: random MLP-family workloads.
struct RandomMlp;
impl Gen for RandomMlp {
    type Value = (usize, usize, usize, usize);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (
            1 + rng.usize(4),       // batch
            8 << rng.usize(4),      // in features
            8 << rng.usize(5),      // hidden
            1 + rng.usize(4),       // layers
        )
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = vec![];
        if v.3 > 1 {
            out.push((v.0, v.1, v.2, v.3 - 1));
        }
        if v.0 > 1 {
            out.push((1, v.1, v.2, v.3));
        }
        out
    }
}

fn graph_of((b, f, h, l): (usize, usize, usize, usize)) -> Graph {
    mlp(b, f, h, l, 10)
}

#[test]
fn prop_training_graphs_are_dags_with_backward_activation_edges() {
    check(25, &RandomMlp, |&dims| {
        let g = graph_of(dims);
        let tg = build_training_graph(
            &g,
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        );
        tg.graph.is_dag()
            && tg
                .graph
                .edges
                .iter()
                .filter(|e| e.is_activation)
                .all(|e| e.src < tg.fwd_len && e.dst >= tg.fwd_len)
    });
}

#[test]
fn prop_fusion_partitions_are_exact_covers() {
    check(20, &RandomMlp, |&dims| {
        let g = graph_of(dims);
        let p = fuse_greedy(&g, &FusionConstraints::default());
        p.validate(&g).is_ok()
    });
}

#[test]
fn prop_exact_cover_solutions_cover_exactly_once() {
    // random candidate pools over small universes
    struct Inst;
    impl Gen for Inst {
        type Value = (usize, Vec<Vec<usize>>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let n = 4 + rng.usize(12);
            let mut cands: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
            for _ in 0..rng.usize(12) {
                let len = 2 + rng.usize(3);
                let start = rng.usize(n.saturating_sub(len) + 1);
                cands.push((start..(start + len).min(n)).collect());
            }
            (n, cands)
        }
    }
    check(40, &Inst, |(n, cands)| {
        let sol = solve_exact_cover(*n, cands, 50_000);
        let mut cnt = vec![0usize; *n];
        for &ci in &sol {
            for &x in &cands[ci] {
                cnt[x] += 1;
            }
        }
        cnt.iter().all(|&c| c == 1)
    });
}

#[test]
fn prop_checkpoint_transform_preserves_backward_reachability() {
    // every backward consumer of a dropped activation must still have a
    // producer (recompute clone) among its predecessors, and the graph
    // stays a DAG, for random recompute masks
    let g = resnet18(1, 32, 10);
    let tg = build_training_graph(&g, TrainOptions::default());
    let cands = checkpoint_candidates(&tg);
    check(25, &BitMask { width: cands.len(), p: 0.35 }, |mask| {
        let plan = CheckpointPlan {
            recompute: cands
                .iter()
                .zip(mask)
                .filter(|(_, &b)| b)
                .map(|(&n, _)| n)
                .collect(),
        };
        let out = apply_checkpointing(&tg, &plan);
        if !out.is_dag() {
            return false;
        }
        // in-degree preservation: every node that had inputs still has them
        for n in 0..tg.graph.len() {
            if tg.graph.in_degree(n) > 0 && out.in_degree(n) == 0 {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_checkpoint_memory_is_monotone_in_mask() {
    let g = mlp(1, 32, 64, 3, 10);
    let tg = build_training_graph(&g, TrainOptions::default());
    let cands = checkpoint_candidates(&tg);
    check(25, &BitMask { width: cands.len(), p: 0.4 }, |mask| {
        let plan = CheckpointPlan {
            recompute: cands
                .iter()
                .zip(mask)
                .filter(|(_, &b)| b)
                .map(|(&n, _)| n)
                .collect(),
        };
        // flipping any additional bit on can only reduce stored bytes
        let base = stored_activation_bytes(&tg, &plan);
        for (i, &bit) in mask.iter().enumerate() {
            if !bit {
                let mut bigger = plan.clone();
                bigger.recompute.insert(cands[i]);
                if stored_activation_bytes(&tg, &bigger) > base {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_schedule_never_violates_group_dependencies() {
    // random contiguous-chain partitions of an MLP: start/finish ordering
    // must respect every cross-group edge
    let g = mlp(2, 32, 64, 4, 10);
    check(25, &UsizeIn(1, 4), |&chunk| {
        // build a partition of consecutive topo nodes in chunks
        let topo = g.topo_order();
        let groups: Vec<Vec<usize>> =
            topo.chunks(chunk).map(|c| c.to_vec()).collect();
        let p = Partition::from_groups(groups);
        if p.validate(&g).is_err() {
            return true; // non-convex chunking is rejected, fine
        }
        let accel = EdgeTpuParams::baseline().build();
        let r = schedule(&g, &p, &accel, &MappingConfig::default());
        let gof = p.group_of(g.len());
        let start: Vec<f64> = {
            let mut s = vec![0.0; p.len()];
            for t in &r.timeline {
                s[t.group] = t.start;
            }
            s
        };
        let finish: Vec<f64> = {
            let mut f = vec![0.0; p.len()];
            for t in &r.timeline {
                f[t.group] = t.finish;
            }
            f
        };
        g.edges.iter().all(|e| {
            let (a, b) = (gof[e.src], gof[e.dst]);
            a == b || finish[a] <= start[b] + 1e-9
        })
    });
}

#[test]
fn prop_nsga2_fronts_are_mutually_nondominated() {
    struct Width;
    impl Gen for Width {
        type Value = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            4 + rng.usize(20)
        }
    }
    check(10, &Width, |&w| {
        let front = nsga2(
            w,
            &GaConfig { population: 16, generations: 6, seed: w as u64, ..Default::default() },
            |g| {
                let ones = g.iter().filter(|&&b| b).count() as f64;
                let runs = g.windows(2).filter(|p| p[0] != p[1]).count() as f64;
                vec![ones, runs]
            },
        );
        front.iter().all(|a| {
            front
                .iter()
                .all(|b| !dominates(&b.objectives, &a.objectives))
        })
    });
}

#[test]
fn prop_sweep_processes_every_job_exactly_once_under_random_workers() {
    let fwd = mlp(1, 16, 32, 2, 8);
    let tg = build_training_graph(&fwd, TrainOptions::default());
    check(8, &UsizeIn(1, 8), |&workers| {
        let points = DesignPoint::edge_space(1500);
        let rows = run_sweep(
            &points,
            &fwd,
            &tg.graph,
            &SweepConfig { workers, ..Default::default() },
            |_, _| {},
        );
        let idx: HashSet<usize> = rows.iter().map(|r| r.index).collect();
        rows.len() == points.len() * 2 && idx.len() == points.len()
    });
}

/// Generator: random small homogeneous deployment spaces + global batch.
struct RandomClusterSpace;
impl Gen for RandomClusterSpace {
    type Value = (Vec<usize>, Vec<LinkTier>, Vec<usize>, usize);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let counts = match rng.usize(3) {
            0 => vec![2],
            1 => vec![4],
            _ => vec![2, 4],
        };
        let tiers = match rng.usize(4) {
            0 => vec![LinkTier::Edge],
            1 => vec![LinkTier::Server],
            2 => vec![LinkTier::Datacenter],
            _ => vec![LinkTier::Edge, LinkTier::Datacenter],
        };
        let ms = if rng.usize(2) == 0 { vec![2] } else { vec![2, 4] };
        let batch = 2 << rng.usize(2);
        (counts, tiers, ms, batch)
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = vec![];
        if v.0.len() > 1 {
            out.push((vec![v.0[0]], v.1.clone(), v.2.clone(), v.3));
        }
        if v.1.len() > 1 {
            out.push((v.0.clone(), vec![v.1[0]], v.2.clone(), v.3));
        }
        if v.2.len() > 1 {
            out.push((v.0.clone(), v.1.clone(), vec![v.2[0]], v.3));
        }
        out
    }
}

fn prop_builder(batch: usize) -> TrainingGraph {
    build_training_graph(&mlp(batch.max(1), 8, 16, 2, 4), TrainOptions::default())
}

/// The admissibility contract behind bound-based front pruning
/// (`Evaluate::lower_bound`), single-device family: for random
/// accelerator points, every emitted row is covered by a bound vector
/// that never exceeds the true scheduled latency/energy in any
/// component — the soundness precondition for the engine skipping a
/// point whose bounds are dominated.
#[test]
fn prop_sweep_lower_bounds_never_exceed_scheduled_truth() {
    check(12, &RandomMlp, |&dims| {
        let fwd = graph_of(dims);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        let cfg = SweepConfig { workers: 1, ..Default::default() };
        let parts = SweepPartitions::prepare(&fwd, &tg.graph, &cfg);
        let eval = SweepEval { fwd: &fwd, train: &tg.graph, parts: &parts, cfg: &cfg };
        let mut scratch = eval.scratch();
        DesignPoint::edge_space(1500).iter().enumerate().all(|(i, p)| {
            let bounds = match eval.lower_bound(i, p, &mut scratch) {
                Some(b) => b,
                None => return false, // the sweep family must bound
            };
            eval.evaluate(i, p, None, &mut scratch).iter().all(|row| {
                let truth = eval.row_objectives(row).expect("pruning geometry");
                bounds.iter().any(|b| {
                    b.len() == truth.len() && b.iter().zip(&truth).all(|(x, y)| x <= y)
                })
            })
        })
    });
}

/// Same admissibility contract, homogeneous cluster family: the
/// roofline deployment bound never exceeds the true scheduled
/// objectives of any randomly drawn deployment point in any of the four
/// components (latency, energy, per-device memory, cluster size).
#[test]
fn prop_cluster_lower_bounds_never_exceed_scheduled_truth() {
    check(8, &RandomClusterSpace, |(counts, tiers, ms, batch)| {
        let space = ClusterSpace {
            device_counts: counts.clone(),
            tiers: tiers.clone(),
            microbatches: ms.clone(),
        };
        let accel = EdgeTpuParams::baseline().build();
        let eval = ClusterEval {
            full_batch: *batch,
            builder: &prop_builder,
            accel: &accel,
            mapping: MappingConfig::edge_tpu_default(),
        };
        let mut scratch = eval.scratch();
        space.enumerate().iter().enumerate().all(|(i, p)| {
            let bounds = match eval.lower_bound(i, p, &mut scratch) {
                Some(b) => b,
                None => return false, // the cluster family must bound
            };
            eval.evaluate(i, p, None, &mut scratch).iter().all(|row| {
                let truth = eval.row_objectives(row).expect("pruning geometry");
                bounds.iter().any(|b| {
                    b.len() == truth.len() && b.iter().zip(&truth).all(|(x, y)| x <= y)
                })
            })
        })
    });
}

/// Pruning soundness end to end on random deployment spaces: whatever
/// the pruner skips, the 4-objective rank-0 front of the pruned run is
/// bit-identical to the full enumeration's front — no true front row is
/// ever dropped, no dominated row is ever promoted.
#[test]
fn prop_pruning_never_drops_a_true_front_row() {
    let front_key = |rows: &[ClusterRow]| -> Vec<(String, u64, u64, u64, usize)> {
        let objs: Vec<Vec<f64>> = rows.iter().map(|r| r.objectives().to_vec()).collect();
        pareto_rank0(&objs)
            .into_iter()
            .map(|i| {
                let r = &rows[i];
                (
                    r.label.clone(),
                    r.latency_cycles.to_bits(),
                    r.energy_pj.to_bits(),
                    r.per_device_mem_bytes,
                    r.devices,
                )
            })
            .collect()
    };
    check(6, &RandomClusterSpace, |(counts, tiers, ms, batch)| {
        let space = ClusterSpace {
            device_counts: counts.clone(),
            tiers: tiers.clone(),
            microbatches: ms.clone(),
        };
        let points = space.enumerate();
        let accel = EdgeTpuParams::baseline().build();
        let cfg = |prune: bool| SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            workers: 2,
            prune,
            ..Default::default()
        };
        let full = run_cluster_sweep_outcome(
            &points,
            *batch,
            &prop_builder,
            &accel,
            &cfg(false),
            |_, _| {},
        )
        .expect("full run");
        let pruned = run_cluster_sweep_outcome(
            &points,
            *batch,
            &prop_builder,
            &accel,
            &cfg(true),
            |_, _| {},
        )
        .expect("pruned run");
        pruned.rows.len() + pruned.skipped.len() == points.len()
            && front_key(&full.rows) == front_key(&pruned.rows)
    });
}

#[test]
fn prop_candidate_subgraphs_respect_all_constraints() {
    check(12, &RandomMlp, |&dims| {
        let g = graph_of(dims);
        let c = FusionConstraints::default();
        enumerate_candidates(&g, &c)
            .iter()
            .all(|cand| monet::fusion::candidates::satisfies(&g, cand, &c))
    });
}
