//! The workload graph: G = (V, E) with V = operators, E = tensors.
//!
//! This is the IR everything else consumes: the autodiff pass rewrites it
//! into a training graph, the fusion solver partitions it, the scheduler
//! walks it, and the checkpointing pass clones subgraphs of it. It replaces
//! the ONNX graph of the paper's toolchain.

use std::collections::{HashMap, HashSet, VecDeque};

use super::op::{OpKind, Phase};

pub type NodeId = usize;
pub type EdgeId = usize;

/// Bytes per element (the paper evaluates FP16 activations for the GA
/// memory metric and FP32 elsewhere; we keep it per-graph).
pub const BYTES_F32: u64 = 4;
pub const BYTES_F16: u64 = 2;

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub phase: Phase,
    /// Forward node this gradient/recompute node derives from (if any).
    pub origin: Option<NodeId>,
}

/// A tensor flowing between two operators.
#[derive(Debug, Clone)]
pub struct Edge {
    pub id: EdgeId,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    /// True when this edge carries a *saved activation* from the forward
    /// pass into the backward pass — the checkpointing candidate set 𝒜.
    pub is_activation: bool,
}

#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    succ: Vec<Vec<EdgeId>>,
    pred: Vec<Vec<EdgeId>>,
    /// Bytes per element for activation tensors in this graph.
    pub elem_bytes: u64,
}

impl Default for Graph {
    fn default() -> Self {
        Self::new()
    }
}

impl Graph {
    pub fn new() -> Self {
        Graph { nodes: vec![], edges: vec![], succ: vec![], pred: vec![], elem_bytes: BYTES_F32 }
    }

    pub fn with_elem_bytes(elem_bytes: u64) -> Self {
        Graph { elem_bytes, ..Self::new() }
    }

    pub fn add_node(&mut self, name: impl Into<String>, kind: OpKind, phase: Phase) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.into(), kind, phase, origin: None });
        self.succ.push(vec![]);
        self.pred.push(vec![]);
        id
    }

    pub fn add_node_with_origin(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        phase: Phase,
        origin: NodeId,
    ) -> NodeId {
        let id = self.add_node(name, kind, phase);
        self.nodes[id].origin = Some(origin);
        id
    }

    /// Connect `src -> dst` carrying `bytes`.
    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, bytes: u64) -> EdgeId {
        self.add_edge_full(src, dst, bytes, false)
    }

    /// Connect a saved-activation edge (forward → backward).
    pub fn add_activation_edge(&mut self, src: NodeId, dst: NodeId, bytes: u64) -> EdgeId {
        self.add_edge_full(src, dst, bytes, true)
    }

    pub fn add_edge_full(
        &mut self,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        is_activation: bool,
    ) -> EdgeId {
        assert!(src < self.nodes.len() && dst < self.nodes.len(), "edge endpoints must exist");
        assert_ne!(src, dst, "self-loops are not allowed");
        let id = self.edges.len();
        self.edges.push(Edge { id, src, dst, bytes, is_activation });
        self.succ[src].push(id);
        self.pred[dst].push(id);
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }
    pub fn edge(&self, id: EdgeId) -> &Edge {
        &self.edges[id]
    }
    pub fn len(&self) -> usize {
        self.nodes.len()
    }
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn successors(&self, id: NodeId) -> impl Iterator<Item = NodeId> + '_ {
        self.succ[id].iter().map(move |&e| self.edges[e].dst)
    }
    pub fn predecessors(&self, id: NodeId) -> impl Iterator<Item = NodeId> + '_ {
        self.pred[id].iter().map(move |&e| self.edges[e].src)
    }
    pub fn out_edges(&self, id: NodeId) -> impl Iterator<Item = &Edge> + '_ {
        self.succ[id].iter().map(move |&e| &self.edges[e])
    }
    pub fn in_edges(&self, id: NodeId) -> impl Iterator<Item = &Edge> + '_ {
        self.pred[id].iter().map(move |&e| &self.edges[e])
    }
    pub fn out_degree(&self, id: NodeId) -> usize {
        self.succ[id].len()
    }
    pub fn in_degree(&self, id: NodeId) -> usize {
        self.pred[id].len()
    }

    /// Output tensor bytes of a node (element count × element width).
    pub fn out_bytes(&self, id: NodeId) -> u64 {
        self.nodes[id].kind.out_elems() * self.elem_bytes
    }

    /// Kahn topological order. Panics if the graph has a cycle (the IR is a
    /// DAG by construction; a cycle is a builder/transform bug).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg: Vec<usize> = (0..self.len()).map(|i| self.in_degree(i)).collect();
        let mut queue: VecDeque<NodeId> =
            (0..self.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(n) = queue.pop_front() {
            order.push(n);
            for s in self.succ[n].iter().map(|&e| self.edges[e].dst) {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "workload graph contains a cycle");
        order
    }

    /// True iff the graph is acyclic (non-panicking check for tests).
    pub fn is_dag(&self) -> bool {
        let mut indeg: Vec<usize> = (0..self.len()).map(|i| self.in_degree(i)).collect();
        let mut queue: VecDeque<NodeId> =
            (0..self.len()).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(n) = queue.pop_front() {
            seen += 1;
            for s in self.succ[n].iter().map(|&e| self.edges[e].dst) {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        seen == self.len()
    }

    /// All nodes reachable from `start` walking *backwards* (ancestors),
    /// excluding `start` itself.
    pub fn ancestors(&self, start: NodeId) -> HashSet<NodeId> {
        let mut seen = HashSet::new();
        let mut stack: Vec<NodeId> = self.predecessors(start).collect();
        while let Some(n) = stack.pop() {
            if seen.insert(n) {
                stack.extend(self.predecessors(n));
            }
        }
        seen
    }

    /// Saved-activation edges — the checkpointing candidate set 𝒜.
    pub fn activation_edges(&self) -> Vec<EdgeId> {
        self.edges
            .iter()
            .filter(|e| e.is_activation)
            .map(|e| e.id)
            .collect()
    }

    /// Total MACs of the graph (optionally restricted to a phase).
    pub fn total_macs(&self, phase: Option<Phase>) -> u64 {
        self.nodes
            .iter()
            .filter(|n| phase.is_none_or(|p| n.phase == p))
            .map(|n| n.kind.macs())
            .sum()
    }

    /// Total trained-parameter bytes (each parameter counted once, at its
    /// forward consumer).
    pub fn total_weight_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.phase == Phase::Forward)
            .map(|n| n.kind.weight_elems() * self.elem_bytes)
            .sum()
    }

    /// Per-phase node counts (reporting).
    pub fn phase_counts(&self) -> HashMap<Phase, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            *m.entry(n.phase).or_insert(0) += 1;
        }
        m
    }

    /// Deep-copy a set of nodes (with induced edges) into `self`, returning
    /// the old→new id mapping. Used by the checkpointing pass to insert
    /// recompute subgraphs.
    pub fn clone_subgraph(
        &mut self,
        source: &Graph,
        nodes: &[NodeId],
        phase: Phase,
    ) -> HashMap<NodeId, NodeId> {
        let set: HashSet<NodeId> = nodes.iter().copied().collect();
        let mut map = HashMap::new();
        // insert in source topo order so edges can be added directly
        for &n in source.topo_order().iter().filter(|n| set.contains(n)) {
            let node = &source.nodes[n];
            let new = self.add_node(
                format!("{}@rc", node.name),
                node.kind.clone(),
                phase,
            );
            self.nodes[new].origin = Some(node.origin.unwrap_or(n));
            map.insert(n, new);
        }
        for e in &source.edges {
            if let (Some(&ns), Some(&nd)) = (map.get(&e.src), map.get(&e.dst)) {
                self.add_edge(ns, nd, e.bytes);
            }
        }
        map
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        let gmacs = self.total_macs(None) as f64 / 1e9;
        format!(
            "{} nodes, {} edges, {:.3} GMACs, {} activation edges",
            self.len(),
            self.edges.len(),
            gmacs,
            self.activation_edges().len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::op::{EltwiseKind, OpKind};

    fn elt(elems: u64) -> OpKind {
        OpKind::Eltwise { kind: EltwiseKind::Relu, elems, arity: 1 }
    }

    fn chain(n: usize) -> Graph {
        let mut g = Graph::new();
        let ids: Vec<_> =
            (0..n).map(|i| g.add_node(format!("n{i}"), elt(10), Phase::Forward)).collect();
        for w in ids.windows(2) {
            g.add_edge(w[0], w[1], 40);
        }
        g
    }

    #[test]
    fn build_and_query() {
        let g = chain(4);
        assert_eq!(g.len(), 4);
        assert_eq!(g.edges.len(), 3);
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.successors(1).collect::<Vec<_>>(), vec![2]);
        assert_eq!(g.predecessors(1).collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn topo_order_respects_edges() {
        let mut g = chain(5);
        g.add_edge(0, 4, 8); // skip connection
        let order = g.topo_order();
        let pos: HashMap<_, _> = order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for e in &g.edges {
            assert!(pos[&e.src] < pos[&e.dst]);
        }
    }

    #[test]
    fn dag_check() {
        let g = chain(3);
        assert!(g.is_dag());
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn rejects_self_loop() {
        let mut g = chain(2);
        g.add_edge(1, 1, 4);
    }

    #[test]
    fn ancestors_of_chain_tail() {
        let g = chain(4);
        let a = g.ancestors(3);
        assert_eq!(a, [0, 1, 2].into_iter().collect());
        assert!(g.ancestors(0).is_empty());
    }

    #[test]
    fn activation_edges_tracked() {
        let mut g = chain(3);
        g.add_activation_edge(0, 2, 100);
        assert_eq!(g.activation_edges().len(), 1);
        assert!(g.edge(g.activation_edges()[0]).is_activation);
    }

    #[test]
    fn clone_subgraph_preserves_structure() {
        let src = chain(4);
        let mut dst = Graph::new();
        let root = dst.add_node("root", elt(1), Phase::Backward);
        let map = dst.clone_subgraph(&src, &[1, 2], Phase::Recompute);
        assert_eq!(map.len(), 2);
        assert_eq!(dst.len(), 3);
        // edge 1->2 is induced; edges 0->1 and 2->3 are not
        assert_eq!(dst.edges.len(), 1);
        assert_eq!(dst.nodes[map[&1]].origin, Some(1));
        let _ = root;
    }

    #[test]
    fn out_bytes_uses_elem_width() {
        let mut g = Graph::with_elem_bytes(BYTES_F16);
        let n = g.add_node("x", elt(100), Phase::Forward);
        assert_eq!(g.out_bytes(n), 200);
    }
}
