//! Workload IR: operator graphs (nodes = ops, edges = tensors), the model
//! zoo that builds them, and the builder DSL. This layer replaces ONNX in
//! the paper's pipeline (DESIGN.md S1/S2).
//!
//! [`op`] defines the operator vocabulary (conv/GEMM/eltwise/norm/…)
//! with closed-form MAC/element counts — the quantities every cost
//! estimate downstream is a function of; [`graph`] is the DAG container
//! with the topo/ancestor utilities the schedulers and splitters lean
//! on; [`models`] builds ResNet-18/50, GPT-2 (full and reduced configs),
//! MobileNet and MLPs at arbitrary batch/resolution, which is what lets
//! the parallelism layer re-instantiate a workload per microbatch or
//! replica batch size.

pub mod builder;
pub mod graph;
pub mod models;
pub mod op;

pub use builder::{GraphBuilder, T};
pub use graph::{Edge, EdgeId, Graph, Node, NodeId};
pub use op::{
    ConvSpec, EltwiseKind, GemmSpec, LoopDim, NormKind, OpKind, Optimizer, Phase,
    PoolSpec, ReduceKind,
};
