//! Workload IR: operator graphs (nodes = ops, edges = tensors), the model
//! zoo that builds them, and the builder DSL. This layer replaces ONNX in
//! the paper's pipeline (DESIGN.md S1/S2).

pub mod builder;
pub mod graph;
pub mod models;
pub mod op;

pub use builder::{GraphBuilder, T};
pub use graph::{Edge, EdgeId, Graph, Node, NodeId};
pub use op::{
    ConvSpec, EltwiseKind, GemmSpec, LoopDim, NormKind, OpKind, Optimizer, Phase,
    PoolSpec, ReduceKind,
};
