//! ResNet family (He et al. 2016) — the paper's §IV-A / Fig 3 workloads.
//!
//! `resnet18` with 32×32 inputs matches the paper's CIFAR-10 Edge-TPU case
//! study; `resnet50` at 224×224 feeds the Fig 3 memory breakdown.

use crate::workload::builder::{GraphBuilder, T};
use crate::workload::graph::Graph;

/// Basic residual block (two 3×3 convs), ResNet-18/34 style.
fn basic_block(b: &mut GraphBuilder, x: T, out_ch: usize, stride: usize) -> T {
    let c1 = b.conv(x, out_ch, 3, stride, 1);
    let n1 = b.batch_norm(c1);
    let r1 = b.relu(n1);
    let c2 = b.conv(r1, out_ch, 3, 1, 1);
    let n2 = b.batch_norm(c2);
    let shortcut = if stride != 1 || x.ch != out_ch {
        let sc = b.conv(x, out_ch, 1, stride, 0);
        b.batch_norm(sc)
    } else {
        x
    };
    let s = b.add(n2, shortcut);
    b.relu(s)
}

/// Bottleneck block (1×1 → 3×3 → 1×1, expansion 4), ResNet-50 style.
fn bottleneck(b: &mut GraphBuilder, x: T, mid_ch: usize, stride: usize) -> T {
    let out_ch = mid_ch * 4;
    let c1 = b.conv(x, mid_ch, 1, 1, 0);
    let n1 = b.batch_norm(c1);
    let r1 = b.relu(n1);
    let c2 = b.conv(r1, mid_ch, 3, stride, 1);
    let n2 = b.batch_norm(c2);
    let r2 = b.relu(n2);
    let c3 = b.conv(r2, out_ch, 1, 1, 0);
    let n3 = b.batch_norm(c3);
    let shortcut = if stride != 1 || x.ch != out_ch {
        let sc = b.conv(x, out_ch, 1, stride, 0);
        b.batch_norm(sc)
    } else {
        x
    };
    let s = b.add(n3, shortcut);
    b.relu(s)
}

/// Shared stem: 7×7/2 + maxpool for ImageNet-scale inputs, 3×3/1 for
/// CIFAR-scale (≤64 px) inputs — the paper models CIFAR-10 (3,32,32).
fn stem(b: &mut GraphBuilder, batch: usize, hw: usize) -> T {
    let x = b.input(batch, 3, hw, hw);
    if hw > 64 {
        let c = b.conv(x, 64, 7, 2, 3);
        let n = b.batch_norm(c);
        let r = b.relu(n);
        b.max_pool(r, 2, 2)
    } else {
        let c = b.conv(x, 64, 3, 1, 1);
        let n = b.batch_norm(c);
        b.relu(n)
    }
}

/// ResNet-18 forward graph.
pub fn resnet18(batch: usize, hw: usize, classes: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let mut x = stem(&mut b, batch, hw);
    for (stage, &ch) in [64usize, 128, 256, 512].iter().enumerate() {
        for block in 0..2 {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            x = basic_block(&mut b, x, ch, stride);
        }
    }
    let p = b.global_avg_pool(x);
    let fc = b.linear(p, classes);
    b.loss(fc);
    b.finish()
}

/// ResNet-50 forward graph.
pub fn resnet50(batch: usize, hw: usize, classes: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let mut x = stem(&mut b, batch, hw);
    let stages: [(usize, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    for (stage, &(mid, blocks)) in stages.iter().enumerate() {
        for block in 0..blocks {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            x = bottleneck(&mut b, x, mid, stride);
        }
    }
    let p = b.global_avg_pool(x);
    let fc = b.linear(p, classes);
    b.loss(fc);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::op::OpKind;

    #[test]
    fn resnet18_cifar_structure() {
        let g = resnet18(1, 32, 10);
        assert!(g.is_dag());
        let convs = g.nodes.iter().filter(|n| n.kind.is_conv()).count();
        // 1 stem + 16 block convs + 3 downsample 1x1 = 20
        assert_eq!(convs, 20);
        // ~0.55 GMACs for CIFAR resnet18 batch 1 (well-known ballpark)
        let gmacs = g.total_macs(None) as f64 / 1e9;
        assert!(gmacs > 0.3 && gmacs < 0.8, "gmacs={gmacs}");
    }

    #[test]
    fn resnet18_imagenet_macs() {
        let g = resnet18(1, 224, 1000);
        let gmacs = g.total_macs(None) as f64 / 1e9;
        // published: ~1.8 GMACs
        assert!(gmacs > 1.4 && gmacs < 2.2, "gmacs={gmacs}");
    }

    #[test]
    fn resnet50_imagenet_macs_and_params() {
        let g = resnet50(1, 224, 1000);
        let gmacs = g.total_macs(None) as f64 / 1e9;
        // published: ~4.1 GMACs
        assert!(gmacs > 3.4 && gmacs < 4.8, "gmacs={gmacs}");
        let wparams: u64 = g
            .nodes
            .iter()
            .map(|n| match &n.kind {
                OpKind::Conv(s) => s.weight_elems(),
                OpKind::Gemm(s) if s.weight_b => (s.k * s.n) as u64,
                _ => 0,
            })
            .sum();
        // ~25.5 M params (convs+fc; BN affine excluded here)
        let m = wparams as f64 / 1e6;
        assert!(m > 22.0 && m < 28.0, "params={m}M");
    }

    #[test]
    fn batch_scales_activations_not_weights() {
        let g1 = resnet50(1, 224, 1000);
        let g8 = resnet50(8, 224, 1000);
        assert_eq!(g1.total_weight_bytes(), g8.total_weight_bytes());
        assert_eq!(g8.total_macs(None), 8 * g1.total_macs(None));
    }

    #[test]
    fn single_loss_sink() {
        let g = resnet18(1, 32, 10);
        let sinks: Vec<_> =
            (0..g.len()).filter(|&n| g.out_degree(n) == 0).collect();
        assert_eq!(sinks.len(), 1);
        assert!(matches!(g.node(sinks[0]).kind, OpKind::Loss { .. }));
    }
}
