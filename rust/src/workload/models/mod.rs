//! Model zoo: forward graphs of the paper's workloads, built from scratch
//! (replacing the PyTorch→ONNX export of the original toolchain).

pub mod gpt2;
pub mod mobilenet;
pub mod mlp;
pub mod resnet;

pub use gpt2::{gpt2, Gpt2Config};
pub use mlp::mlp;
pub use mobilenet::mobilenet_v2;
pub use resnet::{resnet18, resnet50};
