//! GPT-2-style decoder-only transformer — the paper's §IV-B workload
//! ("small GPT-2" on the FuseMax accelerator).
//!
//! Attention is decomposed into explicit operator nodes (QKV projection,
//! QKᵀ matmul, softmax, PV matmul, output projection) so the fusion solver
//! can discover FlashAttention-style fusions (paper §II-C2) instead of
//! treating attention as a monolith.

use crate::workload::builder::GraphBuilder;
use crate::workload::graph::Graph;
use crate::workload::op::ReduceKind;

#[derive(Debug, Clone, Copy)]
pub struct Gpt2Config {
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub mlp_ratio: usize,
    pub batch: usize,
}

impl Gpt2Config {
    /// The "small GPT-2" of the paper's §IV-B, scaled to stay tractable for
    /// per-configuration scheduling during sweeps.
    pub fn small() -> Self {
        Gpt2Config {
            vocab: 50257,
            seq: 256,
            d_model: 768,
            n_head: 12,
            n_layer: 12,
            mlp_ratio: 4,
            batch: 1,
        }
    }

    /// Reduced variant used by unit tests and quick examples.
    pub fn tiny() -> Self {
        Gpt2Config {
            vocab: 256,
            seq: 64,
            d_model: 128,
            n_head: 4,
            n_layer: 2,
            mlp_ratio: 4,
            batch: 1,
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let dm = (self.mlp_ratio * self.d_model) as u64;
        let per_block = 3 * d * d + d * d + d * dm + dm * d + 4 * d;
        (self.vocab as u64) * d + (self.seq as u64) * d + self.n_layer as u64 * per_block + 2 * d
    }
}

/// Forward graph of the decoder-only transformer with causal attention.
pub fn gpt2(cfg: Gpt2Config) -> Graph {
    assert_eq!(cfg.d_model % cfg.n_head, 0);
    let mut b = GraphBuilder::new();
    let d = cfg.d_model;
    let dh = cfg.d_head();

    // token+position embedding: [batch, seq, d]
    let mut x = b.embed(cfg.batch, cfg.seq, cfg.vocab, d);

    for _ in 0..cfg.n_layer {
        // --- attention ---
        let ln1 = b.layer_norm(x);
        let qkv = b.seq_linear(ln1, 3 * d); // [batch, seq, 3d]
        // head-split views: [batch*heads, seq, dh]; the split itself is a
        // reshape (free) so we just reinterpret the handle geometry.
        let mut q = qkv;
        q.batch = cfg.batch * cfg.n_head;
        q.ch = dh;
        q.h = cfg.seq;
        q.w = 1;
        let k = q;
        let v = q;
        // scores = Q Kᵀ : [b·h, seq, seq]
        let scores = b.matmul(q, k, cfg.seq, cfg.seq, dh);
        let probs = b.softmax(scores);
        // ctx = P V : [b·h, seq, dh]
        let ctx = b.matmul(probs, v, cfg.seq, dh, cfg.seq);
        // merge heads back: [batch, seq, d]
        let mut merged = ctx;
        merged.batch = cfg.batch;
        merged.ch = d;
        merged.h = cfg.seq;
        let proj = b.seq_linear(merged, d);
        x = b.add(x, proj);

        // --- mlp ---
        let ln2 = b.layer_norm(x);
        let up = b.seq_linear(ln2, cfg.mlp_ratio * d);
        let act = b.gelu(up);
        let down = b.seq_linear(act, d);
        x = b.add(x, down);
    }

    let lnf = b.layer_norm(x);
    let logits = b.seq_linear(lnf, cfg.vocab);
    b.loss(logits);
    let _ = ReduceKind::Sum; // (reduce helper reserved for variants)
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::op::OpKind;

    #[test]
    fn tiny_structure() {
        let g = gpt2(Gpt2Config::tiny());
        assert!(g.is_dag());
        let gemms = g.nodes.iter().filter(|n| n.kind.is_gemm()).count();
        // per block: qkv, qk, pv, proj, up, down = 6; plus final logits
        assert_eq!(gemms, 6 * 2 + 1);
        let softmaxes = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Softmax { .. }))
            .count();
        assert_eq!(softmaxes, 2);
    }

    #[test]
    fn small_macs_scale() {
        let cfg = Gpt2Config::small();
        let g = gpt2(cfg);
        let gmacs = g.total_macs(None) as f64 / 1e9;
        // ~124M params → fwd ≈ seq·params ≈ 0.256k·0.124G ≈ 32 GMAC + attn
        assert!(gmacs > 15.0 && gmacs < 80.0, "gmacs={gmacs}");
    }

    #[test]
    fn param_count_sanity() {
        // canonical GPT-2 small: ~124M params (incl. embeddings)
        let p = Gpt2Config::small().param_count() as f64 / 1e6;
        assert!(p > 110.0 && p < 140.0, "params={p}M");
    }

    #[test]
    fn attention_matmuls_are_not_weight_gemms() {
        let g = gpt2(Gpt2Config::tiny());
        let act_mm = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Gemm(s) if !s.weight_b))
            .count();
        assert_eq!(act_mm, 2 * 2); // qk + pv per block
    }

    #[test]
    fn batch_scaling() {
        let g1 = gpt2(Gpt2Config::tiny());
        let cfg4 = Gpt2Config { batch: 4, ..Gpt2Config::tiny() };
        let g4 = gpt2(cfg4);
        assert_eq!(g4.total_macs(None), 4 * g1.total_macs(None));
    }
}
