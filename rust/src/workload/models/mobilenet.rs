//! MobileNetV2 (Sandler et al. 2018) — the edge-training workload class the
//! paper motivates via MCUNetv3 (§IV-A): inverted residual bottlenecks with
//! depthwise convolutions, exercising the `groups` dimension of the conv
//! cost model and much lower arithmetic intensity than ResNet.

use crate::workload::builder::{GraphBuilder, T};
use crate::workload::graph::Graph;

/// Inverted residual block: 1×1 expand → 3×3 depthwise → 1×1 project.
fn inverted_residual(b: &mut GraphBuilder, x: T, out_ch: usize, stride: usize, expand: usize) -> T {
    let mid = x.ch * expand;
    let mut h = x;
    if expand != 1 {
        let e = b.conv(h, mid, 1, 1, 0);
        let n = b.batch_norm(e);
        h = b.relu(n); // relu6 modelled as relu
    }
    // depthwise: groups == channels
    let dw = b.conv_grouped(h, mid, 3, stride, 1, mid);
    let n = b.batch_norm(dw);
    let r = b.relu(n);
    let p = b.conv(r, out_ch, 1, 1, 0);
    let pn = b.batch_norm(p);
    if stride == 1 && x.ch == out_ch {
        b.add(pn, x)
    } else {
        pn
    }
}

/// MobileNetV2 forward graph. `width` is the channel multiplier ×100
/// (100 = 1.0×).
pub fn mobilenet_v2(batch: usize, hw: usize, classes: usize, width: usize) -> Graph {
    let w = |c: usize| ((c * width) / 100).max(8);
    let mut b = GraphBuilder::new();
    let x = b.input(batch, 3, hw, hw);
    let stride0 = if hw > 64 { 2 } else { 1 };
    let c = b.conv(x, w(32), 3, stride0, 1);
    let n = b.batch_norm(c);
    let mut h = b.relu(n);

    // (expand, out_ch, repeats, stride) — the canonical V2 schedule
    let blocks: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for &(expand, out_ch, repeats, stride) in &blocks {
        for i in 0..repeats {
            let s = if i == 0 { stride.min(h.h) } else { 1 };
            h = inverted_residual(&mut b, h, w(out_ch), s, expand);
        }
    }
    let c = b.conv(h, w(1280), 1, 1, 0);
    let n = b.batch_norm(c);
    let r = b.relu(n);
    let p = b.global_avg_pool(r);
    let fc = b.linear(p, classes);
    b.loss(fc);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::workload::models::resnet18;
    use crate::workload::op::OpKind;

    #[test]
    fn structure_and_macs() {
        let g = mobilenet_v2(1, 224, 1000, 100);
        assert!(g.is_dag());
        let gmacs = g.total_macs(None) as f64 / 1e9;
        // published: ~0.30 GMACs at 1.0x / 224
        assert!(gmacs > 0.15 && gmacs < 0.6, "gmacs={gmacs}");
    }

    #[test]
    fn depthwise_convs_present() {
        let g = mobilenet_v2(1, 224, 1000, 100);
        let dw = g
            .nodes
            .iter()
            .filter(|n| matches!(&n.kind, OpKind::Conv(s) if s.groups > 1))
            .count();
        assert_eq!(dw, 17); // one per inverted residual
    }

    #[test]
    fn lower_arithmetic_intensity_than_resnet() {
        // MACs per activation byte: mobilenet ≪ resnet (the edge story)
        let mn = mobilenet_v2(1, 224, 1000, 100);
        let rn = resnet18(1, 224, 1000);
        let intensity = |g: &Graph| {
            let bytes: u64 = (0..g.len()).map(|n| g.out_bytes(n)).sum();
            g.total_macs(None) as f64 / bytes as f64
        };
        assert!(intensity(&mn) < intensity(&rn) / 2.0);
    }

    #[test]
    fn trains_end_to_end() {
        let g = mobilenet_v2(1, 32, 10, 50);
        let tg = build_training_graph(&g, TrainOptions::default());
        assert!(tg.graph.is_dag());
        assert!(!tg.saved_activation_sources().is_empty());
    }

    #[test]
    fn width_multiplier_scales_macs() {
        let full = mobilenet_v2(1, 64, 10, 100);
        let half = mobilenet_v2(1, 64, 10, 50);
        let (f, h) = (full.total_macs(None), half.total_macs(None));
        assert!(h < f / 2, "half-width {h} !< full/2 {f}");
    }
}
