//! Small MLP — the minimal workload for unit tests, quickstart, and
//! scheduler/fusion edge-case validation.

use crate::workload::builder::GraphBuilder;
use crate::workload::graph::Graph;

/// `layers` hidden linear+ReLU layers over a flat feature vector.
pub fn mlp(batch: usize, in_features: usize, hidden: usize, layers: usize, classes: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let mut x = b.input(batch, in_features, 1, 1);
    for _ in 0..layers {
        let l = b.linear(x, hidden);
        x = b.relu(l);
    }
    let out = b.linear(x, classes);
    b.loss(out);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let g = mlp(4, 784, 256, 3, 10);
        assert!(g.is_dag());
        // input + 3*(fc+relu) + fc + loss
        assert_eq!(g.len(), 1 + 6 + 1 + 1);
        let gemm_macs: u64 = g
            .nodes
            .iter()
            .filter(|n| n.kind.is_gemm())
            .map(|n| n.kind.macs())
            .sum();
        let want = 4 * (784 * 256 + 256 * 256 * 2 + 256 * 10) as u64;
        assert_eq!(gemm_macs, want);
    }
}
