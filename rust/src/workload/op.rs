//! Operator definitions for the workload IR.
//!
//! A node in the workload graph is an *operator* (the paper's §II-A
//! formalism: nodes = operators, edges = tensors). Each operator carries
//! enough loop-dimension structure for the mapping engine to reason about
//! spatial parallelism, and enough byte/FLOP accounting for the cost model.
//!
//! Training introduces operators absent from inference (the paper §III):
//! gradient primitives decomposed per output (input-grad / weight-grad /
//! bias-grad), explicit transposes and reductions, and optimizer steps.
//! They are first-class `OpKind`s here rather than opaque composites so the
//! fusion solver and scheduler can treat them uniformly.

use std::fmt;

/// Classes of loop dimensions an operator iterates over. Used by the
/// spatial-mapping model to decide how many MACs a dataflow can engage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopDim {
    /// Batch
    B,
    /// Output channels (K in conv nomenclature) / GEMM N
    K,
    /// Input channels / GEMM reduction dim
    C,
    /// Output spatial X
    Ox,
    /// Output spatial Y
    Oy,
    /// Filter X
    Fx,
    /// Filter Y
    Fy,
    /// GEMM M (rows of A / output rows); also sequence length
    M,
    /// Flattened element count for elementwise/reduction ops
    E,
}

/// 2-D convolution geometry (shared by Conv and its gradient primitives).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvSpec {
    pub batch: usize,
    pub in_ch: usize,
    pub out_ch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub k_h: usize,
    pub k_w: usize,
    pub stride: usize,
    pub padding: usize,
    pub groups: usize,
}

impl ConvSpec {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.padding - self.k_h) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.padding - self.k_w) / self.stride + 1
    }
    /// Multiply-accumulate count of the forward conv.
    pub fn macs(&self) -> u64 {
        (self.batch * self.out_ch * self.out_h() * self.out_w()) as u64
            * (self.in_ch / self.groups * self.k_h * self.k_w) as u64
    }
    pub fn weight_elems(&self) -> u64 {
        (self.out_ch * (self.in_ch / self.groups) * self.k_h * self.k_w) as u64
    }
    pub fn out_elems(&self) -> u64 {
        (self.batch * self.out_ch * self.out_h() * self.out_w()) as u64
    }
    pub fn in_elems(&self) -> u64 {
        (self.batch * self.in_ch * self.in_h * self.in_w) as u64
    }
}

/// GEMM geometry: C[M,N] = A[M,K] · B[K,N]. Batched via `batch`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmSpec {
    pub batch: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// True when B is a trained parameter (weight); false for
    /// activation-activation matmuls (e.g. attention QK^T, PV).
    pub weight_b: bool,
}

impl GemmSpec {
    pub fn macs(&self) -> u64 {
        (self.batch * self.m) as u64 * self.n as u64 * self.k as u64
    }
    pub fn out_elems(&self) -> u64 {
        (self.batch * self.m * self.n) as u64
    }
}

/// Pooling geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PoolSpec {
    pub batch: usize,
    pub channels: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub k: usize,
    pub stride: usize,
    pub global: bool,
}

impl PoolSpec {
    pub fn out_h(&self) -> usize {
        if self.global {
            1
        } else {
            (self.in_h - self.k) / self.stride + 1
        }
    }
    pub fn out_w(&self) -> usize {
        if self.global {
            1
        } else {
            (self.in_w - self.k) / self.stride + 1
        }
    }
    pub fn out_elems(&self) -> u64 {
        (self.batch * self.channels * self.out_h() * self.out_w()) as u64
    }
}

/// Elementwise operator flavours. The backward of most of these is itself
/// elementwise (possibly consuming the saved forward activation — exactly
/// the tensors activation checkpointing trades off, paper §II-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EltwiseKind {
    Relu,
    Gelu,
    Tanh,
    Sigmoid,
    Add,
    Mul,
    /// Affine scale+shift (BatchNorm inference form, LayerNorm apply)
    Affine,
    /// Generic copy/cast
    Identity,
}

/// Normalisation flavours (modelled with explicit reduce + affine cost).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NormKind {
    BatchNorm,
    LayerNorm,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
    Mean,
}

/// Optimizer families (paper §II-A, eqs. 4–5 and Adam). Each optimizer step
/// is elementwise over one parameter tensor; `state_per_param` drives the
/// optimizer-state memory accounting of Fig 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Optimizer {
    Sgd,
    SgdMomentum,
    Adam,
    /// GaLore-style (paper §II-A, [17]): Adam applied to a rank-reduced
    /// projection of the gradient — optimizer states shrink by the
    /// compression factor at the cost of projection GEMM work per step.
    Galore,
}

/// GaLore state-compression factor (rank ≈ d / 8 projections).
pub const GALORE_COMPRESSION: u64 = 8;

impl Optimizer {
    /// Number of persistent state tensors per parameter tensor (Galore's
    /// fractional states are handled by `state_bytes`).
    pub fn states_per_param(&self) -> usize {
        match self {
            Optimizer::Sgd => 0,
            Optimizer::SgdMomentum => 1,
            Optimizer::Adam => 2,
            Optimizer::Galore => 2, // held in the compressed domain
        }
    }

    /// Persistent optimizer-state bytes for `param_bytes` of parameters —
    /// the Fig 3 "optimizer states" bar.
    pub fn state_bytes(&self, param_bytes: u64) -> u64 {
        match self {
            Optimizer::Galore => 2 * param_bytes / GALORE_COMPRESSION,
            _ => self.states_per_param() as u64 * param_bytes,
        }
    }

    /// Elementwise operations applied per parameter element per step
    /// (used for FLOP accounting of the update).
    pub fn flops_per_elem(&self) -> u64 {
        match self {
            Optimizer::Sgd => 2,
            Optimizer::SgdMomentum => 4,
            Optimizer::Adam => 10,
            // Adam in the low-rank domain + up/down projection matmuls
            Optimizer::Galore => 10 / GALORE_COMPRESSION + 2 * 2 * GALORE_COMPRESSION,
        }
    }
}

/// The operator taxonomy. Gradient primitives are separate kinds (not a
/// `grad: bool` flag) because their dataflow affinities differ: e.g.
/// `ConvInputGrad` is a transposed conv (input-stationary friendly) while
/// `ConvWeightGrad` reduces over batch+space (output-stationary friendly).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    Conv(ConvSpec),
    /// dL/dInput of a conv — a transposed convolution.
    ConvInputGrad(ConvSpec),
    /// dL/dWeight of a conv — correlation of input with output grad.
    ConvWeightGrad(ConvSpec),
    Gemm(GemmSpec),
    /// dL/dA = dC · Bᵀ
    GemmInputGrad(GemmSpec),
    /// dL/dB = Aᵀ · dC
    GemmWeightGrad(GemmSpec),
    Pool(PoolSpec),
    PoolGrad(PoolSpec),
    Eltwise { kind: EltwiseKind, elems: u64, arity: usize },
    /// Backward of an elementwise op; consumes the upstream grad plus
    /// (for non-linearities) the saved forward activation.
    EltwiseGrad { kind: EltwiseKind, elems: u64 },
    Norm { kind: NormKind, elems: u64, channels: usize },
    NormGrad { kind: NormKind, elems: u64, channels: usize },
    Softmax { rows: usize, cols: usize },
    SoftmaxGrad { rows: usize, cols: usize },
    Reduce { kind: ReduceKind, in_elems: u64, out_elems: u64 },
    Transpose { elems: u64 },
    Reshape { elems: u64 },
    /// Embedding gather (tokens -> vectors).
    Embed { rows: usize, dim: usize, lookups: u64 },
    /// Embedding scatter-add backward.
    EmbedGrad { rows: usize, dim: usize, lookups: u64 },
    /// Cross-entropy loss head (softmax + NLL fused).
    Loss { rows: usize, classes: usize },
    /// Optimizer update for one parameter tensor.
    OptimizerStep { opt: Optimizer, elems: u64 },
}

impl OpKind {
    /// Multiply-accumulate count (FLOPs = 2·macs for MAC-dominated ops; for
    /// pure elementwise ops we count one "mac-equivalent" per op).
    pub fn macs(&self) -> u64 {
        match self {
            OpKind::Conv(s) => s.macs(),
            // dX convolves dY (out_ch maps) with flipped weights back to
            // input geometry: same MAC count as forward.
            OpKind::ConvInputGrad(s) => s.macs(),
            OpKind::ConvWeightGrad(s) => s.macs(),
            OpKind::Gemm(s) | OpKind::GemmInputGrad(s) | OpKind::GemmWeightGrad(s) => {
                s.macs()
            }
            OpKind::Pool(s) => s.out_elems() * (s.k * s.k).max(1) as u64 / 2,
            OpKind::PoolGrad(s) => s.out_elems() * (s.k * s.k).max(1) as u64 / 2,
            OpKind::Eltwise { elems, arity, .. } => elems * (*arity as u64).max(1) / 2,
            OpKind::EltwiseGrad { elems, .. } => *elems,
            OpKind::Norm { elems, .. } => 2 * elems,
            OpKind::NormGrad { elems, .. } => 4 * elems,
            OpKind::Softmax { rows, cols } => 3 * (*rows as u64) * (*cols as u64),
            OpKind::SoftmaxGrad { rows, cols } => 3 * (*rows as u64) * (*cols as u64),
            OpKind::Reduce { in_elems, .. } => in_elems / 2,
            OpKind::Transpose { .. } | OpKind::Reshape { .. } => 0,
            OpKind::Embed { lookups, dim, .. } => lookups * (*dim as u64) / 4,
            OpKind::EmbedGrad { lookups, dim, .. } => lookups * (*dim as u64) / 2,
            OpKind::Loss { rows, classes } => 3 * (*rows as u64) * (*classes as u64),
            OpKind::OptimizerStep { opt, elems } => elems * opt.flops_per_elem() / 2,
        }
    }

    /// Output element count of the operator.
    pub fn out_elems(&self) -> u64 {
        match self {
            OpKind::Conv(s) => s.out_elems(),
            OpKind::ConvInputGrad(s) => s.in_elems(),
            OpKind::ConvWeightGrad(s) => s.weight_elems(),
            OpKind::Gemm(s) => s.out_elems(),
            OpKind::GemmInputGrad(s) => (s.batch * s.m * s.k) as u64,
            OpKind::GemmWeightGrad(s) => (s.k * s.n) as u64,
            OpKind::Pool(s) => s.out_elems(),
            OpKind::PoolGrad(s) => (s.batch * s.channels * s.in_h * s.in_w) as u64,
            OpKind::Eltwise { elems, .. } | OpKind::EltwiseGrad { elems, .. } => *elems,
            OpKind::Norm { elems, .. } | OpKind::NormGrad { elems, .. } => *elems,
            OpKind::Softmax { rows, cols } | OpKind::SoftmaxGrad { rows, cols } => {
                (*rows as u64) * (*cols as u64)
            }
            OpKind::Reduce { out_elems, .. } => *out_elems,
            OpKind::Transpose { elems } | OpKind::Reshape { elems } => *elems,
            OpKind::Embed { lookups, dim, .. } => lookups * (*dim as u64),
            OpKind::EmbedGrad { rows, dim, .. } => (*rows as u64) * (*dim as u64),
            OpKind::Loss { rows, .. } => *rows as u64,
            OpKind::OptimizerStep { elems, .. } => *elems,
        }
    }

    /// Trained-parameter element count read by this op (weights).
    pub fn weight_elems(&self) -> u64 {
        match self {
            OpKind::Conv(s) | OpKind::ConvInputGrad(s) => s.weight_elems(),
            OpKind::ConvWeightGrad(_) => 0, // produces, not consumes, weights
            OpKind::Gemm(s) | OpKind::GemmInputGrad(s) if s.weight_b => {
                (s.k * s.n) as u64
            }
            OpKind::Embed { rows, dim, .. } => (*rows as u64) * (*dim as u64),
            _ => 0,
        }
    }

    /// Loop-dimension signature used by the spatial-mapping model.
    pub fn loop_dims(&self) -> Vec<(LoopDim, usize)> {
        match self {
            OpKind::Conv(s) | OpKind::ConvWeightGrad(s) => vec![
                (LoopDim::B, s.batch),
                (LoopDim::K, s.out_ch),
                (LoopDim::C, s.in_ch / s.groups),
                (LoopDim::Ox, s.out_w()),
                (LoopDim::Oy, s.out_h()),
                (LoopDim::Fx, s.k_w),
                (LoopDim::Fy, s.k_h),
            ],
            OpKind::ConvInputGrad(s) => vec![
                (LoopDim::B, s.batch),
                // roles of K and C swap in the transposed conv
                (LoopDim::K, s.in_ch / s.groups),
                (LoopDim::C, s.out_ch),
                (LoopDim::Ox, s.in_w),
                (LoopDim::Oy, s.in_h),
                (LoopDim::Fx, s.k_w),
                (LoopDim::Fy, s.k_h),
            ],
            OpKind::Gemm(s) => vec![
                (LoopDim::B, s.batch),
                (LoopDim::M, s.m),
                (LoopDim::K, s.n),
                (LoopDim::C, s.k),
            ],
            OpKind::GemmInputGrad(s) => vec![
                (LoopDim::B, s.batch),
                (LoopDim::M, s.m),
                (LoopDim::K, s.k),
                (LoopDim::C, s.n),
            ],
            OpKind::GemmWeightGrad(s) => vec![
                (LoopDim::B, s.batch),
                (LoopDim::M, s.k),
                (LoopDim::K, s.n),
                (LoopDim::C, s.m),
            ],
            OpKind::Pool(s) | OpKind::PoolGrad(s) => vec![
                (LoopDim::B, s.batch),
                (LoopDim::K, s.channels),
                (LoopDim::Ox, s.out_w()),
                (LoopDim::Oy, s.out_h()),
            ],
            other => vec![(LoopDim::E, other.out_elems() as usize)],
        }
    }

    /// True for MAC-array-friendly ops (convs and GEMMs). The fusion
    /// solver's operator-type constraint counts these (paper §V-A).
    pub fn is_conv(&self) -> bool {
        matches!(
            self,
            OpKind::Conv(_) | OpKind::ConvInputGrad(_) | OpKind::ConvWeightGrad(_)
        )
    }
    pub fn is_gemm(&self) -> bool {
        matches!(
            self,
            OpKind::Gemm(_) | OpKind::GemmInputGrad(_) | OpKind::GemmWeightGrad(_)
        )
    }
    /// Elementwise-ish ops: cheap to recompute, profitable to fuse
    /// (Inductor's observation, paper §II-A).
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            OpKind::Eltwise { .. }
                | OpKind::EltwiseGrad { .. }
                | OpKind::Norm { .. }
                | OpKind::Reshape { .. }
                | OpKind::Transpose { .. }
                | OpKind::OptimizerStep { .. }
        )
    }

    /// Feed this operator's full *structural identity* into a hasher: the
    /// kind discriminant plus every geometry/byte-accounting field of its
    /// spec. Two ops with equal structural hash input are interchangeable
    /// for any cost computation: `macs()`, `out_elems()`, `weight_elems()`
    /// and `loop_dims()` are all pure functions of exactly these fields
    /// (which is why the derived `Hash` suffices — `loop_dims` needs no
    /// separate hashing). This is the op half of the memoized-evaluation
    /// cache key (see `eval::cost_cache`).
    pub fn structural_hash<H: std::hash::Hasher>(&self, h: &mut H) {
        use std::hash::Hash;
        self.hash(h);
    }

    /// Short mnemonic for reports.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Conv(_) => "Conv",
            OpKind::ConvInputGrad(_) => "ConvGradX",
            OpKind::ConvWeightGrad(_) => "ConvGradW",
            OpKind::Gemm(_) => "Gemm",
            OpKind::GemmInputGrad(_) => "GemmGradX",
            OpKind::GemmWeightGrad(_) => "GemmGradW",
            OpKind::Pool(_) => "Pool",
            OpKind::PoolGrad(_) => "PoolGrad",
            OpKind::Eltwise { .. } => "Eltwise",
            OpKind::EltwiseGrad { .. } => "EltwiseGrad",
            OpKind::Norm { .. } => "Norm",
            OpKind::NormGrad { .. } => "NormGrad",
            OpKind::Softmax { .. } => "Softmax",
            OpKind::SoftmaxGrad { .. } => "SoftmaxGrad",
            OpKind::Reduce { .. } => "Reduce",
            OpKind::Transpose { .. } => "Transpose",
            OpKind::Reshape { .. } => "Reshape",
            OpKind::Embed { .. } => "Embed",
            OpKind::EmbedGrad { .. } => "EmbedGrad",
            OpKind::Loss { .. } => "Loss",
            OpKind::OptimizerStep { .. } => "OptStep",
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.mnemonic())
    }
}

/// Which phase of the training iteration a node belongs to. Drives the
/// inference-vs-training splits of Figs 1/8/9 and activation lifetimes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
    /// Optimizer update
    Update,
    /// Recompute clone inserted by the checkpointing pass
    Recompute,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv3x3() -> ConvSpec {
        ConvSpec {
            batch: 1,
            in_ch: 16,
            out_ch: 32,
            in_h: 32,
            in_w: 32,
            k_h: 3,
            k_w: 3,
            stride: 1,
            padding: 1,
            groups: 1,
        }
    }

    #[test]
    fn conv_geometry() {
        let s = conv3x3();
        assert_eq!(s.out_h(), 32);
        assert_eq!(s.out_w(), 32);
        assert_eq!(s.macs(), 32 * 32 * 32 * 16 * 9);
        assert_eq!(s.weight_elems(), 32 * 16 * 9);
    }

    #[test]
    fn strided_conv_geometry() {
        let s = ConvSpec { stride: 2, ..conv3x3() };
        assert_eq!(s.out_h(), 16);
        assert_eq!(s.out_w(), 16);
    }

    #[test]
    fn conv_grads_preserve_mac_count() {
        let s = conv3x3();
        assert_eq!(OpKind::ConvInputGrad(s).macs(), OpKind::Conv(s).macs());
        assert_eq!(OpKind::ConvWeightGrad(s).macs(), OpKind::Conv(s).macs());
    }

    #[test]
    fn conv_grad_output_shapes() {
        let s = conv3x3();
        assert_eq!(OpKind::ConvInputGrad(s).out_elems(), s.in_elems());
        assert_eq!(OpKind::ConvWeightGrad(s).out_elems(), s.weight_elems());
    }

    #[test]
    fn gemm_macs_and_grads() {
        let g = GemmSpec { batch: 2, m: 8, n: 16, k: 32, weight_b: true };
        assert_eq!(g.macs(), 2 * 8 * 16 * 32);
        assert_eq!(OpKind::GemmInputGrad(g).out_elems(), 2 * 8 * 32);
        assert_eq!(OpKind::GemmWeightGrad(g).out_elems(), 16 * 32);
        assert_eq!(OpKind::Gemm(g).weight_elems(), 16 * 32);
        let act = GemmSpec { weight_b: false, ..g };
        assert_eq!(OpKind::Gemm(act).weight_elems(), 0);
    }

    #[test]
    fn optimizer_states() {
        assert_eq!(Optimizer::Sgd.states_per_param(), 0);
        assert_eq!(Optimizer::SgdMomentum.states_per_param(), 1);
        assert_eq!(Optimizer::Adam.states_per_param(), 2);
    }

    #[test]
    fn pool_geometry() {
        let p = PoolSpec {
            batch: 1,
            channels: 64,
            in_h: 8,
            in_w: 8,
            k: 8,
            stride: 8,
            global: true,
        };
        assert_eq!(p.out_h(), 1);
        assert_eq!(p.out_elems(), 64);
    }

    #[test]
    fn elementwise_classification() {
        let e = OpKind::Eltwise { kind: EltwiseKind::Relu, elems: 100, arity: 1 };
        assert!(e.is_elementwise());
        assert!(!e.is_conv() && !e.is_gemm());
        assert!(OpKind::Conv(conv3x3()).is_conv());
    }

    #[test]
    fn loop_dims_cover_conv_axes() {
        let dims = OpKind::Conv(conv3x3()).loop_dims();
        let total: usize = dims.iter().map(|(_, s)| *s).product();
        // B*K*C*OX*OY*FX*FY = macs
        assert_eq!(total as u64, OpKind::Conv(conv3x3()).macs());
    }
}
