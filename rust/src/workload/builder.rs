//! Ergonomic graph construction: tensor handles + layer helpers.
//!
//! The model zoo (`workload::models`) uses this builder the way the paper's
//! toolchain uses PyTorch: describe the network once, get the operator graph
//! out. All byte/FLOP accounting flows from `OpKind`, so models stay terse.

use super::graph::{Graph, NodeId};
use super::op::{
    ConvSpec, EltwiseKind, GemmSpec, NormKind, OpKind, Phase, PoolSpec, ReduceKind,
};

/// A tensor handle: the node that produced it plus its logical geometry.
#[derive(Debug, Clone, Copy)]
pub struct T {
    pub node: NodeId,
    /// Channels (feature maps) or model dim
    pub ch: usize,
    pub h: usize,
    pub w: usize,
    /// Batch (or batch·heads for attention internals)
    pub batch: usize,
}

impl T {
    pub fn elems(&self) -> u64 {
        (self.batch * self.ch * self.h * self.w) as u64
    }
}

pub struct GraphBuilder {
    pub g: Graph,
    next_id: usize,
}

impl GraphBuilder {
    pub fn new() -> Self {
        GraphBuilder { g: Graph::new(), next_id: 0 }
    }

    pub fn with_elem_bytes(elem_bytes: u64) -> Self {
        GraphBuilder { g: Graph::with_elem_bytes(elem_bytes), next_id: 0 }
    }

    fn name(&mut self, base: &str) -> String {
        let id = self.next_id;
        self.next_id += 1;
        format!("{base}_{id}")
    }

    fn bytes_of(&self, t: &T) -> u64 {
        t.elems() * self.g.elem_bytes
    }

    /// Network input placeholder (modelled as an Identity elementwise op so
    /// it exists as a node the scheduler can source tensors from).
    pub fn input(&mut self, batch: usize, ch: usize, h: usize, w: usize) -> T {
        let elems = (batch * ch * h * w) as u64;
        let name = self.name("input");
        let node = self.g.add_node(
            name,
            OpKind::Eltwise { kind: EltwiseKind::Identity, elems, arity: 1 },
            Phase::Forward,
        );
        T { node, ch, h, w, batch }
    }

    pub fn conv(&mut self, x: T, out_ch: usize, k: usize, stride: usize, padding: usize) -> T {
        self.conv_grouped(x, out_ch, k, stride, padding, 1)
    }

    pub fn conv_grouped(
        &mut self,
        x: T,
        out_ch: usize,
        k: usize,
        stride: usize,
        padding: usize,
        groups: usize,
    ) -> T {
        let spec = ConvSpec {
            batch: x.batch,
            in_ch: x.ch,
            out_ch,
            in_h: x.h,
            in_w: x.w,
            k_h: k,
            k_w: k,
            stride,
            padding,
            groups,
        };
        let name = self.name("conv");
        let node = self.g.add_node(name, OpKind::Conv(spec), Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: out_ch, h: spec.out_h(), w: spec.out_w(), batch: x.batch }
    }

    pub fn batch_norm(&mut self, x: T) -> T {
        let kind = OpKind::Norm { kind: NormKind::BatchNorm, elems: x.elems(), channels: x.ch };
        let name = self.name("bn");
        let node = self.g.add_node(name, kind, Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ..x }
    }

    pub fn layer_norm(&mut self, x: T) -> T {
        let kind = OpKind::Norm { kind: NormKind::LayerNorm, elems: x.elems(), channels: x.ch };
        let name = self.name("ln");
        let node = self.g.add_node(name, kind, Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ..x }
    }

    pub fn eltwise1(&mut self, x: T, kind: EltwiseKind, base: &str) -> T {
        let op = OpKind::Eltwise { kind, elems: x.elems(), arity: 1 };
        let name = self.name(base);
        let node = self.g.add_node(name, op, Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ..x }
    }

    pub fn relu(&mut self, x: T) -> T {
        self.eltwise1(x, EltwiseKind::Relu, "relu")
    }
    pub fn gelu(&mut self, x: T) -> T {
        self.eltwise1(x, EltwiseKind::Gelu, "gelu")
    }

    pub fn add(&mut self, a: T, b: T) -> T {
        assert_eq!(a.elems(), b.elems(), "residual add requires matching sizes");
        let op = OpKind::Eltwise { kind: EltwiseKind::Add, elems: a.elems(), arity: 2 };
        let name = self.name("add");
        let node = self.g.add_node(name, op, Phase::Forward);
        let (ab, bb) = (self.bytes_of(&a), self.bytes_of(&b));
        self.g.add_edge(a.node, node, ab);
        self.g.add_edge(b.node, node, bb);
        T { node, ..a }
    }

    pub fn mul(&mut self, a: T, b: T) -> T {
        assert_eq!(a.elems(), b.elems());
        let op = OpKind::Eltwise { kind: EltwiseKind::Mul, elems: a.elems(), arity: 2 };
        let name = self.name("mul");
        let node = self.g.add_node(name, op, Phase::Forward);
        let (ab, bb) = (self.bytes_of(&a), self.bytes_of(&b));
        self.g.add_edge(a.node, node, ab);
        self.g.add_edge(b.node, node, bb);
        T { node, ..a }
    }

    pub fn max_pool(&mut self, x: T, k: usize, stride: usize) -> T {
        let spec = PoolSpec {
            batch: x.batch,
            channels: x.ch,
            in_h: x.h,
            in_w: x.w,
            k,
            stride,
            global: false,
        };
        let name = self.name("maxpool");
        let node = self.g.add_node(name, OpKind::Pool(spec), Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: x.ch, h: spec.out_h(), w: spec.out_w(), batch: x.batch }
    }

    pub fn global_avg_pool(&mut self, x: T) -> T {
        let spec = PoolSpec {
            batch: x.batch,
            channels: x.ch,
            in_h: x.h,
            in_w: x.w,
            k: x.h,
            stride: x.h,
            global: true,
        };
        let name = self.name("gap");
        let node = self.g.add_node(name, OpKind::Pool(spec), Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: x.ch, h: 1, w: 1, batch: x.batch }
    }

    /// Fully-connected / linear layer over the flattened tensor:
    /// treats x as [batch·h·w rows? no — batch rows, ch·h·w features].
    pub fn linear(&mut self, x: T, out_features: usize) -> T {
        let in_features = x.ch * x.h * x.w;
        let spec = GemmSpec {
            batch: 1,
            m: x.batch,
            n: out_features,
            k: in_features,
            weight_b: true,
        };
        let name = self.name("fc");
        let node = self.g.add_node(name, OpKind::Gemm(spec), Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: out_features, h: 1, w: 1, batch: x.batch }
    }

    /// Sequence-model linear: x is [batch, rows=h, features=ch]; weight is
    /// [ch, out]. Keeps h as the sequence dimension.
    pub fn seq_linear(&mut self, x: T, out: usize) -> T {
        let spec = GemmSpec { batch: x.batch, m: x.h, n: out, k: x.ch, weight_b: true };
        let name = self.name("proj");
        let node = self.g.add_node(name, OpKind::Gemm(spec), Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: out, h: x.h, w: 1, batch: x.batch }
    }

    /// Activation·activation batched matmul (e.g. attention QKᵀ / PV):
    /// a: [batch, m, k], b interpreted as [batch, k, n].
    pub fn matmul(&mut self, a: T, b: T, m: usize, n: usize, k: usize) -> T {
        assert_eq!(a.batch, b.batch, "batched matmul batch mismatch");
        let spec = GemmSpec { batch: a.batch, m, n, k, weight_b: false };
        let name = self.name("matmul");
        let node = self.g.add_node(name, OpKind::Gemm(spec), Phase::Forward);
        let (ab, bb) = (self.bytes_of(&a), self.bytes_of(&b));
        self.g.add_edge(a.node, node, ab);
        self.g.add_edge(b.node, node, bb);
        T { node, ch: n, h: m, w: 1, batch: a.batch }
    }

    pub fn softmax(&mut self, x: T) -> T {
        let rows = x.batch * x.h;
        let op = OpKind::Softmax { rows, cols: x.ch };
        let name = self.name("softmax");
        let node = self.g.add_node(name, op, Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ..x }
    }

    pub fn embed(&mut self, batch: usize, seq: usize, vocab: usize, dim: usize) -> T {
        let op = OpKind::Embed { rows: vocab, dim, lookups: (batch * seq) as u64 };
        let name = self.name("embed");
        let node = self.g.add_node(name, op, Phase::Forward);
        T { node, ch: dim, h: seq, w: 1, batch }
    }

    pub fn reduce(&mut self, x: T, kind: ReduceKind, out_elems: u64) -> T {
        let op = OpKind::Reduce { kind, in_elems: x.elems(), out_elems };
        let name = self.name("reduce");
        let node = self.g.add_node(name, op, Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: 1, h: 1, w: 1, batch: out_elems as usize }
    }

    /// Cross-entropy loss head over [rows, classes].
    pub fn loss(&mut self, x: T) -> T {
        let rows = x.batch * x.h * x.w;
        let op = OpKind::Loss { rows, classes: x.ch };
        let name = self.name("loss");
        let node = self.g.add_node(name, op, Phase::Forward);
        let bytes = self.bytes_of(&x);
        self.g.add_edge(x.node, node, bytes);
        T { node, ch: 1, h: 1, w: 1, batch: 1 }
    }

    pub fn finish(self) -> Graph {
        self.g
    }
}

impl Default for GraphBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_relu_chain_shapes() {
        let mut b = GraphBuilder::new();
        let x = b.input(1, 3, 32, 32);
        let c = b.conv(x, 16, 3, 1, 1);
        assert_eq!((c.ch, c.h, c.w), (16, 32, 32));
        let r = b.relu(c);
        let p = b.max_pool(r, 2, 2);
        assert_eq!((p.h, p.w), (16, 16));
        let g = b.finish();
        assert_eq!(g.len(), 4);
        assert_eq!(g.edges.len(), 3);
        assert!(g.is_dag());
    }

    #[test]
    fn residual_add_connects_both() {
        let mut b = GraphBuilder::new();
        let x = b.input(1, 8, 8, 8);
        let c1 = b.conv(x, 8, 3, 1, 1);
        let s = b.add(c1, x);
        let g = b.finish();
        assert_eq!(g.in_degree(s.node), 2);
    }

    #[test]
    fn linear_flattens() {
        let mut b = GraphBuilder::new();
        let x = b.input(4, 64, 2, 2);
        let f = b.linear(x, 10);
        assert_eq!(f.elems(), 40);
        let g = b.finish();
        // fc weight = 256*10
        assert_eq!(g.node(f.node).kind.weight_elems(), 2560);
    }

    #[test]
    fn attention_matmul_geometry() {
        let mut b = GraphBuilder::new();
        // q, k as [batch*heads=8, seq=16, dh=4]
        let q = b.input(8, 4, 16, 1);
        let k = b.input(8, 4, 16, 1);
        let s = b.matmul(q, k, 16, 16, 4);
        assert_eq!(s.elems(), 8 * 16 * 16);
        let sm = b.softmax(s);
        assert_eq!(sm.elems(), s.elems());
    }
}
