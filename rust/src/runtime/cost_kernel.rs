//! The AOT Pallas roofline kernel as seen from rust: the DSE pre-filter's
//! hot path (DESIGN.md S13). Rust builds (config × layer) descriptor
//! matrices, pads them to the artifact's fixed shapes, executes the
//! compiled HLO, and unpacks the per-config scores.
//!
//! A bit-exact pure-rust twin (`cost_eval_native`) exists for two reasons:
//! it lets everything above run without artifacts (tests, cold starts),
//! and it cross-validates the full python→HLO→PJRT chain in the
//! integration tests (runtime_roundtrip.rs).

use crate::util::error::Result;

use super::client::{literal_f32, Module, Runtime};

/// Descriptor layouts — must match python/compile/kernels/ref.py.
pub const CFG_W: usize = 8;
pub const LAY_W: usize = 8;
pub const OUT_W: usize = 4;
/// Fixed AOT shapes — must match python/compile/model.py.
pub const N_CFG: usize = 256;
pub const N_LAYER: usize = 1024;

/// One hardware-config descriptor row.
#[derive(Debug, Clone, Copy, Default)]
pub struct CfgRow {
    pub macs: f32,
    pub onchip_bw: f32,
    pub offchip_bw: f32,
    pub local_mem: f32,
    pub e_mac: f32,
    pub e_onchip: f32,
    pub e_offchip: f32,
}

/// One workload-layer descriptor row.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayRow {
    pub flops: f32,
    pub onchip_bytes: f32,
    pub offchip_bytes: f32,
    pub parallelism: f32,
    pub working_set: f32,
    pub weight_bytes: f32,
}

/// Per-config roofline scores.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostOut {
    pub cycles: f32,
    pub energy_pj: f32,
    pub utilization: f32,
    pub spill_bytes: f32,
}

/// The compiled kernel.
pub struct CostKernel {
    module: Module,
}

impl CostKernel {
    pub fn load(rt: &Runtime) -> Result<Self> {
        Ok(CostKernel { module: rt.load("cost_eval")? })
    }

    /// Load the pure-jnp reference artifact instead (ablation/self-check).
    pub fn load_ref(rt: &Runtime) -> Result<Self> {
        Ok(CostKernel { module: rt.load("cost_eval_ref")? })
    }

    /// Score every config against the layer set. Arbitrary lengths: configs
    /// are chunked into batches of N_CFG, layers must fit N_LAYER (the
    /// training graphs here are ≤ ~1.2k nodes; callers aggregate beyond).
    pub fn eval(&self, configs: &[CfgRow], layers: &[LayRow]) -> Result<Vec<CostOut>> {
        assert!(
            layers.len() <= N_LAYER,
            "layer count {} exceeds artifact capacity {N_LAYER}",
            layers.len()
        );
        let mut lay_flat = vec![0f32; N_LAYER * LAY_W];
        for (i, l) in layers.iter().enumerate() {
            let o = i * LAY_W;
            lay_flat[o] = l.flops;
            lay_flat[o + 1] = l.onchip_bytes;
            lay_flat[o + 2] = l.offchip_bytes;
            lay_flat[o + 3] = l.parallelism;
            lay_flat[o + 4] = l.working_set;
            lay_flat[o + 5] = l.weight_bytes;
        }
        let lay_lit = literal_f32(&lay_flat, &[N_LAYER as i64, LAY_W as i64])?;

        let mut out = Vec::with_capacity(configs.len());
        for chunk in configs.chunks(N_CFG) {
            let mut cfg_flat = vec![0f32; N_CFG * CFG_W];
            for (i, c) in chunk.iter().enumerate() {
                let o = i * CFG_W;
                cfg_flat[o] = c.macs;
                cfg_flat[o + 1] = c.onchip_bw;
                cfg_flat[o + 2] = c.offchip_bw;
                cfg_flat[o + 3] = c.local_mem;
                cfg_flat[o + 4] = c.e_mac;
                cfg_flat[o + 5] = c.e_onchip;
                cfg_flat[o + 6] = c.e_offchip;
            }
            let cfg_lit = literal_f32(&cfg_flat, &[N_CFG as i64, CFG_W as i64])?;
            let res = self.module.execute_refs(&[&cfg_lit, &lay_lit])?;
            let flat: Vec<f32> = res[0].to_vec()?;
            for i in 0..chunk.len() {
                let o = i * OUT_W;
                out.push(CostOut {
                    cycles: flat[o],
                    energy_pj: flat[o + 1],
                    utilization: flat[o + 2],
                    spill_bytes: flat[o + 3],
                });
            }
        }
        Ok(out)
    }
}

/// Bit-exact rust twin of the Pallas kernel / jnp oracle (f32 arithmetic,
/// same operation order). Keep in lockstep with ref.py.
pub fn cost_eval_native(configs: &[CfgRow], layers: &[LayRow]) -> Vec<CostOut> {
    const EPS: f32 = 1e-6;
    configs
        .iter()
        .map(|c| {
            let mut total_cyc = 0f32;
            let mut total_energy = 0f32;
            let mut total_spill = 0f32;
            let mut total_flops = 0f32;
            let macs = c.macs.max(EPS);
            for l in layers {
                let eff = macs.min(l.parallelism.max(1.0));
                let compute = l.flops / (2.0 * eff);
                let spill = 2.0 * (l.working_set - c.local_mem).max(0.0);
                let offchip = l.offchip_bytes + spill;
                let mem = (l.onchip_bytes / c.onchip_bw.max(EPS))
                    .max(offchip / c.offchip_bw.max(EPS));
                let cycles = compute.max(mem);
                let energy = 0.5 * l.flops * c.e_mac
                    + l.onchip_bytes * c.e_onchip
                    + offchip * c.e_offchip;
                total_cyc += cycles;
                total_energy += energy;
                total_spill += spill;
                total_flops += l.flops;
            }
            let util = ((0.5 * total_flops) / (c.macs.max(EPS) * total_cyc.max(EPS)))
                .clamp(0.0, 1.0);
            CostOut {
                cycles: total_cyc,
                energy_pj: total_energy,
                utilization: util,
                spill_bytes: total_spill,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_inputs() -> (Vec<CfgRow>, Vec<LayRow>) {
        let configs = vec![
            CfgRow {
                macs: 256.0,
                onchip_bw: 128.0,
                offchip_bw: 64.0,
                local_mem: 2e6,
                e_mac: 0.5,
                e_onchip: 1.0,
                e_offchip: 40.0,
            },
            CfgRow {
                macs: 4096.0,
                onchip_bw: 1024.0,
                offchip_bw: 512.0,
                local_mem: 1e7,
                e_mac: 0.5,
                e_onchip: 1.0,
                e_offchip: 40.0,
            },
        ];
        let layers = vec![
            LayRow {
                flops: 2e8,
                onchip_bytes: 1e6,
                offchip_bytes: 3e5,
                parallelism: 1e6,
                working_set: 3e6,
                weight_bytes: 1e5,
            },
            LayRow {
                flops: 1e6,
                onchip_bytes: 4e5,
                offchip_bytes: 4e5,
                parallelism: 1e6,
                working_set: 1e5,
                weight_bytes: 0.0,
            },
        ];
        (configs, layers)
    }

    #[test]
    fn native_matches_hand_computation() {
        let (configs, layers) = sample_inputs();
        let out = cost_eval_native(&configs, &layers);
        // config 0, layer 0: compute = 2e8/(2*256) = 390625;
        // spill = 2*(3e6-2e6)=2e6; offchip=2.3e6; mem=max(1e6/128, 2.3e6/64)
        // = 35937.5 → compute-bound 390625
        let l0 = 2e8f32 / 512.0;
        // layer 1: compute = 1e6/512 = 1953.125; mem = max(3125, 6250) = 6250
        let l1 = 6250.0f32;
        assert!((out[0].cycles - (l0 + l1)).abs() / (l0 + l1) < 1e-6);
        assert!(out[0].spill_bytes > 0.0 && out[1].spill_bytes == 0.0);
        assert!(out[1].cycles < out[0].cycles);
    }

    #[test]
    fn utilization_bounds() {
        let (configs, layers) = sample_inputs();
        for o in cost_eval_native(&configs, &layers) {
            assert!((0.0..=1.0).contains(&o.utilization));
        }
    }

    #[test]
    fn empty_layers_zero_cost() {
        let (configs, _) = sample_inputs();
        let out = cost_eval_native(&configs, &[]);
        assert_eq!(out[0].cycles, 0.0);
        assert_eq!(out[0].energy_pj, 0.0);
    }
}
