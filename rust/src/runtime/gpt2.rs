//! The tiny-GPT-2 training runner (DESIGN.md S14 / E2E): drives the AOT
//! train-step artifact from rust. Parameters and Adam state live as PJRT
//! literals owned by this struct; each `step` feeds them through the
//! compiled HLO and swaps in the returned updated state. No python anywhere.

use crate::util::error::{Context, Result};

use super::client::{literal_f32, literal_i32, Literal, Module, Runtime};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct Gpt2Meta {
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub batch: usize,
    pub num_params: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
}

impl Gpt2Meta {
    pub fn from_json(meta: &Json, key: &str) -> Result<Self> {
        let g = meta.get(key).with_context(|| format!("meta.json missing {key}"))?;
        let usz = |k: &str| -> Result<usize> {
            g.get(k).and_then(Json::as_usize).with_context(|| format!("meta {key}.{k}"))
        };
        let param_names = g
            .get("param_names")
            .and_then(Json::as_arr)
            .context("param_names")?
            .iter()
            .map(|j| j.as_str().unwrap_or("").to_string())
            .collect();
        let param_shapes = g
            .get("param_shapes")
            .and_then(Json::as_arr)
            .context("param_shapes")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect::<Vec<usize>>()
            })
            .collect();
        Ok(Gpt2Meta {
            vocab: usz("vocab")?,
            seq: usz("seq")?,
            d_model: usz("d_model")?,
            n_layer: usz("n_layer")?,
            batch: usz("batch")?,
            num_params: usz("num_params")?,
            param_names,
            param_shapes,
        })
    }
}

pub struct Gpt2Runner {
    train: Module,
    eval: Module,
    pub meta: Gpt2Meta,
    params: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    pub step_count: u64,
}

impl Gpt2Runner {
    /// Load artifacts for config `cfg_name` (e.g. "tiny") and initialise
    /// parameters from the `gpt2_<cfg>_init.bin` blob.
    pub fn load(rt: &Runtime, cfg_name: &str) -> Result<Self> {
        let meta_json = rt.meta()?;
        let meta = Gpt2Meta::from_json(&meta_json, &format!("gpt2_{cfg_name}"))?;
        let train = rt.load(&format!("gpt2_{cfg_name}_train"))?;
        let eval = rt.load(&format!("gpt2_{cfg_name}_eval"))?;

        let init_path = rt
            .artifacts_dir()
            .join(format!("gpt2_{cfg_name}_init.bin"));
        let raw = std::fs::read(&init_path)
            .with_context(|| format!("reading {}", init_path.display()))?;
        crate::ensure!(
            raw.len() == meta.num_params * 4,
            "init blob size {} != {} params × 4",
            raw.len(),
            meta.num_params
        );
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut params = Vec::with_capacity(meta.param_shapes.len());
        let mut m = Vec::with_capacity(meta.param_shapes.len());
        let mut v = Vec::with_capacity(meta.param_shapes.len());
        let mut off = 0usize;
        for shape in &meta.param_shapes {
            let n: usize = shape.iter().product();
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            params.push(literal_f32(&floats[off..off + n], &dims)?);
            m.push(literal_f32(&vec![0f32; n], &dims)?);
            v.push(literal_f32(&vec![0f32; n], &dims)?);
            off += n;
        }
        Ok(Gpt2Runner { train, eval, meta, params, m, v, step_count: 0 })
    }

    /// One training step on a [batch, seq+1] token window. Returns the loss.
    pub fn step(&mut self, tokens: &[i32]) -> Result<f32> {
        let b = self.meta.batch;
        let s = self.meta.seq + 1;
        crate::ensure!(tokens.len() == b * s, "expected {}x{} tokens", b, s);
        self.step_count += 1;

        let n = self.params.len();
        let tok_lit = literal_i32(tokens, &[b as i64, s as i64])?;
        let step_lit = Literal::from(self.step_count as f32);
        let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n + 2);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.push(&tok_lit);
        inputs.push(&step_lit);

        let mut out = self.train.execute_refs(&inputs)?;
        crate::ensure!(out.len() == 1 + 3 * n, "train step arity {}", out.len());
        let loss = out[0].get_first_element::<f32>()?;
        // swap in updated state (drain from the back to avoid shifting)
        let new_v: Vec<Literal> = out.drain(1 + 2 * n..).collect();
        let new_m: Vec<Literal> = out.drain(1 + n..).collect();
        let new_p: Vec<Literal> = out.drain(1..).collect();
        self.params = new_p;
        self.m = new_m;
        self.v = new_v;
        Ok(loss)
    }

    /// Loss on a token window without updating parameters.
    pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
        let b = self.meta.batch;
        let s = self.meta.seq + 1;
        crate::ensure!(tokens.len() == b * s, "expected {}x{} tokens", b, s);
        let tok_lit = literal_i32(tokens, &[b as i64, s as i64])?;
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.params.len() + 1);
        inputs.extend(self.params.iter());
        inputs.push(&tok_lit);
        let out = self.eval.execute_refs(&inputs)?;
        Ok(out[0].get_first_element::<f32>()?)
    }
}

/// Synthetic byte corpus: a deterministic, learnable token stream (repeating
/// structured patterns + mild noise) for the e2e training demo.
pub struct Corpus {
    data: Vec<i32>,
    cursor: usize,
}

impl Corpus {
    pub fn synthetic(vocab: usize, len: usize, seed: u64) -> Self {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(seed);
        let mut data = Vec::with_capacity(len);
        // repeating arithmetic motifs of varying period — compressible
        // structure a 2-layer transformer learns quickly
        let mut t = 0usize;
        while data.len() < len {
            let period = 3 + rng.usize(6);
            let base = rng.usize(vocab.saturating_sub(period).max(1));
            for _ in 0..(period * (4 + rng.usize(4))) {
                data.push(((base + t % period) % vocab) as i32);
                t += 1;
                if data.len() >= len {
                    break;
                }
            }
        }
        Corpus { data, cursor: 0 }
    }

    /// Next [batch, seq+1] window, wrapping around.
    pub fn next_batch(&mut self, batch: usize, seq_plus1: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            for i in 0..seq_plus1 {
                out.push(self.data[(self.cursor + i) % self.data.len()]);
            }
            self.cursor = (self.cursor + seq_plus1) % self.data.len();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_in_vocab() {
        let mut a = Corpus::synthetic(256, 1000, 7);
        let mut b = Corpus::synthetic(256, 1000, 7);
        let ba = a.next_batch(2, 65);
        let bb = b.next_batch(2, 65);
        assert_eq!(ba, bb);
        assert_eq!(ba.len(), 130);
        assert!(ba.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn corpus_wraps() {
        let mut c = Corpus::synthetic(16, 50, 1);
        for _ in 0..10 {
            let b = c.next_batch(4, 33);
            assert_eq!(b.len(), 132);
        }
    }

    #[test]
    fn meta_parses_from_json() {
        let j = Json::parse(
            r#"{"gpt2_tiny": {"vocab": 256, "seq": 64, "d_model": 128,
                "n_head": 4, "n_layer": 2, "mlp_ratio": 4, "batch": 8,
                "lr": 0.003, "num_params": 437760,
                "param_names": ["tok_emb"], "param_shapes": [[256, 128]]}}"#,
        )
        .unwrap();
        let m = Gpt2Meta::from_json(&j, "gpt2_tiny").unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.batch, 8);
        assert_eq!(m.param_shapes[0], vec![256, 128]);
    }
}
