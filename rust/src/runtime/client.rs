//! PJRT client wrapper (DESIGN.md S12): loads AOT HLO-text artifacts
//! produced by `python/compile/aot.py`, compiles them once, and executes
//! them from the rust hot path. Python never runs at runtime.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

/// Re-exported so the rest of the runtime layer names one `Literal` type
/// whether the real client or the no-pjrt stub is compiled.
pub use xla::Literal;

/// Shared PJRT CPU client. Create once per process (client startup is
/// ~100 ms and owns threadpools).
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

/// One compiled HLO module, ready to execute.
pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load `<artifacts>/<name>.hlo.txt` and compile it.
    ///
    /// HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
    /// 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids (see DESIGN.md / aot.py).
    pub fn load(&self, name: &str) -> Result<Module> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Module { exe, name: name.to_string() })
    }

    /// Read the artifact metadata (meta.json).
    pub fn meta(&self) -> Result<crate::util::Json> {
        let path = self.artifacts_dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        crate::util::Json::parse(&text).map_err(|e| crate::anyhow!("{e}"))
    }
}

impl Module {
    /// Execute with literal inputs. All our AOT graphs are lowered with
    /// `return_tuple=True`, so the single output is a tuple that we
    /// flatten into a `Vec<Literal>`.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run(inputs)
    }

    /// Zero-copy variant: borrow the inputs (hot-path friendly — parameters
    /// stay owned by the caller across steps).
    pub fn execute_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run(inputs)
    }

    fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("untupling result")
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 && dims[0] as usize == data.len() {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshaping literal")
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 && dims[0] as usize == data.len() {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshaping literal")
}
