//! PJRT runtime (DESIGN.md S12-S14): the xla-crate wrapper that loads and
//! executes the AOT artifacts from `make artifacts` — the roofline cost
//! kernel (DSE pre-filter hot path) and the tiny-GPT-2 training step
//! (end-to-end stack validation).

#[cfg(feature = "pjrt")]
pub mod client;
/// Without the `pjrt` feature the client module is an API-compatible stub
/// whose `Runtime::new` fails, routing all callers to the native twin.
#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod client;
pub mod cost_kernel;
pub mod gpt2;

pub use client::{literal_f32, literal_i32, Literal, Module, Runtime};
pub use cost_kernel::{cost_eval_native, CfgRow, CostKernel, CostOut, LayRow};
pub use gpt2::{Corpus, Gpt2Meta, Gpt2Runner};
