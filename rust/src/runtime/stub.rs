//! API-compatible stand-in for `client.rs` compiled when the `pjrt`
//! feature is off (the `xla` crate is not vendored in this offline
//! environment — see Cargo.toml). `Runtime::new` always fails, so every
//! caller takes its no-artifacts path: the DSE pre-filter falls back to
//! the bit-exact native twin (`cost_eval_native`) and the runtime
//! round-trip tests skip with a note, exactly as on a checkout without
//! `make artifacts`.
//!
//! Nothing here can execute: `Runtime` and `Module` have unconstructable
//! private fields, so the method bodies that "run" artifacts are
//! statically dead code kept only to satisfy the shared call sites.

use std::path::Path;

use crate::util::error::Result;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` feature (xla crate not vendored)";

/// Placeholder for `xla::Literal`.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

impl From<f32> for Literal {
    fn from(_: f32) -> Self {
        Literal { _private: () }
    }
}

/// Placeholder for the PJRT CPU client; construction always fails.
pub struct Runtime {
    _private: (),
}

/// Placeholder for a compiled HLO module.
pub struct Module {
    pub name: String,
    _private: (),
}

impl Runtime {
    pub fn new(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn artifacts_dir(&self) -> &Path {
        Path::new("artifacts")
    }

    pub fn load(&self, _name: &str) -> Result<Module> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn meta(&self) -> Result<crate::util::Json> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

impl Module {
    pub fn execute(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn execute_refs(&self, _inputs: &[&Literal]) -> Result<Vec<Literal>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(_data: &[f32], _dims: &[i64]) -> Result<Literal> {
    Err(crate::anyhow!("{UNAVAILABLE}"))
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(_data: &[i32], _dims: &[i64]) -> Result<Literal> {
    Err(crate::anyhow!("{UNAVAILABLE}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_construction_reports_missing_feature() {
        let err = Runtime::new("artifacts").err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn literal_builders_fail_cleanly() {
        assert!(literal_f32(&[1.0], &[1]).is_err());
        assert!(literal_i32(&[1], &[1]).is_err());
    }
}
