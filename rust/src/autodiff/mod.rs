//! Training-graph generation (DESIGN.md S3/S4): symbolic backward pass with
//! decomposed gradient primitives, optimizer insertion, and the activation-
//! checkpointing transform. Replaces ONNX Runtime Training + the paper's
//! custom ONNX passes.

pub mod backward;
pub mod checkpoint;

pub use backward::{build_training_graph, TrainOptions, TrainingGraph};
pub use checkpoint::{
    apply_checkpointing, checkpoint_candidates, recompute_macs,
    stored_activation_bytes, CheckpointPlan,
};
