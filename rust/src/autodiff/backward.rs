//! Training-graph generation: symbolic backward-pass construction.
//!
//! This is MONET's core workflow contribution (paper §III), rebuilt from
//! scratch: where the paper runs ONNX Runtime Training and then decomposes
//! composite gradient ops (ConvGrad, SoftmaxGrad, …) with custom ONNX
//! passes, we differentiate our IR directly — emitting the *decomposed*
//! primitives immediately: separate input-gradient, weight-gradient and
//! bias/affine-gradient nodes, explicit gradient accumulation for fan-out,
//! and per-parameter optimizer-update nodes.
//!
//! Every tensor the backward pass reads from the forward pass becomes a
//! *saved-activation edge* (`Edge::is_activation`), which is exactly the
//! checkpointing candidate set 𝒜 of §II-A / §V-B.

use std::collections::HashMap;

use crate::workload::graph::{Graph, NodeId};
use crate::workload::op::{EltwiseKind, OpKind, Optimizer, Phase};

/// Result of the autodiff pass.
#[derive(Debug, Clone)]
pub struct TrainingGraph {
    /// Combined forward + backward (+ optimizer) graph. Forward nodes keep
    /// their ids from the input graph (0..fwd_len).
    pub graph: Graph,
    /// Number of forward nodes (prefix of `graph.nodes`).
    pub fwd_len: usize,
    /// fwd node -> node producing the gradient w.r.t. its *output*.
    pub grad_of: HashMap<NodeId, NodeId>,
    /// Optimizer-update nodes, one per parameter tensor.
    pub update_nodes: Vec<NodeId>,
    pub optimizer: Optimizer,
}

#[derive(Debug, Clone, Copy)]
pub struct TrainOptions {
    pub optimizer: Optimizer,
    /// Include optimizer-update nodes (false models pure fwd+bwd).
    pub include_update: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { optimizer: Optimizer::Sgd, include_update: true }
    }
}

/// Differentiate a forward graph into a full training-iteration graph.
pub fn build_training_graph(fwd: &Graph, opts: TrainOptions) -> TrainingGraph {
    let mut g = fwd.clone();
    let fwd_len = fwd.len();
    let topo = fwd.topo_order();

    // Gradient contributions accumulated per forward node's output.
    let mut contrib: Vec<Vec<NodeId>> = vec![vec![]; fwd_len];
    let mut grad_of: HashMap<NodeId, NodeId> = HashMap::new();
    let mut update_nodes: Vec<NodeId> = vec![];

    // helper: record that `src_grad_node` contributes grad to fwd node `t`
    // (gradient tensor has the byte size of t's output).
    let add_contrib = |contrib: &mut Vec<Vec<NodeId>>, t: NodeId, gnode: NodeId| {
        contrib[t].push(gnode);
    };

    for &n in topo.iter().rev() {
        let node = fwd.node(n).clone();

        // ---- resolve the accumulated output gradient of n ----
        let grad_out: Option<NodeId> = if matches!(node.kind, OpKind::Loss { .. }) {
            None // the loss seeds gradients; it has no incoming grad
        } else {
            let contribs = contrib[n].clone();
            match contribs.len() {
                0 => {
                    // No consumer needed this node's gradient (e.g. dead
                    // branch) — nothing to backpropagate through it.
                    continue;
                }
                1 => Some(contribs[0]),
                _ => {
                    // fan-out: accumulate with a chain of binary adds
                    let elems = node.kind.out_elems();
                    let bytes = elems * g.elem_bytes;
                    let mut acc = contribs[0];
                    for &c in &contribs[1..] {
                        let add = g.add_node_with_origin(
                            format!("gacc[{}]", node.name),
                            OpKind::Eltwise { kind: EltwiseKind::Add, elems, arity: 2 },
                            Phase::Backward,
                            n,
                        );
                        g.add_edge(acc, add, bytes);
                        g.add_edge(c, add, bytes);
                        acc = add;
                    }
                    Some(acc)
                }
            }
        };
        if let Some(gn) = grad_out {
            grad_of.insert(n, gn);
        }

        let preds: Vec<NodeId> = fwd.predecessors(n).collect();
        let in_bytes = |g: &Graph, p: NodeId| g.node(p).kind.out_elems() * g.elem_bytes;
        let gbytes = node.kind.out_elems() * g.elem_bytes;

        // convenience for the per-parameter optimizer step
        let mut emit_update =
            |g: &mut Graph, wgrad: NodeId, elems: u64, label: &str| {
                if !opts.include_update {
                    return;
                }
                let up = g.add_node_with_origin(
                    format!("opt[{label}]"),
                    OpKind::OptimizerStep { opt: opts.optimizer, elems },
                    Phase::Update,
                    n,
                );
                g.add_edge(wgrad, up, elems * g.elem_bytes);
                update_nodes.push(up);
            };

        match node.kind.clone() {
            OpKind::Loss { rows, classes } => {
                // dL/dlogits = softmax(logits) - onehot: softmax-grad cost,
                // consumes the saved logits.
                let gnode = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::SoftmaxGrad { rows, cols: classes },
                    Phase::Backward,
                    n,
                );
                let p = preds[0];
                let b = in_bytes(&g, p);
                g.add_activation_edge(p, gnode, b);
                add_contrib(&mut contrib, p, gnode);
            }

            OpKind::Conv(spec) => {
                let go = grad_out.unwrap();
                let p = preds[0];
                // dX — transposed conv, consumes grad_out (+weights)
                if fwd.in_degree(p) > 0 || !matches!(fwd.node(p).kind, OpKind::Eltwise { kind: EltwiseKind::Identity, .. }) {
                    let dx = g.add_node_with_origin(
                        format!("dX[{}]", node.name),
                        OpKind::ConvInputGrad(spec),
                        Phase::Backward,
                        n,
                    );
                    g.add_edge(go, dx, gbytes);
                    add_contrib(&mut contrib, p, dx);
                }
                // dW — consumes grad_out + saved input activation
                let dw = g.add_node_with_origin(
                    format!("dW[{}]", node.name),
                    OpKind::ConvWeightGrad(spec),
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dw, gbytes);
                let b = in_bytes(&g, p);
                g.add_activation_edge(p, dw, b);
                emit_update(&mut g, dw, spec.weight_elems(), &node.name);
            }

            OpKind::Gemm(spec) => {
                let go = grad_out.unwrap();
                if spec.weight_b {
                    let p = preds[0];
                    // dA = dC · Bᵀ (weights re-read, no activation needed)
                    let dx = g.add_node_with_origin(
                        format!("dX[{}]", node.name),
                        OpKind::GemmInputGrad(spec),
                        Phase::Backward,
                        n,
                    );
                    g.add_edge(go, dx, gbytes);
                    add_contrib(&mut contrib, p, dx);
                    // dB = Aᵀ · dC (consumes saved input activation)
                    let dw = g.add_node_with_origin(
                        format!("dW[{}]", node.name),
                        OpKind::GemmWeightGrad(spec),
                        Phase::Backward,
                        n,
                    );
                    g.add_edge(go, dw, gbytes);
                    let b = in_bytes(&g, p);
                    g.add_activation_edge(p, dw, b);
                    emit_update(&mut g, dw, (spec.k * spec.n) as u64, &node.name);
                } else {
                    // activation·activation matmul (QKᵀ, PV): both operands
                    // get gradients, each needing the *other* saved operand.
                    let (pa, pb) = (preds[0], preds[1]);
                    let da = g.add_node_with_origin(
                        format!("dA[{}]", node.name),
                        OpKind::GemmInputGrad(spec),
                        Phase::Backward,
                        n,
                    );
                    g.add_edge(go, da, gbytes);
                    let bb = in_bytes(&g, pb);
                    g.add_activation_edge(pb, da, bb);
                    add_contrib(&mut contrib, pa, da);

                    let db = g.add_node_with_origin(
                        format!("dB[{}]", node.name),
                        OpKind::GemmWeightGrad(spec),
                        Phase::Backward,
                        n,
                    );
                    g.add_edge(go, db, gbytes);
                    let ba = in_bytes(&g, pa);
                    g.add_activation_edge(pa, db, ba);
                    add_contrib(&mut contrib, pb, db);
                }
            }

            OpKind::Eltwise { kind, elems, .. } => {
                let go = grad_out.unwrap();
                match kind {
                    EltwiseKind::Add => {
                        // grad flows unchanged to both inputs
                        for &p in &preds {
                            add_contrib(&mut contrib, p, go);
                        }
                    }
                    EltwiseKind::Identity => {
                        for &p in &preds {
                            add_contrib(&mut contrib, p, go);
                        }
                    }
                    EltwiseKind::Mul => {
                        // d(a·b)/da = grad·b — each side saves the other
                        for (i, &p) in preds.iter().enumerate() {
                            let other = preds[1 - i];
                            let dn = g.add_node_with_origin(
                                format!("d[{}]/{}", node.name, i),
                                OpKind::EltwiseGrad { kind, elems },
                                Phase::Backward,
                                n,
                            );
                            g.add_edge(go, dn, gbytes);
                            let b = in_bytes(&g, other);
                            g.add_activation_edge(other, dn, b);
                            add_contrib(&mut contrib, p, dn);
                        }
                    }
                    // unary non-linearities: need a saved forward tensor.
                    // ReLU needs only its output's sign; GeLU/Tanh/Sigmoid
                    // need the forward activation (we save the op's output,
                    // matching what frameworks retain).
                    _ => {
                        let dn = g.add_node_with_origin(
                            format!("d[{}]", node.name),
                            OpKind::EltwiseGrad { kind, elems },
                            Phase::Backward,
                            n,
                        );
                        g.add_edge(go, dn, gbytes);
                        g.add_activation_edge(n, dn, gbytes);
                        if let Some(&p) = preds.first() {
                            add_contrib(&mut contrib, p, dn);
                        }
                    }
                }
            }

            OpKind::Norm { kind, elems, channels } => {
                let go = grad_out.unwrap();
                let dn = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::NormGrad { kind, elems, channels },
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dn, gbytes);
                // needs the saved normalised input
                let p = preds[0];
                let b = in_bytes(&g, p);
                g.add_activation_edge(p, dn, b);
                add_contrib(&mut contrib, p, dn);
                // scale+shift parameter update (2·channels params)
                emit_update(&mut g, dn, 2 * channels as u64, &node.name);
            }

            OpKind::Pool(spec) => {
                let go = grad_out.unwrap();
                let dn = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::PoolGrad(spec),
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dn, gbytes);
                // max-pool routing needs saved argmax indices (output-sized)
                g.add_activation_edge(n, dn, gbytes);
                add_contrib(&mut contrib, preds[0], dn);
            }

            OpKind::Softmax { rows, cols } => {
                let go = grad_out.unwrap();
                let dn = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::SoftmaxGrad { rows, cols },
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dn, gbytes);
                // softmax backward consumes its own saved output
                g.add_activation_edge(n, dn, gbytes);
                add_contrib(&mut contrib, preds[0], dn);
            }

            OpKind::Embed { rows, dim, lookups } => {
                let go = grad_out.unwrap();
                let dn = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::EmbedGrad { rows, dim, lookups },
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dn, gbytes);
                emit_update(&mut g, dn, (rows * dim) as u64, &node.name);
            }

            OpKind::Reduce { kind, in_elems, out_elems } => {
                let go = grad_out.unwrap();
                // broadcast back: modelled as a reduce-shaped grad op
                let dn = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::Reduce { kind, in_elems: out_elems, out_elems: in_elems },
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dn, gbytes);
                add_contrib(&mut contrib, preds[0], dn);
            }

            OpKind::Transpose { elems } | OpKind::Reshape { elems } => {
                let go = grad_out.unwrap();
                let dn = g.add_node_with_origin(
                    format!("d[{}]", node.name),
                    OpKind::Transpose { elems },
                    Phase::Backward,
                    n,
                );
                g.add_edge(go, dn, gbytes);
                add_contrib(&mut contrib, preds[0], dn);
            }

            // backward-only kinds can never appear in a forward graph
            OpKind::ConvInputGrad(_)
            | OpKind::ConvWeightGrad(_)
            | OpKind::GemmInputGrad(_)
            | OpKind::GemmWeightGrad(_)
            | OpKind::PoolGrad(_)
            | OpKind::EltwiseGrad { .. }
            | OpKind::NormGrad { .. }
            | OpKind::SoftmaxGrad { .. }
            | OpKind::EmbedGrad { .. }
            | OpKind::OptimizerStep { .. } => {
                panic!("gradient op {:?} in a forward graph", node.kind)
            }
        }
    }

    TrainingGraph { graph: g, fwd_len, grad_of, update_nodes, optimizer: opts.optimizer }
}

impl TrainingGraph {
    /// Forward nodes whose outputs must be saved for the backward pass —
    /// the unique sources of the activation-edge set 𝒜.
    pub fn saved_activation_sources(&self) -> Vec<NodeId> {
        let mut srcs: Vec<NodeId> = self
            .graph
            .edges
            .iter()
            .filter(|e| e.is_activation)
            .map(|e| e.src)
            .collect();
        srcs.sort_unstable();
        srcs.dedup();
        srcs
    }

    /// Total bytes of saved activations (the Fig 3 "activations" bar).
    pub fn saved_activation_bytes(&self) -> u64 {
        self.saved_activation_sources()
            .iter()
            .map(|&n| self.graph.out_bytes(n))
            .sum()
    }

    /// Parameter bytes (Fig 3 "parameters" bar).
    pub fn param_bytes(&self) -> u64 {
        let from_updates: u64 = self
            .update_nodes
            .iter()
            .map(|&n| self.graph.node(n).kind.out_elems() * self.graph.elem_bytes)
            .sum();
        from_updates
    }

    /// Gradient bytes == parameter bytes (one grad per param).
    pub fn grad_bytes(&self) -> u64 {
        self.param_bytes()
    }

    /// Optimizer-state bytes (Fig 3 "optimizer states" bar). GaLore holds
    /// its states in the rank-compressed domain (§II-A, [17]).
    pub fn optimizer_state_bytes(&self) -> u64 {
        self.optimizer.state_bytes(self.param_bytes())
    }

    /// Saved-activation bytes under Gist-style compression (§II-A, [18]):
    /// ReLU outputs kept only as 1-bit signs, max-pool routing kept as
    /// small indices; everything else stored raw.
    pub fn saved_activation_bytes_gist(&self) -> u64 {
        use crate::workload::op::{EltwiseKind, OpKind};
        self.saved_activation_sources()
            .iter()
            .map(|&n| {
                let bytes = self.graph.out_bytes(n);
                match &self.graph.node(n).kind {
                    // 1 bit per element instead of elem_bytes
                    OpKind::Eltwise { kind: EltwiseKind::Relu, .. } => {
                        (bytes / (8 * self.graph.elem_bytes)).max(1)
                    }
                    // pool argmax indices: 1 byte per output element
                    OpKind::Pool(_) => (bytes / self.graph.elem_bytes).max(1),
                    _ => bytes,
                }
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{gpt2, mlp, resnet18, Gpt2Config};
    use crate::workload::op::Phase;

    fn train(g: &Graph, opt: Optimizer) -> TrainingGraph {
        build_training_graph(g, TrainOptions { optimizer: opt, include_update: true })
    }

    #[test]
    fn mlp_training_graph_is_dag() {
        let fwd = mlp(2, 32, 64, 2, 10);
        let tg = train(&fwd, Optimizer::Sgd);
        assert!(tg.graph.is_dag());
        assert!(tg.graph.len() > fwd.len() * 2);
    }

    #[test]
    fn one_update_per_parameter_tensor() {
        let fwd = mlp(2, 32, 64, 2, 10);
        let tg = train(&fwd, Optimizer::Adam);
        // 3 linear layers → 3 weight updates
        assert_eq!(tg.update_nodes.len(), 3);
        let updates: u64 = tg
            .update_nodes
            .iter()
            .map(|&n| tg.graph.node(n).kind.out_elems())
            .sum();
        assert_eq!(updates, (32 * 64 + 64 * 64 + 64 * 10) as u64);
    }

    #[test]
    fn resnet18_training_node_count_matches_paper_scale() {
        // The paper quotes N ≈ 500 for ResNet-18 training (§V-A); their
        // ONNX decomposition also materialises transposes/reshapes that we
        // fold into the gradient primitives, so our count sits lower but in
        // the same "several-hundred-node" regime.
        let fwd = resnet18(1, 32, 10);
        let tg = train(&fwd, Optimizer::Sgd);
        let n = tg.graph.len();
        assert!(n > 150 && n < 700, "n={n}");
        assert!(tg.graph.is_dag());
    }

    #[test]
    fn backward_macs_roughly_double_forward() {
        // classic rule of thumb: bwd ≈ 2× fwd MACs for conv nets
        let fwd = resnet18(1, 32, 10);
        let tg = train(&fwd, Optimizer::Sgd);
        let f = tg.graph.total_macs(Some(Phase::Forward)) as f64;
        let b = tg.graph.total_macs(Some(Phase::Backward)) as f64;
        let ratio = b / f;
        assert!(ratio > 1.5 && ratio < 2.6, "ratio={ratio}");
    }

    #[test]
    fn activation_edges_exist_and_point_backward() {
        let fwd = resnet18(1, 32, 10);
        let tg = train(&fwd, Optimizer::Sgd);
        let acts = tg.graph.activation_edges();
        assert!(!acts.is_empty());
        for &e in &acts {
            let edge = tg.graph.edge(e);
            assert!(edge.src < tg.fwd_len, "activation source must be a fwd node");
            assert!(edge.dst >= tg.fwd_len, "activation consumer must be bwd");
        }
    }

    #[test]
    fn fanout_gets_accumulation_nodes() {
        // residual blocks fan out → gradient accumulation adds must appear
        let fwd = resnet18(1, 32, 10);
        let tg = train(&fwd, Optimizer::Sgd);
        let gacc = tg
            .graph
            .nodes
            .iter()
            .filter(|n| n.name.starts_with("gacc["))
            .count();
        assert!(gacc > 0);
    }

    #[test]
    fn adam_states_double_params() {
        let fwd = mlp(1, 16, 16, 1, 4);
        let sgd = train(&fwd, Optimizer::Sgd);
        let adam = train(&fwd, Optimizer::Adam);
        assert_eq!(sgd.optimizer_state_bytes(), 0);
        assert_eq!(adam.optimizer_state_bytes(), 2 * adam.param_bytes());
    }

    #[test]
    fn galore_shrinks_states_but_costs_flops() {
        use crate::workload::op::GALORE_COMPRESSION;
        let fwd = mlp(1, 16, 16, 1, 4);
        let adam = train(&fwd, Optimizer::Adam);
        let galore = train(&fwd, Optimizer::Galore);
        assert_eq!(
            galore.optimizer_state_bytes(),
            adam.optimizer_state_bytes() / GALORE_COMPRESSION
        );
        // the update itself does more work (projections)
        let upd_macs = |tg: &TrainingGraph| {
            tg.update_nodes
                .iter()
                .map(|&n| tg.graph.node(n).kind.macs())
                .sum::<u64>()
        };
        assert!(upd_macs(&galore) > upd_macs(&adam));
    }

    #[test]
    fn gist_compression_reduces_activation_bytes() {
        use crate::workload::models::resnet18;
        let tg = train(&resnet18(1, 32, 10), Optimizer::Sgd);
        let raw = tg.saved_activation_bytes();
        let gist = tg.saved_activation_bytes_gist();
        // ReLU outputs are ~1/3 of the saved set in our decomposition
        // (conv inputs and norm inputs stay raw), so Gist trims that third
        // to sign bits — a 20-35% cut at this granularity
        assert!(gist < raw * 4 / 5, "gist {gist} !< 0.8*raw {raw}");
        assert!(gist > raw / 4);
    }

    #[test]
    fn gpt2_training_graph() {
        let tg = train(&gpt2(Gpt2Config::tiny()), Optimizer::Adam);
        assert!(tg.graph.is_dag());
        // attention matmuls produce dA and dB nodes
        let dabs = tg
            .graph
            .nodes
            .iter()
            .filter(|n| n.name.starts_with("dA[") || n.name.starts_with("dB["))
            .count();
        assert_eq!(dabs, 2 * 2 * 2); // 2 matmuls × 2 grads × 2 layers
    }

    #[test]
    fn grad_of_covers_loss_input_chain() {
        let fwd = mlp(1, 8, 8, 1, 4);
        let tg = train(&fwd, Optimizer::Sgd);
        // every weight-bearing fwd node's input has a gradient producer
        for n in 0..tg.fwd_len {
            let kind = &tg.graph.node(n).kind;
            if kind.is_gemm() {
                assert!(
                    tg.grad_of.contains_key(&n),
                    "gemm node {n} missing output grad"
                );
            }
        }
    }
}
