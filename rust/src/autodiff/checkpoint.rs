//! Activation checkpointing as a graph transformation pass (paper §III,
//! §V-B): selected saved activations are dropped and replaced by recompute
//! subgraphs containing only the minimal operators needed to regenerate
//! them before their backward consumers.

use std::collections::{HashMap, HashSet};

use super::backward::TrainingGraph;
use crate::workload::graph::{Graph, NodeId};
use crate::workload::op::Phase;

/// A checkpointing decision: the set of forward nodes whose saved outputs
/// are *dropped* (recomputed in the backward pass). Everything else in the
/// saved-activation set stays checkpointed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckpointPlan {
    pub recompute: HashSet<NodeId>,
}

impl CheckpointPlan {
    pub fn save_all() -> Self {
        Self::default()
    }

    pub fn recompute_set(nodes: impl IntoIterator<Item = NodeId>) -> Self {
        CheckpointPlan { recompute: nodes.into_iter().collect() }
    }
}

/// Candidate activations for checkpointing decisions: forward nodes with at
/// least one saved-activation edge AND at least one predecessor (network
/// inputs cannot be recomputed — there is nothing to recompute them from).
pub fn checkpoint_candidates(tg: &TrainingGraph) -> Vec<NodeId> {
    tg.saved_activation_sources()
        .into_iter()
        .filter(|&n| tg.graph.in_degree(n) > 0)
        .collect()
}

/// Stored-activation bytes under a plan (the GA's memory objective; the
/// paper reports it in FP16 — scale at the call site if desired).
pub fn stored_activation_bytes(tg: &TrainingGraph, plan: &CheckpointPlan) -> u64 {
    tg.saved_activation_sources()
        .iter()
        .filter(|n| !plan.recompute.contains(n))
        .map(|&n| tg.graph.out_bytes(n))
        .sum()
}

/// Apply a checkpointing plan, producing the transformed training graph.
///
/// For every dropped activation `a`, we build its *recompute closure*: the
/// ancestors of `a` (inclusive) that are themselves unstored, walking back
/// until hitting stored activations or network inputs. The closure is
/// cloned once into the graph as `Phase::Recompute` nodes (shared between
/// all backward consumers — recomputing AC10 and AC01 together shares
/// ancestor work, which is exactly the non-additivity of Fig 11), the
/// boundary reads come from stored tensors, and every saved-activation edge
/// out of `a` is rewired to the clone.
pub fn apply_checkpointing(tg: &TrainingGraph, plan: &CheckpointPlan) -> Graph {
    if plan.recompute.is_empty() {
        return tg.graph.clone();
    }
    let src = &tg.graph;
    let stored: HashSet<NodeId> = tg
        .saved_activation_sources()
        .into_iter()
        .filter(|n| !plan.recompute.contains(n))
        .collect();

    // 1. recompute closure over all dropped activations
    let mut closure: HashSet<NodeId> = HashSet::new();
    let mut stack: Vec<NodeId> = plan
        // audit:allow(DT02): seeds a DFS whose output is the `closure` set — membership is visit-order-independent, and every consumer below iterates it sorted (`closure_sorted`) or via `topo_order`
        .recompute
        .iter()
        .copied()
        .filter(|&n| n < tg.fwd_len && src.in_degree(n) > 0)
        .collect();
    while let Some(n) = stack.pop() {
        if !closure.insert(n) {
            continue;
        }
        for p in src.predecessors(n) {
            let is_boundary = stored.contains(&p) || src.in_degree(p) == 0;
            if !is_boundary && !closure.contains(&p) {
                stack.push(p);
            }
        }
    }

    // 2. rebuild the graph without the dropped activation edges
    let mut g = Graph::with_elem_bytes(src.elem_bytes);
    for n in &src.nodes {
        let id = g.add_node(n.name.clone(), n.kind.clone(), n.phase);
        g.nodes[id].origin = n.origin;
    }
    let dropped: Vec<bool> = src
        .edges
        .iter()
        .map(|e| e.is_activation && plan.recompute.contains(&e.src))
        .collect();
    for (i, e) in src.edges.iter().enumerate() {
        if !dropped[i] {
            g.add_edge_full(e.src, e.dst, e.bytes, e.is_activation);
        }
    }

    // 3. clone the closure as recompute nodes
    let mut clone_map: HashMap<NodeId, NodeId> = HashMap::new();
    for &n in src.topo_order().iter().filter(|n| closure.contains(n)) {
        let node = src.node(n);
        let c = g.add_node(format!("{}@rc", node.name), node.kind.clone(), Phase::Recompute);
        g.nodes[c].origin = Some(node.origin.unwrap_or(n));
        clone_map.insert(n, c);
    }
    // internal + boundary edges of the closure, in deterministic node
    // order: HashSet iteration order varies per instance, and edge
    // insertion order is observable downstream (fuse_greedy scans
    // predecessors in edge order) — identical plans must yield identical
    // graphs for the memoized evaluation engine to be reproducible
    let mut closure_sorted: Vec<NodeId> = closure.iter().copied().collect();
    closure_sorted.sort_unstable();
    for &n in &closure_sorted {
        for e in src.in_edges(n) {
            if e.is_activation {
                continue; // fwd→bwd edges don't drive recompute
            }
            let c = clone_map[&n];
            match clone_map.get(&e.src) {
                Some(&cs) => g.add_edge(cs, c, e.bytes),
                None => g.add_edge(e.src, c, e.bytes), // read from stored tensor
            };
        }
    }

    // 4. rewire dropped activation edges to the recompute clones. The edge
    // becomes a plain data edge: the tensor is now produced just-in-time.
    for (i, e) in src.edges.iter().enumerate() {
        if dropped[i] {
            let c = clone_map[&e.src];
            g.add_edge(c, e.dst, e.bytes);
        }
    }

    g
}

/// Recompute MACs added by a plan (reporting / quick cost estimates; the
/// true latency/energy impact comes from scheduling the transformed graph).
pub fn recompute_macs(tg: &TrainingGraph, plan: &CheckpointPlan) -> u64 {
    let g = apply_checkpointing(tg, plan);
    g.nodes
        .iter()
        .filter(|n| n.phase == Phase::Recompute)
        .map(|n| n.kind.macs())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::backward::{build_training_graph, TrainOptions};
    use crate::workload::models::{mlp, resnet18};
    use crate::workload::op::Optimizer;

    fn tg_mlp() -> TrainingGraph {
        build_training_graph(
            &mlp(1, 16, 32, 3, 8),
            TrainOptions { optimizer: Optimizer::Sgd, include_update: true },
        )
    }

    #[test]
    fn save_all_is_identity() {
        let tg = tg_mlp();
        let g = apply_checkpointing(&tg, &CheckpointPlan::save_all());
        assert_eq!(g.len(), tg.graph.len());
        assert_eq!(g.edges.len(), tg.graph.edges.len());
    }

    #[test]
    fn candidates_exclude_inputs() {
        let tg = tg_mlp();
        for &c in &checkpoint_candidates(&tg) {
            assert!(tg.graph.in_degree(c) > 0);
        }
    }

    #[test]
    fn recompute_one_activation_adds_clones_and_stays_dag() {
        let tg = tg_mlp();
        let cands = checkpoint_candidates(&tg);
        let plan = CheckpointPlan::recompute_set([cands[cands.len() / 2]]);
        let g = apply_checkpointing(&tg, &plan);
        assert!(g.is_dag());
        let rc = g.nodes.iter().filter(|n| n.phase == Phase::Recompute).count();
        assert!(rc >= 1);
        // no activation edge may remain sourced at the dropped node
        for e in g.edges.iter().filter(|e| e.is_activation) {
            assert!(!plan.recompute.contains(&e.src));
        }
    }

    #[test]
    fn memory_strictly_decreases() {
        let tg = tg_mlp();
        let cands = checkpoint_candidates(&tg);
        let base = stored_activation_bytes(&tg, &CheckpointPlan::save_all());
        let plan = CheckpointPlan::recompute_set([cands[0]]);
        let less = stored_activation_bytes(&tg, &plan);
        assert!(less < base);
        assert_eq!(base - less, tg.graph.out_bytes(cands[0]));
    }

    #[test]
    fn backward_consumers_still_reachable_from_producers() {
        // semantic preservation: every bwd node that consumed a dropped
        // activation now has a recompute clone as predecessor instead.
        let tg = tg_mlp();
        let cands = checkpoint_candidates(&tg);
        let victim = cands[1];
        let consumers: Vec<NodeId> = tg
            .graph
            .edges
            .iter()
            .filter(|e| e.is_activation && e.src == victim)
            .map(|e| e.dst)
            .collect();
        assert!(!consumers.is_empty());
        let plan = CheckpointPlan::recompute_set([victim]);
        let g = apply_checkpointing(&tg, &plan);
        for &c in &consumers {
            let has_rc_pred = g
                .predecessors(c)
                .any(|p| g.node(p).phase == Phase::Recompute);
            assert!(has_rc_pred, "consumer {c} lost its activation source");
        }
    }

    #[test]
    fn shared_ancestors_cloned_once() {
        // recomputing two adjacent activations must share clones, not
        // duplicate them (the Fig 11 non-additivity mechanism)
        let tg = build_training_graph(
            &resnet18(1, 32, 10),
            TrainOptions { optimizer: Optimizer::Sgd, include_update: false },
        );
        let cands = checkpoint_candidates(&tg);
        let (a, b) = (cands[2], cands[3]);
        let ga = apply_checkpointing(&tg, &CheckpointPlan::recompute_set([a]));
        let gb = apply_checkpointing(&tg, &CheckpointPlan::recompute_set([b]));
        let gab = apply_checkpointing(&tg, &CheckpointPlan::recompute_set([a, b]));
        let rc = |g: &Graph| g.nodes.iter().filter(|n| n.phase == Phase::Recompute).count();
        assert!(rc(&gab) <= rc(&ga) + rc(&gb));
        assert!(gab.is_dag());
    }

    #[test]
    fn recompute_macs_monotone_under_inclusion() {
        let tg = tg_mlp();
        let cands = checkpoint_candidates(&tg);
        let m1 = recompute_macs(&tg, &CheckpointPlan::recompute_set([cands[0]]));
        let m2 = recompute_macs(
            &tg,
            &CheckpointPlan::recompute_set([cands[0], cands[1]]),
        );
        assert!(m2 >= m1);
    }
}
