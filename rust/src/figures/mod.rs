//! One function per paper artifact (DESIGN.md §3): each regenerates the
//! data behind a figure/table, writes a CSV under `results/`, and returns
//! the rows so examples/benches/tests can assert the paper's qualitative
//! claims. The examples/ binaries add the ASCII rendering.

use std::path::Path;

use crate::autodiff::{
    apply_checkpointing, build_training_graph, checkpoint_candidates,
    stored_activation_bytes, CheckpointPlan, TrainOptions, TrainingGraph,
};
use crate::dse::{
    cluster_search, hetero_search, pareto_front, run_sweep_outcome, ClusterSearchOutcome,
    ClusterSpace, DesignPoint, Mode, PointFailure, SweepConfig, SweepRow,
};
use crate::eval::{persist, CacheStats};
use crate::fusion::{fuse, fuse_greedy, fuse_manual_conv_bn_relu, FusionConstraints};
use crate::ga::{CheckpointProblem, GaConfig};
use crate::hardware::presets::EdgeTpuParams;
use crate::mapping::MappingConfig;
use crate::report::write_csv;
use crate::scheduler::{schedule, Partition, ScheduleResult};
use crate::workload::models::{gpt2, resnet18, resnet50, Gpt2Config};
use crate::workload::op::Optimizer;

fn csv_of_sweep(path: &Path, rows: &[SweepRow]) -> std::io::Result<()> {
    write_csv(
        path,
        "index,label,mode,total_macs,color_axis,latency_cycles,energy_pj,peak_dram_bytes,utilization",
        rows.iter().map(|r| {
            vec![
                r.index.to_string(),
                format!("\"{}\"", r.label),
                r.mode.as_str().to_string(),
                r.total_macs.to_string(),
                format!("{:.6e}", r.color_axis),
                format!("{:.6e}", r.latency_cycles),
                format!("{:.6e}", r.energy_pj),
                r.peak_dram_bytes.to_string(),
                format!("{:.4}", r.utilization),
            ]
        }),
    )
}

// ---------------------------------------------------------------------------
// Figs 1 & 8 — ResNet-18 on the Edge TPU space
// ---------------------------------------------------------------------------

pub struct EdgeSweep {
    pub rows: Vec<SweepRow>,
    /// Counters of the group-cost cache shared across the sweep's worker
    /// pool (zeros when the sweep ran with `--no-cache`).
    pub cache: CacheStats,
    /// Design points whose evaluation panicked, isolated by the engine
    /// (empty on a clean run; such points have no rows).
    pub failures: Vec<PointFailure>,
    /// Points replayed from the `--run-dir` journal instead of
    /// re-evaluated (0 without `--resume`).
    pub resumed: usize,
}

/// Sweep the Table II space (strided) with ResNet-18 fwd + training graphs
/// on CIFAR-sized inputs, both modes — the data behind Fig 1 (energy vs
/// latency) and Fig 8 (energy/latency vs total compute resource).
pub fn fig1_fig8_edge_sweep(
    stride: usize,
    out_dir: Option<&Path>,
    progress: impl FnMut(usize, usize),
) -> EdgeSweep {
    fig1_fig8_edge_sweep_cfg(stride, true, None, 0, None, false, out_dir, progress)
}

/// [`fig1_fig8_edge_sweep`] with the cache lifecycle knobs: `use_cache`
/// (`--no-cache` escape hatch, wins over everything), `cache_dir`
/// (`--cache-dir` persistence) and `cache_cap` (`--cache-cap` bound,
/// 0 = unbounded) — plus the crash-safety knobs: `run_dir` (`--run-dir`
/// journaling) and `resume` (`--resume` replay of completed points).
/// Points whose evaluation panics are isolated into
/// [`EdgeSweep::failures`] rather than aborting the sweep.
pub fn fig1_fig8_edge_sweep_cfg(
    stride: usize,
    use_cache: bool,
    cache_dir: Option<&Path>,
    cache_cap: usize,
    run_dir: Option<&Path>,
    resume: bool,
    out_dir: Option<&Path>,
    mut progress: impl FnMut(usize, usize),
) -> EdgeSweep {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
    );
    let points = DesignPoint::edge_space(stride);
    let cfg = SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        use_cache,
        cache_dir: cache_dir.map(|p| p.to_path_buf()),
        cache_cap,
        run_dir: run_dir.map(|p| p.to_path_buf()),
        resume,
        ..Default::default()
    };
    let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |d, n| progress(d, n))
        .unwrap_or_else(|e| panic!("edge sweep failed: {e}"));
    if let Some(dir) = out_dir {
        csv_of_sweep(&dir.join("fig1_fig8_edge_sweep.csv"), &out.rows).unwrap();
    }
    EdgeSweep { rows: out.rows, cache: out.cache, failures: out.failures, resumed: out.resumed }
}

// ---------------------------------------------------------------------------
// Fig 3 — ResNet-50 peak-memory breakdown
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MemoryBreakdown {
    pub batch: usize,
    pub params_bytes: u64,
    pub grads_bytes: u64,
    pub optstate_bytes: u64,
    pub activation_bytes: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.params_bytes + self.grads_bytes + self.optstate_bytes + self.activation_bytes
    }
}

/// Fig 3: the four memory components of a ResNet-50 Adam training
/// iteration at 224², batch 1 and 8 (FP32, like the paper's RTX3090
/// measurement).
pub fn fig3_memory_breakdown(out_dir: Option<&Path>) -> Vec<MemoryBreakdown> {
    let mut out = vec![];
    for &batch in &[1usize, 8] {
        let fwd = resnet50(batch, 224, 1000);
        let tg = build_training_graph(
            &fwd,
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        );
        out.push(MemoryBreakdown {
            batch,
            params_bytes: tg.param_bytes(),
            grads_bytes: tg.grad_bytes(),
            optstate_bytes: tg.optimizer_state_bytes(),
            activation_bytes: tg.saved_activation_bytes(),
        });
    }
    if let Some(dir) = out_dir {
        write_csv(
            dir.join("fig3_memory_breakdown.csv"),
            "batch,params_bytes,grads_bytes,optstate_bytes,activation_bytes,total_bytes",
            out.iter().map(|m| {
                vec![
                    m.batch.to_string(),
                    m.params_bytes.to_string(),
                    m.grads_bytes.to_string(),
                    m.optstate_bytes.to_string(),
                    m.activation_bytes.to_string(),
                    m.total().to_string(),
                ]
            }),
        )
        .unwrap();
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 5 — cluster-scale parallelism Pareto front (edge → datacenter)
// ---------------------------------------------------------------------------

/// One workload's slice of the Fig 5 data.
pub struct ClusterFigure {
    pub workload: String,
    pub outcome: ClusterSearchOutcome,
}

/// Shared `cluster`-command / `fig5` evaluation setup: the enumerated
/// deployment space for `max_devices` plus the baseline Edge-TPU
/// accelerator and mapping every cluster row is modeled on — one
/// definition so the CLI, the figure, and the tests cannot drift apart.
pub fn cluster_setup(
    max_devices: usize,
) -> (ClusterSpace, crate::hardware::accelerator::Accelerator, MappingConfig) {
    (
        ClusterSpace::default_space(max_devices),
        EdgeTpuParams::baseline().build(),
        MappingConfig::edge_tpu_default(),
    )
}

/// Canonical Fig 5 / `cluster`-command ResNet-18 training workload (Adam,
/// CIFAR-sized inputs) for a given per-device batch. One definition so
/// the figure, the CLI, and the tests all model the same graphs.
pub fn cluster_resnet18_builder(batch: usize) -> TrainingGraph {
    build_training_graph(
        &resnet18(batch.max(1), 32, 10),
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    )
}

/// Canonical Fig 5 / `cluster`-command GPT-2 training workload: the
/// reduced `tiny` config (kept sweep-tractable for the same reason Fig 9
/// reduces its workload), Adam, at a given per-device batch.
pub fn cluster_gpt2_builder(batch: usize) -> TrainingGraph {
    build_training_graph(
        &gpt2(Gpt2Config { batch: batch.max(1), ..Gpt2Config::tiny() }),
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    )
}

/// Canonical mixed edge+datacenter device pool for a cluster of exactly
/// `max_devices` devices: half battery-class edge parts (odd budgets
/// round the edge half up), half datacenter-class parts. A 1-device
/// budget degenerates to a pure-edge pool (no mixed placements exist on
/// one device). One definition so the Fig 5 mixed series and the tests
/// model the same pool.
pub fn cluster_mixed_pool(max_devices: usize) -> crate::parallelism::HeteroCluster {
    use crate::parallelism::{DeviceClass, HeteroCluster};
    let n = max_devices.max(1);
    HeteroCluster::new(vec![
        (DeviceClass::edge(), n.div_ceil(2)),
        (DeviceClass::datacenter(), n / 2),
    ])
}

/// Fig 5 made quantitative: enumerate the cluster deployment space
/// (device counts × link tiers × DP/PP/TP factorizations) for ResNet-18
/// and GPT-2 training on clusters of baseline Edge TPUs, rank it with the
/// four-objective NSGA-II set (iteration latency, energy, per-device
/// memory, cluster size) and emit every row plus its front membership.
/// The GPT-2 workload is the reduced `tiny` config for the same
/// tractability reason Fig 9 reduces its sweep workload. A third,
/// **mixed-cluster** series re-runs the GPT-2 workload on the
/// [`cluster_mixed_pool`] edge+datacenter pool with the stage-placement
/// dimension enumerated — the heterogeneous front the paper's
/// edge-to-datacenter title promises.
pub fn fig5_cluster_pareto(
    max_devices: usize,
    full_batch: usize,
    use_cache: bool,
    cache_dir: Option<&Path>,
    cache_cap: usize,
    run_dir: Option<&Path>,
    resume: bool,
    out_dir: Option<&Path>,
    mut progress: impl FnMut(usize, usize),
) -> Vec<ClusterFigure> {
    let (space, accel, mapping) = cluster_setup(max_devices);
    // each series journals into its own subdirectory: the two homogeneous
    // series enumerate the *same* space (identical point ids → identical
    // journal digest), so sharing one journal file would let a resume
    // replay one workload's rows into the other
    let cfg = |series: &str| SweepConfig {
        mapping,
        use_cache,
        cache_dir: cache_dir.map(|p| p.to_path_buf()),
        cache_cap,
        run_dir: run_dir.map(|p| p.join(series)),
        resume,
        ..Default::default()
    };
    let resnet_outcome = cluster_search(
        &space,
        full_batch,
        &cluster_resnet18_builder,
        &accel,
        &cfg("resnet18"),
        &mut progress,
    );
    let gpt2_outcome = cluster_search(
        &space,
        full_batch,
        &cluster_gpt2_builder,
        &accel,
        &cfg("gpt2"),
        &mut progress,
    );
    let pool = cluster_mixed_pool(max_devices);
    let mixed_outcome = hetero_search(
        &pool,
        &space.microbatches,
        full_batch,
        &cluster_gpt2_builder,
        &cfg("gpt2-mixed"),
        &mut progress,
    );
    let figures = vec![
        ClusterFigure { workload: "resnet18".into(), outcome: resnet_outcome },
        ClusterFigure { workload: "gpt2".into(), outcome: gpt2_outcome },
        ClusterFigure { workload: "gpt2-mixed".into(), outcome: mixed_outcome },
    ];
    if let Some(dir) = out_dir {
        write_csv(
            dir.join("fig5_cluster_pareto.csv"),
            "workload,index,label,tier,devices,dp,pp,microbatches,tp,placement,latency_cycles,energy_pj,per_device_mem_bytes,comm_bytes,on_front",
            figures.iter().flat_map(|f| {
                let front: std::collections::HashSet<usize> =
                    f.outcome.front.iter().copied().collect();
                f.outcome.rows.iter().map(move |r| {
                    vec![
                        f.workload.clone(),
                        r.index.to_string(),
                        format!("\"{}\"", r.label),
                        r.tier.as_str().to_string(),
                        r.devices.to_string(),
                        r.dp.to_string(),
                        r.pp.to_string(),
                        r.microbatches.to_string(),
                        r.tp.to_string(),
                        format!("\"{}\"", r.placement),
                        format!("{:.6e}", r.latency_cycles),
                        format!("{:.6e}", r.energy_pj),
                        r.per_device_mem_bytes.to_string(),
                        format!("{:.6e}", r.comm_bytes),
                        front.contains(&r.index).to_string(),
                    ]
                })
            }),
        )
        .unwrap();
    }
    figures
}

/// CSV emitter for the `ga-cluster` command, in the Fig 5 column layout
/// plus a `front` provenance column: every point of the final
/// (backbone ∪ GA) rank-0 front, followed by the block-fallback baseline
/// front it is measured against — so the head-to-head comparison the CLI
/// prints is reproducible from the artifact alone.
pub fn write_ga_cluster_csv(
    dir: &Path,
    workload: &str,
    out: &crate::dse::GaClusterOutcome,
) -> std::io::Result<()> {
    fn row(workload: &str, front: &str, r: &crate::dse::ClusterRow) -> Vec<String> {
        vec![
            workload.to_string(),
            front.to_string(),
            r.index.to_string(),
            format!("\"{}\"", r.label),
            r.tier.as_str().to_string(),
            r.devices.to_string(),
            r.dp.to_string(),
            r.pp.to_string(),
            r.microbatches.to_string(),
            r.tp.to_string(),
            format!("\"{}\"", r.placement),
            format!("{:.6e}", r.latency_cycles),
            format!("{:.6e}", r.energy_pj),
            r.per_device_mem_bytes.to_string(),
            format!("{:.6e}", r.comm_bytes),
        ]
    }
    write_csv(
        dir.join(format!("ga_cluster_front_{workload}.csv")),
        "workload,front,index,label,tier,devices,dp,pp,microbatches,tp,placement,latency_cycles,energy_pj,per_device_mem_bytes,comm_bytes",
        out.rows
            .iter()
            .map(|r| row(workload, "union", r))
            .chain(out.fallback_front.iter().map(|r| row(workload, "fallback", r))),
    )
}

// ---------------------------------------------------------------------------
// Fig 9 — GPT-2 on the FuseMax space
// ---------------------------------------------------------------------------

/// The §IV-B workload: a reduced "small GPT-2" kept sweep-tractable while
/// preserving the structural homogeneity the paper highlights.
pub fn fig9_gpt2_config() -> Gpt2Config {
    Gpt2Config { n_layer: 6, seq: 256, vocab: 50257, d_model: 768, n_head: 12, mlp_ratio: 4, batch: 1 }
}

pub fn fig9_fusemax_sweep(
    stride: usize,
    out_dir: Option<&Path>,
    progress: impl FnMut(usize, usize),
) -> EdgeSweep {
    fig9_fusemax_sweep_cfg(stride, true, None, 0, None, false, out_dir, progress)
}

/// [`fig9_fusemax_sweep`] with the cache lifecycle and crash-safety knobs
/// (see [`fig1_fig8_edge_sweep_cfg`]).
pub fn fig9_fusemax_sweep_cfg(
    stride: usize,
    use_cache: bool,
    cache_dir: Option<&Path>,
    cache_cap: usize,
    run_dir: Option<&Path>,
    resume: bool,
    out_dir: Option<&Path>,
    mut progress: impl FnMut(usize, usize),
) -> EdgeSweep {
    let fwd = gpt2(fig9_gpt2_config());
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let points = DesignPoint::fusemax_space(stride);
    let cfg = SweepConfig {
        mapping: MappingConfig::fusemax_default(),
        use_cache,
        cache_dir: cache_dir.map(|p| p.to_path_buf()),
        cache_cap,
        run_dir: run_dir.map(|p| p.to_path_buf()),
        resume,
        ..Default::default()
    };
    let out = run_sweep_outcome(&points, &fwd, &tg.graph, &cfg, |d, n| progress(d, n))
        .unwrap_or_else(|e| panic!("fusemax sweep failed: {e}"));
    if let Some(dir) = out_dir {
        csv_of_sweep(&dir.join("fig9_fusemax_sweep.csv"), &out.rows).unwrap();
    }
    EdgeSweep { rows: out.rows, cache: out.cache, failures: out.failures, resumed: out.resumed }
}

// ---------------------------------------------------------------------------
// Fig 10 — fusion strategies
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct FusionStrategyRow {
    pub strategy: String,
    pub n_groups: usize,
    pub latency_cycles: f64,
    pub energy_pj: f64,
}

/// Fig 10: ResNet-18 inference on the baseline Edge TPU under Base
/// (layer-by-layer), Manual (conv+bn+relu), and our solver with subgraph
/// limits 4..=8 (operator-type constraint off, as in the paper's §V-A2
/// optimum discussion).
pub fn fig10_fusion_strategies(out_dir: Option<&Path>) -> Vec<FusionStrategyRow> {
    let g = resnet18(1, 32, 10);
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let mut rows = vec![];
    let mut eval = |name: &str, p: &Partition| {
        let r = schedule(&g, p, &accel, &mapping);
        rows.push(FusionStrategyRow {
            strategy: name.to_string(),
            n_groups: p.len(),
            latency_cycles: r.latency_cycles,
            energy_pj: r.energy_pj,
        });
    };
    eval("Base", &Partition::singletons(&g));
    eval("Manual", &fuse_manual_conv_bn_relu(&g));
    for limit in 4..=8usize {
        let c = FusionConstraints {
            max_len: limit,
            op_type_constraint: false,
            per_seed_cap: 128,
            ..Default::default()
        };
        eval(&format!("Limit{limit}"), &fuse(&g, &c));
    }
    if let Some(dir) = out_dir {
        write_csv(
            dir.join("fig10_fusion_strategies.csv"),
            "strategy,n_groups,latency_cycles,energy_pj",
            rows.iter().map(|r| {
                vec![
                    r.strategy.clone(),
                    r.n_groups.to_string(),
                    format!("{:.6e}", r.latency_cycles),
                    format!("{:.6e}", r.energy_pj),
                ]
            }),
        )
        .unwrap();
    }
    rows
}

// ---------------------------------------------------------------------------
// Fig 11 — activation-checkpointing non-linearity
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct LinearityRow {
    pub scenario: String,
    pub latency_delta: f64,
    pub energy_delta: f64,
}

/// Fig 11: recompute the first (AC10), second (AC01) and both (AC11) early
/// backward-used activations of ResNet-18 on the base Edge TPU, under a
/// consistent mapping and our fusion solver; report deltas vs AC00.
/// The paper's claim: delta(AC11) ≠ delta(AC10) + delta(AC01).
pub fn fig11_checkpoint_linearity(out_dir: Option<&Path>) -> Vec<LinearityRow> {
    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let fc = FusionConstraints { per_seed_cap: 96, ..Default::default() };

    let eval = |plan: &CheckpointPlan| -> ScheduleResult {
        let g = apply_checkpointing(&tg, plan);
        let p = fuse_greedy(&g, &fc);
        schedule(&g, &p, &accel, &mapping)
    };
    let base = eval(&CheckpointPlan::save_all());

    // "the first and second activations used during the backward pass and
    // generated by the first layers" (§V-B1). The paper's figure is an
    // illustrative instance; we pick it deterministically: among the
    // activations of the stem + first residual block (the first 8
    // candidates), take the dependent pair whose recompute decisions
    // interact most strongly (largest |Δ(AC11) − Δ(AC10) − Δ(AC01)| on
    // energy). Interaction requires shared recompute ancestry and/or a
    // change in what the fusion solver can merge — the two mechanisms the
    // paper names.
    let cands = checkpoint_candidates(&tg);
    let head = &cands[..cands.len().min(8)];
    let (a, b) = {
        let mut best = (head[0], head[1]);
        let mut best_gap = f64::MIN;
        for (i, &x) in head.iter().enumerate() {
            for &y in &head[i + 1..] {
                if !tg.graph.ancestors(y).contains(&x) {
                    continue;
                }
                let dx = eval(&CheckpointPlan::recompute_set([x]));
                let dy = eval(&CheckpointPlan::recompute_set([y]));
                let dxy = eval(&CheckpointPlan::recompute_set([x, y]));
                let gap = ((dxy.energy_pj - base.energy_pj)
                    - (dx.energy_pj - base.energy_pj)
                    - (dy.energy_pj - base.energy_pj))
                    .abs();
                if gap > best_gap {
                    best_gap = gap;
                    best = (x, y);
                }
            }
        }
        best
    };
    let scenarios: Vec<(&str, CheckpointPlan)> = vec![
        ("AC10", CheckpointPlan::recompute_set([a])),
        ("AC01", CheckpointPlan::recompute_set([b])),
        ("AC11", CheckpointPlan::recompute_set([a, b])),
    ];
    let rows: Vec<LinearityRow> = scenarios
        .into_iter()
        .map(|(name, plan)| {
            let r = eval(&plan);
            LinearityRow {
                scenario: name.to_string(),
                latency_delta: r.latency_cycles - base.latency_cycles,
                energy_delta: r.energy_pj - base.energy_pj,
            }
        })
        .collect();
    if let Some(dir) = out_dir {
        write_csv(
            dir.join("fig11_checkpoint_linearity.csv"),
            "scenario,latency_delta_cycles,energy_delta_pj",
            rows.iter().map(|r| {
                vec![
                    r.scenario.clone(),
                    format!("{:.6e}", r.latency_delta),
                    format!("{:.6e}", r.energy_delta),
                ]
            }),
        )
        .unwrap();
    }
    rows
}

/// Non-additivity measure for Fig 11: |Δ(AC11) − Δ(AC10) − Δ(AC01)| as a
/// fraction of |Δ(AC11)| for (latency, energy).
pub fn linearity_gap(rows: &[LinearityRow]) -> (f64, f64) {
    let get = |s: &str| rows.iter().find(|r| r.scenario == s).unwrap();
    let (a, b, ab) = (get("AC10"), get("AC01"), get("AC11"));
    let lat = (ab.latency_delta - a.latency_delta - b.latency_delta).abs()
        / ab.latency_delta.abs().max(1e-9);
    let en = (ab.energy_delta - a.energy_delta - b.energy_delta).abs()
        / ab.energy_delta.abs().max(1e-9);
    (lat, en)
}

// ---------------------------------------------------------------------------
// Fig 12 — NSGA-II checkpointing Pareto front
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct GaFrontRow {
    pub memory_saving: f64,
    pub stored_mb_fp16: f64,
    pub latency_overhead: f64,
    pub energy_overhead: f64,
}

/// Fig 12: NSGA-II over the checkpointing space of ResNet-18 training
/// (Adam, batch 1, 224² inputs) on the base Edge TPU. Returns rows of
/// (memory saving, latency/energy overhead relative to save-all).
pub fn fig12_checkpoint_ga(
    ga: &GaConfig,
    out_dir: Option<&Path>,
) -> (Vec<GaFrontRow>, TrainingGraph) {
    fig12_checkpoint_ga_cached(ga, None, 0, None, false, out_dir)
}

/// [`fig12_checkpoint_ga`] with the cross-restart cache lifecycle: with a
/// `cache_dir`, the group-cost cache is warm-loaded/persisted and the GA
/// warm-starts from the previous run's front + genome memo
/// (`CheckpointProblem::optimize_persistent`), so a restarted run resumes
/// from the previous Pareto front. `cache_cap` bounds the cost cache
/// (0 = unbounded). With a `run_dir`, every completed generation is
/// journaled (`CheckpointProblem::optimize_journaled`) and `resume`
/// restarts from the last intact checkpoint — `run_dir` wins over the
/// warm-start path (the journal resumes the *same* search; a warm start
/// seeds a *new* one), while the cost cache is warm-loaded/persisted
/// either way.
pub fn fig12_checkpoint_ga_cached(
    ga: &GaConfig,
    cache_dir: Option<&Path>,
    cache_cap: usize,
    run_dir: Option<&Path>,
    resume: bool,
    out_dir: Option<&Path>,
) -> (Vec<GaFrontRow>, TrainingGraph) {
    let fwd = resnet18(1, 224, 1000);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let problem = CheckpointProblem::new_with_cache(
        &tg,
        &accel,
        MappingConfig::edge_tpu_default(),
        FusionConstraints::default(),
        persist::open_cost_cache(cache_dir, cache_cap),
    );
    let (base_lat, base_en, _) = problem.evaluate(&CheckpointPlan::save_all());
    let front = match (run_dir, cache_dir) {
        (Some(rd), _) => problem.optimize_journaled(ga, rd, resume),
        (None, Some(dir)) => problem.optimize_persistent(ga, dir),
        (None, None) => problem.optimize(ga),
    };
    persist::persist_cost_cache(problem.cost_cache(), cache_dir);
    let rows: Vec<GaFrontRow> = front
        .iter()
        .map(|s| GaFrontRow {
            memory_saving: s.memory_saving,
            stored_mb_fp16: s.stored_bytes_fp16 as f64 / (1 << 20) as f64,
            latency_overhead: s.latency_cycles / base_lat - 1.0,
            energy_overhead: s.energy_pj / base_en - 1.0,
        })
        .collect();
    if let Some(dir) = out_dir {
        write_csv(
            dir.join("fig12_checkpoint_ga.csv"),
            "memory_saving,stored_mb_fp16,latency_overhead,energy_overhead",
            rows.iter().map(|r| {
                vec![
                    format!("{:.4}", r.memory_saving),
                    format!("{:.3}", r.stored_mb_fp16),
                    format!("{:.4}", r.latency_overhead),
                    format!("{:.4}", r.energy_overhead),
                ]
            }),
        )
        .unwrap();
    }
    (rows, tg)
}

// ---------------------------------------------------------------------------
// Ablation — MILP (eq. 6) vs NSGA-II under the true non-linear pipeline
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MilpAblationRow {
    pub source: String,
    pub memory_saving: f64,
    pub latency_overhead: f64,
    pub energy_overhead: f64,
}

/// §V-B1 quantified: solve the linear Checkmate-style formulation (eq. 6)
/// for a sweep of budgets, re-evaluate each MILP plan under the *true*
/// fused-layer pipeline, and place them against the NSGA-II front.
pub fn milp_vs_ga_ablation(
    ga: &GaConfig,
    out_dir: Option<&Path>,
) -> Vec<MilpAblationRow> {
    use crate::autodiff::stored_activation_bytes;
    use crate::ga::milp::milp_budget_sweep;

    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let problem = CheckpointProblem::new(
        &tg,
        &accel,
        MappingConfig::edge_tpu_default(),
        FusionConstraints::default(),
    );
    let (base_lat, base_en, _) = problem.evaluate(&CheckpointPlan::save_all());
    let base_mem = stored_activation_bytes(&tg, &CheckpointPlan::save_all()) as f64;

    let mut rows = vec![];
    for (_, _, plan) in milp_budget_sweep(&tg, 10) {
        let (lat, en, _) = problem.evaluate(&plan);
        let mem = stored_activation_bytes(&tg, &plan) as f64;
        rows.push(MilpAblationRow {
            source: "milp".into(),
            memory_saving: 1.0 - mem / base_mem,
            latency_overhead: lat / base_lat - 1.0,
            energy_overhead: en / base_en - 1.0,
        });
    }
    for sol in problem.optimize(ga) {
        let mem = stored_activation_bytes(&tg, &sol.plan) as f64;
        rows.push(MilpAblationRow {
            source: "nsga2".into(),
            memory_saving: 1.0 - mem / base_mem,
            latency_overhead: sol.latency_cycles / base_lat - 1.0,
            energy_overhead: sol.energy_pj / base_en - 1.0,
        });
    }
    if let Some(dir) = out_dir {
        write_csv(
            dir.join("ablation_milp_vs_ga.csv"),
            "source,memory_saving,latency_overhead,energy_overhead",
            rows.iter().map(|r| {
                vec![
                    r.source.clone(),
                    format!("{:.4}", r.memory_saving),
                    format!("{:.4}", r.latency_overhead),
                    format!("{:.4}", r.energy_overhead),
                ]
            }),
        )
        .unwrap();
    }
    rows
}

/// Fraction of MILP points dominated by some GA point in
/// (memory_saving↑, latency↓, energy↓) space.
pub fn milp_dominated_fraction(rows: &[MilpAblationRow]) -> f64 {
    let ga: Vec<&MilpAblationRow> = rows.iter().filter(|r| r.source == "nsga2").collect();
    let milp: Vec<&MilpAblationRow> = rows.iter().filter(|r| r.source == "milp").collect();
    if milp.is_empty() {
        return 0.0;
    }
    let dominated = milp
        .iter()
        .filter(|m| {
            ga.iter().any(|g| {
                g.memory_saving >= m.memory_saving - 1e-9
                    && g.latency_overhead <= m.latency_overhead + 1e-9
                    && g.energy_overhead <= m.energy_overhead + 1e-9
                    && (g.memory_saving > m.memory_saving + 1e-9
                        || g.latency_overhead < m.latency_overhead - 1e-9
                        || g.energy_overhead < m.energy_overhead - 1e-9)
            })
        })
        .count();
    dominated as f64 / milp.len() as f64
}

/// Shared helper for tests/examples: activation bytes stored by a plan, in
/// MiB FP16 (the Fig 12 memory unit).
pub fn stored_mb_fp16(tg: &TrainingGraph, plan: &CheckpointPlan) -> f64 {
    stored_activation_bytes(tg, plan) as f64 / 2.0 / (1 << 20) as f64
}

/// Split sweep rows by mode (Figs 1/8/9 all plot the two separately).
pub fn split_modes(rows: &[SweepRow]) -> (Vec<SweepRow>, Vec<SweepRow>) {
    let inf = rows.iter().filter(|r| r.mode == Mode::Inference).cloned().collect();
    let tr = rows.iter().filter(|r| r.mode == Mode::Training).cloned().collect();
    (inf, tr)
}

/// Paper-shape check used by tests and EXPERIMENTS.md: do the Pareto sets
/// of two modes differ structurally?
pub fn pareto_labels(rows: &[SweepRow]) -> Vec<String> {
    pareto_front(rows).into_iter().map(|i| rows[i].label.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_matches_paper() {
        let bd = fig3_memory_breakdown(None);
        let (b1, b8) = (&bd[0], &bd[1]);
        // Adam states = 2× params; grads = params
        assert_eq!(b1.optstate_bytes, 2 * b1.params_bytes);
        assert_eq!(b1.grads_bytes, b1.params_bytes);
        // activations scale with batch, params don't
        assert_eq!(b1.params_bytes, b8.params_bytes);
        assert!(b8.activation_bytes > 7 * b1.activation_bytes);
        // paper Fig 3: at batch 8 activations dominate everything else
        assert!(b8.activation_bytes > b8.params_bytes + b8.grads_bytes + b8.optstate_bytes);
        // ~25.5M params × 4B ≈ 102 MB
        let pmb = b1.params_bytes as f64 / 1e6;
        assert!(pmb > 80.0 && pmb < 130.0, "params={pmb}MB");
    }

    #[test]
    fn fig10_fusion_always_beats_base() {
        let rows = fig10_fusion_strategies(None);
        let base = rows.iter().find(|r| r.strategy == "Base").unwrap();
        for r in rows.iter().filter(|r| r.strategy.starts_with("Limit")) {
            assert!(r.energy_pj < base.energy_pj, "{} energy", r.strategy);
            assert!(r.latency_cycles < base.latency_cycles, "{} latency", r.strategy);
        }
    }

    #[test]
    fn fig11_is_nonlinear() {
        let rows = fig11_checkpoint_linearity(None);
        assert_eq!(rows.len(), 3);
        let (lat_gap, en_gap) = linearity_gap(&rows);
        // the paper's central §V-B1 claim: strictly non-additive
        assert!(
            lat_gap > 0.01 || en_gap > 0.01,
            "deltas additive: lat_gap={lat_gap}, en_gap={en_gap}"
        );
    }

    #[test]
    fn fig5_covers_all_series_with_nonempty_fronts() {
        let figs = fig5_cluster_pareto(2, 4, true, None, 0, None, false, None, |_, _| {});
        assert_eq!(figs.len(), 3);
        assert_eq!(figs[0].workload, "resnet18");
        assert_eq!(figs[1].workload, "gpt2");
        assert_eq!(figs[2].workload, "gpt2-mixed");
        for f in &figs {
            assert_eq!(f.outcome.rows.len(), f.outcome.n_points);
            assert!(!f.outcome.front.is_empty(), "{}: empty front", f.workload);
            for &i in &f.outcome.front {
                assert!(i < f.outcome.rows.len());
            }
            // the single-device point exists and is on ≤2 devices like all
            // rows of this reduced space (the mixed pool is edge:1+dc:1)
            assert!(f.outcome.rows.iter().all(|r| r.devices <= 2));
            assert!(f.outcome.rows.iter().any(|r| r.devices == 1));
        }
        // the homogeneous series carry no placements; the mixed one does
        assert!(figs[1].outcome.rows.iter().all(|r| r.placement.is_empty()));
        assert!(figs[2].outcome.rows.iter().all(|r| !r.placement.is_empty()));
        assert!(figs[2].outcome.rows.iter().any(|r| r.placement.contains('|')));
    }

    #[test]
    fn fig1_modes_differ_structurally() {
        let sweep = fig1_fig8_edge_sweep(200, None, |_, _| {});
        let (inf, tr) = split_modes(&sweep.rows);
        assert_eq!(inf.len(), tr.len());
        // training is uniformly more expensive...
        for (i, t) in inf.iter().zip(&tr) {
            assert!(t.energy_pj > i.energy_pj);
        }
        // ...and the Pareto-optimal config sets differ (the Fig 1/8 claim)
        let pi: std::collections::HashSet<_> = pareto_labels(&inf).into_iter().collect();
        let pt: std::collections::HashSet<_> = pareto_labels(&tr).into_iter().collect();
        assert_ne!(pi, pt, "inference and training Pareto sets identical");
    }
}
