//! Multi-device parallelism strategies (paper §II-C1, Fig 5): data,
//! pipeline and tensor parallelism across a cluster of HDAs — plus their
//! GPipe/Megatron-style 3D composition ([`Strategy::Hybrid`]), which is
//! what the cluster-scale DSE actually searches over. Clusters come in two
//! flavours: the homogeneous model below (N identical devices on one
//! fabric) and the heterogeneous edge-to-datacenter model in [`hetero`]
//! (per-device [`hetero::DeviceClass`]es, per-link tiers, and a
//! stage-placement dimension).
//!
//! Single-device latency/energy come from the layer-fused scheduler; this
//! module layers the deployment-level costs on top — gradient all-reduce
//! for data parallelism, stage transfers + fill/drain for pipelining,
//! per-layer activation reductions for tensor parallelism — the standard
//! first-order models (GPipe / Megatron style), expressed in cycles over
//! the inter-device fabric. Every collective additionally pays the
//! fabric's per-message launch latency ([`Cluster::hop_cycles`]): on an
//! edge-class fabric (software collectives over a board-level link) this
//! fixed cost dominates and punishes communication-chatty strategies, on
//! a datacenter fabric (switched high-bandwidth links with hardware
//! collectives) it almost vanishes — the mechanism behind the Fig 5
//! edge→datacenter strategy flip.
//!
//! ## Degeneracy contract
//!
//! `Hybrid { dp, pp_stages, microbatches, tp }` composes the three pure
//! models: TP splits layers inside a stage, stages are pipelined, and
//! `dp` replicas all-reduce gradients. The arithmetic is arranged so the
//! degenerate corners are **bit-identical** to the pure strategies (and,
//! at `{1,1,1,1}`, to the single-device fused `schedule()`):
//!
//! * `Hybrid{dp,1,1,1}` ≡ `DataParallel` on `dp` devices
//! * `Hybrid{1,pp,m,1}` ≡ `Pipeline{m}` on `pp` devices
//! * `Hybrid{1,1,1,tp}` ≡ `TensorParallel` on `tp` devices
//!
//! The `parallelism` unit tests pin all four identities at the bit level;
//! they are what lets the cluster DSE enumerate only `Hybrid` points
//! without losing the pure strategies as special cases.

pub mod hetero;

use crate::autodiff::TrainingGraph;
use crate::eval::CostCache;
use crate::fusion::{fuse_greedy, FusionConstraints};
use crate::hardware::accelerator::Accelerator;
use crate::mapping::MappingConfig;
use crate::scheduler::{schedule_lower_bound, schedule_with_cache, ScheduleBound, ScheduleResult};
use crate::workload::graph::Graph;
use crate::workload::op::Phase;

pub use hetero::{
    model_strategy_hetero, model_strategy_hetero_bound, model_strategy_hetero_memo, DeviceClass,
    HeteroCluster, HeteroPoint,
};

/// The inter-device fabric (NVLink/PCIe/NoC-class, in cycle units of the
/// device clock).
#[derive(Debug, Clone, Copy)]
pub struct Cluster {
    pub devices: usize,
    /// Inter-device bandwidth per link, bytes/cycle.
    pub link_bw: f64,
    /// Energy per byte moved between devices.
    pub link_energy_pj: f64,
    /// Fixed launch latency per collective / per pipeline-stage boundary
    /// (cycles): software allreduce setup on an edge fabric, switch
    /// traversal on a datacenter one. 0 models an ideal fabric.
    pub hop_cycles: f64,
}

/// Named fabric classes for the edge→datacenter sweep (Fig 5). Bandwidth
/// rises and per-message latency falls from edge to datacenter — the two
/// knobs that reorder the parallelism strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkTier {
    /// Board-level serial link, software collectives (~8 B/cycle).
    Edge,
    /// Server-chassis interconnect, PCIe-class (~64 B/cycle).
    Server,
    /// Switched datacenter fabric, NVLink/NVSwitch-class with in-network
    /// collectives (~2 KiB/cycle).
    Datacenter,
}

impl LinkTier {
    pub fn all() -> [LinkTier; 3] {
        [LinkTier::Edge, LinkTier::Server, LinkTier::Datacenter]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LinkTier::Edge => "edge",
            LinkTier::Server => "server",
            LinkTier::Datacenter => "datacenter",
        }
    }

    /// Tier ordering for bottleneck computations: 0 is the slowest fabric
    /// (edge), rising toward the datacenter.
    pub fn rank(&self) -> u8 {
        match self {
            LinkTier::Edge => 0,
            LinkTier::Server => 1,
            LinkTier::Datacenter => 2,
        }
    }

    /// The fabric parameters of this tier for an `devices`-wide cluster.
    pub fn cluster(&self, devices: usize) -> Cluster {
        match self {
            LinkTier::Edge => Cluster {
                devices,
                link_bw: 8.0,
                link_energy_pj: 40.0,
                hop_cycles: 40_000.0,
            },
            LinkTier::Server => Cluster {
                devices,
                link_bw: 64.0,
                link_energy_pj: 10.0,
                hop_cycles: 4_000.0,
            },
            LinkTier::Datacenter => Cluster {
                devices,
                link_bw: 2048.0,
                link_energy_pj: 1.5,
                hop_cycles: 50.0,
            },
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Fig 5(a): batch split across devices, gradients all-reduced.
    DataParallel,
    /// Fig 5(b): model split into contiguous stages, microbatch pipeline.
    Pipeline { microbatches: usize },
    /// Fig 5(c): every layer split across devices, activations reduced.
    TensorParallel,
    /// 3D composition over `dp·pp_stages·tp` devices: TP inside a stage,
    /// stages pipelined over `microbatches`, `dp` replicas all-reduced.
    /// Degenerates bit-identically to the pure strategies (module docs).
    Hybrid { dp: usize, pp_stages: usize, microbatches: usize, tp: usize },
}

/// Multi-device estimate for one training iteration.
#[derive(Debug, Clone)]
pub struct MultiDeviceResult {
    pub strategy: Strategy,
    pub devices: usize,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Peak per-device memory (params + states + live activations share).
    pub per_device_mem_bytes: u64,
    /// Total inter-device traffic per iteration.
    pub comm_bytes: f64,
}

pub(crate) fn fused_schedule_cached(
    g: &Graph,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cache: Option<&CostCache>,
) -> ScheduleResult {
    let p = fuse_greedy(g, &FusionConstraints::default());
    schedule_with_cache(g, &p, accel, mapping, cache)
}

/// Ring all-reduce cost of `bytes` over `n` devices: 2·(n−1)/n · bytes per
/// link, overlappable chunks — we charge the non-overlapped wire time.
pub(crate) fn allreduce_cycles(bytes: f64, cluster: &Cluster) -> f64 {
    if cluster.devices <= 1 {
        return 0.0;
    }
    let n = cluster.devices as f64;
    2.0 * (n - 1.0) / n * bytes / cluster.link_bw.max(1.0)
}

/// Megatron-style reduction footprint of a (sub)graph: total output bytes
/// of the sharded MAC layers (one partial-sum all-reduce each, fwd and
/// bwd already both present in a training graph) and how many collectives
/// that is. Shared by the pure TensorParallel model and the TP axis of
/// `Hybrid` so the degenerate case stays bit-identical.
pub(crate) fn tp_reduce_stats<'a>(
    nodes: impl Iterator<Item = &'a crate::workload::graph::Node>,
    elem_bytes: u64,
) -> (f64, usize) {
    let mut reduce_bytes = 0f64;
    let mut n_collectives = 0usize;
    for node in nodes {
        if node.kind.is_conv() || node.kind.is_gemm() {
            reduce_bytes += (node.kind.out_elems() * elem_bytes) as f64;
            n_collectives += 1;
        }
    }
    (reduce_bytes, n_collectives)
}

/// Contiguous MAC-balanced stage split (GPipe-style) over topo order:
/// node ids per stage. Kept as the *seed* of [`split_stages_balanced`] and
/// as the oracle its tests compare against.
fn split_stages(g: &Graph, n_stages: usize) -> Vec<Vec<usize>> {
    let topo = g.topo_order();
    let total_macs: u64 = g.total_macs(None);
    let mut stages: Vec<Vec<usize>> = vec![vec![]; n_stages];
    let mut acc = 0u64;
    for &node in &topo {
        let s = ((acc as u128 * n_stages as u128) / (total_macs.max(1) as u128)) as usize;
        stages[s.min(n_stages - 1)].push(node);
        acc += g.node(node).kind.macs();
    }
    stages
}

/// Boundary-refinement sweeps of the latency-balancing splitter. Two
/// passes let every cut react once to its neighbours' moves; more passes
/// were not observed to shift cuts further on the model zoo.
const BALANCE_PASSES: usize = 2;

/// Per-worker memo of latency-balanced stage splits, keyed on
/// (microbatch size, stage-class sequence) — the ROADMAP hetero
/// follow-up (d): deployment points sharing a placement used to re-derive
/// identical [`split_stages_balanced`] refinements per point (the inner
/// group costs hit the shared cost cache, but the scheduler walks and
/// binary searches did not). The split is a pure function of (microbatch
/// graph, per-stage accelerators, mapping) and `tg_builder` is pure in
/// the batch, so within one sweep the pair (microbatch size, class
/// sequence) determines the stages exactly — a hit returns the same
/// `Vec<Vec<usize>>` a recompute would, bit for bit (node ids are stable
/// because the builder regenerates an identical graph).
///
/// **Validity scope:** one memo must only ever see ONE builder, ONE
/// mapping and ONE class-index→accelerator assignment — i.e. one sweep's
/// evaluator. The engine creates one per worker (`Evaluate::Scratch`),
/// which satisfies that by construction. Not `Sync` (deliberately):
/// sharing across workers would serialize them on a lock for no win.
#[derive(Default)]
pub struct StageCutsMemo {
    stages: std::cell::RefCell<std::collections::HashMap<(usize, Vec<usize>), Vec<Vec<usize>>>>,
    hits: std::cell::Cell<usize>,
    misses: std::cell::Cell<usize>,
    /// Per-stage *evaluation* memo (the incremental-GA seam, ROADMAP
    /// item 5): key = (microbatch size, hosting class index, stage node
    /// set — `None` for the whole-graph pp==1 path), value = the stage's
    /// scheduled latency/energy + TP reduction footprint + outgoing
    /// boundary bytes. A `DeploymentGenome` mutation moves one axis, so
    /// most stages of the mutant share (microbatch, class, node set) with
    /// already-evaluated genomes and skip their `fused_schedule_cached`
    /// walk entirely — only the changed stages are re-costed. Same
    /// validity scope and purity argument as the cuts memo above.
    evals: std::cell::RefCell<
        std::collections::HashMap<(usize, usize, Option<Vec<usize>>), StageEval>,
    >,
    eval_hits: std::cell::Cell<usize>,
    eval_misses: std::cell::Cell<usize>,
}

/// One stage's memoized evaluation: pure function of (microbatch graph,
/// stage node set, hosting accelerator) — deliberately excludes anything
/// that varies per deployment point (tp width, in-flight multipliers).
#[derive(Clone)]
pub(crate) struct StageEval {
    pub latency_cycles: f64,
    pub energy_pj: f64,
    pub reduce_bytes: f64,
    pub n_collectives: usize,
    pub boundary_bytes: f64,
}

impl StageCutsMemo {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memo hits so far (splits returned without re-deriving).
    pub fn hits(&self) -> usize {
        self.hits.get()
    }

    /// Memo misses so far (splits actually derived).
    pub fn misses(&self) -> usize {
        self.misses.get()
    }

    /// Stage-evaluation memo hits so far (stages re-used without
    /// re-scheduling — the incremental-GA counter).
    pub fn eval_hits(&self) -> usize {
        self.eval_hits.get()
    }

    /// Stage-evaluation memo misses so far (stages actually scheduled).
    pub fn eval_misses(&self) -> usize {
        self.eval_misses.get()
    }

    pub fn len(&self) -> usize {
        self.stages.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Schedule + reduction footprint of one stage (or, with `stage: None`,
/// the whole graph) on `accel`, through the optional per-worker stage
/// memo. A hit returns bit-identical numbers to a recompute (scheduler
/// determinism + builder purity — the [`StageCutsMemo`] contract), so
/// incremental and full evaluation cannot diverge.
pub(crate) fn stage_eval_memo(
    g: &Graph,
    stage: Option<&[usize]>,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cache: Option<&CostCache>,
    micro_batch: usize,
    class_idx: usize,
    memo: Option<&StageCutsMemo>,
) -> StageEval {
    let key = (micro_batch, class_idx, stage.map(|s| s.to_vec()));
    if let Some(m) = memo {
        if let Some(v) = m.evals.borrow().get(&key) {
            m.eval_hits.set(m.eval_hits.get() + 1);
            return v.clone();
        }
    }
    let v = match stage {
        None => {
            let r = fused_schedule_cached(g, accel, mapping, cache);
            let (reduce_bytes, n_collectives) = tp_reduce_stats(g.nodes.iter(), g.elem_bytes);
            StageEval {
                latency_cycles: r.latency_cycles,
                energy_pj: r.energy_pj,
                reduce_bytes,
                n_collectives,
                boundary_bytes: 0.0,
            }
        }
        Some(stage) => {
            let (sub, boundary_bytes) = stage_subgraph(g, stage);
            let r = fused_schedule_cached(&sub, accel, mapping, cache);
            let (reduce_bytes, n_collectives) = tp_reduce_stats(sub.nodes.iter(), sub.elem_bytes);
            StageEval {
                latency_cycles: r.latency_cycles,
                energy_pj: r.energy_pj,
                reduce_bytes,
                n_collectives,
                boundary_bytes,
            }
        }
    };
    if let Some(m) = memo {
        m.eval_misses.set(m.eval_misses.get() + 1);
        m.evals.borrow_mut().insert(key, v.clone());
    }
    v
}

/// [`split_stages_balanced`] behind the optional per-worker memo:
/// `micro_batch` is the batch `g` was built with and `classes` the
/// stage-class sequence selecting `stage_accels` (the homogeneous paths
/// pass `vec![0; n_stages]` — one implicit class). `memo: None` always
/// recomputes; results are bit-identical either way.
fn balanced_stages(
    g: &Graph,
    stage_accels: &[&Accelerator],
    mapping: &MappingConfig,
    cache: Option<&CostCache>,
    micro_batch: usize,
    classes: Vec<usize>,
    memo: Option<&StageCutsMemo>,
) -> Vec<Vec<usize>> {
    let Some(m) = memo else {
        return split_stages_balanced(g, stage_accels, mapping, cache);
    };
    let key = (micro_batch, classes);
    if let Some(stages) = m.stages.borrow().get(&key) {
        m.hits.set(m.hits.get() + 1);
        return stages.clone();
    }
    let stages = split_stages_balanced(g, stage_accels, mapping, cache);
    m.misses.set(m.misses.get() + 1);
    m.stages.borrow_mut().insert(key, stages.clone());
    stages
}

/// Contiguous **latency-balanced** stage split: seeds with the
/// MAC-balanced cut over topo order, then refines every cut by binary
/// search on the two adjacent stages' *scheduled* latencies — each probe
/// re-schedules the candidate stages on their assigned accelerators
/// through the shared cost cache. This is what fixes memory-bound stages
/// breaking the MAC proxy (the ROADMAP "pipeline-stage load balancing"
/// item), and what makes heterogeneous placements meaningful:
/// `stage_accels[s]` is the device class hosting the s-th stage, so a
/// slow edge-class stage is handed fewer nodes until the bottleneck
/// equalizes.
///
/// A candidate cut is accepted only when it strictly reduces the
/// bottleneck of the two stages it moves work between, so the global
/// bottleneck (max stage latency) is monotonically non-increasing over
/// the refinement. Deterministic: no RNG, fixed probe order, latencies
/// from the deterministic scheduler (bit-identical with or without the
/// cache, so cached and uncached sweeps pick identical cuts).
pub fn split_stages_balanced(
    g: &Graph,
    stage_accels: &[&Accelerator],
    mapping: &MappingConfig,
    cache: Option<&CostCache>,
) -> Vec<Vec<usize>> {
    let n_stages = stage_accels.len().max(1);
    let topo = g.topo_order();
    // MAC-balanced seed (the PR 3 split exactly), expressed as cut
    // positions into the topo order — `split_stages` assigns stages
    // monotonically over the topo walk, so each stage is one contiguous
    // topo range
    let seed = split_stages(g, n_stages);
    let mut cuts = vec![0usize; n_stages + 1];
    for s in 0..n_stages {
        cuts[s + 1] = cuts[s] + seed[s].len();
    }
    if n_stages > 1 && topo.len() > 1 {
        // scheduled latency of topo[start..end] on the s-th stage's
        // device, memoized per (stage, range); the inner group costs
        // additionally share the sweep-wide cost cache, so repeated stage
        // shapes across candidate cuts, placements and tiers are cheap
        let memo = std::cell::RefCell::new(std::collections::HashMap::new());
        let stage_lat = |s: usize, start: usize, end: usize| -> f64 {
            if start >= end {
                return 0.0;
            }
            if let Some(&v) = memo.borrow().get(&(s, start, end)) {
                return v;
            }
            let (sub, _) = stage_subgraph(g, &topo[start..end]);
            let v = fused_schedule_cached(&sub, stage_accels[s], mapping, cache).latency_cycles;
            memo.borrow_mut().insert((s, start, end), v);
            v
        };
        for _pass in 0..BALANCE_PASSES {
            for b in 1..n_stages {
                let lo = cuts[b - 1];
                let hi = cuts[b + 1];
                if hi - lo < 2 {
                    continue; // cannot keep both adjacent stages non-empty
                }
                let mut best_cut = cuts[b].clamp(lo + 1, hi - 1);
                let mut best = stage_lat(b - 1, lo, best_cut).max(stage_lat(b, best_cut, hi));
                // binary-search the crossing point of the two monotone
                // stage latencies (left grows, right shrinks with the cut)
                let (mut l, mut h) = (lo + 1, hi - 1);
                while l <= h {
                    let mid = l + (h - l) / 2;
                    let left = stage_lat(b - 1, lo, mid);
                    let right = stage_lat(b, mid, hi);
                    let bottleneck = left.max(right);
                    if bottleneck < best {
                        best = bottleneck;
                        best_cut = mid;
                    }
                    if left < right {
                        l = mid + 1;
                    } else if left > right {
                        if mid == l {
                            break;
                        }
                        h = mid - 1;
                    } else {
                        break;
                    }
                }
                cuts[b] = best_cut;
            }
        }
    }
    (0..n_stages).map(|s| topo[cuts[s]..cuts[s + 1]].to_vec()).collect()
}

/// Induced subgraph of one stage plus the stage's outgoing boundary bytes
/// (tensors that must cross to a later stage's device).
pub(crate) fn stage_subgraph(g: &Graph, stage: &[usize]) -> (Graph, f64) {
    let mut sub = Graph::with_elem_bytes(g.elem_bytes);
    let mut map = std::collections::HashMap::new();
    for &old in stage {
        let node = g.node(old);
        let id = sub.add_node(node.name.clone(), node.kind.clone(), node.phase);
        map.insert(old, id);
    }
    let mut boundary_bytes = 0f64;
    for e in &g.edges {
        match (map.get(&e.src), map.get(&e.dst)) {
            (Some(&a), Some(&b)) => {
                sub.add_edge_full(a, b, e.bytes, e.is_activation);
            }
            (Some(_), None) => boundary_bytes += e.bytes as f64,
            _ => {}
        }
    }
    (sub, boundary_bytes)
}

/// Stage weights/states + in-flight microbatch activations of one stage,
/// in the original graph's node ids (the pure-Pipeline accounting, reused
/// by `Hybrid`): `(stage_param_bytes, stage_activation_bytes)`.
pub(crate) fn stage_mem_parts(tg: &TrainingGraph, stage: &[usize]) -> (u64, u64) {
    let stage_params: u64 = stage
        .iter()
        .filter(|&&x| tg.graph.node(x).phase == Phase::Forward)
        .map(|&x| tg.graph.node(x).kind.weight_elems() * tg.graph.elem_bytes)
        .sum();
    let stage_acts: u64 = stage
        .iter()
        .filter(|&&x| tg.graph.out_edges(x).any(|e| e.is_activation))
        .map(|&x| tg.graph.out_bytes(x))
        .sum();
    (stage_params, stage_acts)
}

/// Model one training iteration under a parallelism strategy.
///
/// `tg_builder(batch)` must return the training graph for a given
/// per-device batch (data parallelism shrinks it). For Pipeline /
/// TensorParallel the full-batch graph (`tg_builder(full_batch)`) is used.
pub fn model_strategy(
    strategy: Strategy,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cluster: &Cluster,
) -> MultiDeviceResult {
    model_strategy_cached(strategy, full_batch, tg_builder, accel, mapping, cluster, None)
}

/// [`model_strategy`] with a shared group-cost memo for the inner
/// single-device schedules. The per-device stage cost is a pure function
/// of the stage's structure, so all cluster factorizations that produce
/// the same stage shape hit the same entries — the memoization win the
/// cluster DSE is built on. Results are bit-identical with or without the
/// cache (the `eval` soundness contract).
pub fn model_strategy_cached(
    strategy: Strategy,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cluster: &Cluster,
    cache: Option<&CostCache>,
) -> MultiDeviceResult {
    model_strategy_memo(strategy, full_batch, tg_builder, accel, mapping, cluster, cache, None)
}

/// [`model_strategy_cached`] with the optional per-worker stage-cuts
/// memo ([`StageCutsMemo`]): pipelined factorizations sharing their
/// (microbatch size, stage count) skip re-deriving the latency-balanced
/// split. Results are bit-identical with or without the memo; the
/// engine's per-family evaluators are the intended callers.
pub fn model_strategy_memo(
    strategy: Strategy,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cluster: &Cluster,
    cache: Option<&CostCache>,
    cuts: Option<&StageCutsMemo>,
) -> MultiDeviceResult {
    let n = cluster.devices.max(1);
    match strategy {
        Strategy::DataParallel => {
            let per_dev_batch = full_batch.div_ceil(n);
            let tg = tg_builder(per_dev_batch);
            let r = fused_schedule_cached(&tg.graph, accel, mapping, cache);
            let grad_bytes = tg.grad_bytes() as f64;
            // one flat gradient all-reduce per iteration
            let sync = if n > 1 {
                cluster.hop_cycles + allreduce_cycles(grad_bytes, cluster)
            } else {
                0.0
            };
            let comm = if n > 1 { 2.0 * (n as f64 - 1.0) / n as f64 * grad_bytes * n as f64 } else { 0.0 };
            MultiDeviceResult {
                strategy,
                devices: n,
                latency_cycles: r.latency_cycles + sync,
                energy_pj: r.energy_pj * n as f64 + comm * cluster.link_energy_pj,
                per_device_mem_bytes: tg.param_bytes()
                    + tg.grad_bytes()
                    + tg.optimizer_state_bytes()
                    + tg.saved_activation_bytes(),
                comm_bytes: comm,
            }
        }
        Strategy::Pipeline { microbatches } => {
            let m = microbatches.max(1);
            let micro_batch = full_batch.div_ceil(m).max(1);
            let tg = tg_builder(micro_batch); // one microbatch graph
            // contiguous stage split balanced by scheduled latency
            let stage_accels = vec![accel; n];
            let stages = balanced_stages(
                &tg.graph, &stage_accels, mapping, cache, micro_batch, vec![0; n], cuts,
            );
            // per-stage time = schedule of the induced subgraph; boundary
            // tensors transfer between devices
            let mut stage_time = 0f64;
            let mut stage_energy_sum = 0f64;
            let mut boundary_bytes = 0f64;
            let mut per_dev_mem = 0u64;
            let mut used_stages = 0usize;
            for stage in stages.iter().filter(|s| !s.is_empty()) {
                let se = stage_eval_memo(
                    &tg.graph, Some(stage), accel, mapping, cache, micro_batch, 0, cuts,
                );
                boundary_bytes += se.boundary_bytes;
                stage_time = stage_time.max(se.latency_cycles);
                stage_energy_sum += se.energy_pj;
                used_stages += 1;
                // stage weights/states + in-flight microbatch activations
                let (stage_params, stage_acts) = stage_mem_parts(&tg, stage);
                per_dev_mem = per_dev_mem
                    .max(stage_params * (1 + tg.optimizer.states_per_param() as u64 + 1)
                        + stage_acts * (n.min(m) as u64));
            }
            // GPipe fill/drain: (m + n − 1) stage slots per iteration, plus
            // one hop launch per stage boundary
            let latency = stage_time * (m + n - 1) as f64
                + boundary_bytes / cluster.link_bw.max(1.0)
                + used_stages.saturating_sub(1) as f64 * cluster.hop_cycles;
            MultiDeviceResult {
                strategy,
                devices: n,
                latency_cycles: latency,
                energy_pj: stage_energy_sum * m as f64
                    + boundary_bytes * m as f64 * cluster.link_energy_pj,
                per_device_mem_bytes: per_dev_mem,
                comm_bytes: boundary_bytes * m as f64,
            }
        }
        Strategy::TensorParallel => {
            let tg = tg_builder(full_batch);
            let r = fused_schedule_cached(&tg.graph, accel, mapping, cache);
            // ideal compute split + per-MAC-layer partial-sum reduction of
            // the output activations (Megatron-style, one reduce per
            // sharded matmul in fwd and bwd), each paying a hop launch
            let (reduce_bytes, n_collectives) =
                tp_reduce_stats(tg.graph.nodes.iter(), tg.graph.elem_bytes);
            let hop = if n > 1 { n_collectives as f64 * cluster.hop_cycles } else { 0.0 };
            let comm = reduce_bytes * 2.0 * (n as f64 - 1.0) / n as f64 * n as f64;
            let latency = r.latency_cycles / n as f64
                + allreduce_cycles(reduce_bytes, cluster)
                + hop;
            MultiDeviceResult {
                strategy,
                devices: n,
                latency_cycles: latency,
                energy_pj: r.energy_pj + comm * cluster.link_energy_pj,
                per_device_mem_bytes: (tg.param_bytes()
                    + tg.grad_bytes()
                    + tg.optimizer_state_bytes())
                    / n as u64
                    + tg.saved_activation_bytes(),
                comm_bytes: comm,
            }
        }
        Strategy::Hybrid { dp, pp_stages, microbatches, tp } => {
            let dp = dp.max(1);
            let pp = pp_stages.max(1);
            let m = microbatches.max(1);
            let tp = tp.max(1);
            let devices = dp * pp * tp;
            let tp_cluster = Cluster { devices: tp, ..*cluster };
            let dp_cluster = Cluster { devices: dp, ..*cluster };
            // each replica sees 1/dp of the batch, pipelined in m
            // microbatches (the pure-strategy batch rules composed)
            let replica_batch = full_batch.div_ceil(dp);
            let micro_batch = replica_batch.div_ceil(m).max(1);
            let tg = tg_builder(micro_batch);
            let states_mult = 1 + tg.optimizer.states_per_param() as u64 + 1;

            let mut stage_time = 0f64;
            let mut stage_energy_sum = 0f64;
            let mut boundary_bytes = 0f64;
            let mut per_dev_mem = 0u64;
            let mut tp_comm_bytes = 0f64; // per microbatch, summed over stages
            let mut used_stages = 0usize;

            // one stage's contribution ([`StageEval`] is its single-device
            // schedule + reduction footprint); `stage_states`/
            // `stage_acts_inflight` its per-device memory before TP
            // sharding
            let mut eval_stage = |se: &StageEval,
                                  stage_states: u64,
                                  stage_acts_inflight: u64| {
                let tp_lat = if tp > 1 {
                    se.latency_cycles / tp as f64
                        + allreduce_cycles(se.reduce_bytes, &tp_cluster)
                        + se.n_collectives as f64 * cluster.hop_cycles
                } else {
                    se.latency_cycles
                };
                stage_time = stage_time.max(tp_lat);
                stage_energy_sum += se.energy_pj;
                if tp > 1 {
                    tp_comm_bytes +=
                        se.reduce_bytes * 2.0 * (tp as f64 - 1.0) / tp as f64 * tp as f64;
                }
                per_dev_mem = per_dev_mem.max(stage_states / tp as u64 + stage_acts_inflight);
                used_stages += 1;
            };

            if pp == 1 {
                // single stage: schedule the replica graph directly — no
                // induced-subgraph rebuild, so `Hybrid{1,1,1,1}` replays
                // the single-device `schedule()` bit for bit
                let se = stage_eval_memo(
                    &tg.graph, None, accel, mapping, cache, micro_batch, 0, cuts,
                );
                let states =
                    tg.param_bytes() + tg.grad_bytes() + tg.optimizer_state_bytes();
                eval_stage(&se, states, tg.saved_activation_bytes());
            } else {
                let stage_accels = vec![accel; pp];
                let stages = balanced_stages(
                    &tg.graph, &stage_accels, mapping, cache, micro_batch, vec![0; pp], cuts,
                );
                for stage in stages.iter().filter(|s| !s.is_empty()) {
                    let se = stage_eval_memo(
                        &tg.graph, Some(stage), accel, mapping, cache, micro_batch, 0, cuts,
                    );
                    boundary_bytes += se.boundary_bytes;
                    let (stage_params, stage_acts) = stage_mem_parts(&tg, stage);
                    eval_stage(
                        &se,
                        stage_params * states_mult,
                        stage_acts * (pp.min(m) as u64),
                    );
                }
            }

            // replica-level gradient all-reduce across the dp groups. With
            // pp/tp sharding, each device holds only its ~1/(pp·tp) shard
            // of the parameters and the per-shard all-reduces run
            // concurrently (one dp-group per shard), so the critical-path
            // wire time covers one shard, not the full model; the /1.0 at
            // pp == tp == 1 is exact, preserving the DataParallel
            // degeneracy bit for bit. Total comm *bytes* below are
            // unchanged: pp·tp concurrent groups each move 1/(pp·tp) of
            // the gradients.
            let dp_sync = if dp > 1 {
                cluster.hop_cycles
                    + allreduce_cycles(
                        tg.grad_bytes() as f64 / (pp * tp) as f64,
                        &dp_cluster,
                    )
            } else {
                0.0
            };
            let dp_comm = if dp > 1 {
                2.0 * (dp as f64 - 1.0) / dp as f64 * tg.grad_bytes() as f64 * dp as f64
            } else {
                0.0
            };

            let latency = stage_time * (m + pp - 1) as f64
                + boundary_bytes / cluster.link_bw.max(1.0)
                + used_stages.saturating_sub(1) as f64 * cluster.hop_cycles
                + dp_sync;
            let comm =
                (tp_comm_bytes * m as f64 + boundary_bytes * m as f64) * dp as f64 + dp_comm;
            MultiDeviceResult {
                strategy,
                devices,
                latency_cycles: latency,
                energy_pj: (stage_energy_sum * m as f64) * dp as f64
                    + comm * cluster.link_energy_pj,
                per_device_mem_bytes: per_dev_mem,
                comm_bytes: comm,
            }
        }
    }
}

/// Admissible per-point lower bound of [`model_strategy_memo`] — the
/// deployment-level mirror of [`schedule_lower_bound`], powering the DSE
/// engine's bound-based pruning (`Evaluate::lower_bound`).
///
/// The `Hybrid` arithmetic is mirrored term by term, except each stage's
/// *scheduled* latency/energy is replaced by its roofline
/// [`ScheduleBound`]. Everything else — the latency-balanced stage split
/// (derived through the same shared [`StageCutsMemo`], so bound and
/// evaluation pay for it once), stage-boundary bytes, collective launch
/// latencies, the dp gradient sync and the per-device memory accounting —
/// is computed *exactly* as evaluation would. That tightness is what lets
/// an incumbent row on a fast fabric dominate the bound of the same
/// factorization on a slow one.
///
/// ## Admissibility contract
///
/// For every strategy (pure strategies are bounded through their
/// degenerate `Hybrid` corners, bit-identical by the module's degeneracy
/// contract): `latency_cycles`, `energy_pj` and `comm_bytes` are `<=`,
/// and `per_device_mem_bytes`/`devices` are `==`, the corresponding
/// [`model_strategy_memo`] fields for the same inputs.
/// `tests/front_equivalence.rs` property-checks this against full
/// evaluation on randomized spaces.
#[allow(clippy::too_many_arguments)]
pub fn model_strategy_bound(
    strategy: Strategy,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cluster: &Cluster,
    cache: Option<&CostCache>,
    cuts: Option<&StageCutsMemo>,
) -> MultiDeviceResult {
    let n = cluster.devices.max(1);
    let (dp, pp, m, tp) = match strategy {
        Strategy::DataParallel => (n, 1, 1, 1),
        Strategy::Pipeline { microbatches } => (1, n, microbatches.max(1), 1),
        Strategy::TensorParallel => (1, 1, 1, n),
        Strategy::Hybrid { dp, pp_stages, microbatches, tp } => {
            (dp.max(1), pp_stages.max(1), microbatches.max(1), tp.max(1))
        }
    };
    let devices = dp * pp * tp;
    let tp_cluster = Cluster { devices: tp, ..*cluster };
    let dp_cluster = Cluster { devices: dp, ..*cluster };
    let replica_batch = full_batch.div_ceil(dp);
    let micro_batch = replica_batch.div_ceil(m).max(1);
    let tg = tg_builder(micro_batch);
    let states_mult = 1 + tg.optimizer.states_per_param() as u64 + 1;

    let mut stage_time = 0f64;
    let mut stage_energy_sum = 0f64;
    let mut boundary_bytes = 0f64;
    let mut per_dev_mem = 0u64;
    let mut tp_comm_bytes = 0f64;
    let mut used_stages = 0usize;

    // mirror of `eval_stage` with the stage's schedule replaced by its
    // roofline bound; all adders are the exact evaluation terms
    let mut bound_stage = |b: &ScheduleBound,
                           reduce_bytes: f64,
                           n_collectives: usize,
                           stage_states: u64,
                           stage_acts_inflight: u64| {
        let tp_lat = if tp > 1 {
            b.latency_cycles / tp as f64
                + allreduce_cycles(reduce_bytes, &tp_cluster)
                + n_collectives as f64 * cluster.hop_cycles
        } else {
            b.latency_cycles
        };
        stage_time = stage_time.max(tp_lat);
        stage_energy_sum += b.energy_pj;
        if tp > 1 {
            tp_comm_bytes += reduce_bytes * 2.0 * (tp as f64 - 1.0) / tp as f64 * tp as f64;
        }
        per_dev_mem = per_dev_mem.max(stage_states / tp as u64 + stage_acts_inflight);
        used_stages += 1;
    };

    if pp == 1 {
        let b = schedule_lower_bound(&tg.graph, accel, mapping);
        let (reduce_bytes, n_collectives) =
            tp_reduce_stats(tg.graph.nodes.iter(), tg.graph.elem_bytes);
        let states = tg.param_bytes() + tg.grad_bytes() + tg.optimizer_state_bytes();
        bound_stage(&b, reduce_bytes, n_collectives, states, tg.saved_activation_bytes());
    } else {
        let stage_accels = vec![accel; pp];
        let stages = balanced_stages(
            &tg.graph, &stage_accels, mapping, cache, micro_batch, vec![0; pp], cuts,
        );
        for stage in stages.iter().filter(|s| !s.is_empty()) {
            let (sub, stage_boundary) = stage_subgraph(&tg.graph, stage);
            boundary_bytes += stage_boundary;
            let b = schedule_lower_bound(&sub, accel, mapping);
            let (reduce_bytes, n_collectives) =
                tp_reduce_stats(sub.nodes.iter(), sub.elem_bytes);
            let (stage_params, stage_acts) = stage_mem_parts(&tg, stage);
            bound_stage(
                &b,
                reduce_bytes,
                n_collectives,
                stage_params * states_mult,
                stage_acts * (pp.min(m) as u64),
            );
        }
    }

    let dp_sync = if dp > 1 {
        cluster.hop_cycles
            + allreduce_cycles(tg.grad_bytes() as f64 / (pp * tp) as f64, &dp_cluster)
    } else {
        0.0
    };
    let dp_comm = if dp > 1 {
        2.0 * (dp as f64 - 1.0) / dp as f64 * tg.grad_bytes() as f64 * dp as f64
    } else {
        0.0
    };
    let latency = stage_time * (m + pp - 1) as f64
        + boundary_bytes / cluster.link_bw.max(1.0)
        + used_stages.saturating_sub(1) as f64 * cluster.hop_cycles
        + dp_sync;
    let comm = (tp_comm_bytes * m as f64 + boundary_bytes * m as f64) * dp as f64 + dp_comm;
    MultiDeviceResult {
        strategy,
        devices,
        latency_cycles: latency,
        energy_pj: (stage_energy_sum * m as f64) * dp as f64 + comm * cluster.link_energy_pj,
        per_device_mem_bytes: per_dev_mem,
        comm_bytes: comm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::hardware::presets::EdgeTpuParams;
    use crate::scheduler::schedule;
    use crate::workload::models::resnet18;
    use crate::workload::op::Optimizer;

    fn builder() -> impl Fn(usize) -> TrainingGraph {
        |batch| {
            build_training_graph(
                &resnet18(batch.max(1), 32, 10),
                TrainOptions { optimizer: Optimizer::Adam, include_update: true },
            )
        }
    }

    fn cluster(n: usize) -> Cluster {
        Cluster { devices: n, link_bw: 64.0, link_energy_pj: 10.0, hop_cycles: 0.0 }
    }

    fn run(s: Strategy, n: usize) -> MultiDeviceResult {
        let accel = EdgeTpuParams::baseline().build();
        model_strategy(
            s,
            8,
            &builder(),
            &accel,
            &MappingConfig::edge_tpu_default(),
            &cluster(n),
        )
    }

    fn bit_eq(a: &MultiDeviceResult, b: &MultiDeviceResult) {
        assert_eq!(a.devices, b.devices);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes);
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits());
    }

    #[test]
    fn data_parallel_speeds_up_and_keeps_full_model_per_device() {
        let one = run(Strategy::DataParallel, 1);
        let four = run(Strategy::DataParallel, 4);
        assert!(four.latency_cycles < one.latency_cycles);
        // every device holds the full parameter set (the Fig 5a caveat)
        let tg = builder()(8);
        let full_states = tg.param_bytes() + tg.grad_bytes() + tg.optimizer_state_bytes();
        assert!(four.per_device_mem_bytes >= full_states);
        assert!(four.comm_bytes > 0.0);
        assert_eq!(one.comm_bytes, 0.0);
    }

    #[test]
    fn pipeline_reduces_per_device_memory() {
        let one = run(Strategy::Pipeline { microbatches: 4 }, 1);
        let four = run(Strategy::Pipeline { microbatches: 4 }, 4);
        assert!(four.per_device_mem_bytes < one.per_device_mem_bytes);
        assert!(four.comm_bytes > 0.0, "stage boundaries must transfer");
    }

    #[test]
    fn more_microbatches_amortise_fill_drain() {
        let m2 = run(Strategy::Pipeline { microbatches: 2 }, 4);
        let m8 = run(Strategy::Pipeline { microbatches: 8 }, 4);
        // per-sample latency improves with more microbatches
        assert!(m8.latency_cycles / 8.0 < m2.latency_cycles / 2.0);
    }

    #[test]
    fn tensor_parallel_trades_comm_for_state_sharding() {
        let one = run(Strategy::TensorParallel, 1);
        let four = run(Strategy::TensorParallel, 4);
        assert!(four.per_device_mem_bytes < one.per_device_mem_bytes);
        assert!(four.comm_bytes > one.comm_bytes);
    }

    #[test]
    fn ranking_survives_nan_latency() {
        // regression: the latency ranking above used partial_cmp().unwrap(),
        // which panics the moment an upstream model change lets a NaN
        // through — total_cmp ranks NaN last and never panics
        let mut v = [("a", f64::NAN), ("b", 1.0), ("c", 2.0)];
        v.sort_by(|x, y| x.1.total_cmp(&y.1));
        assert_eq!(v[0].0, "b");
        assert_eq!(v[2].0, "a");
    }

    #[test]
    fn strategies_disagree_on_the_optimum() {
        // the §II-C1 point: no strategy dominates universally — at n=4 on a
        // bandwidth-limited fabric the rankings by latency and by memory
        // must differ
        let dp = run(Strategy::DataParallel, 4);
        let pp = run(Strategy::Pipeline { microbatches: 4 }, 4);
        let tp = run(Strategy::TensorParallel, 4);
        let by_lat = {
            let mut v = [("dp", dp.latency_cycles), ("pp", pp.latency_cycles), ("tp", tp.latency_cycles)];
            v.sort_by(|a, b| a.1.total_cmp(&b.1));
            v[0].0
        };
        let by_mem = {
            let mut v = [
                ("dp", dp.per_device_mem_bytes),
                ("pp", pp.per_device_mem_bytes),
                ("tp", tp.per_device_mem_bytes),
            ];
            v.sort_by_key(|x| x.1);
            v[0].0
        };
        assert_ne!(by_lat, by_mem, "one strategy dominates both axes — model too simple");
    }

    // ---- the degeneracy contract (module docs): hybrids collapse to the
    // pure strategies bit for bit ----

    #[test]
    fn hybrid_1111_is_bit_identical_to_single_device_schedule() {
        let accel = EdgeTpuParams::baseline().build();
        let mapping = MappingConfig::edge_tpu_default();
        let h = model_strategy(
            Strategy::Hybrid { dp: 1, pp_stages: 1, microbatches: 1, tp: 1 },
            8,
            &builder(),
            &accel,
            &mapping,
            &cluster(1),
        );
        let tg = builder()(8);
        let p = fuse_greedy(&tg.graph, &FusionConstraints::default());
        let r = schedule(&tg.graph, &p, &accel, &mapping);
        assert_eq!(h.latency_cycles.to_bits(), r.latency_cycles.to_bits());
        assert_eq!(h.energy_pj.to_bits(), r.energy_pj.to_bits());
        assert_eq!(h.comm_bytes, 0.0);
        assert_eq!(h.devices, 1);
        assert_eq!(
            h.per_device_mem_bytes,
            tg.param_bytes()
                + tg.grad_bytes()
                + tg.optimizer_state_bytes()
                + tg.saved_activation_bytes()
        );
    }

    #[test]
    fn hybrid_dp_only_is_bit_identical_to_data_parallel() {
        let h = run(Strategy::Hybrid { dp: 4, pp_stages: 1, microbatches: 1, tp: 1 }, 4);
        let dp = run(Strategy::DataParallel, 4);
        bit_eq(&h, &dp);
    }

    #[test]
    fn hybrid_pp_only_is_bit_identical_to_pipeline() {
        let h = run(Strategy::Hybrid { dp: 1, pp_stages: 4, microbatches: 4, tp: 1 }, 4);
        let pp = run(Strategy::Pipeline { microbatches: 4 }, 4);
        bit_eq(&h, &pp);
    }

    #[test]
    fn hybrid_tp_only_is_bit_identical_to_tensor_parallel() {
        let h = run(Strategy::Hybrid { dp: 1, pp_stages: 1, microbatches: 1, tp: 4 }, 4);
        let tp = run(Strategy::TensorParallel, 4);
        bit_eq(&h, &tp);
    }

    #[test]
    fn degeneracy_holds_with_nonzero_hop_latency() {
        // the `cluster(n)` helper above pins hop_cycles to 0.0, which
        // zeroes every per-collective launch term — this corner re-pins
        // all three pure-strategy identities on a real fabric tier so an
        // edit to the hop arithmetic in one arm but not the other cannot
        // slip past the suite
        let accel = EdgeTpuParams::baseline().build();
        let mapping = MappingConfig::edge_tpu_default();
        let c = LinkTier::Edge.cluster(4);
        assert!(c.hop_cycles > 0.0);
        let run_c =
            |s: Strategy| model_strategy(s, 8, &builder(), &accel, &mapping, &c);
        bit_eq(
            &run_c(Strategy::Hybrid { dp: 4, pp_stages: 1, microbatches: 1, tp: 1 }),
            &run_c(Strategy::DataParallel),
        );
        bit_eq(
            &run_c(Strategy::Hybrid { dp: 1, pp_stages: 4, microbatches: 4, tp: 1 }),
            &run_c(Strategy::Pipeline { microbatches: 4 }),
        );
        bit_eq(
            &run_c(Strategy::Hybrid { dp: 1, pp_stages: 1, microbatches: 1, tp: 4 }),
            &run_c(Strategy::TensorParallel),
        );
    }

    #[test]
    fn hybrid_composition_is_consistent_and_cache_safe() {
        let accel = EdgeTpuParams::baseline().build();
        let mapping = MappingConfig::edge_tpu_default();
        let c = cluster(4);
        let s = Strategy::Hybrid { dp: 2, pp_stages: 2, microbatches: 4, tp: 1 };
        let plain = model_strategy(s, 8, &builder(), &accel, &mapping, &c);
        assert!(plain.latency_cycles.is_finite() && plain.latency_cycles > 0.0);
        assert!(plain.energy_pj.is_finite() && plain.energy_pj > 0.0);
        assert_eq!(plain.devices, 4);
        assert!(plain.comm_bytes > 0.0, "both dp and pp axes must communicate");
        // pipelining shards the model: less state per device than pure DP
        let dp = run(Strategy::DataParallel, 4);
        assert!(plain.per_device_mem_bytes < dp.per_device_mem_bytes);
        // and the shared cost cache never changes the numbers
        let cache = CostCache::new();
        let cached =
            model_strategy_cached(s, 8, &builder(), &accel, &mapping, &c, Some(&cache));
        bit_eq(&plain, &cached);
        assert!(cache.stats().misses > 0);
    }

    #[test]
    fn balanced_split_partitions_the_graph_contiguously() {
        let tg = builder()(4);
        let accel = EdgeTpuParams::baseline().build();
        let mapping = MappingConfig::edge_tpu_default();
        let accels = [&accel, &accel, &accel, &accel];
        let stages = split_stages_balanced(&tg.graph, &accels, &mapping, None);
        assert_eq!(stages.len(), 4);
        // the stages are exactly the topo order, cut into contiguous ranges
        let flat: Vec<usize> = stages.iter().flatten().copied().collect();
        assert_eq!(flat, tg.graph.topo_order());
    }

    #[test]
    fn balanced_split_never_worsens_the_mac_split_bottleneck() {
        // the refinement accepts only strict pair-bottleneck improvements,
        // so the scheduled max-stage latency is ≤ the MAC-balanced seed's —
        // on an identical-device pipeline and on a mixed edge+datacenter one
        let tg = builder()(4);
        let mapping = MappingConfig::edge_tpu_default();
        let edge = EdgeTpuParams::baseline().build();
        let dc = EdgeTpuParams::datacenter_class().build();
        let bottleneck = |stages: &[Vec<usize>], accels: &[&Accelerator]| -> f64 {
            stages
                .iter()
                .zip(accels)
                .filter(|(s, _)| !s.is_empty())
                .map(|(s, a)| {
                    let (sub, _) = stage_subgraph(&tg.graph, s);
                    fused_schedule_cached(&sub, a, &mapping, None).latency_cycles
                })
                .fold(0.0, f64::max)
        };
        for accels in [[&edge, &edge, &edge, &edge], [&edge, &dc, &edge, &dc]] {
            let seed = split_stages(&tg.graph, 4);
            let balanced = split_stages_balanced(&tg.graph, &accels, &mapping, None);
            assert!(
                bottleneck(&balanced, &accels) <= bottleneck(&seed, &accels),
                "latency balancing worsened the bottleneck"
            );
        }
    }

    #[test]
    fn stage_cuts_memo_is_bit_identical_and_skips_repeat_splits() {
        let accel = EdgeTpuParams::baseline().build();
        let mapping = MappingConfig::edge_tpu_default();
        let c = cluster(4);
        let memo = StageCutsMemo::new();
        // Pipeline{m=4} on 4 devices and Hybrid{1,4,4,1} build the same
        // microbatch graph and stage count, so one derivation must serve
        // all three evaluations — bit-identically to the memo-free path
        let cases = [
            Strategy::Pipeline { microbatches: 4 },
            Strategy::Hybrid { dp: 1, pp_stages: 4, microbatches: 4, tp: 1 },
            Strategy::Hybrid { dp: 1, pp_stages: 4, microbatches: 4, tp: 1 },
        ];
        for s in cases {
            let plain = model_strategy(s, 8, &builder(), &accel, &mapping, &c);
            let memoed =
                model_strategy_memo(s, 8, &builder(), &accel, &mapping, &c, None, Some(&memo));
            bit_eq(&plain, &memoed);
        }
        assert_eq!(memo.misses(), 1, "shared (microbatch, stages) key must derive once");
        assert_eq!(memo.hits(), 2);
        assert_eq!(memo.len(), 1);
        // a different microbatch count changes the graph → fresh entry
        let s = Strategy::Pipeline { microbatches: 2 };
        let plain = model_strategy(s, 8, &builder(), &accel, &mapping, &c);
        let memoed =
            model_strategy_memo(s, 8, &builder(), &accel, &mapping, &c, None, Some(&memo));
        bit_eq(&plain, &memoed);
        assert_eq!(memo.misses(), 2);
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn link_tiers_order_sanely() {
        let e = LinkTier::Edge.cluster(4);
        let s = LinkTier::Server.cluster(4);
        let d = LinkTier::Datacenter.cluster(4);
        assert!(e.link_bw < s.link_bw && s.link_bw < d.link_bw);
        assert!(e.hop_cycles > s.hop_cycles && s.hop_cycles > d.hop_cycles);
        assert!(e.link_energy_pj > d.link_energy_pj);
        assert_eq!(e.devices, 4);
        assert_eq!(LinkTier::Edge.as_str(), "edge");
    }
}
