//! Multi-device parallelism strategies (paper §II-C1, Fig 5): data,
//! pipeline and tensor parallelism across a cluster of identical HDAs.
//!
//! Single-device latency/energy come from the layer-fused scheduler; this
//! module layers the deployment-level costs on top — gradient all-reduce
//! for data parallelism, stage transfers + fill/drain for pipelining,
//! per-layer activation reductions for tensor parallelism — the standard
//! first-order models (GPipe / Megatron style), expressed in cycles over
//! the inter-device fabric.

use crate::autodiff::TrainingGraph;
use crate::fusion::{fuse_greedy, FusionConstraints};
use crate::hardware::accelerator::Accelerator;
use crate::mapping::MappingConfig;
use crate::scheduler::{schedule, ScheduleResult};
use crate::workload::graph::Graph;
use crate::workload::op::Phase;

/// The inter-device fabric (NVLink/PCIe/NoC-class, in cycle units of the
/// device clock).
#[derive(Debug, Clone, Copy)]
pub struct Cluster {
    pub devices: usize,
    /// Inter-device bandwidth per link, bytes/cycle.
    pub link_bw: f64,
    /// Energy per byte moved between devices.
    pub link_energy_pj: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Fig 5(a): batch split across devices, gradients all-reduced.
    DataParallel,
    /// Fig 5(b): model split into contiguous stages, microbatch pipeline.
    Pipeline { microbatches: usize },
    /// Fig 5(c): every layer split across devices, activations reduced.
    TensorParallel,
}

/// Multi-device estimate for one training iteration.
#[derive(Debug, Clone)]
pub struct MultiDeviceResult {
    pub strategy: Strategy,
    pub devices: usize,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Peak per-device memory (params + states + live activations share).
    pub per_device_mem_bytes: u64,
    /// Total inter-device traffic per iteration.
    pub comm_bytes: f64,
}

fn fused_schedule(g: &Graph, accel: &Accelerator, mapping: &MappingConfig) -> ScheduleResult {
    let p = fuse_greedy(g, &FusionConstraints::default());
    schedule(g, &p, accel, mapping)
}

/// Ring all-reduce cost of `bytes` over `n` devices: 2·(n−1)/n · bytes per
/// link, overlappable chunks — we charge the non-overlapped wire time.
fn allreduce_cycles(bytes: f64, cluster: &Cluster) -> f64 {
    if cluster.devices <= 1 {
        return 0.0;
    }
    let n = cluster.devices as f64;
    2.0 * (n - 1.0) / n * bytes / cluster.link_bw.max(1.0)
}

/// Model one training iteration under a parallelism strategy.
///
/// `tg_builder(batch)` must return the training graph for a given
/// per-device batch (data parallelism shrinks it). For Pipeline /
/// TensorParallel the full-batch graph (`tg_builder(full_batch)`) is used.
pub fn model_strategy(
    strategy: Strategy,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    accel: &Accelerator,
    mapping: &MappingConfig,
    cluster: &Cluster,
) -> MultiDeviceResult {
    let n = cluster.devices.max(1);
    match strategy {
        Strategy::DataParallel => {
            let per_dev_batch = full_batch.div_ceil(n);
            let tg = tg_builder(per_dev_batch);
            let r = fused_schedule(&tg.graph, accel, mapping);
            let grad_bytes = tg.grad_bytes() as f64;
            let ar = allreduce_cycles(grad_bytes, cluster);
            let comm = if n > 1 { 2.0 * (n as f64 - 1.0) / n as f64 * grad_bytes * n as f64 } else { 0.0 };
            MultiDeviceResult {
                strategy,
                devices: n,
                latency_cycles: r.latency_cycles + ar,
                energy_pj: r.energy_pj * n as f64 + comm * cluster.link_energy_pj,
                per_device_mem_bytes: tg.param_bytes()
                    + tg.grad_bytes()
                    + tg.optimizer_state_bytes()
                    + tg.saved_activation_bytes(),
                comm_bytes: comm,
            }
        }
        Strategy::Pipeline { microbatches } => {
            let m = microbatches.max(1);
            let tg = tg_builder(full_batch.div_ceil(m).max(1)); // one microbatch graph
            // contiguous stage split balanced by MACs over topo order
            let topo = tg.graph.topo_order();
            let total_macs: u64 = tg.graph.total_macs(None);
            let mut stages: Vec<Vec<usize>> = vec![vec![]; n];
            let mut acc = 0u64;
            for &node in &topo {
                let s = ((acc as u128 * n as u128) / (total_macs.max(1) as u128)) as usize;
                stages[s.min(n - 1)].push(node);
                acc += tg.graph.node(node).kind.macs();
            }
            // per-stage time = schedule of the induced subgraph; boundary
            // tensors transfer between devices
            let mut stage_time = 0f64;
            let mut stage_energy_sum = 0f64;
            let mut boundary_bytes = 0f64;
            let mut per_dev_mem = 0u64;
            for stage in stages.iter().filter(|s| !s.is_empty()) {
                // induced subgraph
                let mut sub = Graph::with_elem_bytes(tg.graph.elem_bytes);
                let mut map = std::collections::HashMap::new();
                for &old in stage {
                    let node = tg.graph.node(old);
                    let id = sub.add_node(node.name.clone(), node.kind.clone(), node.phase);
                    map.insert(old, id);
                }
                for e in &tg.graph.edges {
                    match (map.get(&e.src), map.get(&e.dst)) {
                        (Some(&a), Some(&b)) => {
                            sub.add_edge_full(a, b, e.bytes, e.is_activation);
                        }
                        (Some(_), None) => boundary_bytes += e.bytes as f64,
                        _ => {}
                    }
                }
                let r = fused_schedule(&sub, accel, mapping);
                stage_time = stage_time.max(r.latency_cycles);
                stage_energy_sum += r.energy_pj;
                // stage weights/states + in-flight microbatch activations
                let stage_params: u64 = stage
                    .iter()
                    .filter(|&&x| tg.graph.node(x).phase == Phase::Forward)
                    .map(|&x| tg.graph.node(x).kind.weight_elems() * tg.graph.elem_bytes)
                    .sum();
                let stage_acts: u64 = stage
                    .iter()
                    .filter(|&&x| {
                        tg.graph.out_edges(x).any(|e| e.is_activation)
                    })
                    .map(|&x| tg.graph.out_bytes(x))
                    .sum();
                per_dev_mem = per_dev_mem
                    .max(stage_params * (1 + tg.optimizer.states_per_param() as u64 + 1)
                        + stage_acts * (n.min(m) as u64));
            }
            // GPipe fill/drain: (m + n − 1) stage slots per iteration
            let latency = stage_time * (m + n - 1) as f64
                + boundary_bytes / cluster.link_bw.max(1.0);
            MultiDeviceResult {
                strategy,
                devices: n,
                latency_cycles: latency,
                energy_pj: stage_energy_sum * m as f64
                    + boundary_bytes * m as f64 * cluster.link_energy_pj,
                per_device_mem_bytes: per_dev_mem,
                comm_bytes: boundary_bytes * m as f64,
            }
        }
        Strategy::TensorParallel => {
            let tg = tg_builder(full_batch);
            let r = fused_schedule(&tg.graph, accel, mapping);
            // ideal compute split + per-MAC-layer partial-sum reduction of
            // the output activations (Megatron-style, one reduce per
            // sharded matmul in fwd and bwd)
            let mut reduce_bytes = 0f64;
            for node in &tg.graph.nodes {
                if node.kind.is_conv() || node.kind.is_gemm() {
                    reduce_bytes += (node.kind.out_elems() * tg.graph.elem_bytes) as f64;
                }
            }
            let comm = reduce_bytes * 2.0 * (n as f64 - 1.0) / n as f64 * n as f64;
            let latency = r.latency_cycles / n as f64
                + allreduce_cycles(reduce_bytes, cluster);
            MultiDeviceResult {
                strategy,
                devices: n,
                latency_cycles: latency,
                energy_pj: r.energy_pj + comm * cluster.link_energy_pj,
                per_device_mem_bytes: (tg.param_bytes()
                    + tg.grad_bytes()
                    + tg.optimizer_state_bytes())
                    / n as u64
                    + tg.saved_activation_bytes(),
                comm_bytes: comm,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::hardware::presets::EdgeTpuParams;
    use crate::workload::models::resnet18;
    use crate::workload::op::Optimizer;

    fn builder() -> impl Fn(usize) -> TrainingGraph {
        |batch| {
            build_training_graph(
                &resnet18(batch.max(1), 32, 10),
                TrainOptions { optimizer: Optimizer::Adam, include_update: true },
            )
        }
    }

    fn cluster(n: usize) -> Cluster {
        Cluster { devices: n, link_bw: 64.0, link_energy_pj: 10.0 }
    }

    fn run(s: Strategy, n: usize) -> MultiDeviceResult {
        let accel = EdgeTpuParams::baseline().build();
        model_strategy(
            s,
            8,
            &builder(),
            &accel,
            &MappingConfig::edge_tpu_default(),
            &cluster(n),
        )
    }

    #[test]
    fn data_parallel_speeds_up_and_keeps_full_model_per_device() {
        let one = run(Strategy::DataParallel, 1);
        let four = run(Strategy::DataParallel, 4);
        assert!(four.latency_cycles < one.latency_cycles);
        // every device holds the full parameter set (the Fig 5a caveat)
        let tg = builder()(8);
        let full_states = tg.param_bytes() + tg.grad_bytes() + tg.optimizer_state_bytes();
        assert!(four.per_device_mem_bytes >= full_states);
        assert!(four.comm_bytes > 0.0);
        assert_eq!(one.comm_bytes, 0.0);
    }

    #[test]
    fn pipeline_reduces_per_device_memory() {
        let one = run(Strategy::Pipeline { microbatches: 4 }, 1);
        let four = run(Strategy::Pipeline { microbatches: 4 }, 4);
        assert!(four.per_device_mem_bytes < one.per_device_mem_bytes);
        assert!(four.comm_bytes > 0.0, "stage boundaries must transfer");
    }

    #[test]
    fn more_microbatches_amortise_fill_drain() {
        let m2 = run(Strategy::Pipeline { microbatches: 2 }, 4);
        let m8 = run(Strategy::Pipeline { microbatches: 8 }, 4);
        // per-sample latency improves with more microbatches
        assert!(m8.latency_cycles / 8.0 < m2.latency_cycles / 2.0);
    }

    #[test]
    fn tensor_parallel_trades_comm_for_state_sharding() {
        let one = run(Strategy::TensorParallel, 1);
        let four = run(Strategy::TensorParallel, 4);
        assert!(four.per_device_mem_bytes < one.per_device_mem_bytes);
        assert!(four.comm_bytes > one.comm_bytes);
    }

    #[test]
    fn strategies_disagree_on_the_optimum() {
        // the §II-C1 point: no strategy dominates universally — at n=4 on a
        // bandwidth-limited fabric the rankings by latency and by memory
        // must differ
        let dp = run(Strategy::DataParallel, 4);
        let pp = run(Strategy::Pipeline { microbatches: 4 }, 4);
        let tp = run(Strategy::TensorParallel, 4);
        let by_lat = {
            let mut v = [("dp", dp.latency_cycles), ("pp", pp.latency_cycles), ("tp", tp.latency_cycles)];
            v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            v[0].0
        };
        let by_mem = {
            let mut v = [
                ("dp", dp.per_device_mem_bytes),
                ("pp", pp.per_device_mem_bytes),
                ("tp", tp.per_device_mem_bytes),
            ];
            v.sort_by_key(|x| x.1);
            v[0].0
        };
        assert_ne!(by_lat, by_mem, "one strategy dominates both axes — model too simple");
    }
}
