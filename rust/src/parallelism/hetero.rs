//! Heterogeneous edge-to-datacenter clusters (the paper's title promise,
//! §II-C1 completed): per-device [`DeviceClass`]es drawn from
//! `hardware::presets`, per-link fabric tiers between device pairs, and a
//! **stage-placement** dimension — which class hosts which pipeline stage.
//!
//! The homogeneous model in the parent module assumes N identical devices
//! on one fabric; a mixed HDA deployment (edge + server + datacenter nodes
//! in one training job) breaks both assumptions. This module keeps the
//! same GPipe/Megatron first-order arithmetic but makes three quantities
//! placement-dependent:
//!
//! * **stage time** — each pipeline stage is scheduled on its assigned
//!   class's accelerator, and the latency-balancing splitter
//!   ([`super::split_stages_balanced`]) hands a slow edge-class stage
//!   fewer nodes until the bottleneck equalizes;
//! * **links** — traffic between two classes crosses the weaker of their
//!   fabrics (min bandwidth, max hop latency, max energy-per-byte), and
//!   the dp gradient all-reduce — one concurrent ring per parameter
//!   shard, each ring on its stage's fabric — is charged at the *slowest
//!   ring on its path*;
//! * **energy** — each class carries a [`DeviceClass::energy_scale`]
//!   (voltage/frequency scaling of datacenter silicon vs the edge
//!   baseline) applied to its stages' on-device schedule energy. The
//!   scale is applied *outside* the group-cost cache, so the eval
//!   soundness contract is untouched.
//!
//! ## Degeneracy contract (extended from the parent module)
//!
//! A "mixed" cluster whose classes are all identical collapses to the
//! homogeneous [`super::Strategy::Hybrid`] path on that class's
//! accelerator and fabric tier: latency, per-device memory and comm
//! bytes are **bit-identical**, and energy is bit-identical *up to the
//! class's [`DeviceClass::energy_scale`]* — the on-device stage energies
//! are multiplied by the scale before composition (comm energy is not),
//! so for the scale-1 edge reference class every output matches bit for
//! bit. The arithmetic below is arranged for exactly that: communication
//! is accumulated per link-class pair and multiplied by the link
//! constants once per pair, so a single-class placement collapses to the
//! homogeneous single-fabric expressions. The `uniform_hetero_*` unit
//! tests pin the full bit-identity on the edge class (including a merged
//! edge+edge pool), and [`HeteroCluster::new`] merges identically-named
//! pool entries so the placement enumeration cannot tell two copies of
//! the same class apart (the symmetry pruning).

use crate::autodiff::TrainingGraph;
use crate::eval::CostCache;
use crate::hardware::accelerator::Accelerator;
use crate::hardware::core::Dataflow;
use crate::hardware::presets::EdgeTpuParams;
use crate::mapping::MappingConfig;

use super::{
    allreduce_cycles, stage_mem_parts, stage_subgraph, tp_reduce_stats, Cluster, LinkTier,
    MultiDeviceResult, Strategy,
};

/// One device class of a heterogeneous cluster: an accelerator
/// configuration, the fabric tier its devices share, and its
/// dynamic-energy scale relative to the edge baseline.
#[derive(Debug, Clone)]
pub struct DeviceClass {
    /// Stable name — classes are identified by it ([`HeteroCluster::new`]
    /// merges same-named pool entries) and the CLI selects presets with it
    /// (`--device-classes edge:2,datacenter:2`).
    pub name: String,
    /// The on-device hardware model every stage placed on this class is
    /// scheduled on.
    pub accel: Accelerator,
    /// Fabric among devices of this class; cross-class links combine two
    /// tiers worst-case (see [`HeteroCluster::link`]).
    pub tier: LinkTier,
    /// Dynamic-energy multiplier vs the edge baseline (≈ V²·f scaling:
    /// datacenter parts clock high at high voltage, edge parts are tuned
    /// for pJ/MAC). Applied to the on-device schedule energy of this
    /// class's stages — deployment-level modeling, outside the group-cost
    /// cache.
    pub energy_scale: f64,
}

impl DeviceClass {
    /// Battery-class edge device: the Table II baseline Edge TPU on a
    /// board-level fabric. The energy reference point (`energy_scale` 1).
    pub fn edge() -> Self {
        DeviceClass {
            name: "edge".into(),
            accel: EdgeTpuParams::baseline().build(),
            tier: LinkTier::Edge,
            energy_scale: 1.0,
        }
    }

    /// Server-class device: 2× the per-PE compute and local SRAM, 2× the
    /// off-chip bandwidth, PCIe-class chassis fabric, 2× the per-op
    /// energy.
    pub fn server() -> Self {
        let mut accel = EdgeTpuParams::server_class().build();
        accel.offchip_bw *= 2.0;
        DeviceClass {
            name: "server".into(),
            accel,
            tier: LinkTier::Server,
            energy_scale: 2.0,
        }
    }

    /// Datacenter-class device: 4× the per-PE compute and local SRAM of
    /// the edge baseline, HBM-class off-chip bandwidth (4×), a
    /// proportionally wider vector unit, a switched datacenter fabric —
    /// and 4× the per-op energy (high clock, high voltage, HBM
    /// interfaces).
    pub fn datacenter() -> Self {
        let mut accel = EdgeTpuParams::datacenter_class().build();
        accel.offchip_bw *= 4.0;
        for core in accel.cores.iter_mut() {
            if let Dataflow::Simd { lanes } = core.dataflow {
                core.dataflow = Dataflow::Simd { lanes: lanes * 4 };
                core.onchip_bw *= 4.0;
                core.local_mem_bytes *= 2;
            }
        }
        DeviceClass {
            name: "datacenter".into(),
            accel,
            tier: LinkTier::Datacenter,
            energy_scale: 4.0,
        }
    }

    /// The named presets the CLI accepts (`edge`, `server`, `datacenter`).
    pub fn by_name(name: &str) -> Option<DeviceClass> {
        match name {
            "edge" => Some(Self::edge()),
            "server" => Some(Self::server()),
            "datacenter" => Some(Self::datacenter()),
            _ => None,
        }
    }
}

/// A pool of device classes with per-class device counts — the hardware
/// side of a heterogeneous deployment.
#[derive(Debug, Clone)]
pub struct HeteroCluster {
    /// Distinct classes (same-named pool entries are merged by [`Self::new`]).
    pub classes: Vec<DeviceClass>,
    /// Devices available per class, parallel to `classes`.
    pub counts: Vec<usize>,
}

impl HeteroCluster {
    /// Build a pool, merging identically-named entries and dropping zero
    /// counts — the symmetry pruning that keeps the placement enumeration
    /// from producing permutations of indistinguishable classes. Classes
    /// are identified by name: merging two same-named entries with
    /// *different* hardware would silently mis-model half the pool, so
    /// that misuse is rejected in debug builds.
    pub fn new(pool: Vec<(DeviceClass, usize)>) -> Self {
        let mut classes: Vec<DeviceClass> = vec![];
        let mut counts: Vec<usize> = vec![];
        for (class, count) in pool {
            if count == 0 {
                continue;
            }
            if let Some(i) = classes.iter().position(|c| c.name == class.name) {
                debug_assert!(
                    classes[i].tier == class.tier
                        && classes[i].energy_scale == class.energy_scale
                        && classes[i].accel.name == class.accel.name,
                    "pool entries named {:?} differ in hardware; merging would mis-model them",
                    class.name
                );
                counts[i] += count;
            } else {
                classes.push(class);
                counts.push(count);
            }
        }
        HeteroCluster { classes, counts }
    }

    pub fn total_devices(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Stable pool label, e.g. `edge:2+datacenter:2`.
    pub fn label(&self) -> String {
        self.classes
            .iter()
            .zip(&self.counts)
            .map(|(c, n)| format!("{}:{}", c.name, n))
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Fabric parameters for a `devices`-wide group whose traffic runs
    /// between a class-`a` and a class-`b` device. Same class → that
    /// class's tier; cross-class → worst-case combine (min bandwidth, max
    /// hop latency, max energy per byte): traffic between two fabrics
    /// crosses the slower one plus a gateway.
    pub fn link(&self, a: usize, b: usize, devices: usize) -> Cluster {
        let ca = self.classes[a].tier.cluster(devices);
        if a == b {
            return ca;
        }
        let cb = self.classes[b].tier.cluster(devices);
        Cluster {
            devices,
            link_bw: ca.link_bw.min(cb.link_bw),
            link_energy_pj: ca.link_energy_pj.max(cb.link_energy_pj),
            hop_cycles: ca.hop_cycles.max(cb.hop_cycles),
        }
    }

    /// The fabric tier that bounds a placement: the slowest tier among the
    /// classes it uses (edge < server < datacenter).
    pub fn bottleneck_tier(&self, placement: &[usize]) -> LinkTier {
        placement
            .iter()
            .map(|&c| self.classes[c].tier)
            .min_by_key(|t| t.rank())
            .unwrap_or(LinkTier::Datacenter)
    }
}

/// One heterogeneous deployment point: a hybrid DP/PP/TP factorization
/// plus the **stage placement** — the class index (into
/// [`HeteroCluster::classes`]) hosting each pipeline stage. Every stage
/// occupies `dp·tp` devices of its class (one tp-gang per dp replica).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct HeteroPoint {
    pub dp: usize,
    pub pp: usize,
    /// Pipeline microbatches (1 whenever `pp == 1`).
    pub microbatches: usize,
    pub tp: usize,
    /// Class index per pipeline stage; `placement.len() == pp`.
    pub placement: Vec<usize>,
}

impl HeteroPoint {
    pub fn devices(&self) -> usize {
        self.dp * self.pp * self.tp
    }

    /// Does the pool have enough devices of each class for this placement
    /// (`dp·tp` devices per stage hosted on the stage's class)?
    pub fn feasible(&self, hc: &HeteroCluster) -> bool {
        if self.placement.len() != self.pp.max(1) {
            return false;
        }
        let gang = self.dp.max(1) * self.tp.max(1);
        let mut used = vec![0usize; hc.classes.len()];
        for &c in &self.placement {
            if c >= hc.classes.len() {
                return false;
            }
            used[c] += gang;
        }
        used.iter().zip(&hc.counts).all(|(u, cap)| u <= cap)
    }

    /// Does the placement span more than one device class?
    pub fn is_mixed(&self) -> bool {
        self.placement.windows(2).any(|w| w[0] != w[1])
    }

    /// Stage classes by name, `|`-joined (e.g. `edge|datacenter`).
    pub fn placement_names(&self, hc: &HeteroCluster) -> String {
        self.placement
            .iter()
            .map(|&c| hc.classes[c].name.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }

    /// Stable row label, e.g. `mixed,n4,dp2,pp2,m4,tp1,edge|datacenter`.
    pub fn label(&self, hc: &HeteroCluster) -> String {
        format!(
            "mixed,n{},dp{},pp{},m{},tp{},{}",
            self.devices(),
            self.dp,
            self.pp,
            self.microbatches,
            self.tp,
            self.placement_names(hc)
        )
    }
}

/// Model one training iteration of a heterogeneous deployment point —
/// the placement-aware sibling of [`super::model_strategy_cached`] (see
/// the module docs for what becomes placement-dependent, and for the
/// bit-level degeneracy contract with the homogeneous path).
pub fn model_strategy_hetero(
    point: &HeteroPoint,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    mapping: &MappingConfig,
    hc: &HeteroCluster,
    cache: Option<&CostCache>,
) -> MultiDeviceResult {
    model_strategy_hetero_memo(point, full_batch, tg_builder, mapping, hc, cache, None)
}

/// [`model_strategy_hetero`] with the optional per-worker stage-cuts
/// memo ([`super::StageCutsMemo`]): deployment points sharing their
/// (microbatch size, stage-class placement) — e.g. the same placement at
/// different `tp` widths — skip re-deriving the latency-balanced split.
/// Results are bit-identical with or without the memo.
pub fn model_strategy_hetero_memo(
    point: &HeteroPoint,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    mapping: &MappingConfig,
    hc: &HeteroCluster,
    cache: Option<&CostCache>,
    cuts: Option<&super::StageCutsMemo>,
) -> MultiDeviceResult {
    use std::collections::{BTreeMap, BTreeSet};

    let dp = point.dp.max(1);
    let pp = point.pp.max(1);
    let m = point.microbatches.max(1);
    let tp = point.tp.max(1);
    assert_eq!(
        point.placement.len(),
        pp,
        "placement must assign every pipeline stage a device class"
    );
    let devices = dp * pp * tp;

    // each replica sees 1/dp of the batch, pipelined in m microbatches —
    // the homogeneous `Hybrid` batch rules, unchanged
    let replica_batch = full_batch.div_ceil(dp);
    let micro_batch = replica_batch.div_ceil(m).max(1);
    let tg = tg_builder(micro_batch);
    let states_mult = 1 + tg.optimizer.states_per_param() as u64 + 1;

    // one record per used (non-empty) stage, in stage order:
    // (class, stage eval [schedule + reduce footprint + boundary bytes],
    //  stage states, in-flight activation bytes). The stage eval goes
    //  through the per-worker memo: a `DeploymentGenome` mutation leaves
    //  most stages' (microbatch, class, node set) keys untouched, so only
    //  the changed stages are re-scheduled (incremental GA evaluation).
    type StageInfo = (usize, super::StageEval, u64, u64);
    let mut infos: Vec<StageInfo> = vec![];
    if pp == 1 {
        // single stage: schedule the replica graph directly (no induced-
        // subgraph rebuild), mirroring the homogeneous arm so the
        // degenerate corners replay it bit for bit
        let c = point.placement[0];
        let se = super::stage_eval_memo(
            &tg.graph, None, &hc.classes[c].accel, mapping, cache, micro_batch, c, cuts,
        );
        let states = tg.param_bytes() + tg.grad_bytes() + tg.optimizer_state_bytes();
        infos.push((c, se, states, tg.saved_activation_bytes()));
    } else {
        let stage_accels: Vec<&Accelerator> =
            point.placement.iter().map(|&c| &hc.classes[c].accel).collect();
        let stages = super::balanced_stages(
            &tg.graph,
            &stage_accels,
            mapping,
            cache,
            micro_batch,
            point.placement.clone(),
            cuts,
        );
        for (s, stage) in stages.iter().enumerate() {
            if stage.is_empty() {
                continue;
            }
            let c = point.placement[s];
            let se = super::stage_eval_memo(
                &tg.graph,
                Some(stage),
                &hc.classes[c].accel,
                mapping,
                cache,
                micro_batch,
                c,
                cuts,
            );
            let (stage_params, stage_acts) = stage_mem_parts(&tg, stage);
            infos.push((
                c,
                se,
                stage_params * states_mult,
                stage_acts * (pp.min(m) as u64),
            ));
        }
    }
    let used_n = infos.len();

    // per-link-class-pair communication buckets (BTreeMap: deterministic
    // order). Keyed accumulation is what lets a uniform-class placement
    // collapse bit-identically to the homogeneous arithmetic: bytes are
    // summed first, then divided/multiplied by the link constants once
    // per key, exactly like the homogeneous single-fabric expressions.
    let mut tp_bytes: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut boundary_bytes: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut boundary_hops: BTreeMap<(usize, usize), usize> = BTreeMap::new();

    let mut stage_time = 0f64;
    let mut stage_energy_sum = 0f64;
    let mut per_dev_mem = 0u64;

    for (i, (c, se, stage_states, stage_acts)) in infos.iter().enumerate() {
        let c = *c;
        // TP inside a stage runs on the stage class's own fabric
        let tp_link = hc.link(c, c, tp);
        let tp_lat = if tp > 1 {
            se.latency_cycles / tp as f64
                + allreduce_cycles(se.reduce_bytes, &tp_link)
                + se.n_collectives as f64 * tp_link.hop_cycles
        } else {
            se.latency_cycles
        };
        stage_time = stage_time.max(tp_lat);
        stage_energy_sum += se.energy_pj * hc.classes[c].energy_scale;
        if tp > 1 {
            *tp_bytes.entry((c, c)).or_insert(0.0) +=
                se.reduce_bytes * 2.0 * (tp as f64 - 1.0) / tp as f64 * tp as f64;
        }
        per_dev_mem = per_dev_mem.max(stage_states / tp as u64 + stage_acts);
        // a stage's boundary tensors cross to the next used stage's class
        if i + 1 < used_n && se.boundary_bytes > 0.0 {
            let next_c = infos[i + 1].0;
            let key = (c.min(next_c), c.max(next_c));
            *boundary_bytes.entry(key).or_insert(0.0) += se.boundary_bytes;
        }
    }
    for i in 1..used_n {
        let (a, b) = (infos[i - 1].0, infos[i].0);
        *boundary_hops.entry((a.min(b), a.max(b))).or_insert(0) += 1;
    }

    // replica-level gradient all-reduce: pp·tp concurrent per-shard rings,
    // each stage's rings on that stage's class fabric — the critical path
    // is the slowest ring, i.e. the dp all-reduce crosses the slowest
    // link on its path. Its traffic is charged at that ring's link energy.
    let mut dp_sync = 0f64;
    let mut dp_worst_key: Option<(usize, usize)> = None;
    if dp > 1 {
        for info in &infos {
            let c = info.0;
            let link = hc.link(c, c, dp);
            let t = link.hop_cycles
                + allreduce_cycles(tg.grad_bytes() as f64 / (pp * tp) as f64, &link);
            if t > dp_sync || dp_worst_key.is_none() {
                dp_sync = t;
                dp_worst_key = Some((c, c));
            }
        }
    }
    let dp_comm = if dp > 1 {
        2.0 * (dp as f64 - 1.0) / dp as f64 * tg.grad_bytes() as f64 * dp as f64
    } else {
        0.0
    };

    // latency: identical composition to the homogeneous arm, with the
    // per-key boundary terms collapsing to the single-fabric expressions
    // on a uniform placement
    let mut boundary_lat = 0f64;
    for (&(a, b), &bytes) in &boundary_bytes {
        boundary_lat += bytes / hc.link(a, b, 2).link_bw.max(1.0);
    }
    let mut hop_lat = 0f64;
    for (&(a, b), &cnt) in &boundary_hops {
        hop_lat += cnt as f64 * hc.link(a, b, 2).hop_cycles;
    }
    let latency = stage_time * (m + pp - 1) as f64 + boundary_lat + hop_lat + dp_sync;

    // total comm bytes + comm energy, per link-class pair
    let mut keys: BTreeSet<(usize, usize)> = BTreeSet::new();
    keys.extend(tp_bytes.keys().copied());
    keys.extend(boundary_bytes.keys().copied());
    if let Some(k) = dp_worst_key {
        keys.insert(k);
    }
    let mut comm_total = 0f64;
    let mut comm_energy = 0f64;
    for &(a, b) in &keys {
        let t = tp_bytes.get(&(a, b)).copied().unwrap_or(0.0);
        let bd = boundary_bytes.get(&(a, b)).copied().unwrap_or(0.0);
        let mut k_comm = (t * m as f64 + bd * m as f64) * dp as f64;
        if dp_worst_key == Some((a, b)) {
            k_comm += dp_comm;
        }
        comm_total += k_comm;
        comm_energy += k_comm * hc.link(a, b, 2).link_energy_pj;
    }

    MultiDeviceResult {
        strategy: Strategy::Hybrid { dp, pp_stages: pp, microbatches: m, tp },
        devices,
        latency_cycles: latency,
        energy_pj: (stage_energy_sum * m as f64) * dp as f64 + comm_energy,
        per_device_mem_bytes: per_dev_mem,
        comm_bytes: comm_total,
    }
}

/// Admissible lower bound of [`model_strategy_hetero_memo`] — the
/// heterogeneous sibling of [`super::model_strategy_bound`], with the
/// same contract: every stage's *scheduled* latency/energy is replaced by
/// its roofline [`crate::scheduler::ScheduleBound`] (energy still scaled
/// by the class's [`DeviceClass::energy_scale`]), while the
/// latency-balanced split (shared through the [`super::StageCutsMemo`]),
/// per-pair boundary buckets, collective launches, the worst-ring dp sync
/// and the memory accounting mirror evaluation exactly. Guarantee:
/// `latency_cycles`/`energy_pj`/`comm_bytes` are `<=`, and
/// `per_device_mem_bytes`/`devices` `==`, the corresponding
/// [`model_strategy_hetero_memo`] fields for the same point.
pub fn model_strategy_hetero_bound(
    point: &HeteroPoint,
    full_batch: usize,
    tg_builder: &dyn Fn(usize) -> TrainingGraph,
    mapping: &MappingConfig,
    hc: &HeteroCluster,
    cache: Option<&CostCache>,
    cuts: Option<&super::StageCutsMemo>,
) -> MultiDeviceResult {
    use std::collections::{BTreeMap, BTreeSet};

    let dp = point.dp.max(1);
    let pp = point.pp.max(1);
    let m = point.microbatches.max(1);
    let tp = point.tp.max(1);
    assert_eq!(
        point.placement.len(),
        pp,
        "placement must assign every pipeline stage a device class"
    );
    let devices = dp * pp * tp;

    let replica_batch = full_batch.div_ceil(dp);
    let micro_batch = replica_batch.div_ceil(m).max(1);
    let tg = tg_builder(micro_batch);
    let states_mult = 1 + tg.optimizer.states_per_param() as u64 + 1;

    // (class, latency lb, energy lb, reduce bytes, collectives, states,
    //  in-flight acts, boundary bytes) per used stage — the bound twin of
    //  the memo path's StageInfo
    type StageInfo = (usize, f64, f64, f64, usize, u64, u64, f64);
    let mut infos: Vec<StageInfo> = vec![];
    if pp == 1 {
        let c = point.placement[0];
        let b = crate::scheduler::schedule_lower_bound(&tg.graph, &hc.classes[c].accel, mapping);
        let (reduce_bytes, n_collectives) =
            tp_reduce_stats(tg.graph.nodes.iter(), tg.graph.elem_bytes);
        let states = tg.param_bytes() + tg.grad_bytes() + tg.optimizer_state_bytes();
        infos.push((
            c,
            b.latency_cycles,
            b.energy_pj,
            reduce_bytes,
            n_collectives,
            states,
            tg.saved_activation_bytes(),
            0.0,
        ));
    } else {
        let stage_accels: Vec<&Accelerator> =
            point.placement.iter().map(|&c| &hc.classes[c].accel).collect();
        let stages = super::balanced_stages(
            &tg.graph,
            &stage_accels,
            mapping,
            cache,
            micro_batch,
            point.placement.clone(),
            cuts,
        );
        for (s, stage) in stages.iter().enumerate() {
            if stage.is_empty() {
                continue;
            }
            let c = point.placement[s];
            let (sub, stage_boundary) = stage_subgraph(&tg.graph, stage);
            let b = crate::scheduler::schedule_lower_bound(&sub, &hc.classes[c].accel, mapping);
            let (reduce_bytes, n_collectives) = tp_reduce_stats(sub.nodes.iter(), sub.elem_bytes);
            let (stage_params, stage_acts) = stage_mem_parts(&tg, stage);
            infos.push((
                c,
                b.latency_cycles,
                b.energy_pj,
                reduce_bytes,
                n_collectives,
                stage_params * states_mult,
                stage_acts * (pp.min(m) as u64),
                stage_boundary,
            ));
        }
    }
    let used_n = infos.len();

    let mut tp_bytes: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut boundary_bytes: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut boundary_hops: BTreeMap<(usize, usize), usize> = BTreeMap::new();

    let mut stage_time = 0f64;
    let mut stage_energy_sum = 0f64;
    let mut per_dev_mem = 0u64;

    for (i, (c, lat_lb, energy_lb, reduce_bytes, n_collectives, stage_states, stage_acts, boundary)) in
        infos.iter().enumerate()
    {
        let c = *c;
        let tp_link = hc.link(c, c, tp);
        let tp_lat = if tp > 1 {
            lat_lb / tp as f64
                + allreduce_cycles(*reduce_bytes, &tp_link)
                + *n_collectives as f64 * tp_link.hop_cycles
        } else {
            *lat_lb
        };
        stage_time = stage_time.max(tp_lat);
        stage_energy_sum += energy_lb * hc.classes[c].energy_scale;
        if tp > 1 {
            *tp_bytes.entry((c, c)).or_insert(0.0) +=
                reduce_bytes * 2.0 * (tp as f64 - 1.0) / tp as f64 * tp as f64;
        }
        per_dev_mem = per_dev_mem.max(stage_states / tp as u64 + stage_acts);
        if i + 1 < used_n && *boundary > 0.0 {
            let next_c = infos[i + 1].0;
            let key = (c.min(next_c), c.max(next_c));
            *boundary_bytes.entry(key).or_insert(0.0) += *boundary;
        }
    }
    for i in 1..used_n {
        let (a, b) = (infos[i - 1].0, infos[i].0);
        *boundary_hops.entry((a.min(b), a.max(b))).or_insert(0) += 1;
    }

    let mut dp_sync = 0f64;
    let mut dp_worst_key: Option<(usize, usize)> = None;
    if dp > 1 {
        for info in &infos {
            let c = info.0;
            let link = hc.link(c, c, dp);
            let t = link.hop_cycles
                + allreduce_cycles(tg.grad_bytes() as f64 / (pp * tp) as f64, &link);
            if t > dp_sync || dp_worst_key.is_none() {
                dp_sync = t;
                dp_worst_key = Some((c, c));
            }
        }
    }
    let dp_comm = if dp > 1 {
        2.0 * (dp as f64 - 1.0) / dp as f64 * tg.grad_bytes() as f64 * dp as f64
    } else {
        0.0
    };

    let mut boundary_lat = 0f64;
    for (&(a, b), &bytes) in &boundary_bytes {
        boundary_lat += bytes / hc.link(a, b, 2).link_bw.max(1.0);
    }
    let mut hop_lat = 0f64;
    for (&(a, b), &cnt) in &boundary_hops {
        hop_lat += cnt as f64 * hc.link(a, b, 2).hop_cycles;
    }
    let latency = stage_time * (m + pp - 1) as f64 + boundary_lat + hop_lat + dp_sync;

    let mut keys: BTreeSet<(usize, usize)> = BTreeSet::new();
    keys.extend(tp_bytes.keys().copied());
    keys.extend(boundary_bytes.keys().copied());
    if let Some(k) = dp_worst_key {
        keys.insert(k);
    }
    let mut comm_total = 0f64;
    let mut comm_energy = 0f64;
    for &(a, b) in &keys {
        let t = tp_bytes.get(&(a, b)).copied().unwrap_or(0.0);
        let bd = boundary_bytes.get(&(a, b)).copied().unwrap_or(0.0);
        let mut k_comm = (t * m as f64 + bd * m as f64) * dp as f64;
        if dp_worst_key == Some((a, b)) {
            k_comm += dp_comm;
        }
        comm_total += k_comm;
        comm_energy += k_comm * hc.link(a, b, 2).link_energy_pj;
    }

    MultiDeviceResult {
        strategy: Strategy::Hybrid { dp, pp_stages: pp, microbatches: m, tp },
        devices,
        latency_cycles: latency,
        energy_pj: (stage_energy_sum * m as f64) * dp as f64 + comm_energy,
        per_device_mem_bytes: per_dev_mem,
        comm_bytes: comm_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::parallelism::model_strategy_cached;
    use crate::workload::models::resnet18;
    use crate::workload::op::Optimizer;

    fn builder() -> impl Fn(usize) -> TrainingGraph {
        |batch| {
            build_training_graph(
                &resnet18(batch.max(1), 32, 10),
                TrainOptions { optimizer: Optimizer::Adam, include_update: true },
            )
        }
    }

    fn bit_eq(a: &MultiDeviceResult, b: &MultiDeviceResult) {
        assert_eq!(a.devices, b.devices);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes);
        assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits());
    }

    #[test]
    fn pool_merges_identical_classes_and_drops_zeros() {
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 0),
        ]);
        assert_eq!(hc.classes.len(), 1);
        assert_eq!(hc.counts, vec![4]);
        assert_eq!(hc.total_devices(), 4);
        assert_eq!(hc.label(), "edge:4");
    }

    #[test]
    fn cross_class_links_combine_worst_case() {
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let ee = hc.link(0, 0, 2);
        let dd = hc.link(1, 1, 2);
        let ed = hc.link(0, 1, 2);
        assert_eq!(ed.link_bw.to_bits(), ee.link_bw.min(dd.link_bw).to_bits());
        assert!(ed.hop_cycles >= ee.hop_cycles.max(dd.hop_cycles) - 1e-9);
        assert!(ed.link_energy_pj >= ee.link_energy_pj.max(dd.link_energy_pj) - 1e-9);
        // the bottleneck tier of a mixed placement is the slowest one
        assert_eq!(hc.bottleneck_tier(&[0, 1]), LinkTier::Edge);
        assert_eq!(hc.bottleneck_tier(&[1, 1]), LinkTier::Datacenter);
    }

    #[test]
    fn class_presets_resolve_by_name() {
        for name in ["edge", "server", "datacenter"] {
            let c = DeviceClass::by_name(name).unwrap();
            assert_eq!(c.name, name);
            assert!(c.energy_scale >= 1.0);
            assert!(c.accel.total_macs() > 0);
        }
        assert!(DeviceClass::by_name("laptop").is_none());
        // the ladder is ordered: faster and hungrier toward the datacenter
        let (e, s, d) = (DeviceClass::edge(), DeviceClass::server(), DeviceClass::datacenter());
        assert!(e.accel.total_macs() < s.accel.total_macs());
        assert!(s.accel.total_macs() < d.accel.total_macs());
        assert!(e.energy_scale < s.energy_scale && s.energy_scale < d.energy_scale);
        assert!(e.accel.offchip_bw < d.accel.offchip_bw);
    }

    // ---- the extended degeneracy contract: a "mixed" cluster whose
    // classes are all identical replays the PR 3 homogeneous path bit for
    // bit, at every factorization corner ----

    #[test]
    fn uniform_hetero_cluster_is_bit_identical_to_homogeneous_hybrid() {
        // two identically-named pool entries merge (the symmetry pruning),
        // and the degenerate "mixed" cluster must replay the homogeneous
        // Hybrid arithmetic on the same accelerator and fabric tier
        let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::edge(), 2)]);
        assert_eq!(hc.classes.len(), 1);
        let accel = crate::hardware::presets::EdgeTpuParams::baseline().build();
        let mapping = MappingConfig::edge_tpu_default();
        let cases: Vec<(usize, usize, usize, usize, Vec<usize>)> = vec![
            (1, 1, 1, 1, vec![0]),
            (4, 1, 1, 1, vec![0]),
            (1, 4, 4, 1, vec![0, 0, 0, 0]),
            (1, 1, 1, 4, vec![0]),
            (2, 2, 4, 1, vec![0, 0]),
        ];
        for (dp, pp, m, tp, placement) in cases {
            let point = HeteroPoint { dp, pp, microbatches: m, tp, placement };
            assert!(point.feasible(&hc));
            let h = model_strategy_hetero(&point, 8, &builder(), &mapping, &hc, None);
            let r = model_strategy_cached(
                Strategy::Hybrid { dp, pp_stages: pp, microbatches: m, tp },
                8,
                &builder(),
                &accel,
                &mapping,
                &LinkTier::Edge.cluster(dp * pp * tp),
                None,
            );
            bit_eq(&h, &r);
        }
    }

    #[test]
    fn uniform_hetero_is_bit_identical_with_a_shared_cache_too() {
        let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 4)]);
        let mapping = MappingConfig::edge_tpu_default();
        let point = HeteroPoint { dp: 1, pp: 2, microbatches: 4, tp: 2, placement: vec![0, 0] };
        let plain = model_strategy_hetero(&point, 8, &builder(), &mapping, &hc, None);
        let cache = CostCache::new();
        let cached = model_strategy_hetero(&point, 8, &builder(), &mapping, &hc, Some(&cache));
        bit_eq(&plain, &cached);
        assert!(cache.stats().misses > 0);
    }

    #[test]
    fn mixed_placement_is_finite_and_feasibility_holds() {
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let mapping = MappingConfig::edge_tpu_default();
        let mixed = HeteroPoint { dp: 1, pp: 2, microbatches: 2, tp: 1, placement: vec![0, 1] };
        assert!(mixed.feasible(&hc));
        assert!(mixed.is_mixed());
        assert_eq!(mixed.placement_names(&hc), "edge|datacenter");
        assert_eq!(mixed.label(&hc), "mixed,n2,dp1,pp2,m2,tp1,edge|datacenter");
        let r = model_strategy_hetero(&mixed, 4, &builder(), &mapping, &hc, None);
        assert!(r.latency_cycles.is_finite() && r.latency_cycles > 0.0);
        assert!(r.energy_pj.is_finite() && r.energy_pj > 0.0);
        assert!(r.comm_bytes > 0.0, "a pipeline boundary must communicate");
        assert_eq!(r.devices, 2);
        // too many gangs for the pool → infeasible
        let over = HeteroPoint { dp: 4, pp: 1, microbatches: 1, tp: 1, placement: vec![0] };
        assert!(!over.feasible(&hc));
        let uniform = HeteroPoint { dp: 1, pp: 2, microbatches: 2, tp: 1, placement: vec![1, 1] };
        assert!(!uniform.is_mixed());
    }

    #[test]
    fn hetero_stage_cuts_memo_is_bit_identical_across_tp_widths() {
        use crate::parallelism::StageCutsMemo;
        // two deployment points sharing (microbatch graph, placement) but
        // differing in tp: the balanced split is tp-independent, so the
        // memo derives it once — and never changes a bit of either row
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let mapping = MappingConfig::edge_tpu_default();
        let memo = StageCutsMemo::new();
        let points = [
            HeteroPoint { dp: 1, pp: 2, microbatches: 2, tp: 1, placement: vec![0, 1] },
            HeteroPoint { dp: 1, pp: 2, microbatches: 2, tp: 2, placement: vec![0, 1] },
        ];
        for p in &points {
            assert!(p.feasible(&hc));
            let plain = model_strategy_hetero(p, 4, &builder(), &mapping, &hc, None);
            let memoed =
                model_strategy_hetero_memo(p, 4, &builder(), &mapping, &hc, None, Some(&memo));
            bit_eq(&plain, &memoed);
        }
        assert_eq!(memo.misses(), 1, "shared (microbatch, placement) must derive once");
        assert_eq!(memo.hits(), 1);
        // flipping the placement is a different key
        let flipped =
            HeteroPoint { dp: 1, pp: 2, microbatches: 2, tp: 1, placement: vec![1, 0] };
        let plain = model_strategy_hetero(&flipped, 4, &builder(), &mapping, &hc, None);
        let memoed =
            model_strategy_hetero_memo(&flipped, 4, &builder(), &mapping, &hc, None, Some(&memo));
        bit_eq(&plain, &memoed);
        assert_eq!(memo.misses(), 2);
    }

    #[test]
    fn datacenter_class_is_faster_but_hungrier_than_edge() {
        // the two levers behind the mixed-placement fronts: at the same
        // factorization, an all-datacenter placement must cut latency vs
        // all-edge (bigger arrays, more bandwidth) while paying more
        // energy (the V²·f scale) — otherwise one class dominates and the
        // placement dimension is pointless
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let mapping = MappingConfig::edge_tpu_default();
        let run = |class: usize| {
            let p = HeteroPoint {
                dp: 1,
                pp: 2,
                microbatches: 2,
                tp: 1,
                placement: vec![class, class],
            };
            model_strategy_hetero(&p, 4, &builder(), &mapping, &hc, None)
        };
        let edge = run(0);
        let dc = run(1);
        assert!(
            dc.latency_cycles < edge.latency_cycles,
            "datacenter-class devices must be faster"
        );
        assert!(dc.energy_pj > edge.energy_pj, "datacenter-class devices must pay more energy");
    }
}
