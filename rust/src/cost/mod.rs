//! Analytical per-node cost model (DESIGN.md S7, §4): the Stream-style
//! roofline-with-memory-hierarchy model that the scheduler composes into
//! end-to-end latency/energy. The exact formulas are documented in
//! DESIGN.md §4 so every reported number is reproducible by hand.

use crate::hardware::core::Core;
use crate::hardware::energy;
use crate::workload::op::OpKind;

/// Where a node's operand tensors live when it executes. The layer-fused
/// scheduler sets these flags: tensors produced and consumed inside one
/// fused subgraph stay in local memory (the entire point of fusion,
/// paper §II-C2); everything else streams through DRAM or the global
/// buffer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct TensorPlacement {
    /// Input bytes arriving from local memory (fused predecessor).
    pub in_local: u64,
    /// Input bytes arriving from the shared global buffer.
    pub in_global: u64,
    /// Input bytes arriving over the inter-core bus from another core's
    /// local memory (short-lived producer-consumer tensors).
    pub in_link: u64,
    /// Input bytes arriving from off-chip DRAM (network inputs, weights
    /// are handled separately, and *saved activations* — the long-lived
    /// fwd→bwd tensors that cannot park in a small local SRAM).
    pub in_offchip: u64,
    /// Output stays in local memory (consumed by a fused successor).
    pub out_local: bool,
    /// Output goes to the global buffer instead of DRAM.
    pub out_global: bool,
    /// Output ships over the bus to the consumer's local memory.
    pub out_link: bool,
}

/// Cost of one node on one core.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeCost {
    pub cycles: f64,
    pub energy_pj: f64,
    pub offchip_bytes: f64,
    pub global_bytes: f64,
    pub onchip_bytes: f64,
    /// Spatial utilization achieved (reporting).
    pub utilization: f64,
}

impl NodeCost {
    pub fn accumulate(&mut self, other: &NodeCost) {
        self.cycles += other.cycles;
        self.energy_pj += other.energy_pj;
        self.offchip_bytes += other.offchip_bytes;
        self.global_bytes += other.global_bytes;
        self.onchip_bytes += other.onchip_bytes;
    }
}

/// Bandwidths seen by a core (the accelerator-level shares).
#[derive(Debug, Clone, Copy)]
pub struct MemEnv {
    /// Off-chip DRAM bandwidth available to this execution (bytes/cycle).
    pub offchip_bw: f64,
    /// Global-buffer bandwidth (0 if the HDA has none).
    pub global_bw: f64,
    /// Energy per byte for global-buffer accesses.
    pub global_energy_pj: f64,
    /// Inter-core bus bandwidth (bytes/cycle).
    pub link_bw: f64,
    /// Energy per byte moved over the bus.
    pub link_energy_pj: f64,
}

/// Compute the cost of `kind` running on `core` with operands placed per
/// `place`, work split `tensor_parallel` ways across a gang of identical
/// cores (the per-core cost is returned; the gang runs in lockstep).
///
/// Model (DESIGN.md §4):
///   eff_macs    = peak_macs · spatial_utilization
///   compute_cyc = macs / (tp · eff_macs-per-core)    [work split over gang]
///   weights     = resident if (weights + tile) ≤ local_mem, else re-streamed
///   spill       = 2 · max(0, working_set − local_mem)
///   cycles      = max(compute, onchip/bw, (offchip+spill)/bw, global/bw)
///   energy      = macs·e_mac + rf·e_rf + onchip·e_local
///                 + global·e_glob + (offchip+spill)·e_dram
// audit:pure
pub fn node_cost(
    kind: &OpKind,
    core: &Core,
    place: &TensorPlacement,
    env: &MemEnv,
    tensor_parallel: usize,
    elem_bytes: u64,
) -> NodeCost {
    let tp = tensor_parallel.max(1) as f64;
    let macs = kind.macs() as f64 / tp;
    let util = core.spatial_utilization(kind, tensor_parallel.max(1));
    let eff_macs = (core.peak_macs() as f64 * util).max(1.0);
    let compute_cyc = macs / eff_macs;

    let weight_bytes = (kind.weight_elems() * elem_bytes) as f64 / tp;
    let out_bytes = (kind.out_elems() * elem_bytes) as f64 / tp;
    let in_local = place.in_local as f64 / tp;
    let in_global = place.in_global as f64 / tp;
    let in_link = place.in_link as f64 / tp;
    let in_offchip = place.in_offchip as f64 / tp;

    // Working set: weights + one input tile + one output tile must be
    // co-resident. Tiles are bounded by the register file (innermost) and
    // local memory (outer); overflow spills to DRAM.
    let in_total = in_local + in_global + in_link + in_offchip;
    let working_set = weight_bytes + in_total.min(core.local_mem_bytes as f64 / 2.0)
        + out_bytes.min(core.local_mem_bytes as f64 / 2.0);
    let spill = 2.0 * (working_set - core.local_mem_bytes as f64).max(0.0);

    // Everything the core touches passes its local SRAM once.
    let onchip = in_total + weight_bytes + out_bytes;
    let mut offchip = in_offchip + weight_bytes + spill;
    let mut global = in_global;
    let mut link = in_link;
    if place.out_local {
        // stays put
    } else if place.out_global {
        global += out_bytes;
    } else if place.out_link {
        link += out_bytes;
    } else {
        offchip += out_bytes;
    }

    let mem_cyc_onchip = onchip / core.onchip_bw.max(1.0);
    let mem_cyc_offchip = offchip / env.offchip_bw.max(1.0);
    let mem_cyc_global = if env.global_bw > 0.0 { global / env.global_bw } else { 0.0 };
    let mem_cyc_link = link / env.link_bw.max(1.0);
    let cycles = compute_cyc
        .max(mem_cyc_onchip)
        .max(mem_cyc_offchip)
        .max(mem_cyc_global)
        .max(mem_cyc_link);

    // Register-file traffic: every MAC touches ~3 operands, but spatial
    // reuse inside the array amortises this by the array's reuse factor.
    let rf_bytes = 3.0 * macs * elem_bytes as f64 / (core.peak_macs() as f64).sqrt().max(1.0);

    let energy = macs * energy::E_MAC_PJ
        + rf_bytes * energy::E_RF_PJ_PER_BYTE
        + onchip * energy::E_LOCAL_PJ_PER_BYTE
        + global * env.global_energy_pj
        + link * env.link_energy_pj
        + offchip * energy::E_DRAM_PJ_PER_BYTE;

    NodeCost {
        cycles,
        energy_pj: energy * tp, // gang-wide energy
        offchip_bytes: offchip * tp,
        global_bytes: global * tp,
        onchip_bytes: onchip * tp,
        utilization: util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::core::Dataflow;
    use crate::workload::op::{ConvSpec, EltwiseKind};

    fn core() -> Core {
        Core {
            id: 0,
            name: "t".into(),
            dataflow: Dataflow::WeightStationary { rows: 64, cols: 4 },
            local_mem_bytes: 2 << 20,
            regfile_bytes: 32 << 10,
            onchip_bw: 128.0,
        }
    }

    fn env() -> MemEnv {
        MemEnv { offchip_bw: 64.0, global_bw: 0.0, global_energy_pj: 0.0, link_bw: 256.0, link_energy_pj: 1.8 }
    }

    fn conv() -> OpKind {
        OpKind::Conv(ConvSpec {
            batch: 1,
            in_ch: 64,
            out_ch: 64,
            in_h: 16,
            in_w: 16,
            k_h: 3,
            k_w: 3,
            stride: 1,
            padding: 1,
            groups: 1,
        })
    }

    fn place(in_offchip: u64) -> TensorPlacement {
        TensorPlacement { in_offchip, ..Default::default() }
    }

    #[test]
    fn cost_is_positive_and_bounded_by_roofline() {
        let k = conv();
        let c = node_cost(&k, &core(), &place(64 * 16 * 16 * 4), &env(), 1, 4);
        assert!(c.cycles > 0.0 && c.energy_pj > 0.0);
        // can't beat the pure-compute roofline
        let min_cyc = k.macs() as f64 / core().peak_macs() as f64;
        assert!(c.cycles >= min_cyc);
    }

    #[test]
    fn fusion_reduces_offchip_and_energy() {
        let k = conv();
        let bytes = 64 * 16 * 16 * 4u64;
        let unfused = node_cost(&k, &core(), &place(bytes), &env(), 1, 4);
        let fused = node_cost(
            &k,
            &core(),
            &TensorPlacement { in_local: bytes, out_local: true, ..Default::default() },
            &env(),
            1,
            4,
        );
        assert!(fused.offchip_bytes < unfused.offchip_bytes);
        assert!(fused.energy_pj < unfused.energy_pj);
        assert!(fused.cycles <= unfused.cycles + 1e-9);
    }

    #[test]
    fn tensor_parallel_cuts_cycles_not_total_energy_much() {
        // out_ch=256 folds 4× over the 64-row array, so a 4-way gang
        // genuinely parallelises; (with out_ch=64 the array already fits K
        // and a gang would rightly win nothing)
        let k = OpKind::Conv(ConvSpec {
            out_ch: 256,
            ..match conv() {
                OpKind::Conv(s) => s,
                _ => unreachable!(),
            }
        });
        let bytes = 64 * 16 * 16 * 4u64;
        let c1 = node_cost(&k, &core(), &place(bytes), &env(), 1, 4);
        let c4 = node_cost(&k, &core(), &place(bytes), &env(), 4, 4);
        assert!(c4.cycles < c1.cycles);
        // energy within 2x (parallelism shouldn't create/destroy work)
        assert!(c4.energy_pj < 2.0 * c1.energy_pj && c4.energy_pj > 0.5 * c1.energy_pj);
    }

    #[test]
    fn spill_kicks_in_when_local_memory_small() {
        let k = conv();
        let tiny = Core { local_mem_bytes: 1 << 10, ..core() };
        let bytes = 64 * 16 * 16 * 4u64;
        let c_small = node_cost(&k, &tiny, &place(bytes), &env(), 1, 4);
        let c_big = node_cost(&k, &core(), &place(bytes), &env(), 1, 4);
        assert!(c_small.offchip_bytes > c_big.offchip_bytes);
    }

    #[test]
    fn eltwise_on_simd_core_is_bandwidth_bound() {
        let simd = Core {
            dataflow: Dataflow::Simd { lanes: 256 },
            ..core()
        };
        let k = OpKind::Eltwise { kind: EltwiseKind::Relu, elems: 1 << 20, arity: 1 };
        let bytes = 4u64 << 20;
        let c = node_cost(&k, &simd, &place(bytes), &env(), 1, 4);
        let mem_cyc = c.offchip_bytes / 64.0;
        assert!((c.cycles - mem_cyc).abs() / mem_cyc < 0.5, "should be mem-bound");
    }

    #[test]
    fn global_buffer_path() {
        let e = MemEnv { offchip_bw: 64.0, global_bw: 8192.0, global_energy_pj: 2.0, link_bw: 256.0, link_energy_pj: 1.8 };
        let k = conv();
        let bytes = 64 * 16 * 16 * 4u64;
        let c = node_cost(
            &k,
            &core(),
            &TensorPlacement { in_global: bytes, out_global: true, ..Default::default() },
            &e,
            1,
            4,
        );
        assert!(c.global_bytes > 0.0);
        // weights still stream from DRAM
        assert!(c.offchip_bytes > 0.0);
    }
}
