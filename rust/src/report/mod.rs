//! Report emitters (DESIGN.md S15): CSV files under `results/` plus ASCII
//! scatter/bar/Gantt renderings so every figure regenerates without
//! matplotlib or any plotting dependency.
//!
//! [`write_csv`] is the one CSV serializer every figure goes through
//! (header + row iterator, no quoting logic beyond what callers embed);
//! [`ascii_scatter`], [`ascii_bars`] and [`ascii_gantt`] render the same
//! data for the terminal, and [`fmt_bytes`] pretty-prints memory sizes.
//! Keeping this layer dumb is deliberate: every number in a rendering is
//! computed upstream, so tests pin figures by asserting on the returned
//! rows rather than parsing output.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Write rows as CSV. `header` is a comma-joined line.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &str,
    rows: impl IntoIterator<Item = Vec<String>>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// ASCII scatter plot: log-log by default (the paper's figures span
/// decades). Returns the rendered string.
pub fn ascii_scatter(
    title: &str,
    xs: &[f64],
    ys: &[f64],
    marks: &[char],
    width: usize,
    height: usize,
    log: bool,
) -> String {
    assert_eq!(xs.len(), ys.len());
    assert_eq!(xs.len(), marks.len());
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    if xs.is_empty() {
        writeln!(out, "(no data)").unwrap();
        return out;
    }
    let t = |v: f64| if log { v.max(1e-12).log10() } else { v };
    let (xmin, xmax) = xs.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &v| {
        (lo.min(t(v)), hi.max(t(v)))
    });
    let (ymin, ymax) = ys.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &v| {
        (lo.min(t(v)), hi.max(t(v)))
    });
    let xr = (xmax - xmin).max(1e-9);
    let yr = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for ((&x, &y), &m) in xs.iter().zip(ys).zip(marks) {
        let cx = (((t(x) - xmin) / xr) * (width - 1) as f64).round() as usize;
        let cy = (((t(y) - ymin) / yr) * (height - 1) as f64).round() as usize;
        let row = height - 1 - cy;
        // denser marks win ties visually; simple overwrite is fine
        grid[row][cx] = m;
    }
    for row in grid {
        writeln!(out, "|{}|", row.iter().collect::<String>()).unwrap();
    }
    let fmt = |v: f64| {
        if log {
            format!("1e{v:.1}")
        } else {
            format!("{v:.3e}")
        }
    };
    writeln!(out, " x: [{} .. {}]  y: [{} .. {}]{}",
        fmt(xmin), fmt(xmax), fmt(ymin), fmt(ymax),
        if log { "  (log-log)" } else { "" }).unwrap();
    out
}

/// ASCII horizontal bar chart. Values may be negative (drawn left of the
/// zero column) — Fig 11 plots deltas relative to a baseline.
pub fn ascii_bars(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    let maxabs = values.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1e-12);
    let lab_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v.abs() / maxabs) * width as f64).round() as usize;
        let bar: String = std::iter::repeat('#').take(n).collect();
        if v >= 0.0 {
            writeln!(out, "{l:>lab_w$} | {bar} {v:.4e}").unwrap();
        } else {
            writeln!(out, "{l:>lab_w$} |-{bar} {v:.4e}").unwrap();
        }
    }
    out
}

/// ASCII Gantt chart of a schedule timeline: one row per core, time
/// bucketed into `width` columns, cells marked by the training phase of
/// the occupying group (F/B/U/R) — the paper's "generated execution
/// schedule" deliverable, rendered.
pub fn ascii_gantt(
    title: &str,
    rows: &[(usize, f64, f64, char)], // (core, start, finish, mark)
    n_cores: usize,
    makespan: f64,
    width: usize,
) -> String {
    let mut out = String::new();
    writeln!(out, "== {title} ==").unwrap();
    if makespan <= 0.0 || n_cores == 0 {
        writeln!(out, "(empty schedule)").unwrap();
        return out;
    }
    let mut grid = vec![vec![' '; width]; n_cores];
    for &(core, start, finish, mark) in rows {
        if core >= n_cores {
            continue;
        }
        let a = ((start / makespan) * width as f64) as usize;
        let b = (((finish / makespan) * width as f64).ceil() as usize).min(width);
        for cell in grid[core][a.min(width - 1)..b.max(a + 1).min(width)].iter_mut() {
            *cell = mark;
        }
    }
    for (c, row) in grid.iter().enumerate() {
        writeln!(out, "core {c:>3} |{}|", row.iter().collect::<String>()).unwrap();
    }
    writeln!(out, "          0 {:>w$.3e} cycles", makespan, w = width - 2).unwrap();
    out
}

/// Human-readable byte formatting for reports.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip(){
        let dir = std::env::temp_dir().join("monet_test_csv");
        let path = dir.join("t.csv");
        write_csv(&path, "a,b", vec![vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn scatter_renders_all_extremes() {
        let s = ascii_scatter(
            "t",
            &[1.0, 10.0, 100.0],
            &[100.0, 10.0, 1.0],
            &['a', 'b', 'c'],
            20,
            5,
            true,
        );
        assert!(s.contains('a') && s.contains('b') && s.contains('c'));
        assert!(s.contains("log-log"));
    }

    #[test]
    fn bars_handle_negative() {
        let s = ascii_bars(
            "t",
            &["up".into(), "down".into()],
            &[0.5, -0.25],
            10,
        );
        assert!(s.contains("|-"));
    }

    #[test]
    fn gantt_renders_rows_and_marks() {
        let rows = vec![(0usize, 0.0, 50.0, 'F'), (1usize, 50.0, 100.0, 'B')];
        let s = ascii_gantt("t", &rows, 2, 100.0, 20);
        assert!(s.contains("core   0"));
        assert!(s.contains('F') && s.contains('B'));
        // F occupies the first half of core 0's row only
        let line0 = s.lines().find(|l| l.contains("core   0")).unwrap();
        assert!(line0.find('F').unwrap() < 12);
    }

    #[test]
    fn gantt_empty_schedule() {
        let s = ascii_gantt("t", &[], 0, 0.0, 10);
        assert!(s.contains("empty"));
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512.00 B");
        assert_eq!(fmt_bytes(13 << 20), "13.00 MiB");
    }
}
