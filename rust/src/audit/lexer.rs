//! A lightweight hand-rolled Rust tokenizer — just enough lexical
//! structure for the contract audit (see the [`super`] module docs), with
//! the repo's zero-dependency discipline (no syn/proc-macro).
//!
//! The token stream deliberately drops comments and whitespace (so doc
//! edits never trip a contract fingerprint) but records **line comments**
//! on the side: that is where the `audit:pure` / `audit:allow` marker
//! convention lives. Block comments are skipped entirely — markers must be
//! line comments, which keeps the convention greppable and one-per-line.
//!
//! The grammar handled is the subset real `rust/src/**` files exercise:
//! nested block comments, string/char/byte/raw-string literals (so a
//! banned identifier *inside a string* is never mistaken for code),
//! lifetimes vs char literals, numeric literals with `_`/exponents, and
//! the common multi-character operators (`::`, `->`, `..=`, …) merged
//! into single tokens so rules can match `Instant :: now` robustly.

/// Lexical class of a [`Token`]. Rules match on `Ident`/`Punct` text;
/// literal classes exist so a pattern can never match inside a literal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Number,
    /// String / raw-string / byte-string literal (text is the raw source
    /// slice, quotes included).
    Str,
    /// Character or byte-character literal.
    Char,
    Lifetime,
    Punct,
}

/// One source token with its 1-indexed line.
#[derive(Debug, Clone)]
pub struct Token {
    pub text: String,
    pub kind: TokenKind,
    pub line: u32,
}

/// One `//` line comment (leading `//` stripped, trimmed).
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: u32,
}

/// Result of lexing one file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Multi-character operators merged into one `Punct` token, longest first.
const MULTI_PUNCT: &[&str] = &[
    "..=", "...", "<<=", ">>=", "::", "->", "=>", "..", "==", "!=", "<=", ">=", "&&",
    "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Tokenize `src`. Never fails: unrecognized bytes become single-char
/// `Punct` tokens and an unterminated literal consumes to end-of-file —
/// for a linter, graceful degradation beats a parse error (rustc itself
/// gates compilability in the same CI run).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;

    while i < b.len() {
        let c = b[i];
        // whitespace
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && b[j] != b'\n' {
                j += 1;
            }
            out.comments.push(Comment {
                text: src[start..j].trim().to_string(),
                line,
            });
            i = j;
            continue;
        }
        // nested block comment
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < b.len() && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // raw / byte string prefixes: r" r#" b" br" br#" (and rb variants
        // do not exist in Rust; b'..' byte chars are handled below)
        if (c == b'r' || c == b'b') && i + 1 < b.len() {
            let (prefix_len, rest) = if c == b'b' && b[i + 1] == b'r' {
                (2, i + 2)
            } else {
                (1, i + 1)
            };
            let is_raw = prefix_len == 2 || c == b'r';
            if is_raw && rest < b.len() && (b[rest] == b'"' || b[rest] == b'#') {
                let mut hashes = 0usize;
                let mut j = rest;
                while j < b.len() && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == b'"' {
                    // scan to closing quote + same number of hashes
                    let lit_start = i;
                    let start_line = line;
                    j += 1;
                    'scan: while j < b.len() {
                        if b[j] == b'\n' {
                            line += 1;
                            j += 1;
                            continue;
                        }
                        if b[j] == b'"' {
                            let mut k = 0usize;
                            while k < hashes && j + 1 + k < b.len() && b[j + 1 + k] == b'#' {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    out.tokens.push(Token {
                        text: src[lit_start..j].to_string(),
                        kind: TokenKind::Str,
                        line: start_line,
                    });
                    i = j;
                    continue;
                }
            }
            if c == b'b' && b[i + 1] == b'"' {
                let (j, nl) = scan_quoted(b, i + 1, b'"');
                out.tokens.push(Token {
                    text: src[i..j].to_string(),
                    kind: TokenKind::Str,
                    line,
                });
                line += nl;
                i = j;
                continue;
            }
            if c == b'b' && b[i + 1] == b'\'' {
                let (j, nl) = scan_quoted(b, i + 1, b'\'');
                out.tokens.push(Token {
                    text: src[i..j].to_string(),
                    kind: TokenKind::Char,
                    line,
                });
                line += nl;
                i = j;
                continue;
            }
        }
        // string literal
        if c == b'"' {
            let (j, nl) = scan_quoted(b, i, b'"');
            out.tokens.push(Token {
                text: src[i..j].to_string(),
                kind: TokenKind::Str,
                line,
            });
            line += nl;
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            let next = b.get(i + 1).copied().unwrap_or(0);
            let is_char = next == b'\\'
                || (i + 2 < b.len() && b[i + 2] == b'\'' && next != b'\'')
                || !is_ident_start(next);
            if is_char {
                let (j, nl) = scan_quoted(b, i, b'\'');
                out.tokens.push(Token {
                    text: src[i..j].to_string(),
                    kind: TokenKind::Char,
                    line,
                });
                line += nl;
                i = j;
                continue;
            }
            // lifetime: 'ident (not followed by a closing quote)
            let mut j = i + 1;
            while j < b.len() && is_ident_cont(b[j]) {
                j += 1;
            }
            out.tokens.push(Token {
                text: src[i..j].to_string(),
                kind: TokenKind::Lifetime,
                line,
            });
            i = j;
            continue;
        }
        // identifier / keyword
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < b.len() && is_ident_cont(b[j]) {
                j += 1;
            }
            out.tokens.push(Token {
                text: src[i..j].to_string(),
                kind: TokenKind::Ident,
                line,
            });
            i = j;
            continue;
        }
        // number
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < b.len() {
                let d = b[j];
                if is_ident_cont(d) {
                    // exponent sign: 1e-3, 2.5E+7 (not in hex literals)
                    if (d == b'e' || d == b'E')
                        && !src[i..j].starts_with("0x")
                        && j + 1 < b.len()
                        && (b[j + 1] == b'+' || b[j + 1] == b'-')
                    {
                        j += 2;
                        continue;
                    }
                    j += 1;
                    continue;
                }
                // fractional part: '.' followed by a digit (so `0..n`
                // stays three tokens) and at most one dot per literal
                if d == b'.'
                    && j + 1 < b.len()
                    && b[j + 1].is_ascii_digit()
                    && !src[i..j].contains('.')
                {
                    j += 1;
                    continue;
                }
                break;
            }
            out.tokens.push(Token {
                text: src[i..j].to_string(),
                kind: TokenKind::Number,
                line,
            });
            i = j;
            continue;
        }
        // punctuation: longest multi-char operator first
        let mut matched = false;
        for op in MULTI_PUNCT {
            if src[i..].starts_with(op) {
                out.tokens.push(Token {
                    text: (*op).to_string(),
                    kind: TokenKind::Punct,
                    line,
                });
                i += op.len();
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        out.tokens.push(Token {
            text: (c as char).to_string(),
            kind: TokenKind::Punct,
            line,
        });
        i += 1;
    }
    out
}

/// Scan a quoted literal starting at the opening quote `b[start] ==
/// quote`, honouring backslash escapes. Returns (index one past the
/// closing quote, newlines crossed).
fn scan_quoted(b: &[u8], start: usize, quote: u8) -> (usize, u32) {
    let mut j = start + 1;
    let mut nl = 0u32;
    while j < b.len() {
        match b[j] {
            // an escaped newline (string line-continuation) still ends a
            // source line; clamp so a trailing backslash at EOF does not
            // step past the end
            b'\\' => {
                if b.get(j + 1) == Some(&b'\n') {
                    nl += 1;
                }
                j = (j + 2).min(b.len());
            }
            b'\n' => {
                nl += 1;
                j += 1;
            }
            c if c == quote => return (j + 1, nl),
            _ => j += 1,
        }
    }
    (j, nl)
}

/// Index one past the matching close brace for the open brace at
/// `tokens[open]` (which must be `{`). Returns `tokens.len()` when
/// unbalanced — callers treat the tail as the block.
pub fn match_brace(tokens: &[Token], open: usize) -> usize {
    debug_assert_eq!(tokens[open].text, "{");
    let mut depth = 0isize;
    for (k, t) in tokens.iter().enumerate().skip(open) {
        if t.kind == TokenKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return k + 1;
                    }
                }
                _ => {}
            }
        }
    }
    tokens.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idents_strings_and_comments_separate() {
        let l = lex("fn f() { let s = \"Instant::now\"; } // audit:pure");
        let idents: Vec<&str> = l
            .tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["fn", "f", "let", "s"]);
        // the banned-looking text stays a single Str token
        assert!(l
            .tokens
            .iter()
            .any(|t| t.kind == TokenKind::Str && t.text.contains("Instant")));
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].text, "audit:pure");
    }

    #[test]
    fn lines_are_tracked_through_literals_and_comments() {
        let src = "a\n\"two\nline\"\n/* b\nlock */ c\n'x' 'life d";
        let l = lex(src);
        let find = |name: &str| l.tokens.iter().find(|t| t.text == name).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("c"), 5);
        assert_eq!(find("d"), 6);
        assert!(l.tokens.iter().any(|t| t.kind == TokenKind::Char && t.line == 6));
        assert!(l.tokens.iter().any(|t| t.kind == TokenKind::Lifetime));
    }

    #[test]
    fn multi_char_punct_merges() {
        let l = lex("Instant::now() -> x..=y");
        let texts: Vec<&str> = l.tokens.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"::"));
        assert!(texts.contains(&"->"));
        assert!(texts.contains(&"..="));
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let l = lex("0..n 1.5e-3 0x1f");
        let nums: Vec<&str> = l
            .tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Number)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, ["0", "1.5e-3", "0x1f"]);
    }

    #[test]
    fn escaped_newline_in_string_counts_as_a_line() {
        // string line-continuations (`\` at end of line) still end a
        // source line — finding lines after them must not drift
        let l = lex("let s = \"a\\\nb\\\nc\";\nlet after = 1;");
        assert_eq!(l.tokens.iter().find(|t| t.text == "after").unwrap().line, 4);
    }

    #[test]
    fn raw_strings_and_nesting() {
        let l = lex(r##"let s = r#"quote " inside"#; /* outer /* inner */ still */ end"##);
        assert!(l.tokens.iter().any(|t| t.kind == TokenKind::Str && t.text.starts_with("r#")));
        assert!(l.tokens.iter().any(|t| t.text == "end"));
    }

    #[test]
    fn brace_matching() {
        let l = lex("fn f() { if x { y } else { z } } fn g() {}");
        let open = l.tokens.iter().position(|t| t.text == "{").unwrap();
        let end = match_brace(&l.tokens, open);
        // the token right after f's body is `fn`
        assert_eq!(l.tokens[end].text, "fn");
    }
}
