//! Contract-version drift detection (rules `CV01`–`CV04`).
//!
//! The snapshot-header rule (ROADMAP.md, `eval/mod.rs`) says: any change
//! to the cost formulas, energy constants, cache-key construction,
//! splitter or scheduler tie-break/transfer behaviour must bump
//! [`crate::eval::CACHE_CONTRACT_VERSION`] so persisted snapshots
//! self-invalidate. Runtime tests compare within one build and cannot see
//! a cross-build violation — so this module pins the *source tokens* of
//! every contract-scoped region into a checked-in manifest
//! (`ci/contract_fingerprints.json`) and fails when a region changed
//! while the version did not.
//!
//! Fingerprints are 128-bit [`StructuralHasher`] digests over the
//! region's token texts (comments and whitespace excluded, so doc edits
//! never trip; `mod tests` blocks excluded, so test edits never trip).
//! The manifest itself carries an FNV-64 checksum over its canonical
//! content: hand-editing a fingerprint to dodge the gate is detected as
//! `CV02` rather than silently accepted.
//!
//! The legitimate workflow for a contract change is:
//! 1. edit the scoped code, 2. bump `CACHE_CONTRACT_VERSION` (with a
//! History entry), 3. `monet_audit --bless`, 4. commit code + manifest.
//! `--bless` refuses to regenerate at an unchanged version — that is the
//! entire point of the rule.

use std::hash::Hasher;
use std::path::{Path, PathBuf};

use super::lexer::{Lexed, TokenKind};
use super::{in_ranges, test_mod_ranges, AuditConfig, Finding, Rule, SourceTree};
use crate::eval::StructuralHasher;
use crate::util::json::Json;

/// How a [`Region`]'s tokens are selected from its file.
#[derive(Debug, Clone)]
pub enum RegionSpec {
    /// Every `fn <name>` item (signature + body) for each listed name,
    /// outside `mod tests`, concatenated in source order.
    Fns(Vec<String>),
    /// Every `impl` block whose header mentions the ident.
    ImplsOf(String),
    /// All tokens of the file outside `mod tests`.
    WholeFile,
}

/// One contract-scoped source region.
#[derive(Debug, Clone)]
pub struct Region {
    pub id: String,
    pub file: String,
    pub spec: RegionSpec,
}

impl Region {
    pub fn new(id: &str, file: &str, spec: RegionSpec) -> Region {
        Region { id: id.to_string(), file: file.to_string(), spec }
    }
}

/// A computed region fingerprint.
#[derive(Debug, Clone)]
pub struct RegionFp {
    pub id: String,
    pub file: PathBuf,
    /// Line of the region's first token (where `CV01` points).
    pub line: u32,
    /// 32-hex-digit digest of the region tokens.
    pub fp: String,
}

/// Token ranges a region spec selects, in source order.
fn region_ranges(lexed: &Lexed, spec: &RegionSpec) -> Vec<std::ops::Range<usize>> {
    let toks = &lexed.tokens;
    let tests = test_mod_ranges(lexed);
    match spec {
        RegionSpec::WholeFile => {
            let mut out = Vec::new();
            let mut k = 0;
            while k < toks.len() {
                if let Some(t) = tests.iter().find(|r| r.contains(&k)) {
                    k = t.end;
                    continue;
                }
                let start = k;
                while k < toks.len() && !in_ranges(k, &tests) {
                    k += 1;
                }
                out.push(start..k);
            }
            out
        }
        RegionSpec::Fns(names) => {
            let mut out = Vec::new();
            for k in 0..toks.len().saturating_sub(1) {
                if in_ranges(k, &tests) {
                    continue;
                }
                if toks[k].kind == TokenKind::Ident
                    && toks[k].text == "fn"
                    && toks[k + 1].kind == TokenKind::Ident
                    && names.contains(&toks[k + 1].text)
                {
                    if let Some(open) = (k..toks.len()).find(|&j| toks[j].text == "{") {
                        out.push(k..super::lexer::match_brace(toks, open));
                    }
                }
            }
            out.sort_by_key(|r| r.start);
            out
        }
        RegionSpec::ImplsOf(name) => {
            let mut out = Vec::new();
            let mut k = 0;
            while k < toks.len() {
                if toks[k].kind == TokenKind::Ident
                    && toks[k].text == "impl"
                    && !in_ranges(k, &tests)
                {
                    if let Some(open) = (k..toks.len()).find(|&j| toks[j].text == "{") {
                        let header_hits = toks[k..open].iter().any(|t| &t.text == name);
                        let end = super::lexer::match_brace(toks, open);
                        if header_hits {
                            out.push(k..end);
                        }
                        k = open + 1; // nested impls don't occur; move past header
                        continue;
                    }
                }
                k += 1;
            }
            out
        }
    }
}

/// Compute every configured region fingerprint. Regions that cannot be
/// resolved become `CV03` findings instead.
pub fn compute(tree: &SourceTree, cfg: &AuditConfig) -> (Vec<RegionFp>, Vec<Finding>) {
    let mut fps = Vec::new();
    let mut findings = Vec::new();
    for region in &cfg.regions {
        let file = PathBuf::from(&region.file);
        let Some(lexed) = tree.files.get(&file) else {
            findings.push(Finding::new(
                Rule::Cv03,
                &file,
                0,
                format!("contract region '{}' names a missing file", region.id),
            ));
            continue;
        };
        let ranges = region_ranges(lexed, &region.spec);
        if ranges.is_empty() || ranges.iter().all(|r| r.is_empty()) {
            findings.push(Finding::new(
                Rule::Cv03,
                &file,
                0,
                format!("contract region '{}' matched no source items", region.id),
            ));
            continue;
        }
        let mut h = StructuralHasher::new();
        let mut line = u32::MAX;
        for r in &ranges {
            for t in &lexed.tokens[r.clone()] {
                h.write(t.text.as_bytes());
                h.write(&[0x1f]);
                line = line.min(t.line);
            }
        }
        fps.push(RegionFp {
            id: region.id.clone(),
            file,
            line: if line == u32::MAX { 0 } else { line },
            fp: format!("{:032x}", h.finish128()),
        });
    }
    fps.sort_by(|a, b| a.id.cmp(&b.id));
    (fps, findings)
}

/// Read the `const <name>: u32 = N;` contract version out of the
/// configured file's token stream.
pub fn extract_version(tree: &SourceTree, cfg: &AuditConfig) -> Result<u32, Finding> {
    let file = PathBuf::from(&cfg.version_file);
    let Some(lexed) = tree.files.get(&file) else {
        return Err(Finding::new(
            Rule::Cv03,
            &file,
            0,
            format!("contract-version file '{}' not found", cfg.version_file),
        ));
    };
    let toks = &lexed.tokens;
    for k in 0..toks.len().saturating_sub(5) {
        if toks[k].text == "const"
            && toks[k + 1].text == cfg.version_const
            && toks[k + 2].text == ":"
            && toks[k + 3].text == "u32"
            && toks[k + 4].text == "="
            && toks[k + 5].kind == TokenKind::Number
        {
            let raw: String = toks[k + 5].text.chars().filter(|c| *c != '_').collect();
            return raw.parse::<u32>().map_err(|_| {
                Finding::new(
                    Rule::Cv03,
                    &file,
                    toks[k + 5].line,
                    format!("could not parse {} value '{}'", cfg.version_const, toks[k + 5].text),
                )
            });
        }
    }
    Err(Finding::new(
        Rule::Cv03,
        &file,
        0,
        format!("const {} not found in '{}'", cfg.version_const, cfg.version_file),
    ))
}

/// Line on which the version const is declared (for `CV04` reporting);
/// 0 when unknown.
fn version_line(tree: &SourceTree, cfg: &AuditConfig) -> u32 {
    tree.files
        .get(Path::new(&cfg.version_file))
        .and_then(|l| l.tokens.iter().find(|t| t.text == cfg.version_const))
        .map(|t| t.line)
        .unwrap_or(0)
}

/// FNV-64 checksum over the manifest's canonical content, so a
/// hand-edited manifest is rejected (`CV02`) rather than trusted.
fn manifest_checksum(version: u32, fps: &[(String, String)]) -> String {
    let mut h = StructuralHasher::new();
    h.write(format!("contract_version={version}").as_bytes());
    for (id, fp) in fps {
        h.write(&[0x1f]);
        h.write(id.as_bytes());
        h.write(&[0x1e]);
        h.write(fp.as_bytes());
    }
    format!("{:016x}", h.finish())
}

/// A parsed, checksum-verified manifest.
pub struct Manifest {
    pub contract_version: u32,
    /// (region id, fingerprint), sorted by id.
    pub regions: Vec<(String, String)>,
}

/// Parse and verify `ci/contract_fingerprints.json`.
pub fn read_manifest(path: &Path) -> Result<Manifest, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("manifest unreadable ({e}) — run --bless to create it"))?;
    let j = Json::parse(&text).map_err(|e| format!("manifest is not valid JSON: {e:?}"))?;
    let version = j
        .get("contract_version")
        .and_then(|v| v.as_usize())
        .ok_or("manifest missing 'contract_version'")? as u32;
    let regions_obj = j.get("regions").ok_or("manifest missing 'regions'")?;
    let mut regions = Vec::new();
    if let Json::Obj(m) = regions_obj {
        // insertion-order iteration is fine: pairs are sorted by id below
        for (k, v) in m.iter() {
            let fp = v.as_str().ok_or_else(|| format!("region '{k}' fingerprint is not a string"))?;
            regions.push((k.clone(), fp.to_string()));
        }
    } else {
        return Err("manifest 'regions' is not an object".to_string());
    }
    regions.sort();
    let recorded = j
        .get("checksum")
        .and_then(|v| v.as_str())
        .ok_or("manifest missing 'checksum'")?;
    let expect = manifest_checksum(version, &regions);
    if recorded != expect {
        return Err(format!(
            "manifest checksum mismatch (recorded {recorded}, content hashes to {expect}) — \
             the manifest was hand-edited; regenerate it with --bless after a version bump"
        ));
    }
    Ok(Manifest { contract_version: version, regions })
}

/// Serialize and write a manifest (deterministic: sorted keys via the
/// `util::json` renderer, trailing newline).
pub fn write_manifest(path: &Path, version: u32, fps: &[RegionFp]) -> std::io::Result<()> {
    let pairs: Vec<(String, String)> =
        fps.iter().map(|r| (r.id.clone(), r.fp.clone())).collect();
    let regions = Json::obj(
        pairs.iter().map(|(id, fp)| (id.as_str(), Json::Str(fp.clone()))).collect(),
    );
    let j = Json::obj(vec![
        ("contract_version", Json::Num(version as f64)),
        ("regions", regions),
        ("checksum", Json::Str(manifest_checksum(version, &pairs))),
    ]);
    std::fs::write(path, format!("{j}\n"))
}

/// The `--check` half: compare computed fingerprints against the
/// manifest under the version-bump rule.
pub fn check(tree: &SourceTree, cfg: &AuditConfig, manifest_path: &Path) -> Vec<Finding> {
    let (fps, mut findings) = compute(tree, cfg);
    let current = match extract_version(tree, cfg) {
        Ok(v) => v,
        Err(f) => {
            findings.push(f);
            return findings;
        }
    };
    let manifest = match read_manifest(manifest_path) {
        Ok(m) => m,
        Err(e) => {
            findings.push(Finding::new(
                Rule::Cv02,
                manifest_path,
                0,
                e,
            ));
            return findings;
        }
    };
    let config_ids: Vec<&str> = fps.iter().map(|f| f.id.as_str()).collect();
    let manifest_ids: Vec<&str> = manifest.regions.iter().map(|(id, _)| id.as_str()).collect();
    if config_ids != manifest_ids {
        findings.push(Finding::new(
            Rule::Cv02,
            manifest_path,
            0,
            format!(
                "manifest regions {manifest_ids:?} do not match the configured set \
                 {config_ids:?} — run --bless after a version bump"
            ),
        ));
        return findings;
    }
    if manifest.contract_version != current {
        findings.push(Finding::new(
            Rule::Cv04,
            Path::new(&cfg.version_file),
            version_line(tree, cfg),
            format!(
                "{} is {} but the manifest records contract {} — run --bless to re-pin \
                 the fingerprints under the new contract",
                cfg.version_const, current, manifest.contract_version
            ),
        ));
        return findings;
    }
    for (computed, (id, recorded)) in fps.iter().zip(&manifest.regions) {
        debug_assert_eq!(&computed.id, id);
        if &computed.fp != recorded {
            findings.push(Finding::new(
                Rule::Cv01,
                &computed.file,
                computed.line,
                format!(
                    "contract region '{}' changed without a {} bump (contract still {}) — \
                     if the change alters any persisted cost/schedule meaning, bump the \
                     version (with a History entry) and run --bless; a pure refactor that \
                     provably keeps bit-identity still requires the bump+bless ritual, \
                     which is what makes it reviewable",
                    computed.id, cfg.version_const, current
                ),
            ));
        }
    }
    findings
}

/// The `--bless` half. Refuses to regenerate fingerprints at an
/// unchanged contract version (that would neuter the gate) and refuses
/// to overwrite a tampered manifest silently.
pub fn bless(tree: &SourceTree, cfg: &AuditConfig, manifest_path: &Path) -> Result<String, String> {
    let (fps, findings) = compute(tree, cfg);
    if !findings.is_empty() {
        return Err(findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n"));
    }
    let current = extract_version(tree, cfg).map_err(|f| f.to_string())?;
    match read_manifest(manifest_path) {
        Ok(m) => {
            let changed: Vec<&str> = fps
                .iter()
                .zip(&m.regions)
                .filter(|(c, (_, rec))| &c.fp != rec)
                .map(|(c, _)| c.id.as_str())
                .collect();
            let same_region_set =
                fps.len() == m.regions.len()
                    && fps.iter().zip(&m.regions).all(|(c, (id, _))| &c.id == id);
            if m.contract_version == current && same_region_set && !changed.is_empty() {
                return Err(format!(
                    "refusing to bless: region(s) {changed:?} changed but {} is still {} — \
                     bump the version first (eval/mod.rs History), then bless",
                    cfg.version_const, current
                ));
            }
            write_manifest(manifest_path, current, &fps).map_err(|e| e.to_string())?;
            Ok(format!(
                "blessed {} region(s) at contract version {} (was {})",
                fps.len(),
                current,
                m.contract_version
            ))
        }
        Err(_) if !manifest_path.exists() => {
            write_manifest(manifest_path, current, &fps).map_err(|e| e.to_string())?;
            Ok(format!(
                "created manifest: {} region(s) at contract version {}",
                fps.len(),
                current
            ))
        }
        Err(e) => Err(format!(
            "refusing to bless over an invalid manifest ({e}); delete \
             {} to regenerate from scratch",
            manifest_path.display()
        )),
    }
}
