//! `monet-audit` — a std-only static contract checker for the standing
//! contracts in `ROADMAP.md`, run in CI before the test matrix (the
//! `contract-audit` job) and as `cargo run --bin monet_audit`.
//!
//! The runtime test suite pins bit-identity *within one build*; it cannot
//! catch a cross-build snapshot poisoning (a cost-formula edit that lands
//! without a [`crate::eval::CACHE_CONTRACT_VERSION`] bump) or a
//! nondeterminism bug on a path the tests don't exercise. This module
//! closes that gap with three typed, `file:line`-reporting rule families
//! over a hand-rolled token stream ([`lexer`] — no syn/proc-macro,
//! matching the crate's zero-dependency discipline):
//!
//! * **CV — contract-version drift** ([`fingerprint`]): the
//!   contract-scoped source regions (cost formulas, energy constants,
//!   cache-key construction, the stage splitter, tie-break/transfer
//!   rules) are fingerprinted into `ci/contract_fingerprints.json`; any
//!   token-level change to a scoped region without a matching
//!   `CACHE_CONTRACT_VERSION` bump fails the build. `--bless`
//!   regenerates the manifest only when the version was bumped.
//! * **PU — evaluator purity** ([`purity`]): inside declared purity
//!   scopes (`// audit:pure` markers on `Evaluate` impls, the
//!   `group_cost`/`node_cost` formulas, `serve::api::answer`), clock
//!   reads, environment reads, file IO, RNG construction and
//!   `CacheStats` reads are forbidden.
//! * **DT — determinism** ([`determinism`]): NaN-panicking
//!   `partial_cmp().unwrap()` comparators and order-sensitive iteration
//!   over `HashMap`/`HashSet` without an order-restoring consumer.
//!
//! ## Marker convention
//!
//! Markers are **line comments** (block comments are not scanned):
//!
//! * `// audit:pure` — the next `fn` or `impl` item is a purity scope;
//!   every token of its body is checked against the banned-pattern list.
//! * `// audit:allow(RULE_ID): reason` — suppress one finding of
//!   `RULE_ID` on the same or the next line. The reason is mandatory and
//!   echoed by the tool (`--verbose`); an allow that suppresses nothing
//!   is itself an error (`AU01`), so stale waivers cannot accumulate.
//!   Only `PU01`/`DT01`/`DT02` are allowable — contract-version rules
//!   cannot be waived inline, by design.
//!
//! A per-file module allowlist ([`AuditConfig::module_allow`]) carries
//! the few whole-file waivers (each with a reason string the tool
//! echoes); everything else must be justified at the violation site.
//!
//! The rule set is self-tested against known-bad fixtures in
//! `tests/audit.rs`, and the repo tip is pinned clean there too.

pub mod determinism;
pub mod fingerprint;
pub mod lexer;
pub mod purity;

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use lexer::{lex, Lexed};

/// Typed rule identifiers. The short ids are the stable interface: they
/// appear in findings, allow markers, CI annotations and `docs/AUDIT.md`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Contract-scoped region changed without a `CACHE_CONTRACT_VERSION`
    /// bump (fingerprint mismatch at equal versions).
    Cv01,
    /// Fingerprint manifest missing, unparseable, tampered (checksum
    /// mismatch) or not covering the configured region set.
    Cv02,
    /// A configured contract region was not found in the source tree.
    Cv03,
    /// `CACHE_CONTRACT_VERSION` was bumped but the manifest still records
    /// the old contract — run `--bless`.
    Cv04,
    /// Impure construct (clock / env / file IO / RNG / `CacheStats`)
    /// inside a declared purity scope.
    Pu01,
    /// A required purity scope is missing its `audit:pure` marker (or the
    /// item itself was not found).
    Pu02,
    /// NaN-panicking `partial_cmp().unwrap()`/`expect()` comparator.
    Dt01,
    /// Order-sensitive iteration over a `HashMap`/`HashSet` value with no
    /// order-restoring consumer in sight.
    Dt02,
    /// Malformed, dangling or unused audit marker.
    Au01,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::Cv01 => "CV01",
            Rule::Cv02 => "CV02",
            Rule::Cv03 => "CV03",
            Rule::Cv04 => "CV04",
            Rule::Pu01 => "PU01",
            Rule::Pu02 => "PU02",
            Rule::Dt01 => "DT01",
            Rule::Dt02 => "DT02",
            Rule::Au01 => "AU01",
        }
    }

    pub fn from_id(s: &str) -> Option<Rule> {
        Some(match s {
            "CV01" => Rule::Cv01,
            "CV02" => Rule::Cv02,
            "CV03" => Rule::Cv03,
            "CV04" => Rule::Cv04,
            "PU01" => Rule::Pu01,
            "PU02" => Rule::Pu02,
            "DT01" => Rule::Dt01,
            "DT02" => Rule::Dt02,
            "AU01" => Rule::Au01,
            _ => return None,
        })
    }

    /// Rules an inline `audit:allow` marker may waive. Contract-version
    /// and marker-hygiene rules are deliberately not waivable.
    pub fn allowable(self) -> bool {
        matches!(self, Rule::Pu01 | Rule::Dt01 | Rule::Dt02)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One lint finding. `allowed` is `Some(reason)` when a marker or module
/// allowlist entry waived it — waived findings are not failures but are
/// still reported (`--verbose`) with the reason echoed.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    /// Path relative to the audited root (`src/...`).
    pub file: PathBuf,
    /// 1-indexed line (0 = file-level finding).
    pub line: u32,
    pub message: String,
    pub allowed: Option<String>,
}

impl Finding {
    pub fn new(rule: Rule, file: &Path, line: u32, message: impl Into<String>) -> Finding {
        Finding { rule, file: file.to_path_buf(), line, message: message.into(), allowed: None }
    }

    pub fn is_active(&self) -> bool {
        self.allowed.is_none()
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}:{} {}", self.rule, self.file.display(), self.line, self.message)?;
        if let Some(r) = &self.allowed {
            write!(f, " [allowed: {r}]")?;
        }
        Ok(())
    }
}

/// What an `audit:pure` requirement anchors to.
#[derive(Debug, Clone)]
pub enum ItemSpec {
    /// `fn <name>` (first match outside `mod tests`).
    Fn(String),
    /// `impl <trait> for <type>` — both idents must appear in the impl
    /// header (before the body brace).
    ImplTraitFor(String, String),
}

impl fmt::Display for ItemSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ItemSpec::Fn(n) => write!(f, "fn {n}"),
            ItemSpec::ImplTraitFor(t, ty) => write!(f, "impl {t} for {ty}"),
        }
    }
}

/// A purity scope the audited tree is required to declare (`PU02` when
/// the marker is missing).
#[derive(Debug, Clone)]
pub struct RequiredScope {
    pub file: String,
    pub item: ItemSpec,
}

/// Whole-file waiver for one rule, with a reason the tool echoes.
#[derive(Debug, Clone)]
pub struct ModuleAllow {
    pub file: String,
    pub rule: Rule,
    pub reason: String,
}

/// Everything the audit needs to know about a tree: the contract regions
/// to fingerprint, where the contract version lives, the purity scopes
/// that must exist, and the module allowlist. [`default_config`] is the
/// MONET instance; fixture tests build tiny ones.
#[derive(Debug, Clone, Default)]
pub struct AuditConfig {
    pub regions: Vec<fingerprint::Region>,
    /// File (relative to root) holding the contract-version const.
    pub version_file: String,
    /// Name of the `const <name>: u32` to read.
    pub version_const: String,
    pub required_scopes: Vec<RequiredScope>,
    pub module_allow: Vec<ModuleAllow>,
}

/// The MONET audit configuration: the standing contracts of `ROADMAP.md`
/// as machine-checkable scopes. Region ids are stable — they key the
/// fingerprint manifest and appear in `docs/AUDIT.md`.
pub fn default_config() -> AuditConfig {
    use fingerprint::{Region, RegionSpec};
    let fns = |names: &[&str]| RegionSpec::Fns(names.iter().map(|s| s.to_string()).collect());
    AuditConfig {
        regions: vec![
            // the cost formulas: any value change is a contract bump
            Region::new("cost.node_cost", "src/cost/mod.rs", fns(&["node_cost"])),
            Region::new("scheduler.group_cost", "src/scheduler/engine.rs", fns(&["group_cost"])),
            // tie-breaks, transfer rules and memory accounting: the GA
            // warm-start memo persists whole-schedule() objectives, so
            // scheduler behaviour is load-bearing for snapshots
            Region::new(
                "scheduler.schedule",
                "src/scheduler/engine.rs",
                fns(&["schedule_with_cache", "group_placements"]),
            ),
            Region::new(
                "hardware.energy_constants",
                "src/hardware/energy.rs",
                RegionSpec::WholeFile,
            ),
            // cache-key construction: both the per-field hash functions
            // and the hasher that defines what a key byte means
            Region::new(
                "eval.cache_key",
                "src/eval/mod.rs",
                fns(&["hash_env", "hash_group_node", "hash_core_class"]),
            ),
            Region::new(
                "eval.structural_hasher",
                "src/eval/cost_cache.rs",
                RegionSpec::ImplsOf("StructuralHasher".to_string()),
            ),
            // the splitter decides every persisted stage shape (the v2→v3
            // bump in eval/mod.rs history)
            Region::new(
                "parallelism.splitter",
                "src/parallelism/mod.rs",
                fns(&["split_stages", "split_stages_balanced"]),
            ),
            // fabric constants feed scheduled numbers via the collective
            // model (ROADMAP item 3 re-derives these)
            Region::new(
                "parallelism.link_tiers",
                "src/parallelism/mod.rs",
                fns(&["cluster", "allreduce_cycles"]),
            ),
        ],
        version_file: "src/eval/mod.rs".to_string(),
        version_const: "CACHE_CONTRACT_VERSION".to_string(),
        required_scopes: vec![
            RequiredScope {
                file: "src/cost/mod.rs".into(),
                item: ItemSpec::Fn("node_cost".into()),
            },
            RequiredScope {
                file: "src/scheduler/engine.rs".into(),
                item: ItemSpec::Fn("group_cost".into()),
            },
            RequiredScope {
                file: "src/dse/sweep.rs".into(),
                item: ItemSpec::ImplTraitFor("Evaluate".into(), "SweepEval".into()),
            },
            RequiredScope {
                file: "src/dse/sweep.rs".into(),
                item: ItemSpec::ImplTraitFor("Evaluate".into(), "ClusterEval".into()),
            },
            RequiredScope {
                file: "src/dse/sweep.rs".into(),
                item: ItemSpec::ImplTraitFor("Evaluate".into(), "HeteroEval".into()),
            },
            RequiredScope {
                file: "src/serve/api.rs".into(),
                item: ItemSpec::Fn("answer".into()),
            },
        ],
        module_allow: vec![ModuleAllow {
            file: "src/util/json.rs".into(),
            rule: Rule::Dt02,
            reason: "Json::Obj iteration is always key-sorted before anything escapes \
                     (Display sorts; parse only inserts)"
                .into(),
        }],
    }
}

/// A parsed audit marker.
#[derive(Debug, Clone)]
pub enum Marker {
    /// `audit:pure` at this line — scopes the next `fn`/`impl` item.
    Pure { line: u32 },
    /// `audit:allow(RULE): reason` at this line.
    Allow { line: u32, rule: Rule, reason: String },
}

/// Scan a file's line comments for markers. Malformed markers (an
/// `audit:` comment that parses as neither form, a missing reason, an
/// unknown or non-allowable rule) become `AU01` findings immediately.
pub fn parse_markers(file: &Path, lexed: &Lexed) -> (Vec<Marker>, Vec<Finding>) {
    let mut markers = Vec::new();
    let mut findings = Vec::new();
    for c in &lexed.comments {
        // a marker is a comment that STARTS with `audit:` — doc comments
        // and prose that merely mention the convention never match
        if !c.text.starts_with("audit:") {
            continue;
        }
        let body = c.text.as_str();
        if body.starts_with("audit:pure") {
            markers.push(Marker::Pure { line: c.line });
            continue;
        }
        if let Some(rest) = body.strip_prefix("audit:allow(") {
            let Some(close) = rest.find(')') else {
                findings.push(Finding::new(
                    Rule::Au01,
                    file,
                    c.line,
                    "malformed audit:allow marker: missing ')'",
                ));
                continue;
            };
            let rule_id = &rest[..close];
            let Some(rule) = Rule::from_id(rule_id) else {
                findings.push(Finding::new(
                    Rule::Au01,
                    file,
                    c.line,
                    format!("audit:allow names unknown rule '{rule_id}'"),
                ));
                continue;
            };
            if !rule.allowable() {
                findings.push(Finding::new(
                    Rule::Au01,
                    file,
                    c.line,
                    format!("rule {rule} cannot be waived with audit:allow"),
                ));
                continue;
            }
            let reason = rest[close + 1..].trim_start_matches(':').trim().to_string();
            if reason.is_empty() {
                findings.push(Finding::new(
                    Rule::Au01,
                    file,
                    c.line,
                    format!("audit:allow({rule}) requires a reason after ':'"),
                ));
                continue;
            }
            markers.push(Marker::Allow { line: c.line, rule, reason });
            continue;
        }
        findings.push(Finding::new(
            Rule::Au01,
            file,
            c.line,
            format!("unrecognized audit marker: '{}'", c.text),
        ));
    }
    (markers, findings)
}

/// Recursively list `.rs` files under `root/src`, sorted for
/// deterministic reports.
pub fn rust_sources(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.join("src")];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> =
            std::fs::read_dir(&dir)?.collect::<Result<Vec<_>, _>>()?;
        entries.sort_by_key(|e| e.path());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lexed sources of one audit run, keyed by root-relative path.
pub struct SourceTree {
    pub root: PathBuf,
    pub files: BTreeMap<PathBuf, Lexed>,
}

impl SourceTree {
    /// Read and tokenize every file under `root/src`.
    pub fn load(root: &Path) -> std::io::Result<SourceTree> {
        let mut files = BTreeMap::new();
        for p in rust_sources(root)? {
            let text = std::fs::read_to_string(&p)?;
            let rel = p.strip_prefix(root).unwrap_or(&p).to_path_buf();
            files.insert(rel, lex(&text));
        }
        Ok(SourceTree { root: root.to_path_buf(), files })
    }
}

/// Run every rule family over `root/src` against `manifest` and apply the
/// allow mechanisms. The returned list contains *all* findings; callers
/// treat those with [`Finding::is_active`] as failures.
pub fn run_audit(root: &Path, cfg: &AuditConfig, manifest: &Path) -> std::io::Result<Vec<Finding>> {
    let tree = SourceTree::load(root)?;
    let mut findings = Vec::new();
    let mut all_markers: BTreeMap<PathBuf, Vec<Marker>> = BTreeMap::new();
    for (file, lexed) in &tree.files {
        let (markers, marker_findings) = parse_markers(file, lexed);
        findings.extend(marker_findings);
        all_markers.insert(file.clone(), markers);
    }

    if !cfg.regions.is_empty() {
        findings.extend(fingerprint::check(&tree, cfg, manifest));
    }
    findings.extend(purity::check(&tree, cfg, &all_markers));
    findings.extend(determinism::check(&tree));

    apply_allows(&mut findings, cfg, &all_markers);
    findings.sort_by(|a, b| {
        (a.rule, &a.file, a.line).cmp(&(b.rule, &b.file, b.line))
    });
    Ok(findings)
}

/// Waive findings covered by inline `audit:allow` markers (same or next
/// line) or the module allowlist; flag unused inline allows as `AU01`.
fn apply_allows(
    findings: &mut Vec<Finding>,
    cfg: &AuditConfig,
    markers: &BTreeMap<PathBuf, Vec<Marker>>,
) {
    let mut used: BTreeMap<(PathBuf, u32, String), bool> = BTreeMap::new();
    for (file, ms) in markers {
        for m in ms {
            if let Marker::Allow { line, rule, .. } = m {
                used.insert((file.clone(), *line, rule.id().to_string()), false);
            }
        }
    }
    for f in findings.iter_mut() {
        if f.allowed.is_some() || !f.rule.allowable() {
            continue;
        }
        if let Some(ms) = markers.get(&f.file) {
            for m in ms {
                if let Marker::Allow { line, rule, reason } = m {
                    if *rule == f.rule && (*line == f.line || *line + 1 == f.line) {
                        f.allowed = Some(reason.clone());
                        used.insert((f.file.clone(), *line, rule.id().to_string()), true);
                        break;
                    }
                }
            }
        }
        if f.allowed.is_none() {
            if let Some(ma) = cfg
                .module_allow
                .iter()
                .find(|ma| ma.rule == f.rule && Path::new(&ma.file) == f.file)
            {
                f.allowed = Some(format!("module allowlist: {}", ma.reason));
            }
        }
    }
    for ((file, line, rule), was_used) in used {
        if !was_used {
            findings.push(Finding::new(
                Rule::Au01,
                &file,
                line,
                format!("audit:allow({rule}) suppresses nothing — remove the stale waiver"),
            ));
        }
    }
}

/// Find the body token range of the item (fn/impl) that starts at or
/// after `line` — the scope an `audit:pure` marker at `line` declares.
/// Returns `(item_token_index, body_range)` or `None`.
pub fn item_after_line(lexed: &Lexed, line: u32) -> Option<(usize, std::ops::Range<usize>)> {
    let toks = &lexed.tokens;
    let start = toks.iter().position(|t| t.line > line)?;
    let item = (start..toks.len()).find(|&k| {
        toks[k].kind == lexer::TokenKind::Ident
            && (toks[k].text == "fn" || toks[k].text == "impl")
    })?;
    let open = (item..toks.len()).find(|&k| toks[k].text == "{")?;
    let end = lexer::match_brace(toks, open);
    Some((item, open..end))
}

/// Token ranges of `mod tests { ... }` blocks — excluded from both
/// fingerprint regions and item resolution so test-code edits and
/// test-local helpers never alias a contract region.
pub fn test_mod_ranges(lexed: &Lexed) -> Vec<std::ops::Range<usize>> {
    let toks = &lexed.tokens;
    let mut out = Vec::new();
    let mut k = 0;
    while k + 2 < toks.len() {
        if toks[k].text == "mod"
            && toks[k].kind == lexer::TokenKind::Ident
            && toks[k + 1].text == "tests"
            && toks[k + 2].text == "{"
        {
            let end = lexer::match_brace(toks, k + 2);
            out.push(k..end);
            k = end;
        } else {
            k += 1;
        }
    }
    out
}

/// True when token index `k` falls inside any of `ranges`.
pub fn in_ranges(k: usize, ranges: &[std::ops::Range<usize>]) -> bool {
    ranges.iter().any(|r| r.contains(&k))
}
