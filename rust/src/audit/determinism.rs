//! Determinism lint (rules `DT01`/`DT02`) — the PR 2 bug class, as a
//! static check instead of a postmortem.
//!
//! * **DT01** — `partial_cmp(..).unwrap()` (or `.expect(..)`)
//!   comparators panic on the first NaN an upstream cost-model change
//!   lets through, killing a worker mid-sweep. `f64::total_cmp` is a
//!   total order (NaN sorts last) and is what every PR 2 fix switched
//!   to; the lint points there.
//! * **DT02** — iterating a `HashMap`/`HashSet` yields a
//!   process-varying order; when the iteration feeds rows, journals,
//!   f64 accumulation or serialized output, runs stop being
//!   bit-identical (the `apply_checkpointing` HashSet-order bug).
//!   The lint flags iteration over values it can *see* are hash
//!   containers (declared or constructed as such in the same file)
//!   unless an order-restoring or order-insensitive consumer (`sort*`,
//!   `BTreeMap`/`BTreeSet`, `sum`/`count`/`min`/`max`/`all`/`any`/…)
//!   appears in the same or the immediately following statement.
//!   Genuinely order-free sites carry an inline
//!   `// audit:allow(DT02): reason` — the justification is the point.
//!
//! Both rules scan test code too: nondeterministic tests are flaky
//! tests, and the three comparators this lint flagged on day one
//! included one inside a `#[cfg(test)]` module.

use std::path::Path;

use super::lexer::{Lexed, TokenKind};
use super::{Finding, Rule, SourceTree};

/// Methods whose receiver ordering escapes into the iteration.
const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain"];

/// Idents that make a hash-order iteration safe when they appear in the
/// same statement (or the next one — the `let v: Vec<_> = m.iter()
/// .collect(); v.sort…` idiom): either they restore a deterministic
/// order or they reduce order-insensitively.
const SAFE_CONSUMERS: &[&str] = &[
    "sort",
    "sort_by",
    "sort_by_key",
    "sort_unstable",
    "sort_unstable_by",
    "sort_unstable_by_key",
    "BTreeMap",
    "BTreeSet",
    "BinaryHeap",
    "sum",
    "count",
    "len",
    "is_empty",
    "min",
    "max",
    "min_by",
    "max_by",
    "min_by_key",
    "max_by_key",
    "all",
    "any",
    "contains",
    "contains_key",
];

/// Run both determinism rules over every file.
pub fn check(tree: &SourceTree) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (file, lexed) in &tree.files {
        check_partial_cmp(file, lexed, &mut findings);
        check_hash_order(file, lexed, &mut findings);
    }
    findings
}

fn check_partial_cmp(file: &Path, lexed: &Lexed, findings: &mut Vec<Finding>) {
    let toks = &lexed.tokens;
    for k in 0..toks.len() {
        if toks[k].kind != TokenKind::Ident || toks[k].text != "partial_cmp" {
            continue;
        }
        let window = &toks[k + 1..toks.len().min(k + 12)];
        if window
            .iter()
            .any(|t| t.kind == TokenKind::Ident && (t.text == "unwrap" || t.text == "expect"))
        {
            findings.push(Finding::new(
                Rule::Dt01,
                file,
                toks[k].line,
                "NaN-panicking comparator: partial_cmp().unwrap() aborts the worker on \
                 the first NaN a cost-model change lets through — use f64::total_cmp \
                 (NaN orders last, deterministically)",
            ));
        }
    }
}

/// Names in this file the lint can prove are hash containers: bound or
/// declared against a `HashMap`/`HashSet` type (let bindings, fn params,
/// struct fields, `= HashMap::new()`.)
fn hash_container_names(lexed: &Lexed) -> Vec<String> {
    let toks = &lexed.tokens;
    let mut names = Vec::new();
    for k in 0..toks.len() {
        if toks[k].kind != TokenKind::Ident
            || (toks[k].text != "HashMap" && toks[k].text != "HashSet")
        {
            continue;
        }
        // `use std::collections::HashMap` / `HashMap::new()` paths and
        // nested generic positions (`Vec<HashMap<..>>`) are not bindings
        if k > 0 && (toks[k - 1].text == "::" || toks[k - 1].text == "<") {
            continue;
        }
        // walk back over `& &mut mut` to the binding shape
        let mut j = k as isize - 1;
        while j >= 0 && (toks[j as usize].text == "&" || toks[j as usize].text == "mut") {
            j -= 1;
        }
        if j < 1 {
            continue;
        }
        let (p, p2) = (&toks[j as usize], &toks[j as usize - 1]);
        let binder = match p.text.as_str() {
            // `name: HashMap<..>` (let annotation, fn param, struct field)
            ":" if p2.kind == TokenKind::Ident => Some(&p2.text),
            // `let name = HashMap::new()`
            "=" if p2.kind == TokenKind::Ident => Some(&p2.text),
            _ => None,
        };
        if let Some(name) = binder {
            if !names.contains(name) {
                names.push(name.clone());
            }
        }
    }
    names
}

fn check_hash_order(file: &Path, lexed: &Lexed, findings: &mut Vec<Finding>) {
    let toks = &lexed.tokens;
    let names = hash_container_names(lexed);
    if names.is_empty() {
        return;
    }
    let mut flagged_lines: Vec<u32> = Vec::new();
    let mut flag = |k: usize, findings: &mut Vec<Finding>, name: &str| {
        if flagged_lines.contains(&toks[k].line) {
            return;
        }
        flagged_lines.push(toks[k].line);
        findings.push(Finding::new(
            Rule::Dt02,
            file,
            toks[k].line,
            format!(
                "order-sensitive iteration over hash container `{name}`: HashMap/HashSet \
                 order varies per process, so anything it feeds (rows, journals, f64 \
                 accumulation, serialized output) loses bit-identity — sort the items, \
                 collect into a BTree collection, or justify with \
                 `// audit:allow(DT02): reason`"
            ),
        ));
    };
    for k in 0..toks.len() {
        if toks[k].kind != TokenKind::Ident || !names.iter().any(|n| *n == toks[k].text) {
            continue;
        }
        let name = toks[k].text.clone();
        // `name.iter()` / `.keys()` / … method chains
        if k + 2 < toks.len()
            && toks[k + 1].text == "."
            && ITER_METHODS.contains(&toks[k + 2].text.as_str())
            && toks.get(k + 3).is_some_and(|t| t.text == "(")
        {
            if !consumed_safely(toks, k + 3) {
                flag(k, findings, &name);
            }
            continue;
        }
        // `for x in &name {` / `for x in name {`
        let prev = |i: usize| toks.get(k.wrapping_sub(i)).map(|t| t.text.as_str());
        let after = toks.get(k + 1).map(|t| t.text.as_str());
        let preceded_by_in = prev(1) == Some("in")
            || (prev(1) == Some("&") && prev(2) == Some("in"))
            || (prev(1) == Some("mut") && prev(2) == Some("&") && prev(3) == Some("in"));
        if preceded_by_in && after == Some("{") {
            flag(k, findings, &name);
        }
    }
}

/// Scan forward from the token after an iteration call for a safe
/// consumer: to the end of this statement, then through the next
/// statement (40-token budget) — covering both `m.iter().map(..).sum()`
/// and `let v: Vec<_> = m.iter().collect(); v.sort();`.
fn consumed_safely(toks: &[super::lexer::Token], from: usize) -> bool {
    let mut semis = 0;
    for t in toks.iter().skip(from).take(80) {
        if t.kind == TokenKind::Ident && SAFE_CONSUMERS.contains(&t.text.as_str()) {
            return true;
        }
        if t.text == ";" {
            semis += 1;
            if semis >= 2 {
                return false;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::lexer::lex;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn run(src: &str) -> Vec<Finding> {
        let mut files = BTreeMap::new();
        files.insert(PathBuf::from("src/x.rs"), lex(src));
        check(&SourceTree { root: PathBuf::from("."), files })
    }

    #[test]
    fn partial_cmp_unwrap_flagged_total_cmp_not() {
        let fs = run(concat!(
            "fn f(v: &mut Vec<f64>) {\n",
            " v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n",
            " v.sort_by(|a, b| a.total_cmp(b));\n}",
        ));
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, Rule::Dt01);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn hashmap_for_loop_flagged() {
        let fs = run(concat!(
            "fn f() { let m: HashMap<u32, u32> = HashMap::new();\n",
            "for (k, v) in &m { out.push(*k); }\n}",
        ));
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, Rule::Dt02);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn sorted_collect_suppresses() {
        let fs = run(concat!(
            "fn f(m: &HashMap<u32, u32>) {\n",
            " let mut v: Vec<_> = m.iter().collect();\n v.sort_unstable();\n}",
        ));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn order_insensitive_reduction_suppresses() {
        let fs = run("fn f(m: &HashMap<u32, u32>) -> u32 { m.values().sum() }");
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn vec_iteration_never_flagged() {
        let fs = run("fn f(v: &Vec<u32>) { for x in v.iter() { use_it(x); } }");
        assert!(fs.is_empty(), "{fs:?}");
    }
}
