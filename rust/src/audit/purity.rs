//! Evaluator-purity lint (rules `PU01`/`PU02`).
//!
//! The `dse::engine::Evaluate` rustdoc and the `serve` module contract
//! both state a purity rule in prose: evaluators and query handlers must
//! be pure functions of their inputs — no clock, no process environment,
//! no file IO, no RNG construction, no `CacheStats` reads (stats vary
//! with cache temperature; reading them inside an answer breaks the
//! warm-daemon ≡ one-shot bit-identity bar). Violations break the
//! 1/2/8-worker bit-identity matrix *only on exercised paths*; this lint
//! checks every token of every declared scope.
//!
//! Scopes are declared with a `// audit:pure` line comment immediately
//! above a `fn` or `impl` item (the whole body is the scope). The
//! [`super::AuditConfig::required_scopes`] list pins the scopes that must
//! exist — deleting a marker is `PU02`, not a silent un-scoping.

use std::collections::BTreeMap;
use std::path::PathBuf;

use super::lexer::{Lexed, TokenKind};
use super::{
    in_ranges, item_after_line, test_mod_ranges, AuditConfig, Finding, ItemSpec, Marker, Rule,
    SourceTree,
};

/// Token patterns forbidden inside a purity scope. Matching is over
/// `Ident`/`Punct` token text only — a banned name inside a string
/// literal is one `Str` token and can never match.
const BANNED: &[(&[&str], &str)] = &[
    (&["Instant", "::", "now"], "clock read (Instant::now)"),
    (&["SystemTime"], "clock read (SystemTime)"),
    (&["std", "::", "env"], "process-environment read (std::env)"),
    (&["env", "::", "var"], "process-environment read (env::var)"),
    (&["std", "::", "fs"], "file IO (std::fs)"),
    (&["fs", "::"], "file IO (fs::)"),
    (&["File", "::"], "file IO (File::)"),
    (&["OpenOptions"], "file IO (OpenOptions)"),
    (&["read_dir"], "file IO (read_dir)"),
    (&["read_to_string"], "file IO (read_to_string)"),
    (&["Rng", "::"], "RNG construction (Rng::)"),
    (&["seed_from_u64"], "RNG construction (seed_from_u64)"),
    (&["CacheStats"], "CacheStats read"),
    (&[".", "stats", "("], "CacheStats read (.stats())"),
    (&["hit_rate"], "CacheStats read (hit_rate)"),
    (&["thread", "::", "sleep"], "timing dependence (thread::sleep)"),
];

/// A resolved purity scope.
struct Scope {
    file: PathBuf,
    /// Token index of the `fn`/`impl` keyword.
    item: usize,
    body: std::ops::Range<usize>,
    marker_line: u32,
}

/// Resolve every `audit:pure` marker to the item it scopes. Dangling
/// markers become `AU01`.
fn resolve_scopes(
    tree: &SourceTree,
    markers: &BTreeMap<PathBuf, Vec<Marker>>,
) -> (Vec<Scope>, Vec<Finding>) {
    let mut scopes = Vec::new();
    let mut findings = Vec::new();
    for (file, ms) in markers {
        let lexed = &tree.files[file];
        for m in ms {
            let Marker::Pure { line } = m else { continue };
            match item_after_line(lexed, *line) {
                Some((item, body)) => scopes.push(Scope {
                    file: file.clone(),
                    item,
                    body,
                    marker_line: *line,
                }),
                None => findings.push(Finding::new(
                    Rule::Au01,
                    file,
                    *line,
                    "dangling audit:pure marker: no fn/impl item follows it",
                )),
            }
        }
    }
    (scopes, findings)
}

/// Token index of the item a [`RequiredScope`](super::RequiredScope)
/// spec names, outside `mod tests`.
fn find_item(lexed: &Lexed, item: &ItemSpec) -> Option<usize> {
    let toks = &lexed.tokens;
    let tests = test_mod_ranges(lexed);
    match item {
        ItemSpec::Fn(name) => (0..toks.len().saturating_sub(1)).find(|&k| {
            !in_ranges(k, &tests)
                && toks[k].kind == TokenKind::Ident
                && toks[k].text == "fn"
                && toks[k + 1].text == *name
        }),
        ItemSpec::ImplTraitFor(trait_name, type_name) => (0..toks.len()).find(|&k| {
            if in_ranges(k, &tests) || toks[k].kind != TokenKind::Ident || toks[k].text != "impl" {
                return false;
            }
            let Some(open) = (k..toks.len()).find(|&j| toks[j].text == "{") else {
                return false;
            };
            let header = &toks[k..open];
            header.iter().any(|t| &t.text == trait_name)
                && header.iter().any(|t| &t.text == type_name)
        }),
    }
}

/// Run the purity lint: scope resolution, required-scope presence
/// (`PU02`), banned-pattern scan (`PU01`, deduped per line).
pub fn check(
    tree: &SourceTree,
    cfg: &AuditConfig,
    markers: &BTreeMap<PathBuf, Vec<Marker>>,
) -> Vec<Finding> {
    let (scopes, mut findings) = resolve_scopes(tree, markers);

    for req in &cfg.required_scopes {
        let file = PathBuf::from(&req.file);
        let Some(lexed) = tree.files.get(&file) else {
            findings.push(Finding::new(
                Rule::Pu02,
                &file,
                0,
                format!("required purity scope '{}' names a missing file", req.item),
            ));
            continue;
        };
        let Some(item) = find_item(lexed, &req.item) else {
            findings.push(Finding::new(
                Rule::Pu02,
                &file,
                0,
                format!("required purity scope '{}' not found in this file", req.item),
            ));
            continue;
        };
        if !scopes.iter().any(|s| s.file == file && s.item == item) {
            findings.push(Finding::new(
                Rule::Pu02,
                &file,
                lexed.tokens[item].line,
                format!(
                    "'{}' must carry an `// audit:pure` marker (declared purity contract)",
                    req.item
                ),
            ));
        }
    }

    for scope in &scopes {
        let lexed = &tree.files[&scope.file];
        let toks = &lexed.tokens;
        let mut hit_lines: Vec<u32> = Vec::new();
        for k in scope.body.clone() {
            for (pat, why) in BANNED {
                if k + pat.len() > scope.body.end {
                    continue;
                }
                let m = pat.iter().zip(&toks[k..k + pat.len()]).all(|(p, t)| {
                    matches!(t.kind, TokenKind::Ident | TokenKind::Punct) && t.text == *p
                });
                if m && !hit_lines.contains(&toks[k].line) {
                    hit_lines.push(toks[k].line);
                    findings.push(Finding::new(
                        Rule::Pu01,
                        &scope.file,
                        toks[k].line,
                        format!(
                            "{why} inside the purity scope declared at line {} — \
                             evaluator/handler results must be pure functions of their inputs",
                            scope.marker_line
                        ),
                    ));
                    break;
                }
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::lexer::lex;
    use crate::audit::parse_markers;

    fn tree_of(file: &str, src: &str) -> SourceTree {
        let mut files = BTreeMap::new();
        files.insert(PathBuf::from(file), lex(src));
        SourceTree { root: PathBuf::from("."), files }
    }

    fn markers_of(tree: &SourceTree) -> BTreeMap<PathBuf, Vec<Marker>> {
        tree.files
            .iter()
            .map(|(f, l)| (f.clone(), parse_markers(f, l).0))
            .collect()
    }

    #[test]
    fn banned_in_scope_flagged_outside_ignored() {
        let src = "
fn free() { let t = Instant::now(); }
// audit:pure
fn pure_one(x: u64) -> u64 { let t = Instant::now(); x }
";
        let tree = tree_of("src/a.rs", src);
        let cfg = AuditConfig::default();
        let fs = check(&tree, &cfg, &markers_of(&tree));
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, Rule::Pu01);
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn string_literals_never_match() {
        let src = "
// audit:pure
fn pure_one() -> &'static str { \"Instant::now SystemTime fs::read\" }
";
        let tree = tree_of("src/a.rs", src);
        let fs = check(&tree, &AuditConfig::default(), &markers_of(&tree));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn required_scope_missing_marker_is_pu02() {
        let src = "fn answer() {}";
        let tree = tree_of("src/api.rs", src);
        let cfg = AuditConfig {
            required_scopes: vec![super::super::RequiredScope {
                file: "src/api.rs".into(),
                item: ItemSpec::Fn("answer".into()),
            }],
            ..Default::default()
        };
        let fs = check(&tree, &cfg, &markers_of(&tree));
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::Pu02);
    }

    #[test]
    fn impl_scope_covers_whole_block() {
        let src = "
// audit:pure
impl Evaluate for SweepEval {
    fn evaluate(&self) { self.cache.stats(); }
}
";
        let tree = tree_of("src/s.rs", src);
        let cfg = AuditConfig {
            required_scopes: vec![super::super::RequiredScope {
                file: "src/s.rs".into(),
                item: ItemSpec::ImplTraitFor("Evaluate".into(), "SweepEval".into()),
            }],
            ..Default::default()
        };
        let fs = check(&tree, &cfg, &markers_of(&tree));
        // PU01 on .stats(), no PU02 (the marker is present)
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, Rule::Pu01);
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn dangling_marker_is_au01() {
        let src = "// audit:pure\n";
        let tree = tree_of("src/a.rs", src);
        let fs = check(&tree, &AuditConfig::default(), &markers_of(&tree));
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, Rule::Au01);
    }
}
