//! The HDA abstraction (paper §II-B): a set of dataflow cores joined by
//! buses/point-to-point links, sharing an optional global buffer and an
//! off-chip memory.

use super::core::Core;

/// Inter-core / core-to-memory communication fabric. We model a shared bus
/// (the Edge TPU of Fig 4) or an all-to-all fabric with a global buffer
/// (FuseMax, Fig 7) with aggregate bandwidths; per-pair point-to-point
/// links can be added on top.
#[derive(Debug, Clone)]
pub struct Interconnect {
    /// Aggregate core↔core bandwidth (bytes/cycle).
    pub link_bw: f64,
    /// Energy per byte moved between cores.
    pub link_energy_pj: f64,
}

#[derive(Debug, Clone)]
pub struct Accelerator {
    pub name: String,
    pub cores: Vec<Core>,
    pub interconnect: Interconnect,
    /// Shared on-chip global buffer (0 = none).
    pub global_buffer_bytes: u64,
    /// Global buffer bandwidth (bytes/cycle).
    pub global_buffer_bw: f64,
    /// Off-chip DRAM bandwidth (bytes/cycle).
    pub offchip_bw: f64,
    /// Clock, used only to convert cycle counts for human-readable reports.
    pub clock_ghz: f64,
}

impl Accelerator {
    /// Total compute resource U·L·nPEs of the paper's Fig 8 x-axis.
    pub fn total_macs(&self) -> u64 {
        self.cores.iter().map(|c| c.peak_macs()).sum()
    }

    /// Peak MACs of the largest single core.
    pub fn max_core_macs(&self) -> u64 {
        self.cores.iter().map(|c| c.peak_macs()).max().unwrap_or(0)
    }

    /// Cores by dataflow class.
    pub fn mac_cores(&self) -> Vec<usize> {
        self.cores
            .iter()
            .filter(|c| !matches!(c.dataflow, super::core::Dataflow::Simd { .. }))
            .map(|c| c.id)
            .collect()
    }

    pub fn simd_cores(&self) -> Vec<usize> {
        self.cores
            .iter()
            .filter(|c| matches!(c.dataflow, super::core::Dataflow::Simd { .. }))
            .map(|c| c.id)
            .collect()
    }

    /// Sum of per-core local memories (bytes).
    pub fn total_local_mem(&self) -> u64 {
        self.cores.iter().map(|c| c.local_mem_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::core::Dataflow;

    fn accel() -> Accelerator {
        let mk = |id: usize, df: Dataflow| Core {
            id,
            name: format!("c{id}"),
            dataflow: df,
            local_mem_bytes: 1 << 20,
            regfile_bytes: 16 << 10,
            onchip_bw: 128.0,
        };
        Accelerator {
            name: "test".into(),
            cores: vec![
                mk(0, Dataflow::WeightStationary { rows: 16, cols: 16 }),
                mk(1, Dataflow::WeightStationary { rows: 16, cols: 16 }),
                mk(2, Dataflow::Simd { lanes: 64 }),
            ],
            interconnect: Interconnect { link_bw: 64.0, link_energy_pj: 0.8 },
            global_buffer_bytes: 0,
            global_buffer_bw: 0.0,
            offchip_bw: 32.0,
            clock_ghz: 1.0,
        }
    }

    #[test]
    fn totals() {
        let a = accel();
        assert_eq!(a.total_macs(), 2 * 256 + 64);
        assert_eq!(a.max_core_macs(), 256);
        assert_eq!(a.total_local_mem(), 3 << 20);
    }

    #[test]
    fn core_classes() {
        let a = accel();
        assert_eq!(a.mac_cores(), vec![0, 1]);
        assert_eq!(a.simd_cores(), vec![2]);
    }
}
