//! A single dataflow accelerator core inside an HDA (paper §II-B): a
//! spatial PE array with a prescribed dataflow plus a private memory
//! hierarchy (register file + local SRAM).

use crate::workload::op::{LoopDim, OpKind};

/// The spatial dataflow a core implements — which loop dimensions its PE
/// array binds spatially. This is the key determinant of how well an
/// operator maps (paper §II-B, Fig 4/7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Weights resident in the array; binds (K, C·Fx·Fy). TPU-like, great
    /// for convs/GEMMs with large channel counts (Edge TPU PEs, Fig 4).
    WeightStationary { rows: usize, cols: usize },
    /// Outputs resident; binds (M/spatial, N). FuseMax's MAC array (Fig 7).
    OutputStationary { rows: usize, cols: usize },
    /// Vector/SIMD core: binds the flattened element dimension. Handles
    /// elementwise, norm, softmax, optimizer ops.
    Simd { lanes: usize },
}

impl Dataflow {
    /// Peak MACs per cycle.
    pub fn peak_macs(&self) -> u64 {
        match self {
            Dataflow::WeightStationary { rows, cols }
            | Dataflow::OutputStationary { rows, cols } => (rows * cols) as u64,
            Dataflow::Simd { lanes } => *lanes as u64,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Core {
    pub id: usize,
    pub name: String,
    pub dataflow: Dataflow,
    /// Private SRAM (bytes).
    pub local_mem_bytes: u64,
    /// Register file (bytes) — bounds the innermost tile.
    pub regfile_bytes: u64,
    /// Local SRAM bandwidth (bytes/cycle).
    pub onchip_bw: f64,
}

impl Core {
    pub fn peak_macs(&self) -> u64 {
        self.dataflow.peak_macs()
    }

    /// Spatial utilization of `op` on this core in (0, 1]: the fraction of
    /// the PE array the operator's loop dims can keep busy, including the
    /// ceiling losses when a dim doesn't divide the array axis.
    ///
    /// `tensor_parallel` models the paper's §IV-A strategy of splitting
    /// output channels across a gang of identical cores: the bound dim is
    /// divided before mapping.
    pub fn spatial_utilization(&self, op: &OpKind, tensor_parallel: usize) -> f64 {
        let dims = op.loop_dims();
        let get = |d: LoopDim| -> usize {
            dims.iter().find(|(k, _)| *k == d).map(|(_, s)| *s).unwrap_or(1)
        };
        let axis_eff = |dim: usize, axis: usize| -> f64 {
            if dim == 0 || axis == 0 {
                return 1.0;
            }
            let folds = dim.div_ceil(axis);
            dim as f64 / (folds * axis) as f64
        };
        match self.dataflow {
            Dataflow::WeightStationary { rows, cols } => {
                if !(op.is_conv() || op.is_gemm()) {
                    // non-MAC op on a MAC array: only one row of PEs streams
                    return (1.0 / rows as f64).min(1.0);
                }
                // rows bind output channels K (split across the gang),
                // cols bind the reduction C·Fx·Fy
                let k = get(LoopDim::K).div_ceil(tensor_parallel.max(1));
                let red = get(LoopDim::C) * get(LoopDim::Fx) * get(LoopDim::Fy);
                axis_eff(k, rows) * axis_eff(red.max(1), cols)
            }
            Dataflow::OutputStationary { rows, cols } => {
                if !(op.is_conv() || op.is_gemm()) {
                    return (1.0 / rows as f64).min(1.0);
                }
                // rows bind spatial/M (Ox·Oy or M·B), cols bind K/N
                let m = get(LoopDim::Ox) * get(LoopDim::Oy) * get(LoopDim::M)
                    * get(LoopDim::B);
                let k = get(LoopDim::K).div_ceil(tensor_parallel.max(1));
                axis_eff(m.max(1), rows) * axis_eff(k.max(1), cols)
            }
            Dataflow::Simd { lanes } => {
                let e: usize = dims.iter().map(|(_, s)| *s).product();
                axis_eff(e.max(1), lanes)
            }
        }
    }

    /// Dataflow affinity: how natural this op class is for the core. Used
    /// by the scheduler's core-selection policy (pipeline parallelism maps
    /// layers "to the most suitable compute units", paper §IV-A).
    pub fn affinity(&self, op: &OpKind) -> f64 {
        let mac_op = op.is_conv() || op.is_gemm();
        match self.dataflow {
            Dataflow::WeightStationary { .. } | Dataflow::OutputStationary { .. } => {
                if mac_op {
                    1.0
                } else {
                    0.05
                }
            }
            Dataflow::Simd { .. } => {
                if mac_op {
                    0.1
                } else {
                    1.0
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::op::{ConvSpec, EltwiseKind, GemmSpec};

    fn ws(rows: usize, cols: usize) -> Core {
        Core {
            id: 0,
            name: "ws".into(),
            dataflow: Dataflow::WeightStationary { rows, cols },
            local_mem_bytes: 2 << 20,
            regfile_bytes: 32 << 10,
            onchip_bw: 256.0,
        }
    }

    fn conv(out_ch: usize, in_ch: usize) -> OpKind {
        OpKind::Conv(ConvSpec {
            batch: 1,
            in_ch,
            out_ch,
            in_h: 16,
            in_w: 16,
            k_h: 3,
            k_w: 3,
            stride: 1,
            padding: 1,
            groups: 1,
        })
    }

    #[test]
    fn perfect_fit_is_full_utilization() {
        let c = ws(64, 144); // K=64, C*9=144 exactly
        let u = c.spatial_utilization(&conv(64, 16), 1);
        assert!((u - 1.0).abs() < 1e-12, "u={u}");
    }

    #[test]
    fn ceiling_losses_reduce_utilization() {
        let c = ws(48, 144); // K=64 over 48 rows: 64/(2*48) = 2/3
        let u = c.spatial_utilization(&conv(64, 16), 1);
        assert!((u - 64.0 / 96.0).abs() < 1e-12, "u={u}");
    }

    #[test]
    fn tensor_parallel_splits_output_channels() {
        let c = ws(64, 144);
        let u1 = c.spatial_utilization(&conv(32, 16), 1); // K=32 on 64 rows
        let u2 = c.spatial_utilization(&conv(32, 16), 2); // K=16 each
        assert!(u2 <= u1 + 1e-12);
        assert!((u1 - 0.5).abs() < 1e-12);
        assert!((u2 - 0.25).abs() < 1e-12);
    }

    #[test]
    fn simd_core_prefers_eltwise() {
        let simd = Core {
            id: 1,
            name: "v".into(),
            dataflow: Dataflow::Simd { lanes: 128 },
            local_mem_bytes: 1 << 20,
            regfile_bytes: 8 << 10,
            onchip_bw: 512.0,
        };
        let relu = OpKind::Eltwise { kind: EltwiseKind::Relu, elems: 1 << 16, arity: 1 };
        assert!(simd.affinity(&relu) > simd.affinity(&conv(64, 16)));
        let u = simd.spatial_utilization(&relu, 1);
        assert!((u - 1.0).abs() < 1e-9); // 65536 % 128 == 0
    }

    #[test]
    fn gemm_on_output_stationary() {
        let os = Core {
            id: 2,
            name: "os".into(),
            dataflow: Dataflow::OutputStationary { rows: 128, cols: 128 },
            local_mem_bytes: 8 << 20,
            regfile_bytes: 64 << 10,
            onchip_bw: 1024.0,
        };
        let g = OpKind::Gemm(GemmSpec { batch: 1, m: 256, n: 128, k: 64, weight_b: true });
        let u = os.spatial_utilization(&g, 1);
        assert!((u - 1.0).abs() < 1e-12, "u={u}"); // 256·1 over 128 rows folds evenly
    }

    #[test]
    fn peak_macs() {
        assert_eq!(ws(64, 144).peak_macs(), 64 * 144);
        let simd = Dataflow::Simd { lanes: 256 };
        assert_eq!(simd.peak_macs(), 256);
    }
}
