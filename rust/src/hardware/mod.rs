//! Hardware model (DESIGN.md S5): heterogeneous dataflow accelerators —
//! dataflow cores with private memory hierarchies, interconnect, a shared
//! buffer and off-chip memory. Replaces Stream's hardware description.

pub mod accelerator;
pub mod core;
pub mod energy;
pub mod presets;

pub use accelerator::{Accelerator, Interconnect};
pub use core::{Core, Dataflow};
pub use presets::{EdgeTpuParams, FuseMaxParams};
