//! Hardware model (DESIGN.md S5): heterogeneous dataflow accelerators —
//! dataflow cores with private memory hierarchies, interconnect, a shared
//! buffer and off-chip memory. Replaces Stream's hardware description.
//!
//! [`core`] models one dataflow core (weight-/output-stationary arrays,
//! SIMD) and its spatial utilization per op; [`accelerator`] composes
//! cores into an HDA; [`energy`] holds the Horowitz-lineage pJ constants
//! whose *ratios* (MAC ≪ SRAM ≪ DRAM) drive every qualitative
//! conclusion; [`presets`] builds the paper's Table II/III search spaces
//! plus the named device-class configurations
//! (`EdgeTpuParams::server_class`, `EdgeTpuParams::datacenter_class`)
//! that the heterogeneous cluster model in
//! [`crate::parallelism::hetero`] wraps with fabric tiers and energy
//! scales.

pub mod accelerator;
pub mod core;
pub mod energy;
pub mod presets;

pub use accelerator::{Accelerator, Interconnect};
pub use core::{Core, Dataflow};
pub use presets::{EdgeTpuParams, FuseMaxParams};
