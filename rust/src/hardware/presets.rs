//! Accelerator presets and search spaces — Tables II and III of the paper.
//!
//! * Edge TPU (Fig 4, Zhou et al. [19]): a grid of weight-stationary PEs
//!   (each: U SIMD units × L compute lanes, a local memory and a register
//!   file) on a shared bus to off-chip memory, plus one vector core — the
//!   heterogeneity the paper exploits with pipeline parallelism (§IV-A).
//! * FuseMax (Fig 7, Nayak et al. [30]): one large output-stationary MAC
//!   array + one large vector array, both behind a shared global buffer
//!   that talks to off-chip memory (§IV-B).

use super::accelerator::{Accelerator, Interconnect};
use super::core::{Core, Dataflow};
use super::energy;

// ---------------------------------------------------------------------------
// Edge TPU (Table II)
// ---------------------------------------------------------------------------

/// One point in the Edge TPU search space (Table II). Bold baseline:
/// 4×4 PEs, U=64, L=4, 2 MB local memory, 64 KB register file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeTpuParams {
    pub x_pes: usize,
    pub y_pes: usize,
    /// SIMD units per compute lane
    pub u: usize,
    /// Compute lanes per PE
    pub l: usize,
    /// Local memory per PE, bytes
    pub local_mem: u64,
    /// Register file per lane, bytes
    pub regfile: u64,
}

impl EdgeTpuParams {
    pub fn baseline() -> Self {
        EdgeTpuParams {
            x_pes: 4,
            y_pes: 4,
            u: 64,
            l: 4,
            local_mem: 2 << 20,
            regfile: 64 << 10,
        }
    }

    pub fn n_pes(&self) -> usize {
        self.x_pes * self.y_pes
    }

    /// Per-PE compute resource U·L (the Fig 8 colour axis).
    pub fn per_pe_macs(&self) -> u64 {
        (self.u * self.l) as u64
    }

    /// Total compute resource U·L·nPEs (the Fig 8 x-axis).
    pub fn total_macs(&self) -> u64 {
        self.per_pe_macs() * self.n_pes() as u64
    }

    /// Server-class scale-up of the Edge TPU microarchitecture: same 4×4
    /// PE grid, twice the per-PE compute (U=128) and local SRAM. The
    /// mid-point of the heterogeneous `DeviceClass` ladder
    /// (`parallelism::hetero`).
    pub fn server_class() -> Self {
        EdgeTpuParams {
            x_pes: 4,
            y_pes: 4,
            u: 128,
            l: 4,
            local_mem: 4 << 20,
            regfile: 64 << 10,
        }
    }

    /// Datacenter-class scale-up: 4× the per-PE compute (U=128, L=8) and
    /// local SRAM of the baseline, 2× its register file — the
    /// high-throughput end of the heterogeneous `DeviceClass` ladder. The
    /// matching fabric/bandwidth/energy deltas live on
    /// `parallelism::hetero::DeviceClass`, not here: these params only
    /// size the on-chip array.
    pub fn datacenter_class() -> Self {
        EdgeTpuParams {
            x_pes: 4,
            y_pes: 4,
            u: 128,
            l: 8,
            local_mem: 8 << 20,
            regfile: 128 << 10,
        }
    }

    /// The full Table II cartesian space (10 000 configurations).
    pub fn space() -> Vec<EdgeTpuParams> {
        let mut out = vec![];
        for &x_pes in &[1usize, 2, 4, 6, 8] {
            for &y_pes in &[1usize, 2, 4, 6, 8] {
                for &u in &[16usize, 32, 64, 128] {
                    for &l in &[1usize, 2, 4, 8] {
                        for &mem_half_mb in &[1u64, 2, 4, 6, 8] {
                            for &rf_kb in &[8u64, 16, 32, 64, 128] {
                                out.push(EdgeTpuParams {
                                    x_pes,
                                    y_pes,
                                    u,
                                    l,
                                    local_mem: mem_half_mb * (1 << 19),
                                    regfile: rf_kb << 10,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Deterministically subsampled space (every `stride`-th point) for
    /// sweep budgets; stride 1 = full space.
    pub fn space_strided(stride: usize) -> Vec<EdgeTpuParams> {
        Self::space().into_iter().step_by(stride.max(1)).collect()
    }

    /// Build the HDA: nPEs weight-stationary cores + one vector core.
    pub fn build(&self) -> Accelerator {
        let mut cores = Vec::with_capacity(self.n_pes() + 1);
        for id in 0..self.n_pes() {
            cores.push(Core {
                id,
                name: format!("pe{id}"),
                // U SIMD units bind output channels, L lanes bind the
                // reduction — the weight-stationary layout of [19].
                dataflow: Dataflow::WeightStationary { rows: self.u, cols: self.l },
                local_mem_bytes: self.local_mem,
                regfile_bytes: self.regfile,
                // local SRAM feeds the array; scale with array width
                onchip_bw: (2 * self.u) as f64,
            });
        }
        let vid = cores.len();
        cores.push(Core {
            id: vid,
            name: "vector".into(),
            dataflow: Dataflow::Simd { lanes: 256 },
            local_mem_bytes: 1 << 20,
            regfile_bytes: 16 << 10,
            onchip_bw: 512.0,
        });
        Accelerator {
            name: format!(
                "edgetpu[{}x{} U{} L{} M{}K R{}K]",
                self.x_pes,
                self.y_pes,
                self.u,
                self.l,
                self.local_mem >> 10,
                self.regfile >> 10
            ),
            cores,
            interconnect: Interconnect {
                link_bw: 256.0,
                link_energy_pj: energy::E_LINK_PJ_PER_BYTE,
            },
            global_buffer_bytes: 0,
            global_buffer_bw: 0.0,
            offchip_bw: 128.0,
            clock_ghz: 0.8,
        }
    }
}

// ---------------------------------------------------------------------------
// FuseMax (Table III)
// ---------------------------------------------------------------------------

/// One point in the FuseMax search space (Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuseMaxParams {
    pub x_pes: usize,
    pub y_pes: usize,
    pub vector_pes: usize,
    /// Global buffer bandwidth, bytes/cycle
    pub buffer_bw: u64,
    /// Global buffer size, bytes
    pub buffer_size: u64,
    /// Off-chip bandwidth, bytes/cycle
    pub offchip_bw: u64,
}

impl FuseMaxParams {
    /// FuseMax's published configuration: 128×128 MAC array.
    pub fn baseline() -> Self {
        FuseMaxParams {
            x_pes: 128,
            y_pes: 128,
            vector_pes: 128,
            buffer_bw: 8192,
            buffer_size: 16 << 20,
            offchip_bw: 2048,
        }
    }

    pub fn total_macs(&self) -> u64 {
        (self.x_pes * self.y_pes + self.vector_pes) as u64
    }

    /// The full Table III cartesian space (2 560 configurations).
    pub fn space() -> Vec<FuseMaxParams> {
        let mut out = vec![];
        for &x_pes in &[64usize, 128, 256, 512] {
            for &y_pes in &[64usize, 128, 256, 512] {
                for &vector_pes in &[32usize, 64, 128, 256] {
                    for &buffer_bw in &[8192u64, 16384] {
                        for &buffer_mb in &[4u64, 8, 16, 32] {
                            for &offchip_bw in &[512u64, 1024, 2048, 4096, 8192] {
                                out.push(FuseMaxParams {
                                    x_pes,
                                    y_pes,
                                    vector_pes,
                                    buffer_bw,
                                    buffer_size: buffer_mb << 20,
                                    offchip_bw,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    pub fn space_strided(stride: usize) -> Vec<FuseMaxParams> {
        Self::space().into_iter().step_by(stride.max(1)).collect()
    }

    /// Build the HDA: one output-stationary MAC array + one vector array
    /// behind a shared global buffer (Fig 7).
    pub fn build(&self) -> Accelerator {
        let cores = vec![
            Core {
                id: 0,
                name: "mac_array".into(),
                dataflow: Dataflow::OutputStationary { rows: self.x_pes, cols: self.y_pes },
                // array-adjacent staging memory
                local_mem_bytes: 2 << 20,
                regfile_bytes: 128 << 10,
                onchip_bw: self.buffer_bw as f64 / 2.0,
            },
            Core {
                id: 1,
                name: "vector_array".into(),
                dataflow: Dataflow::Simd { lanes: self.vector_pes },
                local_mem_bytes: 1 << 20,
                regfile_bytes: 64 << 10,
                onchip_bw: self.buffer_bw as f64 / 2.0,
            },
        ];
        Accelerator {
            name: format!(
                "fusemax[{}x{} V{} BW{} B{}M D{}]",
                self.x_pes,
                self.y_pes,
                self.vector_pes,
                self.buffer_bw,
                self.buffer_size >> 20,
                self.offchip_bw
            ),
            cores,
            interconnect: Interconnect {
                link_bw: self.buffer_bw as f64,
                link_energy_pj: energy::E_GLOBAL_PJ_PER_BYTE,
            },
            global_buffer_bytes: self.buffer_size,
            global_buffer_bw: self.buffer_bw as f64,
            offchip_bw: self.offchip_bw as f64,
            clock_ghz: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_space_size() {
        assert_eq!(EdgeTpuParams::space().len(), 5 * 5 * 4 * 4 * 5 * 5);
    }

    #[test]
    fn table3_space_size() {
        assert_eq!(FuseMaxParams::space().len(), 4 * 4 * 4 * 2 * 4 * 5);
    }

    #[test]
    fn baseline_edge_tpu_matches_paper() {
        let p = EdgeTpuParams::baseline();
        assert_eq!(p.n_pes(), 16);
        assert_eq!(p.per_pe_macs(), 256);
        assert!(EdgeTpuParams::space().contains(&p));
        let a = p.build();
        assert_eq!(a.cores.len(), 17); // 16 PEs + vector
        assert_eq!(a.mac_cores().len(), 16);
        assert_eq!(a.simd_cores().len(), 1);
    }

    #[test]
    fn baseline_fusemax_matches_paper() {
        let p = FuseMaxParams::baseline();
        assert!(FuseMaxParams::space().contains(&p));
        let a = p.build();
        assert_eq!(a.cores.len(), 2);
        assert_eq!(a.total_macs(), 128 * 128 + 128);
        assert_eq!(a.global_buffer_bytes, 16 << 20);
    }

    #[test]
    fn device_class_params_scale_monotonically() {
        let e = EdgeTpuParams::baseline();
        let s = EdgeTpuParams::server_class();
        let d = EdgeTpuParams::datacenter_class();
        assert!(e.per_pe_macs() < s.per_pe_macs() && s.per_pe_macs() < d.per_pe_macs());
        assert!(e.local_mem < s.local_mem && s.local_mem < d.local_mem);
        assert_eq!(d.per_pe_macs(), 4 * e.per_pe_macs());
    }

    #[test]
    fn strided_subsampling() {
        let full = EdgeTpuParams::space().len();
        let sub = EdgeTpuParams::space_strided(10).len();
        assert_eq!(sub, full.div_ceil(10));
    }

    #[test]
    fn total_macs_axis() {
        let p = EdgeTpuParams::baseline();
        assert_eq!(p.total_macs(), 64 * 4 * 16);
    }
}
