//! Energy model constants (pJ), loosely calibrated to the 45nm CMOS access
//! energy table popularised by Horowitz (ISSCC'14) and used by the
//! Accelergy/ZigZag/Stream lineage the paper builds on. Absolute values are
//! technology-dependent; the *ratios* (MAC ≪ SRAM ≪ DRAM) are what drive
//! every qualitative conclusion the paper draws, and those are preserved.

/// Energy of one 8-32 bit MAC operation.
pub const E_MAC_PJ: f64 = 0.5;

/// Register-file access energy per byte (small SRAM, <64 KiB).
pub const E_RF_PJ_PER_BYTE: f64 = 0.12;

/// Local (per-core) SRAM access energy per byte (0.5–4 MiB).
pub const E_LOCAL_PJ_PER_BYTE: f64 = 1.0;

/// Shared on-chip global buffer access energy per byte.
pub const E_GLOBAL_PJ_PER_BYTE: f64 = 2.0;

/// Off-chip DRAM access energy per byte.
pub const E_DRAM_PJ_PER_BYTE: f64 = 40.0;

/// Inter-core link transfer energy per byte (NoC/bus hop).
pub const E_LINK_PJ_PER_BYTE: f64 = 0.8;

/// Static/idle power expressed as pJ per cycle per active core. Kept small:
/// the paper's metrics are dominated by dynamic energy.
pub const E_IDLE_PJ_PER_CYCLE: f64 = 0.05;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_ordering_preserved() {
        // the invariant every conclusion depends on
        assert!(E_RF_PJ_PER_BYTE < E_LOCAL_PJ_PER_BYTE);
        assert!(E_LOCAL_PJ_PER_BYTE < E_GLOBAL_PJ_PER_BYTE);
        assert!(E_GLOBAL_PJ_PER_BYTE < E_DRAM_PJ_PER_BYTE);
        assert!(E_MAC_PJ < E_DRAM_PJ_PER_BYTE);
    }
}
