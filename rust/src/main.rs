//! MONET command-line launcher: regenerate any paper figure, run the
//! end-to-end AOT training demo, or validate the runtime against the
//! native cost model. (clap is not vendored offline; the argument grammar
//! is small and hand-rolled.)

use std::path::PathBuf;

use monet::bail;
use monet::figures;
use monet::util::error::{Context, Result};
use monet::ga::GaConfig;
use monet::report::{ascii_bars, ascii_scatter, fmt_bytes};
use monet::runtime::{Corpus, CostKernel, Gpt2Runner, Runtime};
use monet::serve::parse_device_pool;

/// The CLI grammar. `docs/CLI.md` is checked against this text by the
/// `cli_reference_covers_usage` unit test, so the two cannot drift.
const USAGE: &str = "MONET — modeling & optimization of NN training on heterogeneous dataflow accelerators

USAGE: monet <command> [options]

COMMANDS
  fig1            ResNet-18 Edge-TPU sweep, energy-vs-latency (also fig8 data)
  fig3            ResNet-50 peak-memory breakdown (batch 1 & 8)
  fig5            cluster-parallelism Pareto front, edge→datacenter
                  (ResNet-18 + GPT-2 training, plus a mixed edge+datacenter
                  GPT-2 series with stage placements; CSV with front
                  membership)
  fig9            GPT-2 FuseMax sweep
  fig10           layer-fusion strategies comparison
  fig11           activation-checkpointing non-linearity
  fig12           NSGA-II checkpointing Pareto front
  all             regenerate every figure
  schedule        generate + render the fused training schedule (Gantt + CSV)
  search          find the best training configs: AOT-Pallas prefilter + detailed schedule
  cluster         cluster-scale parallelism DSE: enumerate DP/PP/TP hybrid
                  factorizations across device counts and link tiers
                  (edge/server/datacenter) and rank them with the
                  4-objective NSGA-II set (iteration latency, energy,
                  per-device memory, cluster size); prints the front and
                  the per-tier latency optimum. With --device-classes the
                  space becomes heterogeneous: a mixed device pool with a
                  stage-placement dimension (which class hosts which
                  pipeline stage)
  ga-cluster      cluster DSE for pools past the exhaustive-enumeration
                  wall (256+ devices): evolves full (dp, pp, m, tp)
                  factorizations with per-stage class placements over the
                  generic NSGA-II core, seeded from — and reported
                  head-to-head against — the contiguous-block fallback
                  enumeration. Requires --device-classes; --pop and
                  --gens size the GA; with --run-dir both the backbone and
                  every completed GA generation are journaled, so --resume
                  covers the whole search
  ablation        MILP (eq. 6) vs NSGA-II checkpointing under the true pipeline
  train           end-to-end: train tiny GPT-2 via the AOT HLO artifacts
  validate        cross-check the AOT cost kernel against the native model
  info            workload/hardware inventory
  serve           DSE-as-a-service: a resident optimizer daemon answering
                  concurrent HTTP/JSON optimization queries (every design-
                  space family: sweep, cluster, hetero, ga-cluster) from
                  one warm shared cost cache. Endpoints: POST /query
                  (blocking), POST /jobs + GET /jobs/<id> (pollable
                  progress for long GA queries), GET /healthz, GET /stats
                  (cache hit/miss/eviction counters), POST /shutdown
                  (graceful: drains the queue, persists the --cache-dir
                  snapshot, exits 0)
  query           answer one serve-API JSON request body (--request FILE)
                  as a one-shot run and print the answer — the daemon's
                  CLI fallback, bit-identical to the same query against a
                  warm serve daemon

OPTIONS
  --stride N      design-space subsampling stride (fig1/fig9/all; default 20)
  --pop N         GA population (fig12/ablation/ga-cluster; default 32)
  --gens N        GA generations (fig12/ablation/ga-cluster; default 30)
  --devices N     max cluster size (cluster/fig5; device counts are the
                  powers of two ≤ N; default 8). Ignored by cluster
                  --device-classes and ga-cluster: there the pool defines
                  the size
  --batch N       global training batch split across the cluster
                  (cluster/fig5/ga-cluster; default 4)
  --workload W    cluster workload: resnet18 | gpt2 | both (cluster and
                  ga-cluster; default both — gpt2 is the reduced tiny
                  config, like the fig9 sweep workload)
  --device-classes L
                  heterogeneous device pool for the cluster and ga-cluster
                  commands, e.g. edge:2,datacenter:2 (classes: edge |
                  server | datacenter). Switches cluster to the
                  stage-placement DSE: every feasible dp/pp/tp
                  factorization × placement of pipeline stages onto
                  classes is enumerated, ranked with the same 4-objective
                  set, and the front is compared against the best all-edge
                  and all-datacenter deployments. ga-cluster searches the
                  same space with the GA instead of enumerating it
  --steps N       training steps (train; default 300)
  --config NAME   gpt2 config (train; default tiny)
  --artifacts DIR artifacts directory (default artifacts)
  --out DIR       results directory (default results)
  --no-prune      disable bound-based front pruning for the cluster and
                  ga-cluster commands (pruning is on by default there):
                  with pruning, design points whose roofline lower bound
                  is already Pareto-dominated by evaluated rows are
                  skipped — the 4-objective rank-0 front is bit-identical
                  either way, but dominated diagnostic rows (per-tier
                  latency optima, full-enumeration CSV exports) may be
                  thinned. The figure commands (fig5/all) and search
                  always enumerate every row; serve/query requests carry
                  their own \"prune\" key (default true)
  --no-cache      disable the shared group-cost memo for the sweep commands
                  (fig1/fig5/fig9/search/cluster/ga-cluster/all) — A/B
                  timing; results are bit-identical with or without it
  --cache-dir DIR persist the group-cost cache across runs: warm-load the
                  snapshot in DIR before a sweep/search/GA, write it back
                  after (fig1/fig5/fig9/search/cluster/all/fig12, and the
                  ga-cluster backbone sweep; the
                  cluster commands share entries across factorizations,
                  placements and link tiers — the stage-schedule
                  memoization win). Stale/incompatible
                  snapshots are rejected wholesale. Sweep/search rows stay
                  bit-identical to a cold run; fig12 additionally
                  warm-starts the GA from the previous run's Pareto front,
                  which deliberately resumes (and so changes) the search.
                  --no-cache wins over this.
  --cache-cap N   bound the group-cost cache to ~N entries (second-chance/
                  CLOCK eviction; default 0 = unbounded)
  --run-dir DIR   crash-safety: journal every completed design point (and
                  every completed GA generation for fig12/ga-cluster) into
                  DIR as it finishes
                  (fig1/fig5/fig9/search/cluster/ga-cluster/all/fig12). Each
                  command journals into its own subdirectory of DIR, so
                  one DIR serves a whole `all` run. Rows are bit-identical
                  with journaling on or off
  --resume        replay completed work from the --run-dir journal and
                  evaluate only the remainder (requires --run-dir). A torn
                  record from a mid-write crash is truncated back to the
                  last intact record; a journal from a different design
                  space or format is quarantined to a .corrupt sidecar and
                  the run starts fresh. Resumed results are bit-identical
                  to an uninterrupted run
  --port N        serve: TCP port to listen on, bound to 127.0.0.1
                  (default 0 = ephemeral; the bound address is printed at
                  boot as `serving on http://ADDR`)
  --serve-workers N
                  serve: worker threads answering queries from the shared
                  bounded queue (default 2)
  --queue N       serve: bounded request-queue depth; requests arriving
                  past it are rejected with a structured 503, never
                  buffered unboundedly (default 64)
  --checkpoint-every N
                  serve: with --cache-dir, also persist the cache snapshot
                  after every N completed queries, not only at graceful
                  shutdown (default 32; 0 = shutdown-only)
  --request FILE  query: read the serve-API JSON request body from FILE";

fn usage() -> ! {
    eprintln!("{USAGE}");
    std::process::exit(2);
}

struct Args {
    cmd: String,
    stride: usize,
    pop: usize,
    gens: usize,
    devices: usize,
    batch: usize,
    workload: String,
    device_classes: Option<String>,
    steps: usize,
    config: String,
    artifacts: PathBuf,
    out: PathBuf,
    no_cache: bool,
    no_prune: bool,
    cache_dir: Option<PathBuf>,
    cache_cap: usize,
    run_dir: Option<PathBuf>,
    resume: bool,
    port: u16,
    serve_workers: usize,
    queue: usize,
    checkpoint_every: u64,
    request: Option<PathBuf>,
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        stride: 20,
        pop: 32,
        gens: 30,
        devices: 8,
        batch: 4,
        workload: "both".into(),
        device_classes: None,
        steps: 300,
        config: "tiny".into(),
        artifacts: "artifacts".into(),
        out: "results".into(),
        no_cache: false,
        no_prune: false,
        cache_dir: None,
        cache_cap: 0,
        run_dir: None,
        resume: false,
        port: 0,
        serve_workers: 2,
        queue: 64,
        checkpoint_every: 32,
        request: None,
    };
    let mut it = std::env::args().skip(1);
    match it.next() {
        Some(c) => args.cmd = c,
        None => usage(),
    }
    while let Some(flag) = it.next() {
        let mut val = || it.next().unwrap_or_else(|| usage());
        match flag.as_str() {
            "--stride" => args.stride = val().parse().unwrap_or_else(|_| usage()),
            "--pop" => args.pop = val().parse().unwrap_or_else(|_| usage()),
            "--gens" => args.gens = val().parse().unwrap_or_else(|_| usage()),
            "--devices" => args.devices = val().parse().unwrap_or_else(|_| usage()),
            "--batch" => args.batch = val().parse().unwrap_or_else(|_| usage()),
            "--workload" => args.workload = val(),
            "--device-classes" => args.device_classes = Some(val()),
            "--steps" => args.steps = val().parse().unwrap_or_else(|_| usage()),
            "--config" => args.config = val(),
            "--artifacts" => args.artifacts = val().into(),
            "--out" => args.out = val().into(),
            "--no-cache" => args.no_cache = true,
            "--no-prune" => args.no_prune = true,
            "--cache-dir" => args.cache_dir = Some(val().into()),
            "--cache-cap" => args.cache_cap = val().parse().unwrap_or_else(|_| usage()),
            "--run-dir" => args.run_dir = Some(val().into()),
            "--resume" => args.resume = true,
            "--port" => args.port = val().parse().unwrap_or_else(|_| usage()),
            "--serve-workers" => args.serve_workers = val().parse().unwrap_or_else(|_| usage()),
            "--queue" => args.queue = val().parse().unwrap_or_else(|_| usage()),
            "--checkpoint-every" => args.checkpoint_every = val().parse().unwrap_or_else(|_| usage()),
            "--request" => args.request = Some(val().into()),
            _ => usage(),
        }
    }
    if args.resume && args.run_dir.is_none() {
        eprintln!("error: --resume requires --run-dir (there is no journal to resume from)");
        std::process::exit(2);
    }
    // validate directory-taking flags at parse time: a typo'd or
    // unwritable path must fail now with an actionable message, not hours
    // into a sweep when the first snapshot/journal write happens
    if let Some(dir) = &args.cache_dir {
        validate_dir_flag("--cache-dir", dir);
    }
    if let Some(dir) = &args.run_dir {
        validate_dir_flag("--run-dir", dir);
    }
    args
}

/// Parse-time check of a directory-valued flag: the path must be an
/// existing directory or creatable (existing parent), and writable.
fn validate_dir_flag(flag: &str, path: &std::path::Path) {
    let fail = |msg: String| -> ! {
        eprintln!("error: {flag} {}: {msg}", path.display());
        std::process::exit(2);
    };
    if path.exists() {
        if !path.is_dir() {
            fail("exists but is not a directory".into());
        }
    } else {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() && !parent.exists() {
                fail(format!(
                    "parent directory {} does not exist (create it or fix the path)",
                    parent.display()
                ));
            }
        }
        if let Err(e) = std::fs::create_dir_all(path) {
            fail(format!("cannot create directory: {e}"));
        }
    }
    let probe = path.join(".monet_write_probe");
    match std::fs::write(&probe, b"probe") {
        Ok(()) => {
            let _ = std::fs::remove_file(&probe);
        }
        Err(e) => fail(format!("directory is not writable: {e}")),
    }
}

/// Per-command subdirectory of `--run-dir`, so `all` (and the two-workload
/// `cluster` command) never share one journal file between runs over
/// different — or identically-enumerated but differently-modeled — spaces.
fn run_subdir(args: &Args, name: &str) -> Option<PathBuf> {
    args.run_dir.as_ref().map(|d| d.join(name))
}

/// Print resume/failure diagnostics for one sweep family; returns `Err`
/// when any point failed so the process exits nonzero (degraded results
/// must not look like clean ones), while the completed rows and CSVs
/// above remain usable.
fn report_run_health(
    what: &str,
    resumed: usize,
    failures: &[monet::dse::PointFailure],
) -> Result<()> {
    if resumed > 0 {
        eprintln!("  {what}: {resumed} point(s) replayed from the run journal");
    }
    for f in failures {
        eprintln!(
            "  {what}: point {} ({}) FAILED and was isolated: {}",
            f.index, f.point_id, f.diagnostic
        );
    }
    if failures.is_empty() {
        Ok(())
    } else {
        bail!(
            "{what}: {} design point(s) failed (results above are complete for all other points)",
            failures.len()
        )
    }
}

fn progress(done: usize, total: usize) {
    if done % 100 == 0 || done == total {
        eprint!("\r  {done}/{total} points");
        if done == total {
            eprintln!();
        }
    }
}

fn render_sweep(title: &str, rows: &[monet::dse::SweepRow]) {
    let (inf, tr) = figures::split_modes(rows);
    for (mode, set) in [("inference", &inf), ("training", &tr)] {
        if set.is_empty() {
            continue;
        }
        let xs: Vec<f64> = set.iter().map(|r| r.latency_cycles).collect();
        let ys: Vec<f64> = set.iter().map(|r| r.energy_pj).collect();
        let cmax = set.iter().map(|r| r.color_axis).fold(f64::MIN, f64::max);
        let marks: Vec<char> = set
            .iter()
            .map(|r| {
                let f = (r.color_axis / cmax * 4.0).min(4.0) as usize;
                ['.', ':', 'o', 'O', '@'][f]
            })
            .collect();
        println!(
            "{}",
            ascii_scatter(
                &format!("{title} [{mode}] energy (pJ) vs latency (cycles), mark=colour axis"),
                &xs,
                &ys,
                &marks,
                72,
                18,
                true
            )
        );
    }
}

fn print_cache_stats(what: &str, s: &monet::eval::CacheStats) {
    if s.hits + s.misses > 0 {
        eprintln!(
            "  {what} group-cost cache: {} hits / {} misses ({:.1}% hit rate, {} entries, {} evictions)",
            s.hits,
            s.misses,
            s.hit_rate() * 100.0,
            s.entries,
            s.evictions
        );
    }
    // lifecycle trouble is rare — only surface the counters when nonzero
    if s.snapshots_rejected + s.snapshots_quarantined + s.io_retries > 0 {
        eprintln!(
            "  {what} cache lifecycle: {} snapshot(s) rejected, {} quarantined, {} IO retr{}",
            s.snapshots_rejected,
            s.snapshots_quarantined,
            s.io_retries,
            if s.io_retries == 1 { "y" } else { "ies" }
        );
    }
}

fn cmd_fig1(args: &Args) -> Result<()> {
    eprintln!("Edge-TPU sweep (Table II, stride {})...", args.stride);
    let run_dir = run_subdir(args, "fig1");
    let sweep = figures::fig1_fig8_edge_sweep_cfg(
        args.stride,
        !args.no_cache,
        args.cache_dir.as_deref(),
        args.cache_cap,
        run_dir.as_deref(),
        args.resume,
        Some(&args.out),
        progress,
    );
    render_sweep("Fig 1/8: ResNet-18 on Edge TPU", &sweep.rows);
    print_cache_stats("sweep", &sweep.cache);
    println!("rows: {} → {}/fig1_fig8_edge_sweep.csv", sweep.rows.len(), args.out.display());
    report_run_health("fig1", sweep.resumed, &sweep.failures)
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let bd = figures::fig3_memory_breakdown(Some(&args.out));
    for m in &bd {
        println!(
            "{}",
            ascii_bars(
                &format!("Fig 3: ResNet-50 (Adam, 224²) peak memory, batch {}", m.batch),
                &[
                    "parameters".into(),
                    "gradients".into(),
                    "optimizer states".into(),
                    "activations".into(),
                ],
                &[
                    m.params_bytes as f64,
                    m.grads_bytes as f64,
                    m.optstate_bytes as f64,
                    m.activation_bytes as f64,
                ],
                40
            )
        );
        println!("  total: {}", fmt_bytes(m.total()));
    }
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    use monet::dse::front_factorizations;
    eprintln!(
        "cluster-parallelism space (≤{} devices, batch {}, edge→datacenter)...",
        args.devices, args.batch
    );
    let run_dir = run_subdir(args, "fig5");
    let figs = figures::fig5_cluster_pareto(
        args.devices,
        args.batch,
        !args.no_cache,
        args.cache_dir.as_deref(),
        args.cache_cap,
        run_dir.as_deref(),
        args.resume,
        Some(&args.out),
        progress,
    );
    for f in &figs {
        let facts = front_factorizations(&f.outcome);
        println!(
            "Fig 5 [{}]: {} deployment points, {} on the 4-objective front, {} distinct dp/pp/tp factorizations",
            f.workload,
            f.outcome.rows.len(),
            f.outcome.front.len(),
            facts.len()
        );
        print_cache_stats("cluster", &f.outcome.cache);
    }
    println!("rows → {}/fig5_cluster_pareto.csv", args.out.display());
    for f in &figs {
        let what = format!("fig5 [{}]", f.workload);
        report_run_health(&what, f.outcome.resumed, &f.outcome.failures)?;
    }
    Ok(())
}

/// `cluster --device-classes …`: the heterogeneous stage-placement DSE.
fn cmd_cluster_hetero(args: &Args, spec: &str) -> Result<()> {
    use monet::autodiff::TrainingGraph;
    use monet::dse::{
        front_factorizations, hetero_search, mixed_domination_witness, placed_only_on,
        ClusterRow, SweepConfig,
    };
    use monet::figures::{cluster_gpt2_builder, cluster_resnet18_builder};
    use monet::mapping::MappingConfig;
    use monet::report::fmt_bytes;

    let hc = parse_device_pool(spec).unwrap_or_else(|| usage());
    let wanted: Vec<&str> = match args.workload.as_str() {
        "both" => vec!["resnet18", "gpt2"],
        "resnet18" => vec!["resnet18"],
        "gpt2" => vec!["gpt2"],
        _ => usage(),
    };
    // per-workload journal subdirectories: both workloads enumerate the
    // same placement space (same point ids → same journal digest), so they
    // must not share one journal file
    let cfg = |series: &str| SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        use_cache: !args.no_cache,
        cache_dir: args.cache_dir.clone(),
        cache_cap: args.cache_cap,
        run_dir: run_subdir(args, &format!("cluster-hetero/{series}")),
        resume: args.resume,
        prune: !args.no_prune,
        ..Default::default()
    };
    // the uniform extremes the mixed front is measured against: latency vs
    // the slowest-fabric class, energy vs the hungriest class
    let lat_class = hc
        .classes
        .iter()
        .min_by_key(|c| c.tier.rank())
        .map(|c| c.name.clone())
        .unwrap_or_else(|| usage());
    let en_class = hc
        .classes
        .iter()
        .max_by(|a, b| a.energy_scale.total_cmp(&b.energy_scale))
        .map(|c| c.name.clone())
        .unwrap_or_else(|| usage());
    // same microbatch options as the homogeneous space, so the two modes
    // of the `cluster` command explore consistent pipelines
    let microbatches = monet::dse::ClusterSpace::default_space(hc.total_devices()).microbatches;
    for name in wanted {
        eprintln!(
            "cluster DSE [hetero]: {name} training, batch {}, pool {} (stage placements enumerated)...",
            args.batch,
            hc.label()
        );
        let builder: &(dyn Fn(usize) -> TrainingGraph + Sync) = if name == "resnet18" {
            &cluster_resnet18_builder
        } else {
            &cluster_gpt2_builder
        };
        let out = hetero_search(&hc, &microbatches, args.batch, builder, &cfg(name), progress);
        println!(
            "\n[{name} | {}] {} deployment points evaluated in {:.2}s",
            hc.label(),
            out.rows.len(),
            out.secs
        );
        if out.skipped > 0 {
            println!(
                "bound pruning: {} of {} points skipped ({:.1}%) — front unchanged (--no-prune for every row)",
                out.skipped,
                out.n_points,
                out.skipped as f64 / out.n_points.max(1) as f64 * 100.0
            );
        }
        print_cache_stats("cluster", &out.cache);
        report_run_health(&format!("cluster [{name}]"), out.resumed, &out.failures)?;
        let facts = front_factorizations(&out);
        println!(
            "4-objective Pareto front (latency, energy, mem/device, devices): {} points, {} distinct dp/pp/tp factorizations",
            out.front.len(),
            facts.len()
        );
        let mut front_rows: Vec<&ClusterRow> =
            out.front.iter().map(|&i| &out.rows[i]).collect();
        front_rows.sort_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles));
        println!(
            "{:<44} {:>13} {:>13} {:>11} {:>12}",
            "deployment (placement)", "latency (cyc)", "energy (pJ)", "mem/device", "comm (B)"
        );
        for r in front_rows.iter().take(16) {
            println!(
                "{:<44} {:>13.3e} {:>13.3e} {:>11} {:>12.3e}",
                r.label,
                r.latency_cycles,
                r.energy_pj,
                fmt_bytes(r.per_device_mem_bytes),
                r.comm_bytes
            );
        }
        if front_rows.len() > 16 {
            println!("  ... {} more front points", front_rows.len() - 16);
        }
        let best_lat = out
            .rows
            .iter()
            .filter(|r| placed_only_on(r, &lat_class))
            .map(|r| r.latency_cycles)
            .fold(f64::INFINITY, f64::min);
        let best_en = out
            .rows
            .iter()
            .filter(|r| placed_only_on(r, &en_class))
            .map(|r| r.energy_pj)
            .fold(f64::INFINITY, f64::min);
        println!(
            "uniform extremes: best all-{lat_class} latency {best_lat:.3e} cyc, best all-{en_class} energy {best_en:.3e} pJ"
        );
        match mixed_domination_witness(&out, &lat_class, &en_class) {
            Some(i) => {
                let w = &out.rows[i];
                println!(
                    "mixed-placement witness: {} — {:.3e} cyc (< all-{lat_class}) and {:.3e} pJ (< all-{en_class})",
                    w.label, w.latency_cycles, w.energy_pj
                );
            }
            None => println!(
                "no mixed-placement front point dominates both uniform extremes on this pool"
            ),
        }
    }
    println!("\n(fig5 writes the full row set + placements + front membership as CSV)");
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    use monet::dse::{
        best_latency_factorization, cluster_search, front_factorizations, ClusterRow,
        ClusterSearchOutcome, SweepConfig,
    };
    use monet::figures::{cluster_gpt2_builder, cluster_resnet18_builder, cluster_setup};
    use monet::parallelism::LinkTier;
    use monet::report::fmt_bytes;

    if let Some(spec) = args.device_classes.clone() {
        return cmd_cluster_hetero(args, &spec);
    }

    let wanted: Vec<&str> = match args.workload.as_str() {
        "both" => vec!["resnet18", "gpt2"],
        "resnet18" => vec!["resnet18"],
        "gpt2" => vec!["gpt2"],
        _ => usage(),
    };
    // shared with figures::fig5_cluster_pareto so the command and the
    // figure always model the same space on the same hardware
    let (space, accel, mapping) = cluster_setup(args.devices);
    let top_devices = *space.device_counts.last().unwrap_or(&1);
    // per-workload journal subdirectories — both workloads sweep the same
    // space (same point ids → same journal digest)
    let cfg = |series: &str| SweepConfig {
        mapping,
        use_cache: !args.no_cache,
        cache_dir: args.cache_dir.clone(),
        cache_cap: args.cache_cap,
        run_dir: run_subdir(args, &format!("cluster/{series}")),
        resume: args.resume,
        prune: !args.no_prune,
        ..Default::default()
    };
    for name in wanted {
        eprintln!(
            "cluster DSE: {name} training, batch {}, device counts {:?}, tiers {:?}...",
            args.batch,
            space.device_counts,
            space.tiers.iter().map(|t| t.as_str()).collect::<Vec<_>>()
        );
        // the canonical fig5 workload builders, so `cluster` and `fig5`
        // can never drift apart on what they model
        let out: ClusterSearchOutcome = if name == "resnet18" {
            let b = &cluster_resnet18_builder;
            cluster_search(&space, args.batch, b, &accel, &cfg(name), progress)
        } else {
            let b = &cluster_gpt2_builder;
            cluster_search(&space, args.batch, b, &accel, &cfg(name), progress)
        };
        println!(
            "\n[{name}] {} deployment points evaluated in {:.2}s",
            out.rows.len(),
            out.secs
        );
        if out.skipped > 0 {
            println!(
                "bound pruning: {} of {} points skipped ({:.1}%) — front unchanged (--no-prune for every row)",
                out.skipped,
                out.n_points,
                out.skipped as f64 / out.n_points.max(1) as f64 * 100.0
            );
        }
        print_cache_stats("cluster", &out.cache);
        report_run_health(&format!("cluster [{name}]"), out.resumed, &out.failures)?;
        let facts = front_factorizations(&out);
        println!(
            "4-objective Pareto front (latency, energy, mem/device, devices): {} points, {} distinct dp/pp/tp factorizations",
            out.front.len(),
            facts.len()
        );
        let mut front_rows: Vec<&ClusterRow> =
            out.front.iter().map(|&i| &out.rows[i]).collect();
        front_rows.sort_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles));
        println!(
            "{:<34} {:>13} {:>13} {:>11} {:>12}",
            "deployment", "latency (cyc)", "energy (pJ)", "mem/device", "comm (B)"
        );
        for r in front_rows.iter().take(16) {
            println!(
                "{:<34} {:>13.3e} {:>13.3e} {:>11} {:>12.3e}",
                r.label,
                r.latency_cycles,
                r.energy_pj,
                fmt_bytes(r.per_device_mem_bytes),
                r.comm_bytes
            );
        }
        if front_rows.len() > 16 {
            println!("  ... {} more front points", front_rows.len() - 16);
        }
        println!("latency optimum at {top_devices} devices, per link tier:");
        for tier in LinkTier::all() {
            if let Some((dp, pp, tp)) =
                best_latency_factorization(&out.rows, tier, top_devices)
            {
                println!("  {:<10} dp{dp} pp{pp} tp{tp}", tier.as_str());
            }
        }
    }
    println!("\n(fig5 writes the full row set + front membership as CSV)");
    Ok(())
}

/// `ga-cluster`: the NSGA-II deployment search for pools past the
/// exhaustive-enumeration wall. The block-fallback enumeration is
/// evaluated as the journaled backbone and head-to-head baseline; the GA
/// then evolves full factorization + placement genomes the fallback
/// never visits.
fn cmd_ga_cluster(args: &Args) -> Result<()> {
    use monet::autodiff::TrainingGraph;
    use monet::dse::{ga_cluster_search, ClusterRow, ClusterSpace, SweepConfig};
    use monet::figures::{cluster_gpt2_builder, cluster_resnet18_builder};
    use monet::mapping::MappingConfig;
    use monet::report::fmt_bytes;

    let spec = match &args.device_classes {
        Some(s) => s.clone(),
        None => {
            eprintln!("error: ga-cluster requires --device-classes (the pool to search)");
            std::process::exit(2);
        }
    };
    let hc = parse_device_pool(&spec).unwrap_or_else(|| usage());
    let wanted: Vec<&str> = match args.workload.as_str() {
        "both" => vec!["resnet18", "gpt2"],
        "resnet18" => vec!["resnet18"],
        "gpt2" => vec!["gpt2"],
        _ => usage(),
    };
    // same microbatch options as the enumerating cluster command, so the
    // GA searches the exact space the enumeration would
    let microbatches = ClusterSpace::default_space(hc.total_devices()).microbatches;
    for name in wanted {
        let ga: GaConfig<monet::ga::DeploymentGenome> =
            GaConfig { population: args.pop, generations: args.gens, ..Default::default() };
        let cfg = SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            use_cache: !args.no_cache,
            cache_dir: args.cache_dir.clone(),
            cache_cap: args.cache_cap,
            run_dir: run_subdir(args, &format!("ga-cluster/{name}")),
            resume: args.resume,
            prune: !args.no_prune,
            ..Default::default()
        };
        eprintln!(
            "ga-cluster: {name} training, batch {}, pool {} (pop {}, gens {})...",
            args.batch,
            hc.label(),
            args.pop,
            args.gens
        );
        let builder: &(dyn Fn(usize) -> TrainingGraph + Sync) = if name == "resnet18" {
            &cluster_resnet18_builder
        } else {
            &cluster_gpt2_builder
        };
        let out =
            ga_cluster_search(&hc, &microbatches, args.batch, builder, name, &ga, &cfg, progress);
        println!(
            "\n[{name} | {}] {} points visited ({} backbone + {} GA) of {} enumerable ({:.2}%) in {:.2}s",
            hc.label(),
            out.evaluated,
            out.evaluated - out.stats.evaluated,
            out.stats.evaluated,
            out.enumerated,
            out.evaluated as f64 / out.enumerated.max(1) as f64 * 100.0,
            out.secs
        );
        if out.skipped > 0 {
            println!(
                "bound pruning: {} backbone point(s) skipped — ranking unchanged (--no-prune to evaluate them)",
                out.skipped
            );
        }
        println!(
            "GA: {} generation(s), {} offspring produced, {} evaluated, {} memo hits, {} repaired ({:.1}% repair rate){}",
            out.stats.generations,
            out.stats.produced,
            out.stats.evaluated,
            out.stats.memo_hits,
            out.stats.repaired,
            out.stats.repair_rate() * 100.0,
            if out.ga_resumed { " — resumed from the GA journal" } else { "" }
        );
        print_cache_stats("backbone", &out.cache);
        print_cache_stats("ga", &out.ga_cache);
        monet::figures::write_ga_cluster_csv(&args.out, name, &out)?;
        println!("rows → {}/ga_cluster_front_{name}.csv", args.out.display());
        report_run_health(&format!("ga-cluster [{name}]"), out.resumed, &out.failures)?;
        println!(
            "4-objective front over backbone ∪ GA: {} points (block-fallback front: {} points, every one weakly dominated)",
            out.rows.len(),
            out.fallback_front.len()
        );
        let mut front_rows: Vec<&ClusterRow> = out.rows.iter().collect();
        front_rows.sort_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles));
        println!(
            "{:<44} {:>13} {:>13} {:>11} {:>12}",
            "deployment (placement)", "latency (cyc)", "energy (pJ)", "mem/device", "comm (B)"
        );
        for r in front_rows.iter().take(16) {
            println!(
                "{:<44.44} {:>13.3e} {:>13.3e} {:>11} {:>12.3e}",
                r.label,
                r.latency_cycles,
                r.energy_pj,
                fmt_bytes(r.per_device_mem_bytes),
                r.comm_bytes
            );
        }
        if front_rows.len() > 16 {
            println!("  ... {} more front points", front_rows.len() - 16);
        }
        // head-to-head: how much of the baseline front the GA strictly beat
        let improved = out
            .fallback_front
            .iter()
            .filter(|fb| {
                let fo = fb.objectives().to_vec();
                out.rows.iter().any(|r| {
                    let ro = r.objectives().to_vec();
                    ro.iter().zip(&fo).all(|(a, b)| a <= b)
                        && ro.iter().zip(&fo).any(|(a, b)| a < b)
                })
            })
            .count();
        println!(
            "head-to-head: {improved}/{} block-fallback front rows strictly dominated by a GA front member",
            out.fallback_front.len()
        );
    }
    Ok(())
}

fn cmd_fig9(args: &Args) -> Result<()> {
    eprintln!("FuseMax sweep (Table III, stride {})...", args.stride);
    let run_dir = run_subdir(args, "fig9");
    let sweep = figures::fig9_fusemax_sweep_cfg(
        args.stride,
        !args.no_cache,
        args.cache_dir.as_deref(),
        args.cache_cap,
        run_dir.as_deref(),
        args.resume,
        Some(&args.out),
        progress,
    );
    render_sweep("Fig 9: GPT-2 on FuseMax", &sweep.rows);
    print_cache_stats("sweep", &sweep.cache);
    println!("rows: {} → {}/fig9_fusemax_sweep.csv", sweep.rows.len(), args.out.display());
    report_run_health("fig9", sweep.resumed, &sweep.failures)
}

fn cmd_fig10(args: &Args) -> Result<()> {
    let rows = figures::fig10_fusion_strategies(Some(&args.out));
    let labels: Vec<String> =
        rows.iter().map(|r| format!("{} ({} groups)", r.strategy, r.n_groups)).collect();
    let lat: Vec<f64> = rows.iter().map(|r| r.latency_cycles).collect();
    let en: Vec<f64> = rows.iter().map(|r| r.energy_pj).collect();
    println!("{}", ascii_bars("Fig 10: latency (cycles)", &labels, &lat, 40));
    println!("{}", ascii_bars("Fig 10: energy (pJ)", &labels, &en, 40));
    Ok(())
}

fn cmd_fig11(args: &Args) -> Result<()> {
    let rows = figures::fig11_checkpoint_linearity(Some(&args.out));
    let labels: Vec<String> = rows.iter().map(|r| r.scenario.clone()).collect();
    let lat: Vec<f64> = rows.iter().map(|r| r.latency_delta).collect();
    let en: Vec<f64> = rows.iter().map(|r| r.energy_delta).collect();
    println!("{}", ascii_bars("Fig 11: Δ latency vs save-all (cycles)", &labels, &lat, 36));
    println!("{}", ascii_bars("Fig 11: Δ energy vs save-all (pJ)", &labels, &en, 36));
    let (gl, ge) = figures::linearity_gap(&rows);
    println!(
        "non-additivity gap: latency {:.1}%, energy {:.1}% (a linear MILP model assumes 0%)",
        gl * 100.0,
        ge * 100.0
    );
    Ok(())
}

fn cmd_fig12(args: &Args) -> Result<()> {
    eprintln!("NSGA-II checkpointing (pop {}, gens {})...", args.pop, args.gens);
    let ga = GaConfig { population: args.pop, generations: args.gens, ..Default::default() };
    let cache_dir = if args.no_cache { None } else { args.cache_dir.as_deref() };
    if cache_dir.is_some() {
        eprintln!("  (cache lifecycle on: cost cache + GA warm-start persisted)");
    }
    let run_dir = run_subdir(args, "fig12");
    if let Some(rd) = &run_dir {
        eprintln!(
            "  (crash-safety on: per-generation checkpoints journaled to {}{})",
            rd.display(),
            if args.resume { ", resuming from the last intact one" } else { "" }
        );
    }
    let (rows, _tg) = figures::fig12_checkpoint_ga_cached(
        &ga,
        cache_dir,
        args.cache_cap,
        run_dir.as_deref(),
        args.resume,
        Some(&args.out),
    );
    println!("Fig 12: Pareto front (ResNet-18 training, Adam, batch 1, 224²)");
    println!("{:>10} {:>14} {:>12} {:>12}", "mem saved", "stored (MiB16)", "Δlatency", "Δenergy");
    for r in &rows {
        println!(
            "{:>9.1}% {:>14.1} {:>11.2}% {:>11.2}%",
            r.memory_saving * 100.0,
            r.stored_mb_fp16,
            r.latency_overhead * 100.0,
            r.energy_overhead * 100.0
        );
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    use monet::autodiff::{build_training_graph, TrainOptions};
    use monet::fusion::{fuse, FusionConstraints};
    use monet::hardware::presets::EdgeTpuParams;
    use monet::mapping::MappingConfig;
    use monet::report::ascii_gantt;
    use monet::scheduler::schedule;
    use monet::workload::models::resnet18;
    use monet::workload::op::{Optimizer, Phase};

    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = EdgeTpuParams::baseline().build();
    let p = fuse(&tg.graph, &FusionConstraints::default());
    let r = schedule(&tg.graph, &p, &accel, &MappingConfig::edge_tpu_default());

    // phase mark per group (dominant member phase)
    let mark = |gid: usize| -> char {
        let mut counts = [0usize; 4];
        for &n in &p.groups[gid] {
            counts[monet::scheduler::phase_index(tg.graph.node(n).phase)] += 1;
        }
        ['F', 'B', 'U', 'R'][(0..4).max_by_key(|&i| counts[i]).unwrap()]
    };
    let rows: Vec<(usize, f64, f64, char)> = r
        .timeline
        .iter()
        .map(|t| (t.core, t.start, t.finish, mark(t.group)))
        .collect();
    println!(
        "{}",
        ascii_gantt(
            "ResNet-18 training iteration on the baseline Edge TPU (F=fwd B=bwd U=update)",
            &rows,
            accel.cores.len(),
            r.latency_cycles,
            100
        )
    );
    println!(
        "makespan {:.3e} cycles, energy {:.3e} pJ, {} fused groups, utilization {:.1}%",
        r.latency_cycles,
        r.energy_pj,
        r.n_groups,
        r.utilization() * 100.0
    );
    monet::report::write_csv(
        args.out.join("schedule_timeline.csv"),
        "group,core,gang,start_cycles,finish_cycles,energy_pj,phase",
        r.timeline.iter().map(|t| {
            vec![
                t.group.to_string(),
                t.core.to_string(),
                t.gang.to_string(),
                format!("{:.1}", t.start),
                format!("{:.1}", t.finish),
                format!("{:.3e}", t.energy_pj),
                mark(t.group).to_string(),
            ]
        }),
    )?;
    let _ = Phase::Forward;
    println!("timeline CSV: {}/schedule_timeline.csv", args.out.display());
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    use monet::autodiff::{build_training_graph, TrainOptions};
    use monet::dse::{search, DesignPoint, SweepConfig};
    use monet::mapping::MappingConfig;
    use monet::workload::models::resnet18;
    use monet::workload::op::Optimizer;

    let fwd = resnet18(1, 32, 10);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let points = DesignPoint::edge_space(args.stride);
    let cfg = SweepConfig {
        mapping: MappingConfig::edge_tpu_default(),
        use_cache: !args.no_cache,
        cache_dir: args.cache_dir.clone(),
        cache_cap: args.cache_cap,
        run_dir: run_subdir(args, "search"),
        resume: args.resume,
        ..Default::default()
    };
    // the AOT Pallas kernel if artifacts exist, native twin otherwise
    let rt = Runtime::new(&args.artifacts).ok();
    let kernel = rt.as_ref().and_then(|r| CostKernel::load(r).ok());
    eprintln!(
        "searching {} Edge-TPU configs for ResNet-18 training ({} prefilter)...",
        points.len(),
        if kernel.is_some() { "AOT Pallas/PJRT" } else { "native" }
    );
    let out = search(&points, &fwd, &tg.graph, &cfg, kernel.as_ref(), 0.1);
    println!(
        "prefilter: {} → {} survivors in {:.2}s; detailed scheduling in {:.2}s",
        out.n_points, out.n_survivors, out.prefilter_secs, out.detail_secs
    );
    print_cache_stats("search", &out.cache);
    println!("\ntop configurations (training latency):");
    println!("{:<44} {:>13} {:>13} {:>7}", "config", "latency (cyc)", "energy (pJ)", "util");
    for r in out.rows.iter().take(10) {
        println!(
            "{:<44} {:>13.3e} {:>13.3e} {:>6.1}%",
            r.label,
            r.latency_cycles,
            r.energy_pj,
            r.utilization * 100.0
        );
    }
    println!("\nPareto front: {} configs", out.front.len());
    report_run_health("search", out.resumed, &out.failures)
}

fn cmd_ablation(args: &Args) -> Result<()> {
    eprintln!("MILP budget sweep + NSGA-II (pop {}, gens {})...", args.pop, args.gens);
    let ga = GaConfig { population: args.pop, generations: args.gens, ..Default::default() };
    let rows = figures::milp_vs_ga_ablation(&ga, Some(&args.out));
    println!("{:>7} {:>10} {:>11} {:>11}", "source", "mem saved", "Δ latency", "Δ energy");
    for r in &rows {
        println!(
            "{:>7} {:>9.1}% {:>10.2}% {:>10.2}%",
            r.source,
            r.memory_saving * 100.0,
            r.latency_overhead * 100.0,
            r.energy_overhead * 100.0
        );
    }
    let frac = figures::milp_dominated_fraction(&rows);
    println!(
        "\n{:.0}% of MILP plans are Pareto-dominated by GA plans when evaluated under the\n\
         true fused-layer pipeline — the §V-B1 linear-model inadequacy, quantified.",
        frac * 100.0
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::new(&args.artifacts).context("PJRT runtime")?;
    eprintln!("platform: {}; loading gpt2_{} artifacts...", rt.platform(), args.config);
    let mut runner = Gpt2Runner::load(&rt, &args.config)?;
    let m = runner.meta.clone();
    println!(
        "tiny GPT-2: {} params, vocab {}, seq {}, batch {}, {} layers",
        m.num_params, m.vocab, m.seq, m.batch, m.n_layer
    );
    let mut corpus = Corpus::synthetic(m.vocab, 64 * 1024, 42);
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut losses = vec![];
    for step in 1..=args.steps {
        let tokens = corpus.next_batch(m.batch, m.seq + 1);
        let loss = runner.step(&tokens)?;
        if first.is_none() {
            first = Some(loss);
        }
        losses.push(loss as f64);
        if step % 20 == 0 || step == 1 {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed();
    let final_loss = *losses.last().unwrap();
    println!(
        "\ntrained {} steps in {:.1?} ({:.1} ms/step); loss {:.3} → {:.3}",
        args.steps,
        dt,
        dt.as_secs_f64() * 1e3 / args.steps as f64,
        first.unwrap(),
        final_loss
    );
    monet::report::write_csv(
        args.out.join("e2e_train_loss.csv"),
        "step,loss",
        losses.iter().enumerate().map(|(i, l)| vec![(i + 1).to_string(), format!("{l:.5}")]),
    )?;
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use monet::dse::{accel_to_cfg, graph_to_layers};
    use monet::runtime::cost_eval_native;
    use monet::workload::models::resnet18;

    let rt = Runtime::new(&args.artifacts)?;
    let kernel = CostKernel::load(&rt)?;
    let g = resnet18(1, 32, 10);
    let layers = graph_to_layers(&g);
    let cfgs: Vec<_> = monet::hardware::presets::EdgeTpuParams::space_strided(37)
        .into_iter()
        .map(|p| accel_to_cfg(&p.build()))
        .collect();
    let hlo = kernel.eval(&cfgs, &layers)?;
    let native = cost_eval_native(&cfgs, &layers);
    let mut max_rel = 0f64;
    for (a, b) in hlo.iter().zip(&native) {
        let rel = ((a.cycles - b.cycles).abs() / b.cycles.max(1.0)) as f64;
        max_rel = max_rel.max(rel);
    }
    println!(
        "cost kernel parity: {} configs, max relative cycle error {:.2e} (HLO/PJRT vs native rust)",
        cfgs.len(),
        max_rel
    );
    if max_rel > 1e-4 {
        bail!("AOT kernel diverges from the native model");
    }
    println!("validate OK");
    Ok(())
}

fn cmd_info() -> Result<()> {
    use monet::autodiff::{build_training_graph, TrainOptions};
    use monet::workload::models::{gpt2, resnet18, resnet50, Gpt2Config};
    use monet::workload::op::Optimizer;
    for (name, g) in [
        ("resnet18/32", resnet18(1, 32, 10)),
        ("resnet18/224", resnet18(1, 224, 1000)),
        ("resnet50/224", resnet50(1, 224, 1000)),
        ("gpt2-small(fig9)", gpt2(figures::fig9_gpt2_config())),
        ("gpt2-tiny", gpt2(Gpt2Config::tiny())),
    ] {
        let tg = build_training_graph(
            &g,
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        );
        println!("{name:<18} fwd: {:<46} train: {}", g.summary(), tg.graph.summary());
    }
    println!(
        "\nEdge TPU space: {} configs (Table II); FuseMax space: {} configs (Table III)",
        monet::hardware::presets::EdgeTpuParams::space().len(),
        monet::hardware::presets::FuseMaxParams::space().len()
    );
    Ok(())
}

/// `monet serve`: boot the resident optimizer daemon and block until a
/// graceful `POST /shutdown` drains the queue and persists the cache.
fn cmd_serve(args: &Args) -> Result<()> {
    use monet::serve::{ServeConfig, Server};
    let cfg = ServeConfig {
        addr: format!("127.0.0.1:{}", args.port),
        serve_workers: args.serve_workers,
        queue_cap: args.queue,
        use_cache: !args.no_cache,
        cache_dir: args.cache_dir.clone(),
        cache_cap: args.cache_cap,
        checkpoint_every: args.checkpoint_every,
    };
    let server = Server::bind(cfg).context("binding the serve listener")?;
    // the smoke test and the worked README example scrape this line for
    // the ephemeral port, so its shape is load-bearing
    println!("serving on http://{}", server.local_addr());
    server.run().context("running the serve daemon")?;
    eprintln!("serve: graceful shutdown complete (queue drained, snapshot persisted)");
    Ok(())
}

/// `monet query`: answer one serve-API request body as a one-shot run.
/// Prints exactly the bytes a warm daemon would return for the same
/// body — the reference side of the serving bit-identity bar.
fn cmd_query(args: &Args) -> Result<()> {
    let Some(path) = &args.request else {
        bail!("query requires --request FILE (a serve-API JSON request body)");
    };
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("reading request body {}", path.display()))?;
    let opts = monet::serve::OneShotOpts {
        use_cache: !args.no_cache,
        cache_dir: args.cache_dir.clone(),
        cache_cap: args.cache_cap,
    };
    match monet::serve::one_shot(&body, &opts) {
        // the response is newline-terminated already; print byte-for-byte
        Ok(resp) => {
            print!("{resp}");
            Ok(())
        }
        Err(e) => bail!("query failed ({}): {}", e.status, e.message),
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    std::fs::create_dir_all(&args.out).ok();
    match args.cmd.as_str() {
        "fig1" | "fig8" => cmd_fig1(&args),
        "fig3" => cmd_fig3(&args),
        "fig5" => cmd_fig5(&args),
        "fig9" => cmd_fig9(&args),
        "fig10" => cmd_fig10(&args),
        "fig11" => cmd_fig11(&args),
        "fig12" => cmd_fig12(&args),
        "all" => {
            cmd_fig1(&args)?;
            cmd_fig3(&args)?;
            cmd_fig5(&args)?;
            cmd_fig9(&args)?;
            cmd_fig10(&args)?;
            cmd_fig11(&args)?;
            cmd_fig12(&args)
        }
        "schedule" => cmd_schedule(&args),
        "search" => cmd_search(&args),
        "cluster" => cmd_cluster(&args),
        "ga-cluster" => cmd_ga_cluster(&args),
        "ablation" => cmd_ablation(&args),
        "train" => cmd_train(&args),
        "validate" => cmd_validate(&args),
        "info" => cmd_info(),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        _ => usage(),
    }
}

#[cfg(test)]
mod tests {
    use super::USAGE;

    /// `docs/CLI.md` is the human-readable CLI reference; this pins it to
    /// `usage()` so the two cannot drift: every command and flag of the
    /// usage text must be documented, and every flag the reference
    /// mentions must actually exist. (`include_str!` additionally makes a
    /// missing reference file a build error.)
    #[test]
    fn cli_reference_covers_usage() {
        let md = include_str!("../../docs/CLI.md");
        let token =
            |s: &str| s.trim_matches(|c: char| !(c.is_alphanumeric() || c == '-')).to_string();
        // a flag is two dashes followed by a word — this keeps markdown
        // table separators ("---") and em-dash runs out of the flag sets
        let is_flag = |w: &String| {
            w.starts_with("--") && w.chars().nth(2).is_some_and(|c| c.is_alphanumeric())
        };

        let usage_flags: std::collections::BTreeSet<String> =
            USAGE.split_whitespace().map(token).filter(is_flag).collect();
        let md_flags: std::collections::BTreeSet<String> =
            md.split_whitespace().map(token).filter(is_flag).collect();
        assert!(!usage_flags.is_empty());
        assert_eq!(usage_flags, md_flags, "docs/CLI.md flags drift from usage()");

        // commands: the first token of each entry line of the COMMANDS
        // section (entry lines are indented exactly two spaces;
        // continuation lines are indented further)
        let commands: Vec<&str> = {
            let body = USAGE.split("COMMANDS").nth(1).expect("COMMANDS section");
            let body = body.split("OPTIONS").next().expect("OPTIONS section");
            body.lines()
                .filter(|l| l.starts_with("  ") && !l.starts_with("   "))
                .filter_map(|l| l.trim().split_whitespace().next())
                .collect()
        };
        assert!(commands.contains(&"cluster") && commands.contains(&"fig5"));
        for cmd in &commands {
            assert!(
                md.contains(&format!("`{cmd}`")),
                "docs/CLI.md is missing command `{cmd}`"
            );
        }
    }

    /// The unified `dse::engine` audit of the cache/GA flag surface
    /// (ISSUE 5 satellite): flags consumed by a handler must list that
    /// command in their usage entry, and flags a path ignores must say
    /// so. Pins the two findings so they cannot regress: `--pop`/`--gens`
    /// are read by `ablation` as well as `fig12`, and the heterogeneous
    /// `cluster --device-classes` path derives the cluster size from the
    /// pool, ignoring `--devices`.
    #[test]
    fn usage_flag_applicability_matches_the_handlers() {
        let entry = |flag: &str| -> &str {
            let start = USAGE.find(flag).expect(flag);
            let rest = &USAGE[start..];
            // an entry runs until the next "  --" option line
            let end = rest[2..].find("\n  --").map(|i| i + 2).unwrap_or(rest.len());
            &rest[..end]
        };
        assert!(entry("--pop N").contains("ablation"), "--pop is read by cmd_ablation");
        assert!(entry("--gens N").contains("ablation"), "--gens is read by cmd_ablation");
        assert!(
            entry("--devices N").contains("Ignored by cluster\n                  --device-classes"),
            "the hetero cluster path ignores --devices; usage() must say so"
        );
    }
}
