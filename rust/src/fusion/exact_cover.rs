//! Exact-cover integer program (paper §V-A eq. at the end of the section):
//!
//!   minimize Σ_g x_g   s.t.   Σ_{g ∋ i} x_g = 1  ∀ nodes i
//!
//! Minimizing the number of selected subgraphs maximizes fusion. The paper
//! uses an IP solver "with a heuristic goal to approximate the best
//! solution"; we implement a branch-and-bound over the exact-cover
//! structure with a greedy warm start, bitset row representation, and a
//! node-expansion budget after which the incumbent (always feasible —
//! singletons guarantee a cover) is returned.

use crate::workload::graph::NodeId;

/// Compact bitset over node ids.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    pub fn new(n: usize) -> Self {
        BitSet { words: vec![0; n.div_ceil(64)] }
    }
    pub fn from_nodes(n: usize, nodes: &[NodeId]) -> Self {
        let mut b = BitSet::new(n);
        for &x in nodes {
            b.set(x);
        }
        b
    }
    #[inline]
    pub fn set(&mut self, i: usize) {
        self.words[i / 64] |= 1 << (i % 64);
    }
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        self.words[i / 64] >> (i % 64) & 1 == 1
    }
    #[inline]
    pub fn intersects(&self, o: &BitSet) -> bool {
        self.words.iter().zip(&o.words).any(|(a, b)| a & b != 0)
    }
    #[inline]
    pub fn union_with(&mut self, o: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&o.words) {
            *a |= b;
        }
    }
    #[inline]
    pub fn subtract(&mut self, o: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&o.words) {
            *a &= !b;
        }
    }
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
    pub fn first_set(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(wi * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }

    /// True iff self has a bit set outside `allowed` (i.e. the candidate
    /// would re-cover an already covered node).
    fn intersects_complement(&self, allowed: &BitSet) -> bool {
        self.words.iter().zip(&allowed.words).any(|(a, b)| a & !b != 0)
    }
}

/// Solve min-cardinality exact cover. `candidates` must include every
/// singleton so a cover always exists. Returns indices into `candidates`.
pub fn solve_exact_cover(
    n_nodes: usize,
    candidates: &[Vec<NodeId>],
    node_budget: usize,
) -> Vec<usize> {
    let rows: Vec<BitSet> =
        candidates.iter().map(|c| BitSet::from_nodes(n_nodes, c)).collect();

    // candidates covering each node, largest-first (greedy & branching order)
    let mut covering: Vec<Vec<usize>> = vec![vec![]; n_nodes];
    for (ci, cand) in candidates.iter().enumerate() {
        for &n in cand {
            covering[n].push(ci);
        }
    }
    for list in covering.iter_mut() {
        list.sort_by_key(|&ci| std::cmp::Reverse(candidates[ci].len()));
    }

    // ---- greedy warm start: repeatedly take the largest disjoint cand ----
    let greedy = {
        let mut uncovered = BitSet::new(n_nodes);
        for i in 0..n_nodes {
            uncovered.set(i);
        }
        let mut chosen: Vec<usize> = vec![];
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        order.sort_by_key(|&ci| std::cmp::Reverse(candidates[ci].len()));
        while let Some(node) = uncovered.first_set() {
            // take the largest candidate covering `node` that fits
            let pick = covering[node]
                .iter()
                .copied()
                .find(|&ci| {
                    candidates[ci].iter().all(|&x| uncovered.get(x))
                })
                .expect("singletons guarantee cover");
            uncovered.subtract(&rows[pick]);
            chosen.push(pick);
        }
        chosen
    };

    // ---- branch & bound ----
    struct Ctx<'a> {
        rows: &'a [BitSet],
        candidates: &'a [Vec<NodeId>],
        covering: &'a [Vec<usize>],
        best: Vec<usize>,
        best_len: usize,
        budget: usize,
        max_cand: usize,
    }

    fn rec(ctx: &mut Ctx, uncovered: &BitSet, chosen: &mut Vec<usize>) {
        if ctx.budget == 0 {
            return;
        }
        ctx.budget -= 1;
        let remaining = uncovered.count();
        if remaining == 0 {
            if chosen.len() < ctx.best_len {
                ctx.best_len = chosen.len();
                ctx.best = chosen.clone();
            }
            return;
        }
        // lower bound: need at least ceil(remaining / max_cand_size) more
        let lb = chosen.len() + remaining.div_ceil(ctx.max_cand);
        if lb >= ctx.best_len {
            return;
        }
        let node = uncovered.first_set().unwrap();
        // branch over candidates covering `node` (largest first), only
        // those disjoint from the current cover
        let opts: Vec<usize> = ctx.covering[node]
            .iter()
            .copied()
            .filter(|&ci| !ctx.rows[ci].intersects_complement(uncovered))
            .collect();
        for ci in opts {
            let mut next = uncovered.clone();
            next.subtract(&ctx.rows[ci]);
            chosen.push(ci);
            rec(ctx, &next, chosen);
            chosen.pop();
            if ctx.budget == 0 {
                return;
            }
        }
        let _ = ctx.candidates;
    }

    let max_cand = candidates.iter().map(|c| c.len()).max().unwrap_or(1);
    let mut ctx = Ctx {
        rows: &rows,
        candidates,
        covering: &covering,
        best_len: greedy.len(),
        best: greedy,
        budget: node_budget,
        max_cand,
    };
    let mut uncovered = BitSet::new(n_nodes);
    for i in 0..n_nodes {
        uncovered.set(i);
    }
    let mut chosen = vec![];
    rec(&mut ctx, &uncovered, &mut chosen);
    ctx.best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover_ok(n: usize, cands: &[Vec<usize>], sol: &[usize]) -> bool {
        let mut cnt = vec![0usize; n];
        for &ci in sol {
            for &x in &cands[ci] {
                cnt[x] += 1;
            }
        }
        cnt.iter().all(|&c| c == 1)
    }

    fn with_singletons(n: usize, mut cands: Vec<Vec<usize>>) -> Vec<Vec<usize>> {
        for i in 0..n {
            cands.push(vec![i]);
        }
        cands
    }

    #[test]
    fn trivial_chain() {
        let cands = with_singletons(4, vec![vec![0, 1], vec![2, 3], vec![1, 2]]);
        let sol = solve_exact_cover(4, &cands, 10_000);
        assert!(cover_ok(4, &cands, &sol));
        assert_eq!(sol.len(), 2); // {01},{23}
    }

    #[test]
    fn forced_singletons() {
        let cands = with_singletons(3, vec![]);
        let sol = solve_exact_cover(3, &cands, 1000);
        assert!(cover_ok(3, &cands, &sol));
        assert_eq!(sol.len(), 3);
    }

    #[test]
    fn overlap_forces_choice() {
        // {0,1,2} and {2,3} overlap at 2: optimum = {0,1,2} + {3}
        let cands = with_singletons(4, vec![vec![0, 1, 2], vec![2, 3]]);
        let sol = solve_exact_cover(4, &cands, 10_000);
        assert!(cover_ok(4, &cands, &sol));
        assert_eq!(sol.len(), 2);
    }

    #[test]
    fn finds_optimal_on_random_instances() {
        // brute-force cross-check on small instances
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..20 {
            let n = 8;
            let mut cands: Vec<Vec<usize>> = vec![];
            for _ in 0..10 {
                let len = 2 + rng.usize(3);
                let start = rng.usize(n - len + 1);
                cands.push((start..start + len).collect());
            }
            let cands = with_singletons(n, cands);
            let sol = solve_exact_cover(n, &cands, 1_000_000);
            assert!(cover_ok(n, &cands, &sol));
            // exhaustive optimum by DP over subsets
            let full = (1usize << n) - 1;
            let mut dp = vec![usize::MAX; 1 << n];
            dp[0] = 0;
            for mask in 0..=full {
                if dp[mask] == usize::MAX {
                    continue;
                }
                for c in &cands {
                    let cm: usize = c.iter().map(|&x| 1usize << x).sum();
                    if mask & cm == 0 {
                        let nm = mask | cm;
                        dp[nm] = dp[nm].min(dp[mask] + 1);
                    }
                }
            }
            assert_eq!(sol.len(), dp[full], "not optimal");
        }
    }

    #[test]
    fn budget_exhaustion_still_returns_feasible() {
        let cands = with_singletons(6, vec![vec![0, 1, 2], vec![3, 4, 5], vec![1, 2, 3]]);
        let sol = solve_exact_cover(6, &cands, 1); // essentially greedy only
        assert!(cover_ok(6, &cands, &sol));
    }

    #[test]
    fn bitset_ops() {
        let mut a = BitSet::new(130);
        a.set(0);
        a.set(129);
        assert!(a.get(129) && !a.get(64));
        assert_eq!(a.count(), 2);
        assert_eq!(a.first_set(), Some(0));
        let b = BitSet::from_nodes(130, &[129]);
        assert!(a.intersects(&b));
        a.subtract(&b);
        assert!(!a.get(129));
    }
}
