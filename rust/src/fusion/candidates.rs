//! Candidate fused-subgraph enumeration (paper §V-A): BFS from each node,
//! growing connected convex subgraphs, with the paper's constraints applied
//! as backtracking filters:
//!
//! * memory: Σ m_i,c ≤ M_c on the target core class,
//! * intra-core tiling: all fixed tiling factors pairwise divide,
//! * operator type: ≤ 3 convolutions and ≤ 2 GEMMs per subgraph,
//! * single external output: Σ o_v ≤ 1 (no intermediate tensor may be
//!   required by another subgraph → no off-chip round trip).

use std::collections::HashSet;

use crate::workload::graph::{Graph, NodeId};
use crate::workload::op::OpKind;

#[derive(Debug, Clone, Copy)]
pub struct FusionConstraints {
    /// Maximum subgraph size (the BFS length limit; Fig 10's Limit4..8).
    pub max_len: usize,
    /// Local memory bound of the target core class (bytes).
    pub mem_budget: u64,
    /// Intra-core tiling divisor used for the per-node memory estimate.
    pub tiling: usize,
    pub max_convs: usize,
    pub max_gemms: usize,
    /// Enforce the operator-type constraint (the paper ablates it off for
    /// the "optimal without operator constraints" comparison in §V-A2).
    pub op_type_constraint: bool,
    /// Cap on candidates enumerated per seed node (tractability guard).
    pub per_seed_cap: usize,
}

impl Default for FusionConstraints {
    fn default() -> Self {
        FusionConstraints {
            max_len: 6,
            mem_budget: 2 << 20,
            tiling: 4,
            max_convs: 3,
            max_gemms: 2,
            op_type_constraint: true,
            per_seed_cap: 64,
        }
    }
}

/// Per-node memory requirement m_i,c: weights resident + one streamed tile
/// of the output (out_bytes / T).
pub fn node_mem(g: &Graph, n: NodeId, tiling: usize) -> u64 {
    let k = &g.node(n).kind;
    k.weight_elems() * g.elem_bytes + (k.out_elems() * g.elem_bytes) / tiling.max(1) as u64
}

/// Intra-core tiling factor T_i of a node. MAC/pool ops tile their outer
/// spatial loop; elementwise ops are flexible (0 = wildcard, compatible
/// with everything).
pub fn node_tiling(kind: &OpKind) -> usize {
    use crate::workload::op::LoopDim;
    if kind.is_elementwise() {
        return 0;
    }
    let dims = kind.loop_dims();
    let get = |d: LoopDim| dims.iter().find(|(k, _)| *k == d).map(|(_, s)| *s).unwrap_or(0);
    let spatial = get(LoopDim::Oy).max(get(LoopDim::M)).max(1);
    // largest power of two ≤ spatial, capped at 16: the scheduler streams
    // that many output tiles through local memory
    let mut t = 1;
    while t * 2 <= spatial && t < 16 {
        t *= 2;
    }
    t
}

fn tilings_compatible(ts: &[usize]) -> bool {
    for (i, &a) in ts.iter().enumerate() {
        for &b in &ts[i + 1..] {
            if a == 0 || b == 0 {
                continue; // wildcard
            }
            if a % b != 0 && b % a != 0 {
                return false;
            }
        }
    }
    true
}

/// External-output count: nodes with at least one successor outside `set`.
/// The sink node of the whole graph counts as zero (its output is the
/// final result, not an intermediate).
fn external_outputs(g: &Graph, set: &[NodeId]) -> usize {
    let s: HashSet<NodeId> = set.iter().copied().collect();
    set.iter()
        .filter(|&&n| g.out_degree(n) > 0 && g.out_edges(n).any(|e| !s.contains(&e.dst)))
        .count()
}

/// A subgraph is convex iff no path between two members leaves the set.
/// For BFS-grown downward-closed-frontier sets the cheap sufficient check
/// is: every member's predecessors are either all outside (entry) or the
/// inside ones form no "hole". We verify convexity exactly with a bounded
/// reachability check (sets are ≤ max_len nodes, graphs are modest).
fn is_convex(g: &Graph, members: &HashSet<NodeId>) -> bool {
    // for each edge leaving the set from node u, no descendant outside may
    // re-enter the set; bounded DFS from each exit edge
    // audit:allow(DT02): the result is an OR over independent per-(u,edge) hole checks, so the boolean is iteration-order-invariant
    for &u in members {
        for e in g.out_edges(u) {
            if members.contains(&e.dst) {
                continue;
            }
            // walk forward from the outside node; if we re-enter set → hole
            let mut stack = vec![e.dst];
            let mut seen = HashSet::new();
            while let Some(x) = stack.pop() {
                if !seen.insert(x) {
                    continue;
                }
                for s in g.successors(x) {
                    if members.contains(&s) {
                        return false;
                    }
                    if seen.len() < 256 {
                        stack.push(s);
                    }
                }
            }
        }
    }
    true
}

/// Check all constraints on a candidate node set.
pub fn satisfies(g: &Graph, set: &[NodeId], c: &FusionConstraints) -> bool {
    if set.len() > c.max_len {
        return false;
    }
    let mem: u64 = set.iter().map(|&n| node_mem(g, n, c.tiling)).sum();
    if mem > c.mem_budget {
        return false;
    }
    if c.op_type_constraint {
        let convs = set.iter().filter(|&&n| g.node(n).kind.is_conv()).count();
        let gemms = set.iter().filter(|&&n| g.node(n).kind.is_gemm()).count();
        if convs > c.max_convs || gemms > c.max_gemms {
            return false;
        }
    }
    let ts: Vec<usize> = set.iter().map(|&n| node_tiling(&g.node(n).kind)).collect();
    if !tilings_compatible(&ts) {
        return false;
    }
    if external_outputs(g, set) > 1 {
        return false;
    }
    let hs: HashSet<NodeId> = set.iter().copied().collect();
    is_convex(g, &hs)
}

/// Enumerate candidate fused subgraphs: BFS growth from every seed node,
/// adding reachable successors/predecessors of the current set, pruning by
/// the *monotone* constraints (size, memory, op-type) during growth and by
/// the full constraint set on emission. Deduplicated globally.
pub fn enumerate_candidates(g: &Graph, c: &FusionConstraints) -> Vec<Vec<NodeId>> {
    let mut seen: HashSet<Vec<NodeId>> = HashSet::new();
    let mut out: Vec<Vec<NodeId>> = vec![];

    // singletons are always valid cover fallbacks
    for n in 0..g.len() {
        let set = vec![n];
        if seen.insert(set.clone()) {
            out.push(set);
        }
    }

    for seed in 0..g.len() {
        let mut emitted = 0usize;
        // frontier of partial sets to grow
        let mut stack: Vec<Vec<NodeId>> = vec![vec![seed]];
        let mut local_seen: HashSet<Vec<NodeId>> = HashSet::new();
        while let Some(cur) = stack.pop() {
            if emitted >= c.per_seed_cap {
                break;
            }
            // growth moves: successors of members (BFS over the DAG)
            let curset: HashSet<NodeId> = cur.iter().copied().collect();
            let mut nexts: Vec<NodeId> = vec![];
            for &n in &cur {
                for s in g.successors(n) {
                    if !curset.contains(&s) && !nexts.contains(&s) {
                        nexts.push(s);
                    }
                }
            }
            for nx in nexts {
                if cur.len() + 1 > c.max_len {
                    continue;
                }
                let mut grown = cur.clone();
                grown.push(nx);
                grown.sort_unstable();
                if !local_seen.insert(grown.clone()) {
                    continue;
                }
                // monotone prunes (backtracking)
                let mem: u64 = grown.iter().map(|&n| node_mem(g, n, c.tiling)).sum();
                if mem > c.mem_budget {
                    continue;
                }
                if c.op_type_constraint {
                    let convs =
                        grown.iter().filter(|&&n| g.node(n).kind.is_conv()).count();
                    let gemms =
                        grown.iter().filter(|&&n| g.node(n).kind.is_gemm()).count();
                    if convs > c.max_convs || gemms > c.max_gemms {
                        continue;
                    }
                }
                if satisfies(g, &grown, c) && seen.insert(grown.clone()) {
                    out.push(grown.clone());
                    emitted += 1;
                }
                stack.push(grown);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{mlp, resnet18};

    #[test]
    fn tilings_compatibility_rules() {
        assert!(tilings_compatible(&[4, 8, 16]));
        assert!(tilings_compatible(&[0, 4, 0]));
        assert!(!tilings_compatible(&[4, 6]));
        assert!(tilings_compatible(&[]));
    }

    #[test]
    fn singletons_always_present() {
        let g = mlp(1, 16, 32, 2, 8);
        let cands = enumerate_candidates(&g, &FusionConstraints::default());
        for n in 0..g.len() {
            assert!(cands.contains(&vec![n]));
        }
    }

    #[test]
    fn chain_candidates_grow_up_to_limit() {
        let g = mlp(1, 16, 32, 3, 8);
        let c = FusionConstraints { max_len: 3, ..Default::default() };
        let cands = enumerate_candidates(&g, &c);
        assert!(cands.iter().any(|s| s.len() == 2));
        assert!(cands.iter().any(|s| s.len() == 3));
        assert!(cands.iter().all(|s| s.len() <= 3));
    }

    #[test]
    fn memory_budget_prunes() {
        let g = resnet18(1, 32, 10);
        let tight = FusionConstraints { mem_budget: 1 << 10, ..Default::default() };
        let cands = enumerate_candidates(&g, &tight);
        // with a 1 KiB budget almost nothing besides singletons survives;
        // singletons are kept as fallback regardless
        assert!(cands.iter().filter(|s| s.len() > 1).count() < 10);
    }

    #[test]
    fn op_type_constraint_limits_convs() {
        let g = resnet18(1, 32, 10);
        let c = FusionConstraints { max_len: 8, per_seed_cap: 200, ..Default::default() };
        for cand in enumerate_candidates(&g, &c) {
            let convs = cand.iter().filter(|&&n| g.node(n).kind.is_conv()).count();
            assert!(convs <= 3);
        }
    }

    #[test]
    fn single_external_output_enforced() {
        let g = resnet18(1, 32, 10);
        let c = FusionConstraints::default();
        for cand in enumerate_candidates(&g, &c) {
            assert!(external_outputs(&g, &cand) <= 1, "cand={cand:?}");
        }
    }

    #[test]
    fn all_candidates_satisfy_full_constraints() {
        let g = mlp(2, 32, 64, 3, 10);
        let c = FusionConstraints::default();
        for cand in enumerate_candidates(&g, &c) {
            assert!(satisfies(&g, &cand, &c), "cand={cand:?}");
        }
    }
}
