//! Constraint-based layer-fusion solver (DESIGN.md S9, paper §V-A):
//! partition the operator graph into fused subgraphs whose intermediate
//! tensors never leave a core's local memory — the paper's main lever
//! against the off-chip traffic that dominates training energy.
//!
//! Two stages: [`candidates`] enumerates connected fusable subgraphs by
//! BFS under the §V-A constraints (subgraph size, operator types, memory
//! footprint, single entry/exit), and [`exact_cover`] picks a
//! minimum-cardinality exact cover of the graph from them. [`fuse`] runs
//! both; [`fuse_greedy`] is the fast approximation used inside sweeps and
//! the GA, and [`fuse_manual_conv_bn_relu`] reproduces the hand pattern
//! the paper compares against (Fig 10). The fusion decision depends only
//! on the workload graph and the constraints — never on the accelerator —
//! which is why sweeps hoist it out of their per-design-point loop.

pub mod candidates;
pub mod exact_cover;

use crate::scheduler::Partition;
use crate::workload::graph::Graph;

pub use candidates::{enumerate_candidates, node_mem, node_tiling, FusionConstraints};
pub use exact_cover::solve_exact_cover;

/// End-to-end fusion: enumerate candidates under the constraints, solve the
/// exact cover minimizing subgraph count, return the partition.
pub fn fuse(g: &Graph, constraints: &FusionConstraints) -> Partition {
    let cands = enumerate_candidates(g, constraints);
    let chosen = solve_exact_cover(g.len(), &cands, 200_000);
    let groups = chosen.into_iter().map(|ci| cands[ci].clone()).collect();
    let p = Partition::from_groups(groups);
    debug_assert!(p.validate(g).is_ok());
    p
}

/// Cheap greedy fusion used inside GA inner loops (paper notes the COP
/// solve is too expensive to run per GA individual): walk the topo order,
/// greedily absorbing each node into its predecessor's group while all
/// constraints still hold.
///
/// §Perf: this is the hot path of both the sweep-partition preparation and
/// every GA plan evaluation, so all constraint checks are incremental —
/// per-group running sums for memory/op-type, pairwise tiling checked only
/// against the new member, and convexity via precomputed ancestor bitsets
/// (adding `n` to group G is convex iff no outside predecessor of `n`
/// descends from G).
pub fn fuse_greedy(g: &Graph, constraints: &FusionConstraints) -> Partition {
    let n_nodes = g.len();
    let words = n_nodes.div_ceil(64);

    // ancestor bitsets, one pass in topo order: anc(n) = ∪ anc(p) ∪ {p}
    let topo = g.topo_order();
    let mut anc = vec![0u64; n_nodes * words];
    for &n in &topo {
        // collect into a scratch row to appease the borrow checker
        let mut row = vec![0u64; words];
        for p in g.predecessors(n) {
            row[p / 64] |= 1 << (p % 64);
            let src = &anc[p * words..(p + 1) * words];
            for (r, s) in row.iter_mut().zip(src) {
                *r |= s;
            }
        }
        anc[n * words..(n + 1) * words].copy_from_slice(&row);
    }

    struct GroupState {
        members: Vec<usize>,
        mask: Vec<u64>,
        mem: u64,
        convs: usize,
        gemms: usize,
        tilings: Vec<usize>,
    }

    let mut group_of: Vec<Option<usize>> = vec![None; n_nodes];
    let mut groups: Vec<GroupState> = vec![];

    for &n in &topo {
        let kind = &g.node(n).kind;
        let n_mem = candidates::node_mem(g, n, constraints.tiling);
        let n_tiling = candidates::node_tiling(kind);
        let n_conv = kind.is_conv() as usize;
        let n_gemm = kind.is_gemm() as usize;

        let mut placed = false;
        for p in g.predecessors(n) {
            let Some(gi) = group_of[p] else { continue };
            let gs = &groups[gi];
            // monotone constraints, incrementally
            if gs.members.len() + 1 > constraints.max_len
                || gs.mem + n_mem > constraints.mem_budget
            {
                continue;
            }
            if constraints.op_type_constraint
                && (gs.convs + n_conv > constraints.max_convs
                    || gs.gemms + n_gemm > constraints.max_gemms)
            {
                continue;
            }
            // tiling: new factor must divide-or-be-divided by each member
            if n_tiling != 0
                && gs.tilings.iter().any(|&t| {
                    t != 0 && n_tiling % t != 0 && t % n_tiling != 0
                })
            {
                continue;
            }
            // convexity: every outside predecessor of n must NOT descend
            // from the group (otherwise a path leaves and re-enters)
            let hole = g.predecessors(n).any(|q| {
                group_of[q] != Some(gi)
                    && anc[q * words..(q + 1) * words]
                        .iter()
                        .zip(&gs.mask)
                        .any(|(a, m)| a & m != 0)
            });
            if hole {
                continue;
            }
            // single-external-output: after adding n, members with
            // successors outside {group ∪ n} must number ≤ 1. Group is
            // small (≤ max_len) — check directly.
            let in_new = |x: usize| group_of[x] == Some(gi) || x == n;
            let externals = gs
                .members
                .iter()
                .chain(std::iter::once(&n))
                .filter(|&&m| {
                    g.out_degree(m) > 0 && g.successors(m).any(|s| !in_new(s))
                })
                .count();
            if externals > 1 {
                continue;
            }

            let gs = &mut groups[gi];
            gs.members.push(n);
            gs.mask[n / 64] |= 1 << (n % 64);
            gs.mem += n_mem;
            gs.convs += n_conv;
            gs.gemms += n_gemm;
            gs.tilings.push(n_tiling);
            group_of[n] = Some(gi);
            placed = true;
            break;
        }
        if !placed {
            let mut mask = vec![0u64; words];
            mask[n / 64] |= 1 << (n % 64);
            group_of[n] = Some(groups.len());
            groups.push(GroupState {
                members: vec![n],
                mask,
                mem: n_mem,
                convs: n_conv,
                gemms: n_gemm,
                tilings: vec![n_tiling],
            });
        }
    }
    let p = Partition::from_groups(groups.into_iter().map(|gs| gs.members).collect());
    debug_assert!(p.validate(g).is_ok(), "{:?}", p.validate(g));
    p
}

/// The "Manual" baseline of Fig 10: the hand-designed fusion pattern
/// Stream ships for CNNs — each Conv absorbs its following BatchNorm and
/// ReLU (and a trailing Add when it is the sole consumer); everything else
/// stays layer-by-layer.
pub fn fuse_manual_conv_bn_relu(g: &Graph) -> Partition {
    use crate::workload::op::{EltwiseKind, OpKind};
    let mut assigned = vec![false; g.len()];
    let mut groups: Vec<Vec<usize>> = vec![];
    for n in g.topo_order() {
        if assigned[n] {
            continue;
        }
        let mut grp = vec![n];
        assigned[n] = true;
        if g.node(n).kind.is_conv() {
            // absorb a chain of bn / relu / add with single-consumer links
            let mut cur = n;
            loop {
                let succs: Vec<_> = g.successors(cur).collect();
                if succs.len() != 1 {
                    break;
                }
                let s = succs[0];
                if assigned[s] || g.in_degree(s) != 1 {
                    break;
                }
                let absorb = matches!(
                    g.node(s).kind,
                    OpKind::Norm { .. }
                        | OpKind::Eltwise { kind: EltwiseKind::Relu, .. }
                );
                if !absorb {
                    break;
                }
                grp.push(s);
                assigned[s] = true;
                cur = s;
            }
        }
        groups.push(grp);
    }
    let p = Partition::from_groups(groups);
    debug_assert!(p.validate(g).is_ok());
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::EdgeTpuParams;
    use crate::mapping::MappingConfig;
    use crate::scheduler::schedule;
    use crate::workload::models::{mlp, resnet18};

    #[test]
    fn fuse_covers_exactly() {
        let g = mlp(1, 32, 64, 3, 10);
        let p = fuse(&g, &FusionConstraints::default());
        p.validate(&g).unwrap();
        assert!(p.len() < g.len(), "should fuse something");
    }

    #[test]
    fn greedy_covers_exactly() {
        let g = resnet18(1, 32, 10);
        let p = fuse_greedy(&g, &FusionConstraints::default());
        p.validate(&g).unwrap();
        assert!(p.len() < g.len());
    }

    #[test]
    fn solver_beats_or_matches_greedy_on_group_count() {
        let g = resnet18(1, 32, 10);
        let c = FusionConstraints::default();
        let ip = fuse(&g, &c);
        let gr = fuse_greedy(&g, &c);
        assert!(ip.len() <= gr.len(), "ip={} greedy={}", ip.len(), gr.len());
    }

    #[test]
    fn fusion_improves_schedule_over_layer_by_layer() {
        // the Fig 10 claim, in miniature
        let g = resnet18(1, 32, 10);
        let accel = EdgeTpuParams::baseline().build();
        let cfg = MappingConfig::edge_tpu_default();
        let base = schedule(&g, &Partition::singletons(&g), &accel, &cfg);
        let fused = schedule(&g, &fuse(&g, &FusionConstraints::default()), &accel, &cfg);
        assert!(fused.energy_pj < base.energy_pj);
    }

    #[test]
    fn manual_fusion_groups_conv_bn_relu() {
        let g = resnet18(1, 32, 10);
        let p = fuse_manual_conv_bn_relu(&g);
        p.validate(&g).unwrap();
        // stem conv+bn+relu must be one group of 3
        assert!(p.groups.iter().any(|grp| grp.len() == 3));
        assert!(p.len() < g.len());
    }

    #[test]
    fn larger_limit_never_increases_group_count() {
        let g = mlp(1, 32, 64, 4, 10);
        let c4 = FusionConstraints { max_len: 4, ..Default::default() };
        let c8 = FusionConstraints { max_len: 8, ..Default::default() };
        assert!(fuse(&g, &c8).len() <= fuse(&g, &c4).len());
    }
}
