//! A deliberately tiny HTTP/1.1 codec over `std::io` — just enough for
//! the daemon's JSON API: one request per connection (`Connection:
//! close`), bounded head and body sizes, `Content-Length` bodies only
//! (no chunked encoding). Anything outside that envelope is a
//! structured client error, never a panic.

use std::io::{self, Read, Write};

/// Maximum request head (request line + headers) the codec will buffer.
const MAX_HEAD: usize = 16 * 1024;
/// Maximum request body. Query bodies are tiny; anything near this is a
/// client error.
const MAX_BODY: usize = 1024 * 1024;

/// A parsed request: method, path (query string not split off — the API
/// has no use for one), body bytes.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Why a request could not be read: a transport error (drop the
/// connection) vs a protocol violation (answer with the status).
#[derive(Debug)]
pub enum ReadError {
    Io(io::Error),
    Bad(u16, &'static str),
}

impl From<io::Error> for ReadError {
    fn from(e: io::Error) -> Self {
        ReadError::Io(e)
    }
}

/// Read and parse one request. Bounded: at most [`MAX_HEAD`] head bytes
/// and [`MAX_BODY`] body bytes are ever buffered.
pub fn read_request(stream: &mut impl Read) -> Result<Request, ReadError> {
    // read until the blank line terminating the head
    let mut head = Vec::with_capacity(512);
    let mut byte = [0u8; 1];
    loop {
        let n = stream.read(&mut byte)?;
        if n == 0 {
            return Err(ReadError::Bad(400, "truncated request head"));
        }
        head.push(byte[0]);
        if head.ends_with(b"\r\n\r\n") {
            break;
        }
        if head.len() >= MAX_HEAD {
            return Err(ReadError::Bad(431, "request head too large"));
        }
    }
    let head = std::str::from_utf8(&head).map_err(|_| ReadError::Bad(400, "non-UTF-8 head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().ok_or(ReadError::Bad(400, "empty request line"))?.to_string();
    let path = parts.next().ok_or(ReadError::Bad(400, "missing request path"))?.to_string();

    let mut content_length: usize = 0;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| ReadError::Bad(400, "bad Content-Length"))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(ReadError::Bad(413, "request body too large"));
    }
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).map_err(|_| ReadError::Bad(400, "truncated request body"))?;
    Ok(Request { method, path, body })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one JSON response and flush. Always `Connection: close` — the
/// codec serves exactly one exchange per connection.
pub fn write_response(stream: &mut impl Write, status: u16, body: &str) -> io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        reason(status),
        body.len()
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_post_with_body() {
        let raw = b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let r = read_request(&mut &raw[..]).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/query");
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn parses_a_get_without_body() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let r = read_request(&mut &raw[..]).unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.body.is_empty());
    }

    #[test]
    fn rejects_truncation_and_oversize() {
        assert!(matches!(
            read_request(&mut &b"GET /x HTTP/1.1\r\n"[..]),
            Err(ReadError::Bad(400, _))
        ));
        let raw = b"POST /q HTTP/1.1\r\nContent-Length: 9999999999\r\n\r\n";
        assert!(matches!(read_request(&mut &raw[..]), Err(ReadError::Bad(413, _))));
        let raw = b"POST /q HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        assert!(matches!(read_request(&mut &raw[..]), Err(ReadError::Bad(400, _))));
    }

    #[test]
    fn response_has_content_length_and_close() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "{}\n").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 3\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\n{}\n"));
    }
}
