//! The pure core of `monet serve`: request parsing/validation, query
//! execution against a caller-supplied cache handle, and deterministic
//! response rendering. The daemon (`super::Server`) and the one-shot
//! CLI (`monet query`) both call [`answer`] — one code path, so the
//! bit-identity contract between them is structural, not coincidental.
//!
//! Every validation failure is a structured [`ApiError`] (HTTP status +
//! message), never a panic: the daemon must survive arbitrary client
//! input.

use std::path::PathBuf;
use std::sync::Arc;

use crate::autodiff::{build_training_graph, TrainOptions, TrainingGraph};
use crate::dse::{
    cluster_search, ga_cluster_search, hetero_search, pareto_front, run_sweep_stats, ClusterRow,
    DesignPoint, Mode, SharedCache, SweepConfig,
};
use crate::eval::{open_cost_cache, persist_cost_cache, CostCache};
use crate::figures::{cluster_gpt2_builder, cluster_resnet18_builder, cluster_setup};
use crate::ga::{DeploymentGenome, GaConfig};
use crate::mapping::MappingConfig;
use crate::parallelism::{DeviceClass, HeteroCluster};
use crate::util::json::Json;
use crate::workload::models::resnet18;
use crate::workload::op::Optimizer;

/// A structured request failure: an HTTP status plus a human-readable
/// message, rendered as `{"error":{"message":…,"status":…}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub status: u16,
    pub message: String,
}

impl ApiError {
    pub fn bad(message: impl Into<String>) -> ApiError {
        ApiError { status: 400, message: message.into() }
    }

    pub fn with_status(status: u16, message: impl Into<String>) -> ApiError {
        ApiError { status, message: message.into() }
    }

    /// The response body for this error (newline-terminated, like every
    /// response body).
    pub fn render(&self) -> String {
        let j = Json::obj(vec![(
            "error",
            Json::obj(vec![
                ("message", Json::Str(self.message.clone())),
                ("status", Json::Num(self.status as f64)),
            ]),
        )]);
        format!("{j}\n")
    }
}

/// The workload axis shared by the cluster-shaped families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Resnet18,
    Gpt2,
}

impl Workload {
    fn by_name(name: &str) -> Option<Workload> {
        match name {
            "resnet18" => Some(Workload::Resnet18),
            "gpt2" => Some(Workload::Gpt2),
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            Workload::Resnet18 => "resnet18",
            Workload::Gpt2 => "gpt2",
        }
    }

    fn builder(&self) -> &'static (dyn Fn(usize) -> TrainingGraph + Sync) {
        match self {
            Workload::Resnet18 => &cluster_resnet18_builder,
            Workload::Gpt2 => &cluster_gpt2_builder,
        }
    }
}

/// A validated optimization query — one variant per design-space family.
/// Every variant carries `prune` (request key `"prune"`, default `true`):
/// bound-based front pruning skips design points whose roofline lower
/// bound is already dominated. The reported front is bit-identical with
/// it on or off — it is part of the query (not daemon state) so the
/// pure-function-of-the-query response contract holds either way.
#[derive(Debug, Clone)]
pub enum Query {
    /// Single-device accelerator sweep (the fig1 family), training mode.
    Sweep { stride: usize, prune: bool },
    /// Homogeneous cluster deployments (the `cluster` command family).
    Cluster { devices: usize, batch: usize, workload: Workload, prune: bool },
    /// Heterogeneous stage placements (`cluster --device-classes`).
    Hetero {
        pool: HeteroCluster,
        pool_spec: String,
        microbatches: Vec<usize>,
        batch: usize,
        workload: Workload,
        prune: bool,
    },
    /// Past-the-wall deployment GA (the `ga-cluster` command family).
    GaCluster {
        pool: HeteroCluster,
        pool_spec: String,
        microbatches: Vec<usize>,
        batch: usize,
        workload: Workload,
        pop: usize,
        gens: usize,
        seed: u64,
        prune: bool,
    },
}

/// Parse `edge:2,datacenter:2` into a device pool. Shared with the CLI's
/// `--device-classes` flag so the serve API and the command line cannot
/// drift on pool syntax.
pub fn parse_device_pool(spec: &str) -> Option<HeteroCluster> {
    let mut pool = vec![];
    for part in spec.split(',') {
        let (name, count) = part.split_once(':')?;
        let class = DeviceClass::by_name(name.trim())?;
        let count: usize = count.trim().parse().ok()?;
        pool.push((class, count));
    }
    let hc = HeteroCluster::new(pool);
    if hc.total_devices() == 0 {
        return None;
    }
    Some(hc)
}

fn field_usize(
    j: &Json,
    key: &str,
    default: usize,
    min: usize,
    max: usize,
) -> Result<usize, ApiError> {
    let Some(v) = j.get(key) else {
        return Ok(default);
    };
    let n = v
        .as_f64()
        .ok_or_else(|| ApiError::bad(format!("field '{key}' must be a number")))?;
    if n.fract() != 0.0 || n < 0.0 {
        return Err(ApiError::bad(format!("field '{key}' must be a non-negative integer")));
    }
    let n = n as usize;
    if n < min || n > max {
        return Err(ApiError::bad(format!("field '{key}' must be in {min}..={max} (got {n})")));
    }
    Ok(n)
}

fn field_bool(j: &Json, key: &str, default: bool) -> Result<bool, ApiError> {
    match j.get(key) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => Err(ApiError::bad(format!("field '{key}' must be a boolean"))),
    }
}

fn field_workload(j: &Json) -> Result<Workload, ApiError> {
    let Some(v) = j.get("workload") else {
        return Ok(Workload::Resnet18);
    };
    let s = v.as_str().ok_or_else(|| ApiError::bad("field 'workload' must be a string"))?;
    Workload::by_name(s)
        .ok_or_else(|| ApiError::bad(format!("unknown workload '{s}' (expected resnet18|gpt2)")))
}

fn field_pool(j: &Json) -> Result<(HeteroCluster, String), ApiError> {
    let v = j
        .get("device_classes")
        .ok_or_else(|| ApiError::bad("field 'device_classes' is required for this family"))?;
    let spec = v
        .as_str()
        .ok_or_else(|| ApiError::bad("field 'device_classes' must be a string"))?;
    let hc = parse_device_pool(spec).ok_or_else(|| {
        ApiError::bad(format!(
            "bad device pool '{spec}' (expected e.g. 'edge:2,datacenter:1'; \
             classes: edge|server|datacenter)"
        ))
    })?;
    if hc.total_devices() > 512 {
        return Err(ApiError::bad(format!(
            "device pool too large for a serving query: {} devices (max 512)",
            hc.total_devices()
        )));
    }
    Ok((hc, spec.to_string()))
}

fn field_microbatches(j: &Json, pool: &HeteroCluster) -> Result<Vec<usize>, ApiError> {
    let Some(v) = j.get("microbatches") else {
        // the CLI default: the canonical space's microbatch options
        return Ok(crate::dse::ClusterSpace::default_space(pool.total_devices()).microbatches);
    };
    let arr = v
        .as_arr()
        .ok_or_else(|| ApiError::bad("field 'microbatches' must be an array of integers"))?;
    if arr.is_empty() || arr.len() > 8 {
        return Err(ApiError::bad("field 'microbatches' must hold 1..=8 options"));
    }
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let n = v
            .as_f64()
            .ok_or_else(|| ApiError::bad("field 'microbatches' must be an array of integers"))?;
        if n.fract() != 0.0 || n < 1.0 || n > 4096.0 {
            return Err(ApiError::bad("each microbatch option must be an integer in 1..=4096"));
        }
        out.push(n as usize);
    }
    Ok(out)
}

/// Reject unknown keys so a typo'd field name fails loudly instead of
/// silently falling back to its default.
fn check_keys(j: &Json, allowed: &[&str]) -> Result<(), ApiError> {
    if let Json::Obj(m) = j {
        let mut unknown: Vec<&str> =
            m.keys().map(|k| k.as_str()).filter(|k| !allowed.contains(k)).collect();
        unknown.sort_unstable();
        if !unknown.is_empty() {
            return Err(ApiError::bad(format!(
                "unknown field(s) {unknown:?} (allowed: {allowed:?})"
            )));
        }
    }
    Ok(())
}

/// Parse and validate a request body into a [`Query`]. Every failure is
/// a structured 400 — malformed JSON, wrong types, out-of-range values,
/// unknown fields — never a panic.
pub fn parse_query(body: &str) -> Result<Query, ApiError> {
    let j = Json::parse(body).map_err(|e| ApiError::bad(format!("bad JSON: {e}")))?;
    if !matches!(j, Json::Obj(_)) {
        return Err(ApiError::bad("request must be a JSON object"));
    }
    let family = j
        .get("family")
        .ok_or_else(|| ApiError::bad("field 'family' is required"))?
        .as_str()
        .ok_or_else(|| ApiError::bad("field 'family' must be a string"))?;
    match family {
        "sweep" => {
            check_keys(&j, &["family", "stride", "prune"])?;
            Ok(Query::Sweep {
                stride: field_usize(&j, "stride", 20, 1, 10_000)?,
                prune: field_bool(&j, "prune", true)?,
            })
        }
        "cluster" => {
            check_keys(&j, &["family", "devices", "batch", "workload", "prune"])?;
            Ok(Query::Cluster {
                devices: field_usize(&j, "devices", 4, 1, 64)?,
                batch: field_usize(&j, "batch", 4, 1, 4096)?,
                workload: field_workload(&j)?,
                prune: field_bool(&j, "prune", true)?,
            })
        }
        "hetero" => {
            check_keys(
                &j,
                &["family", "device_classes", "microbatches", "batch", "workload", "prune"],
            )?;
            let (pool, pool_spec) = field_pool(&j)?;
            let microbatches = field_microbatches(&j, &pool)?;
            Ok(Query::Hetero {
                pool,
                pool_spec,
                microbatches,
                batch: field_usize(&j, "batch", 4, 1, 4096)?,
                workload: field_workload(&j)?,
                prune: field_bool(&j, "prune", true)?,
            })
        }
        "ga-cluster" => {
            check_keys(
                &j,
                &[
                    "family",
                    "device_classes",
                    "microbatches",
                    "batch",
                    "workload",
                    "pop",
                    "gens",
                    "seed",
                    "prune",
                ],
            )?;
            let (pool, pool_spec) = field_pool(&j)?;
            let microbatches = field_microbatches(&j, &pool)?;
            Ok(Query::GaCluster {
                pool,
                pool_spec,
                microbatches,
                batch: field_usize(&j, "batch", 4, 1, 4096)?,
                workload: field_workload(&j)?,
                pop: field_usize(&j, "pop", 16, 2, 256)?,
                gens: field_usize(&j, "gens", 4, 1, 64)?,
                seed: field_usize(&j, "seed", 0xACAC, 0, (1usize << 53) - 1)? as u64,
                prune: field_bool(&j, "prune", true)?,
            })
        }
        other => Err(ApiError::bad(format!(
            "unknown family '{other}' (expected sweep|cluster|hetero|ga-cluster)"
        ))),
    }
}

/// The per-query sweep config: the caller's resident cache (when any) is
/// attached as a [`SharedCache`], so the engine neither opens nor
/// persists a snapshot — the cache owner controls that lifecycle.
fn base_cfg(mapping: MappingConfig, cache: Option<&Arc<CostCache>>) -> SweepConfig {
    SweepConfig {
        mapping,
        use_cache: cache.is_some(),
        shared_cache: cache.map(|c| SharedCache(c.clone())),
        ..Default::default()
    }
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn cluster_row_json(r: &ClusterRow) -> Json {
    Json::obj(vec![
        ("label", Json::Str(r.label.clone())),
        ("devices", num(r.devices as f64)),
        ("tier", Json::Str(r.tier.as_str().to_string())),
        ("dp", num(r.dp as f64)),
        ("pp", num(r.pp as f64)),
        ("microbatches", num(r.microbatches as f64)),
        ("tp", num(r.tp as f64)),
        ("placement", Json::Str(r.placement.clone())),
        ("latency_cycles", num(r.latency_cycles)),
        ("energy_pj", num(r.energy_pj)),
        ("per_device_mem_bytes", num(r.per_device_mem_bytes as f64)),
        ("comm_bytes", num(r.comm_bytes)),
    ])
}

fn render(j: Json) -> String {
    format!("{j}\n")
}

/// Points whose evaluation panicked are isolated by the engine; a
/// serving query reports them as a structured 500 instead of returning
/// a silently degraded front.
fn check_failures(failures: &[crate::dse::PointFailure]) -> Result<(), ApiError> {
    if let Some(f) = failures.first() {
        return Err(ApiError::with_status(
            500,
            format!(
                "{} point(s) failed during evaluation (first: {} — {})",
                failures.len(),
                f.point_id,
                f.diagnostic
            ),
        ));
    }
    Ok(())
}

/// Answer a validated [`Query`] against an optional resident cache.
///
/// The response is a **pure function of the query** (see the module
/// contract on [`crate::serve`]): no timings, no cache counters, no
/// daemon state — those live on `/stats`. This is what makes a warm
/// daemon answer bit-identical to a cold one-shot run.
///
/// `progress(done, total)` fires as the underlying engine completes
/// points (for `ga-cluster`, over the backbone enumeration phase).
// audit:pure
pub fn answer(
    q: &Query,
    cache: Option<&Arc<CostCache>>,
    progress: &mut dyn FnMut(usize, usize),
) -> Result<String, ApiError> {
    match q {
        Query::Sweep { stride, prune } => {
            let fwd = resnet18(1, 32, 10);
            let tg = build_training_graph(
                &fwd,
                TrainOptions { optimizer: Optimizer::SgdMomentum, include_update: true },
            );
            let points = DesignPoint::edge_space(*stride);
            let mut cfg = base_cfg(MappingConfig::edge_tpu_default(), cache);
            cfg.modes = vec![Mode::Training];
            cfg.prune = *prune;
            let (rows, _stats) =
                run_sweep_stats(&points, &fwd, &tg.graph, &cfg, &mut *progress);
            let front = pareto_front(&rows);
            let front_rows: Vec<Json> = front
                .iter()
                .map(|&i| {
                    let r = &rows[i];
                    Json::obj(vec![
                        ("label", Json::Str(r.label.clone())),
                        ("latency_cycles", num(r.latency_cycles)),
                        ("energy_pj", num(r.energy_pj)),
                        ("peak_dram_bytes", num(r.peak_dram_bytes as f64)),
                        ("utilization", num(r.utilization)),
                    ])
                })
                .collect();
            Ok(render(Json::obj(vec![
                ("family", Json::Str("sweep".into())),
                ("stride", num(*stride as f64)),
                ("points", num(points.len() as f64)),
                ("front", Json::Arr(front_rows)),
            ])))
        }
        Query::Cluster { devices, batch, workload, prune } => {
            let (space, accel, mapping) = cluster_setup(*devices);
            let mut cfg = base_cfg(mapping, cache);
            cfg.prune = *prune;
            let out = cluster_search(&space, *batch, workload.builder(), &accel, &cfg, &mut *progress);
            check_failures(&out.failures)?;
            let front_rows: Vec<Json> =
                out.front.iter().map(|&i| cluster_row_json(&out.rows[i])).collect();
            Ok(render(Json::obj(vec![
                ("family", Json::Str("cluster".into())),
                ("workload", Json::Str(workload.name().into())),
                ("devices", num(*devices as f64)),
                ("batch", num(*batch as f64)),
                ("points", num(out.n_points as f64)),
                ("front", Json::Arr(front_rows)),
            ])))
        }
        Query::Hetero { pool, pool_spec, microbatches, batch, workload, prune } => {
            let mut cfg = base_cfg(MappingConfig::edge_tpu_default(), cache);
            cfg.prune = *prune;
            let out = hetero_search(pool, microbatches, *batch, workload.builder(), &cfg, &mut *progress);
            check_failures(&out.failures)?;
            let front_rows: Vec<Json> =
                out.front.iter().map(|&i| cluster_row_json(&out.rows[i])).collect();
            Ok(render(Json::obj(vec![
                ("family", Json::Str("hetero".into())),
                ("workload", Json::Str(workload.name().into())),
                ("device_classes", Json::Str(pool_spec.clone())),
                ("batch", num(*batch as f64)),
                ("points", num(out.n_points as f64)),
                ("front", Json::Arr(front_rows)),
            ])))
        }
        Query::GaCluster { pool, pool_spec, microbatches, batch, workload, pop, gens, seed, prune } => {
            let mut cfg = base_cfg(MappingConfig::edge_tpu_default(), cache);
            cfg.prune = *prune;
            let ga: GaConfig<DeploymentGenome> = GaConfig {
                population: *pop,
                generations: *gens,
                seed: *seed,
                ..Default::default()
            };
            let out = ga_cluster_search(
                pool,
                microbatches,
                *batch,
                workload.builder(),
                workload.name(),
                &ga,
                &cfg,
                &mut *progress,
            );
            check_failures(&out.failures)?;
            let front_rows: Vec<Json> = out.rows.iter().map(cluster_row_json).collect();
            Ok(render(Json::obj(vec![
                ("family", Json::Str("ga-cluster".into())),
                ("workload", Json::Str(workload.name().into())),
                ("device_classes", Json::Str(pool_spec.clone())),
                ("batch", num(*batch as f64)),
                ("pop", num(*pop as f64)),
                ("gens", num(*gens as f64)),
                ("seed", num(*seed as f64)),
                ("evaluated", num(out.evaluated as f64)),
                ("enumerated", num(out.enumerated as f64)),
                ("generations", num(out.stats.generations as f64)),
                ("fallback_front_size", num(out.fallback_front.len() as f64)),
                ("front", Json::Arr(front_rows)),
            ])))
        }
    }
}

/// Cache flags for a one-shot query (the CLI triple).
#[derive(Debug, Clone, Default)]
pub struct OneShotOpts {
    pub use_cache: bool,
    pub cache_dir: Option<PathBuf>,
    pub cache_cap: usize,
}

/// Answer one request body the way a freshly started daemon would —
/// the CLI `monet query` entry point, and the reference side of the
/// bit-identity pin in `tests/serve.rs`. Opens the cache per the CLI
/// flags, answers through the same [`answer`] path the daemon uses, and
/// persists the snapshot afterwards when a `cache_dir` is set (the
/// one-shot process owns its cache lifecycle, like any CLI command).
pub fn one_shot(body: &str, opts: &OneShotOpts) -> Result<String, ApiError> {
    let q = parse_query(body)?;
    let cache = if opts.use_cache {
        Some(Arc::new(open_cost_cache(opts.cache_dir.as_deref(), opts.cache_cap)))
    } else {
        None
    };
    let resp = answer(&q, cache.as_ref(), &mut |_, _| {})?;
    if let Some(c) = &cache {
        persist_cost_cache(c, opts.cache_dir.as_deref());
    }
    Ok(resp)
}
