//! `monet serve` — DSE-as-a-service: a std-only HTTP/JSON daemon that
//! keeps one warm, bounded, persisted [`CostCache`] resident and
//! answers concurrent optimization queries ("best deployment for model
//! M on pool P under batch B") by building a per-query design space and
//! running it through the existing `dse` engine (ROADMAP item 1;
//! OptDNN ships its optimizer in exactly this HTTP-service-with-CLI-
//! fallback shape).
//!
//! ## Architecture
//!
//! * **One resident cache.** All queries share a single [`CostCache`]
//!   (it is `Sync` and read-lock-hit), attached to every per-query
//!   engine run as a [`crate::dse::SharedCache`] — the engine neither
//!   opens nor persists snapshots; the daemon owns that lifecycle.
//! * **Bounded admission.** Requests enter a bounded queue
//!   (`queue_cap`) drained by a fixed pool of query workers
//!   (`serve_workers`). A full queue is a structured `503`, not an
//!   unbounded pile-up.
//! * **Sync and pollable queries.** `POST /query` blocks until the
//!   answer; `POST /jobs` + `GET /jobs/<id>` is the pollable variant
//!   for long GA queries (progress = engine completion ticks over the
//!   enumeration backbone).
//! * **Snapshot lifecycle.** With `--cache-dir`, the snapshot is
//!   warm-loaded at boot and persisted at exactly two kinds of points,
//!   both serialized by one persist lock: a periodic checkpoint (every
//!   `checkpoint_every` completed queries) and graceful shutdown
//!   (`POST /shutdown`, which stops admission, drains the queue, joins
//!   the workers, persists, and returns from [`Server::run`]).
//! * **Eviction pressure.** Many tenants colliding on one `--cache-cap`
//!   shows up as a rising `evictions` counter on `GET /stats` (the
//!   [`CacheStats`] counters plus daemon counters); results never
//!   change — eviction costs recomputation, not correctness.
//!
//! ## The handler contract (what a query handler may and may NOT read)
//!
//! Mirroring the `Evaluate` purity contract (`dse::engine`): the
//! response to a query must be a **pure function of the request body**
//! (plus the build's constants — model zoo, hardware presets). A
//! handler may not read:
//!
//! * wall-clock time, timings, or anything derived from them;
//! * cache *statistics* or cache *temperature* — cached values are pure
//!   functions of their keys, so hits may make a query faster, never
//!   different;
//! * other queries' state, the queue depth, worker identity, or any
//!   daemon counter (those belong to `/stats` and `/healthz` only);
//! * environment variables or global mutable state.
//!
//! This is what the non-negotiable serving bar rests on: **a query
//! answered by the warm daemon is bit-identical to the same query run
//! as a one-shot CLI command** (`monet query`), pinned in
//! `tests/serve.rs` and exercised end-to-end by the CI `serve-smoke`
//! job.

pub mod api;
pub mod http;

pub use api::{one_shot, parse_device_pool, ApiError, OneShotOpts};

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::eval::{open_cost_cache, persist_cost_cache, CacheStats, CostCache};
use crate::util::json::Json;

/// Daemon knobs (the `monet serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (printed by the CLI).
    pub addr: String,
    /// Query worker threads draining the request queue. Each query
    /// additionally parallelizes internally over the engine's own pool.
    pub serve_workers: usize,
    /// Bounded request-queue capacity; a full queue rejects with 503.
    pub queue_cap: usize,
    /// The resident cache triple — same semantics as every CLI command
    /// (`--no-cache` / `--cache-dir` / `--cache-cap`).
    pub use_cache: bool,
    pub cache_dir: Option<PathBuf>,
    pub cache_cap: usize,
    /// Persist the snapshot every this many completed queries (0 =
    /// only at shutdown). Only meaningful with `cache_dir`.
    pub checkpoint_every: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            serve_workers: 2,
            queue_cap: 64,
            use_cache: true,
            cache_dir: None,
            cache_cap: 0,
            checkpoint_every: 32,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobStatus {
    Queued,
    Running,
    Done,
}

struct JobState {
    status: JobStatus,
    done: usize,
    total: usize,
    result: Option<Result<String, ApiError>>,
}

struct State {
    cfg: ServeConfig,
    cache: Option<Arc<CostCache>>,
    addr: SocketAddr,
    shutdown: AtomicBool,
    queries_done: AtomicU64,
    queries_rejected: AtomicU64,
    jobs: Mutex<HashMap<u64, JobState>>,
    next_job: AtomicU64,
    /// The daemon's single persist point: checkpoint and shutdown
    /// persists serialize here, so at most one snapshot write-out is in
    /// flight per daemon (the tmp+rename in `eval::persist` is
    /// additionally safe under concurrent writers — defense in depth).
    persist_lock: Mutex<()>,
}

impl State {
    fn persist(&self) {
        if let (Some(cache), Some(_)) = (&self.cache, &self.cfg.cache_dir) {
            let _guard = self.persist_lock.lock().unwrap_or_else(|e| e.into_inner());
            persist_cost_cache(cache, self.cfg.cache_dir.as_deref());
        }
    }

    /// Bump the completed-query counter; checkpoint the snapshot on the
    /// configured cadence.
    fn note_done(&self) {
        let done = self.queries_done.fetch_add(1, Ordering::Relaxed) + 1;
        let every = self.cfg.checkpoint_every;
        if every > 0 && done % every == 0 {
            self.persist();
        }
    }

    fn stats_body(&self) -> String {
        let cache = self.cache.as_ref().map(|c| c.stats()).unwrap_or_default();
        let jobs = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        let jobs_open = jobs.values().filter(|j| j.status != JobStatus::Done).count();
        let j = Json::obj(vec![
            ("cache", cache_stats_json(&cache)),
            ("cache_capacity", Json::Num(self.cfg.cache_cap as f64)),
            ("queue_capacity", Json::Num(self.cfg.queue_cap as f64)),
            ("serve_workers", Json::Num(self.cfg.serve_workers as f64)),
            ("queries_done", Json::Num(self.queries_done.load(Ordering::Relaxed) as f64)),
            ("queries_rejected", Json::Num(self.queries_rejected.load(Ordering::Relaxed) as f64)),
            ("jobs_open", Json::Num(jobs_open as f64)),
            ("jobs_total", Json::Num(jobs.len() as f64)),
        ]);
        format!("{j}\n")
    }
}

fn cache_stats_json(s: &CacheStats) -> Json {
    Json::obj(vec![
        ("hits", Json::Num(s.hits as f64)),
        ("misses", Json::Num(s.misses as f64)),
        ("entries", Json::Num(s.entries as f64)),
        ("evictions", Json::Num(s.evictions as f64)),
        ("snapshots_rejected", Json::Num(s.snapshots_rejected as f64)),
        ("snapshots_quarantined", Json::Num(s.snapshots_quarantined as f64)),
        ("io_retries", Json::Num(s.io_retries as f64)),
    ])
}

enum Task {
    /// A blocking `POST /query`: the connection thread waits on `reply`.
    Sync { query: api::Query, reply: mpsc::Sender<Result<String, ApiError>> },
    /// A pollable `POST /jobs` job.
    Job { id: u64, query: api::Query },
}

/// The resident optimizer daemon. [`Server::bind`] opens the listener
/// and warm-loads the cache; [`Server::run`] serves until a graceful
/// `POST /shutdown`, then drains, persists, and returns.
pub struct Server {
    listener: TcpListener,
    state: Arc<State>,
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let cache = if cfg.use_cache {
            Some(Arc::new(open_cost_cache(cfg.cache_dir.as_deref(), cfg.cache_cap)))
        } else {
            None
        };
        let state = Arc::new(State {
            cache,
            addr,
            cfg,
            shutdown: AtomicBool::new(false),
            queries_done: AtomicU64::new(0),
            queries_rejected: AtomicU64::new(0),
            jobs: Mutex::new(HashMap::new()),
            next_job: AtomicU64::new(1),
            persist_lock: Mutex::new(()),
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Serve until graceful shutdown. Blocking; returns after the
    /// request queue has drained, the query workers have joined, and
    /// the final snapshot (with `cache_dir`) has been persisted.
    pub fn run(self) -> io::Result<()> {
        let state = self.state;
        let (tx, rx) = mpsc::sync_channel::<Task>(state.cfg.queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<_> = (0..state.cfg.serve_workers.max(1))
            .map(|_| {
                let state = Arc::clone(&state);
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&state, &rx))
            })
            .collect();

        for stream in self.listener.incoming() {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let state = Arc::clone(&state);
            let tx = tx.clone();
            std::thread::spawn(move || handle_connection(stream, &state, &tx));
        }

        // graceful drain: closing the queue lets each worker finish its
        // current and queued tasks, then exit
        drop(tx);
        for w in workers {
            w.join().ok();
        }
        state.persist();
        Ok(())
    }
}

fn worker_loop(state: &State, rx: &Mutex<mpsc::Receiver<Task>>) {
    loop {
        let task = {
            let rx = rx.lock().unwrap_or_else(|e| e.into_inner());
            rx.recv()
        };
        let Ok(task) = task else { return };
        match task {
            Task::Sync { query, reply } => {
                let res = api::answer(&query, state.cache.as_ref(), &mut |_, _| {});
                reply.send(res).ok();
                state.note_done();
            }
            Task::Job { id, query } => {
                set_job(state, id, |j| j.status = JobStatus::Running);
                let mut tick = 0usize;
                let res = api::answer(&query, state.cache.as_ref(), &mut |done, total| {
                    // throttle map-lock traffic: every 8th tick + the last
                    tick += 1;
                    if tick % 8 == 0 || done == total {
                        set_job(state, id, |j| {
                            j.done = done;
                            j.total = total;
                        });
                    }
                });
                set_job(state, id, |j| {
                    j.status = JobStatus::Done;
                    j.result = Some(res);
                });
                state.note_done();
            }
        }
    }
}

fn set_job(state: &State, id: u64, f: impl FnOnce(&mut JobState)) {
    let mut jobs = state.jobs.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(j) = jobs.get_mut(&id) {
        f(j);
    }
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) {
    http::write_response(stream, status, body).ok();
}

fn respond_err(stream: &mut TcpStream, e: &ApiError) {
    respond(stream, e.status, &e.render());
}

fn handle_connection(mut stream: TcpStream, state: &State, tx: &mpsc::SyncSender<Task>) {
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(http::ReadError::Io(_)) => return,
        Err(http::ReadError::Bad(status, msg)) => {
            respond_err(&mut stream, &ApiError::with_status(status, msg));
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(&mut stream, 200, "{\"status\":\"ok\"}\n"),
        ("GET", "/stats") => {
            let body = state.stats_body();
            respond(&mut stream, 200, &body);
        }
        ("POST", "/shutdown") => {
            respond(&mut stream, 200, "{\"status\":\"shutting down\"}\n");
            state.shutdown.store(true, Ordering::SeqCst);
            // poke the accept loop awake so it observes the flag
            TcpStream::connect(state.addr).ok();
        }
        ("POST", "/query") => handle_query(&mut stream, state, tx, &req.body),
        ("POST", "/jobs") => handle_job_submit(&mut stream, state, tx, &req.body),
        ("GET", path) if path.starts_with("/jobs/") => handle_job_poll(&mut stream, state, path),
        (_, "/healthz" | "/stats" | "/shutdown" | "/query" | "/jobs") => {
            respond_err(&mut stream, &ApiError::with_status(405, "method not allowed"));
        }
        _ => respond_err(&mut stream, &ApiError::with_status(404, "no such endpoint")),
    }
}

fn parse_body_query(state: &State, body: &[u8]) -> Result<api::Query, ApiError> {
    if state.shutdown.load(Ordering::SeqCst) {
        return Err(ApiError::with_status(503, "daemon is shutting down"));
    }
    let body = std::str::from_utf8(body).map_err(|_| ApiError::bad("body must be UTF-8"))?;
    api::parse_query(body)
}

fn handle_query(stream: &mut TcpStream, state: &State, tx: &mpsc::SyncSender<Task>, body: &[u8]) {
    let query = match parse_body_query(state, body) {
        Ok(q) => q,
        Err(e) => return respond_err(stream, &e),
    };
    let (reply_tx, reply_rx) = mpsc::channel();
    match tx.try_send(Task::Sync { query, reply: reply_tx }) {
        Ok(()) => {}
        Err(mpsc::TrySendError::Full(_)) => {
            state.queries_rejected.fetch_add(1, Ordering::Relaxed);
            return respond_err(
                stream,
                &ApiError::with_status(503, "request queue is full; retry later"),
            );
        }
        Err(mpsc::TrySendError::Disconnected(_)) => {
            return respond_err(stream, &ApiError::with_status(503, "daemon is shutting down"));
        }
    }
    match reply_rx.recv() {
        Ok(Ok(resp)) => respond(stream, 200, &resp),
        Ok(Err(e)) => respond_err(stream, &e),
        Err(_) => respond_err(stream, &ApiError::with_status(500, "query worker died")),
    }
}

fn handle_job_submit(
    stream: &mut TcpStream,
    state: &State,
    tx: &mpsc::SyncSender<Task>,
    body: &[u8],
) {
    let query = match parse_body_query(state, body) {
        Ok(q) => q,
        Err(e) => return respond_err(stream, &e),
    };
    let id = state.next_job.fetch_add(1, Ordering::Relaxed);
    {
        let mut jobs = state.jobs.lock().unwrap_or_else(|e| e.into_inner());
        jobs.insert(
            id,
            JobState { status: JobStatus::Queued, done: 0, total: 0, result: None },
        );
    }
    match tx.try_send(Task::Job { id, query }) {
        Ok(()) => {
            let j = Json::obj(vec![
                ("job", Json::Num(id as f64)),
                ("poll", Json::Str(format!("/jobs/{id}"))),
            ]);
            respond(stream, 202, &format!("{j}\n"));
        }
        Err(_) => {
            let mut jobs = state.jobs.lock().unwrap_or_else(|e| e.into_inner());
            jobs.remove(&id);
            drop(jobs);
            state.queries_rejected.fetch_add(1, Ordering::Relaxed);
            respond_err(stream, &ApiError::with_status(503, "request queue is full; retry later"));
        }
    }
}

fn handle_job_poll(stream: &mut TcpStream, state: &State, path: &str) {
    let Ok(id) = path["/jobs/".len()..].parse::<u64>() else {
        return respond_err(stream, &ApiError::bad("bad job id"));
    };
    let jobs = state.jobs.lock().unwrap_or_else(|e| e.into_inner());
    let Some(job) = jobs.get(&id) else {
        drop(jobs);
        return respond_err(stream, &ApiError::with_status(404, "no such job"));
    };
    let status = match job.status {
        JobStatus::Queued => "queued",
        JobStatus::Running => "running",
        JobStatus::Done => "done",
    };
    let mut fields = vec![
        ("job", Json::Num(id as f64)),
        ("status", Json::Str(status.into())),
        ("done", Json::Num(job.done as f64)),
        ("total", Json::Num(job.total as f64)),
    ];
    match &job.result {
        Some(Ok(resp)) => {
            // the response body is itself JSON; re-parse so it nests as a
            // value (cheap — responses are small) rather than a string
            if let Ok(v) = Json::parse(resp) {
                fields.push(("result", v));
            }
        }
        Some(Err(e)) => {
            fields.push((
                "error",
                Json::obj(vec![
                    ("message", Json::Str(e.message.clone())),
                    ("status", Json::Num(e.status as f64)),
                ]),
            ));
        }
        None => {}
    }
    let body = format!("{}\n", Json::obj(fields));
    drop(jobs);
    respond(stream, 200, &body);
}
