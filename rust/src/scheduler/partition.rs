//! Graph partitions: the fused-subgraph structure the scheduler executes.
//! A partition is an exact cover of the node set; each group is one fused
//! subgraph that runs as a unit on one core (or one tensor-parallel gang).

use std::collections::HashMap;

use crate::workload::graph::{Graph, NodeId};

#[derive(Debug, Clone, Default)]
pub struct Partition {
    pub groups: Vec<Vec<NodeId>>,
}

impl Partition {
    /// Layer-by-layer baseline: every node its own group.
    pub fn singletons(g: &Graph) -> Self {
        Partition { groups: (0..g.len()).map(|n| vec![n]).collect() }
    }

    pub fn from_groups(groups: Vec<Vec<NodeId>>) -> Self {
        Partition { groups }
    }

    /// node → group index lookup.
    pub fn group_of(&self, n_nodes: usize) -> Vec<usize> {
        let mut map = vec![usize::MAX; n_nodes];
        for (gi, grp) in self.groups.iter().enumerate() {
            for &n in grp {
                map[n] = gi;
            }
        }
        map
    }

    /// Exact-cover validation: every node in exactly one group, groups
    /// non-empty, and the induced group DAG acyclic (groups must be convex
    /// enough to schedule as units).
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        let mut seen = vec![false; g.len()];
        for (gi, grp) in self.groups.iter().enumerate() {
            if grp.is_empty() {
                return Err(format!("group {gi} is empty"));
            }
            for &n in grp {
                if n >= g.len() {
                    return Err(format!("group {gi} references unknown node {n}"));
                }
                if seen[n] {
                    return Err(format!("node {n} covered twice"));
                }
                seen[n] = true;
            }
        }
        if let Some(n) = seen.iter().position(|&s| !s) {
            return Err(format!("node {n} not covered"));
        }
        // group-DAG acyclicity via Kahn
        let gof = self.group_of(g.len());
        let ng = self.groups.len();
        let mut indeg = vec![0usize; ng];
        let mut adj: HashMap<(usize, usize), ()> = HashMap::new();
        for e in &g.edges {
            let (a, b) = (gof[e.src], gof[e.dst]);
            if a != b && adj.insert((a, b), ()).is_none() {
                indeg[b] += 1;
            }
        }
        let mut queue: Vec<usize> = (0..ng).filter(|&i| indeg[i] == 0).collect();
        let mut seen_g = 0;
        let mut succ: HashMap<usize, Vec<usize>> = HashMap::new();
        // audit:allow(DT02): feeds only the Kahn reachability count (acyclic ⇔ seen_g == ng), which is iteration-order-invariant
        for &(a, b) in adj.keys() {
            succ.entry(a).or_default().push(b);
        }
        while let Some(x) = queue.pop() {
            seen_g += 1;
            if let Some(ss) = succ.get(&x) {
                for &s in ss {
                    indeg[s] -= 1;
                    if indeg[s] == 0 {
                        queue.push(s);
                    }
                }
            }
        }
        if seen_g != ng {
            return Err("group DAG has a cycle (non-convex partition)".into());
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }
    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::mlp;

    #[test]
    fn singletons_validate() {
        let g = mlp(1, 8, 8, 2, 4);
        let p = Partition::singletons(&g);
        assert_eq!(p.len(), g.len());
        p.validate(&g).unwrap();
    }

    #[test]
    fn missing_node_rejected() {
        let g = mlp(1, 8, 8, 2, 4);
        let mut p = Partition::singletons(&g);
        p.groups.pop();
        assert!(p.validate(&g).is_err());
    }

    #[test]
    fn double_cover_rejected() {
        let g = mlp(1, 8, 8, 2, 4);
        let mut p = Partition::singletons(&g);
        p.groups.push(vec![0]);
        assert!(p.validate(&g).is_err());
    }

    #[test]
    fn non_convex_partition_rejected() {
        // chain a->b->c with {a,c} fused but b outside creates a 2-cycle in
        // the group DAG
        let g = mlp(1, 8, 8, 1, 4); // input,fc,relu,fc,loss = 5 nodes chain
        let p = Partition::from_groups(vec![vec![0, 2], vec![1], vec![3], vec![4]]);
        assert!(p.validate(&g).is_err());
    }

    #[test]
    fn contiguous_fusion_validates() {
        let g = mlp(1, 8, 8, 1, 4);
        let p = Partition::from_groups(vec![vec![0], vec![1, 2], vec![3, 4]]);
        p.validate(&g).unwrap();
    }
}
