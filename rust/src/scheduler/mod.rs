//! Layer-fused scheduling (DESIGN.md S8): graph partitions + the
//! event-driven list scheduler over HDA cores and links.
//!
//! [`Partition`] groups graph nodes into fused subgraphs (from the
//! [`crate::fusion`] solver or singletons); [`engine`] list-schedules the
//! group DAG over the accelerator's cores, choosing a core class and
//! tensor-parallel gang width per group by earliest finish time, charging
//! transfers, memory lifetimes and energy along the way. Everything is
//! deterministic — ties broken structurally, never by iteration order —
//! because the DSE and GA layers pin bit-identical results across worker
//! counts and cache settings. [`schedule_with_cache`] is the memoized
//! entry point: the per-(group, core class, gang, env) costs go through
//! the [`crate::eval`] group-cost cache, whose key must widen whenever
//! this module's cost inputs do (the soundness contract in `eval`'s
//! docs).

pub mod engine;
pub mod partition;

pub use engine::{
    phase_index, schedule, schedule_lower_bound, schedule_with_cache, GroupRecord, ScheduleBound,
    ScheduleResult,
};
pub use partition::Partition;
