//! Layer-fused scheduling (DESIGN.md S8): graph partitions + the
//! event-driven list scheduler over HDA cores and links.

pub mod engine;
pub mod partition;

pub use engine::{phase_index, schedule, schedule_with_cache, GroupRecord, ScheduleResult};
pub use partition::Partition;
