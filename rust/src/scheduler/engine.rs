//! The layer-fused event-driven scheduler (DESIGN.md S8): executes a
//! partitioned workload graph on an HDA, producing latency, energy, peak
//! memory and per-core utilization. This is the MONET equivalent of
//! Stream's scheduling engine, extended with training-aware memory
//! lifetimes (saved activations live from forward producer to backward
//! consumer unless the checkpointing pass rewired them).

use std::collections::HashMap;
use std::hash::Hash;

use super::partition::Partition;
use crate::cost::{node_cost, MemEnv, NodeCost, TensorPlacement};
use crate::eval::{hash_core_class, hash_env, hash_group_node, CostCache, StructuralHasher};
use crate::hardware::accelerator::Accelerator;
use crate::hardware::energy;
use crate::mapping::{candidate_cores, dominant_op, MappingConfig};
use crate::workload::graph::{Graph, NodeId};

/// One scheduled group, for timelines and debugging.
#[derive(Debug, Clone)]
pub struct GroupRecord {
    pub group: usize,
    pub core: usize,
    /// Gang width if tensor-parallel (1 = single core).
    pub gang: usize,
    pub start: f64,
    pub finish: f64,
    pub energy_pj: f64,
}

/// Aggregate result of scheduling one graph on one accelerator.
#[derive(Debug, Clone)]
pub struct ScheduleResult {
    /// Makespan in cycles (includes the DRAM-bandwidth serialization bound).
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Peak of dynamically-live DRAM tensor bytes during the run.
    pub peak_dram_bytes: u64,
    /// Total off-chip traffic (bytes).
    pub offchip_bytes: f64,
    /// Per-core busy cycles.
    pub core_busy: Vec<f64>,
    /// Busy cycles by training phase [Forward, Backward, Update, Recompute]
    /// — a training-aware breakdown inference tools cannot produce.
    pub phase_busy: [f64; 4],
    pub n_groups: usize,
    pub timeline: Vec<GroupRecord>,
}

/// Index into `ScheduleResult::phase_busy`.
pub fn phase_index(p: crate::workload::op::Phase) -> usize {
    match p {
        crate::workload::op::Phase::Forward => 0,
        crate::workload::op::Phase::Backward => 1,
        crate::workload::op::Phase::Update => 2,
        crate::workload::op::Phase::Recompute => 3,
    }
}

impl ScheduleResult {
    pub fn utilization(&self) -> f64 {
        if self.latency_cycles <= 0.0 || self.core_busy.is_empty() {
            return 0.0;
        }
        self.core_busy.iter().sum::<f64>()
            / (self.latency_cycles * self.core_busy.len() as f64)
    }
}

/// A cheap, **admissible** lower bound on what [`schedule`] /
/// [`schedule_with_cache`] can report for `graph` on `accel` — the
/// MAC/peak-bandwidth roofline the DSE engine's bound-based pruning rests
/// on.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleBound {
    pub latency_cycles: f64,
    pub energy_pj: f64,
}

/// Admissible roofline lower bound of scheduling `graph` on `accel`.
///
/// ## Admissibility contract
///
/// For **every** partition (fused or singleton), core assignment and gang
/// width the scheduler could pick, the returned `latency_cycles` /
/// `energy_pj` are `<=` the corresponding fields of [`schedule`]'s
/// result (same `graph`, `accel`, `cfg`). The bound is built only from
/// terms the cost model charges unconditionally:
///
/// * every MAC of a conv/GEMM node costs at least
///   `1 / max_over_(core, gang)(peak_macs · spatial_utilization)` busy
///   cycles on whatever core hosts it (`node_cost`'s compute roofline —
///   the fused elementwise rider never rewrites conv/GEMM cycles);
/// * every node moves at least its weight + output bytes through some
///   core's local SRAM (`onchip` in `node_cost` counts them regardless of
///   placement), which also floors the rewritten elementwise-rider
///   cycles;
/// * aggregate busy time over `n` cores floors the makespan at
///   `busy / n`, and the shared DRAM bus floors it at
///   `weight_bytes / offchip_bw` (weights always stream off-chip);
/// * energy counts only the unconditional MAC, register-file, local-SRAM
///   and weight-stream DRAM terms, plus idle leakage over the latency
///   bound itself.
///
/// Anything placement- or schedule-dependent (transfers, spills, input
/// placement) is dropped, never estimated — looser, but provably below
/// the truth. `tests/front_equivalence.rs` property-checks the contract
/// against full evaluation on randomized spaces.
pub fn schedule_lower_bound(
    graph: &Graph,
    accel: &Accelerator,
    cfg: &MappingConfig,
) -> ScheduleBound {
    let n_cores = accel.cores.len().max(1);
    let gang_cap = cfg.tensor_parallel.max(1);
    // node_cost clamps every bandwidth denominator with .max(1.0); mirror
    // that so the floor never exceeds the model's own arithmetic
    let max_onchip_bw =
        accel.cores.iter().map(|c| c.onchip_bw).fold(0.0, f64::max).max(1.0);
    let max_peak = accel.cores.iter().map(|c| c.peak_macs()).max().unwrap_or(1).max(1);
    let mut busy_sum = 0f64; // lower bound on total core-busy cycles
    let mut busy_max = 0f64; // lower bound on any single node's elapsed time
    let mut weight_bytes = 0f64;
    let mut energy = 0f64;
    for node in &graph.nodes {
        let kind = &node.kind;
        let macs = kind.macs() as f64;
        let wb = (kind.weight_elems() * graph.elem_bytes) as f64;
        let ob = (kind.out_elems() * graph.elem_bytes) as f64;
        weight_bytes += wb;
        // weights + outputs always pass the hosting core's local SRAM
        let mem_busy = (wb + ob) / max_onchip_bw;
        let busy = if kind.is_conv() || kind.is_gemm() {
            // compute roofline: the cheapest (core, gang) the scheduler
            // could possibly place this node on
            let mut best = f64::INFINITY;
            for core in &accel.cores {
                for gang in 1..=gang_cap {
                    let eff = (core.peak_macs() as f64
                        * core.spatial_utilization(kind, gang))
                    .max(1.0);
                    best = best.min(macs / eff);
                }
            }
            best.max(mem_busy)
        } else {
            // non-MAC nodes may be rewritten to the fused elementwise
            // rider (pure local-bandwidth cost), so only the SRAM floor
            // is unconditional
            mem_busy
        };
        busy_sum += busy;
        busy_max = busy_max.max(busy / gang_cap as f64);
        energy += macs * energy::E_MAC_PJ
            + 3.0 * macs * graph.elem_bytes as f64 / (max_peak as f64).sqrt().max(1.0)
                * energy::E_RF_PJ_PER_BYTE
            + (wb + ob) * energy::E_LOCAL_PJ_PER_BYTE
            + wb * energy::E_DRAM_PJ_PER_BYTE;
    }
    let latency = (busy_sum / n_cores as f64)
        .max(busy_max)
        .max(weight_bytes / accel.offchip_bw.max(1.0));
    ScheduleBound {
        latency_cycles: latency,
        energy_pj: energy
            + energy::E_IDLE_PJ_PER_CYCLE * latency * accel.cores.len() as f64,
    }
}

/// Identical-core classes (for gang scheduling): cores with equal dataflow
/// and memory are interchangeable.
fn core_classes(accel: &Accelerator) -> Vec<Vec<usize>> {
    let mut classes: Vec<Vec<usize>> = vec![];
    'outer: for c in &accel.cores {
        for class in classes.iter_mut() {
            let rep = &accel.cores[class[0]];
            if rep.dataflow == c.dataflow
                && rep.local_mem_bytes == c.local_mem_bytes
                && rep.onchip_bw == c.onchip_bw
            {
                class.push(c.id);
                continue 'outer;
            }
        }
        classes.push(vec![c.id]);
    }
    classes
}

/// Tensor placements for every node of a group — independent of the core
/// choice, so the scheduler computes them once per group and reuses them
/// across every (core class × gang width) candidate (§Perf hoisting).
fn group_placements(
    graph: &Graph,
    group: &[NodeId],
    gof: &[usize],
    gid: usize,
    has_global: bool,
) -> Vec<TensorPlacement> {
    group
        .iter()
        .map(|&n| {
            let mut place = TensorPlacement::default();
            for e in graph.in_edges(n) {
                if gof[e.src] == gid {
                    place.in_local += e.bytes;
                } else if e.is_activation {
                    // saved activations are long-lived (fwd→bwd): they park
                    // in DRAM, they cannot squat in a small local SRAM for
                    // the whole iteration — the training-memory story of
                    // Fig 3
                    place.in_offchip += e.bytes;
                } else if has_global {
                    place.in_global += e.bytes;
                } else {
                    // short-lived producer→consumer tensor: ships over the
                    // bus into this core's local memory
                    place.in_link += e.bytes;
                }
            }
            let mut any_out = false;
            let mut all_internal = true;
            let mut feeds_backward = false;
            for e in graph.out_edges(n) {
                any_out = true;
                if gof[e.dst] != gid {
                    all_internal = false;
                    if e.is_activation {
                        feeds_backward = true;
                    }
                }
            }
            let all_internal = any_out && all_internal;
            place.out_local = all_internal;
            // sinks (no consumers at all) keep their output in DRAM — a
            // tensor nobody reads never crosses the bus or the global
            // buffer, so `any_out` gates the transfer flags
            place.out_global = any_out && !all_internal && !feeds_backward && has_global;
            place.out_link = any_out && !all_internal && !feeds_backward && !has_global;
            // (otherwise the output goes to DRAM: final outputs, sink
            // outputs, and tensors saved for the backward pass)
            place
        })
        .collect()
}

/// Cost of running a whole fused group sequentially on `core`, honouring
/// intra-group tensor placements (internal edges stay local — the fusion
/// payoff) and tensor parallelism.
// audit:pure
fn group_cost(
    graph: &Graph,
    group: &[NodeId],
    places: &[TensorPlacement],
    core_id: usize,
    accel: &Accelerator,
    env: &MemEnv,
    tp: usize,
) -> NodeCost {
    let core = &accel.cores[core_id];
    let is_mac_core =
        !matches!(core.dataflow, crate::hardware::core::Dataflow::Simd { .. });
    let mut total = NodeCost::default();
    for (&n, place) in group.iter().zip(places) {
        let kind = &graph.node(n).kind;
        let mut c = node_cost(kind, core, place, env, tp, graph.elem_bytes);
        // Fused elementwise riders: inside a multi-node subgraph on a MAC
        // core, elementwise/norm ops process tiles as they stream out of
        // the array (the fused-layer pipeline of §II-C2) — they cost local
        // bandwidth, not a serialised pass over the underutilised array.
        // Energy is unchanged (the operations still happen).
        if group.len() > 1 && is_mac_core && !(kind.is_conv() || kind.is_gemm()) {
            c.cycles = c.onchip_bytes / (tp.max(1) as f64) / core.onchip_bw.max(1.0);
        }
        total.accumulate(&c);
        total.utilization = total.utilization.max(c.utilization);
    }
    total
}

/// Schedule `graph` partitioned by `partition` onto `accel`.
///
/// List scheduling over the group DAG: each group is placed on the core (or
/// tensor-parallel gang of identical MAC cores) minimizing its finish time,
/// among the two best-affinity core classes. Inter-group tensors pay a
/// transfer latency over the interconnect (or global buffer) and DRAM
/// energy when cores differ. The final makespan is additionally lower-
/// bounded by total-offchip-bytes / DRAM bandwidth (shared-bus contention).
pub fn schedule(
    graph: &Graph,
    partition: &Partition,
    accel: &Accelerator,
    cfg: &MappingConfig,
) -> ScheduleResult {
    schedule_with_cache(graph, partition, accel, cfg, None)
}

/// [`schedule`] with an optional shared group-cost memo (`eval::CostCache`).
///
/// With `Some(cache)`, every `group_cost` evaluation is keyed on its full
/// structural input (see `eval` module docs for the soundness contract) and
/// looked up before being computed, so sweeps/GAs sharing one cache compute
/// each unique (group, core class, gang, env) cost once. Results are
/// bit-identical to the uncached path: the cache stores the exact
/// `NodeCost` the pure computation produced.
pub fn schedule_with_cache(
    graph: &Graph,
    partition: &Partition,
    accel: &Accelerator,
    cfg: &MappingConfig,
    cache: Option<&CostCache>,
) -> ScheduleResult {
    debug_assert!(partition.validate(graph).is_ok());
    let ng = partition.groups.len();
    let gof = partition.group_of(graph.len());
    let env = MemEnv {
        offchip_bw: accel.offchip_bw,
        global_bw: accel.global_buffer_bw,
        global_energy_pj: energy::E_GLOBAL_PJ_PER_BYTE,
        link_bw: accel.interconnect.link_bw,
        link_energy_pj: accel.interconnect.link_energy_pj + energy::E_LOCAL_PJ_PER_BYTE,
    };
    // schedule-wide prefix of the memo key: environment + element width
    let base_hash = cache.map(|_| {
        let mut h = StructuralHasher::new();
        hash_env(&mut h, &env, graph.elem_bytes);
        h
    });

    // ---- group DAG ----
    let mut indeg = vec![0usize; ng];
    let mut gsucc: Vec<Vec<(usize, u64)>> = vec![vec![]; ng]; // (dst group, bytes)
    let mut gpred: Vec<Vec<(usize, u64)>> = vec![vec![]; ng]; // (src group, bytes)
    {
        // one contribution per (source tensor, consumer group): a tensor
        // read by k nodes of one remote group crosses the bus once, not k
        // times — the same per-tensor dedup the DRAM-lifetime accounting
        // below applies (integer sums, so HashMap order is irrelevant)
        let mut tensor_bytes: HashMap<(usize, usize), u64> = HashMap::new(); // (src node, dst group)
        for e in &graph.edges {
            let (a, b) = (gof[e.src], gof[e.dst]);
            if a != b {
                let t = tensor_bytes.entry((e.src, b)).or_insert(0);
                *t = (*t).max(e.bytes);
            }
        }
        let mut pair_bytes: HashMap<(usize, usize), u64> = HashMap::new();
        // audit:allow(DT02): commutative integer += into `pair_bytes`, which is itself sorted before the order-sensitive f64 work below
        for (&(src, b), &bytes) in &tensor_bytes {
            *pair_bytes.entry((gof[src], b)).or_insert(0) += bytes;
        }
        // deterministic successor order (HashMap iteration order varies
        // per instance, and the f64 transfer-energy accumulation below is
        // order-sensitive at the bit level)
        let mut pairs: Vec<((usize, usize), u64)> =
            pair_bytes.into_iter().collect();
        pairs.sort_unstable_by_key(|&(k, _)| k);
        for ((a, b), bytes) in pairs {
            gsucc[a].push((b, bytes));
            gpred[b].push((a, bytes));
            indeg[b] += 1;
        }
    }

    // topological order over groups (deterministic: smallest id first)
    let mut order: Vec<usize> = vec![];
    {
        let mut q: std::collections::BinaryHeap<std::cmp::Reverse<usize>> = (0..ng)
            .filter(|&i| indeg[i] == 0)
            .map(std::cmp::Reverse)
            .collect();
        // consume `indeg` in place — it has no readers after this walk
        while let Some(std::cmp::Reverse(x)) = q.pop() {
            order.push(x);
            for &(s, _) in &gsucc[x] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    q.push(std::cmp::Reverse(s));
                }
            }
        }
        assert_eq!(order.len(), ng, "partition group DAG has a cycle");
    }

    let classes = core_classes(accel);
    // core id → class index, computed once per schedule (replaces the
    // per-group `classes.iter().find(...)` linear scans)
    let mut class_of = vec![0usize; accel.cores.len()];
    for (ci, cl) in classes.iter().enumerate() {
        for &c in cl {
            class_of[c] = ci;
        }
    }
    let mut core_free = vec![0.0f64; accel.cores.len()];
    let mut core_busy = vec![0.0f64; accel.cores.len()];
    let mut group_finish = vec![0.0f64; ng];
    let mut group_core = vec![0usize; ng];
    let mut ready = vec![0.0f64; ng]; // data-ready time incl. transfers
    let mut energy = 0.0f64;
    let mut offchip_total = 0.0f64;
    let mut timeline = Vec::with_capacity(ng);
    let mut phase_busy = [0f64; 4];

    let transfer_bw = if accel.global_buffer_bw > 0.0 {
        accel.global_buffer_bw
    } else {
        accel.interconnect.link_bw
    };

    for &gid in &order {
        let group = &partition.groups[gid];
        let dom = dominant_op(group.iter().map(|&n| &graph.node(n).kind))
            .expect("group is non-empty")
            .clone();
        let is_mac_group = dom.is_conv() || dom.is_gemm();
        let prefs = candidate_cores(accel, &dom);
        let places =
            group_placements(graph, group, &gof, gid, accel.global_buffer_bytes > 0);
        // memo-key prefix for this group: ops + placements (independent of
        // the core class / gang width candidates tried below)
        let group_hash = base_hash.as_ref().map(|base| {
            let mut h = base.clone();
            for (&n, place) in group.iter().zip(&places) {
                hash_group_node(&mut h, &graph.node(n).kind, place);
            }
            h
        });

        // candidate placements: for each core class (take the first core of
        // the class in preference order), single-core and (for MAC groups)
        // gang placement.
        let mut best: Option<(f64, f64, usize, usize, NodeCost)> = None; // (finish, start, core, gang, cost)
        let mut tried_classes = 0;
        for &cid in &prefs {
            let class = &classes[class_of[cid]];
            if class[0] != cid {
                continue; // evaluate each class once, via its representative
            }
            tried_classes += 1;
            if tried_classes > 2 {
                break; // two best-affinity classes suffice
            }
            // tensor-parallel gang width: the useful split is bounded by
            // how many array-widths the bound output-channel dim folds
            // into — splitting further only idles rows. Evaluate 1, the
            // analytic preference, and its neighbours (§Perf: replaces the
            // full power-of-two scan, ~3× fewer group_cost calls).
            let mut gang_options: Vec<usize> = vec![1];
            if is_mac_group {
                let cap = cfg.tensor_parallel.min(class.len());
                let rows = match accel.cores[cid].dataflow {
                    crate::hardware::core::Dataflow::WeightStationary { rows, .. } => rows,
                    crate::hardware::core::Dataflow::OutputStationary { cols, .. } => cols,
                    crate::hardware::core::Dataflow::Simd { lanes } => lanes,
                };
                let k_dim = dom
                    .loop_dims()
                    .iter()
                    .find(|(d, _)| *d == crate::workload::op::LoopDim::K)
                    .map(|(_, s)| *s)
                    .unwrap_or(1);
                let pref = (k_dim / rows.max(1)).next_power_of_two().clamp(1, cap.max(1));
                for g in [pref / 2, pref, pref * 2, cap] {
                    if g > 1 && g <= cap && !gang_options.contains(&g) {
                        gang_options.push(g);
                    }
                }
            }
            for &gang in &gang_options {
                let cost = match (cache, &group_hash) {
                    (Some(cache), Some(gh)) => {
                        let mut h = gh.clone();
                        hash_core_class(&mut h, &accel.cores[cid]);
                        gang.hash(&mut h);
                        cache.get_or_compute(h.finish128(), || {
                            group_cost(graph, group, &places, cid, accel, &env, gang)
                        })
                    }
                    _ => group_cost(graph, group, &places, cid, accel, &env, gang),
                };
                // pick the `gang` earliest-free cores of this class
                // (total_cmp: identical order for the finite times that
                // occur here, and a degenerate NaN cost can't panic the
                // whole schedule)
                let mut frees: Vec<(f64, usize)> =
                    class.iter().map(|&c| (core_free[c], c)).collect();
                frees.sort_by(|a, b| a.0.total_cmp(&b.0));
                let gang_free = frees[gang - 1].0; // all gang members must be free
                let start = gang_free.max(ready[gid]);
                let finish = start + cost.cycles;
                if best.as_ref().is_none_or(|b| finish < b.0) {
                    best = Some((finish, start, frees[..gang].iter().map(|f| f.1).min().unwrap(), gang, cost));
                    // store the representative core id; gang members resolved below
                    let _ = cid;
                }
            }
        }
        let (finish, start, core0, gang, cost) = best.expect("no core candidates");

        // occupy the gang
        let class = &classes[class_of[core0]];
        let mut frees: Vec<(f64, usize)> =
            class.iter().map(|&c| (core_free[c], c)).collect();
        frees.sort_by(|a, b| a.0.total_cmp(&b.0));
        for &(_, c) in frees.iter().take(gang) {
            core_free[c] = finish;
            core_busy[c] += finish - start;
        }

        group_finish[gid] = finish;
        group_core[gid] = core0;
        energy += cost.energy_pj;
        offchip_total += cost.offchip_bytes;

        // inter-group transfer energy: pay link energy only "when cores
        // differ" (the contract in the fn docs) — a producer→consumer pair
        // the list scheduler lands on one core moves nothing over the bus.
        // Charged here, at consumer placement, because only now are both
        // endpoint cores known; predecessors are already placed
        // (topological order), and `gpred` iterates in sorted group order
        // so the f64 accumulation stays bit-deterministic.
        for &(p, bytes) in &gpred[gid] {
            if group_core[p] != core0 {
                energy += bytes as f64 * accel.interconnect.link_energy_pj;
            }
        }

        // propagate readiness + transfer latency to successors (energy is
        // handled above, once the consumer's core is known)
        for &(s, bytes) in &gsucc[gid] {
            let tx_cycles = bytes as f64 / transfer_bw.max(1.0);
            ready[s] = ready[s].max(finish + tx_cycles);
        }

        // attribute the group's busy time to the dominant phase of its
        // members (groups rarely mix phases: fusion follows data flow)
        {
            let mut counts = [0usize; 4];
            for &n in group {
                counts[phase_index(graph.node(n).phase)] += 1;
            }
            let dom_phase =
                (0..4).max_by_key(|&i| counts[i]).unwrap_or(0);
            phase_busy[dom_phase] += finish - start;
        }
        timeline.push(GroupRecord {
            group: gid,
            core: core0,
            gang,
            start,
            finish,
            energy_pj: cost.energy_pj,
        });
    }

    let makespan_cores = group_finish.iter().cloned().fold(0.0, f64::max);
    // shared DRAM bus bound
    let makespan = makespan_cores.max(offchip_total / accel.offchip_bw.max(1.0));
    energy += energy::E_IDLE_PJ_PER_CYCLE * makespan * accel.cores.len() as f64;

    // ---- memory lifetimes (dynamic DRAM-live tensors) ----
    // A tensor that crosses groups lives in DRAM (or the global buffer,
    // but that is capacity-limited too) from its producer's finish to its
    // *last* consumer's finish — one allocation per source tensor, not one
    // per edge. (The pre-fix per-edge events allocated a tensor consumed
    // by k groups k times and freed it at every consumer, overstating
    // training peaks by the consumer fan-out of each saved activation.)
    // Saved activations (fwd→bwd edges) are exactly the long-lived ones —
    // this is where training peaks (Fig 3).
    let peak_dram_bytes = {
        // src node -> (tensor bytes, last cross-group consumer finish)
        let mut tensors: HashMap<usize, (u64, f64)> = HashMap::new();
        for e in &graph.edges {
            let (a, b) = (gof[e.src], gof[e.dst]);
            if a == b {
                continue;
            }
            let t = tensors.entry(e.src).or_insert((0, f64::NEG_INFINITY));
            // out-edges of one node all carry its output tensor; `max`
            // rather than `+=` keeps multi-consumer fan-out a single
            // allocation of the tensor's size
            t.0 = t.0.max(e.bytes);
            t.1 = t.1.max(group_finish[b]);
        }
        let mut events: Vec<(f64, i64)> = Vec::with_capacity(tensors.len() * 2);
        // audit:allow(DT02): events are fully sorted by (time, delta) before the running sum, restoring a deterministic order
        for (&src, &(bytes, last_use)) in &tensors {
            events.push((group_finish[gof[src]], bytes as i64));
            events.push((last_use, -(bytes as i64)));
        }
        // sort fully (time, delta): HashMap iteration order varies, but
        // equal (time, delta) events commute in the running sum, so the
        // peak is deterministic; frees land first at ties
        events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
        let mut live = 0i64;
        let mut peak = 0i64;
        for (_, d) in events {
            live += d;
            peak = peak.max(live);
        }
        peak.max(0) as u64
    };

    ScheduleResult {
        latency_cycles: makespan,
        energy_pj: energy,
        peak_dram_bytes,
        offchip_bytes: offchip_total,
        core_busy,
        phase_busy,
        n_groups: ng,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::{EdgeTpuParams, FuseMaxParams};
    use crate::scheduler::partition::Partition;
    use crate::workload::models::{gpt2, mlp, resnet18, Gpt2Config};

    fn edge() -> Accelerator {
        EdgeTpuParams::baseline().build()
    }

    #[test]
    fn mlp_schedules_and_is_consistent() {
        let g = mlp(1, 64, 128, 3, 10);
        let p = Partition::singletons(&g);
        let r = schedule(&g, &p, &edge(), &MappingConfig::default());
        assert!(r.latency_cycles > 0.0);
        assert!(r.energy_pj > 0.0);
        assert!(r.utilization() > 0.0 && r.utilization() <= 1.0);
        assert_eq!(r.n_groups, g.len());
        assert_eq!(r.timeline.len(), g.len());
    }

    #[test]
    fn timeline_respects_dependencies() {
        let g = mlp(1, 64, 128, 2, 10);
        let p = Partition::singletons(&g);
        let r = schedule(&g, &p, &edge(), &MappingConfig::default());
        let finish: HashMap<usize, f64> =
            r.timeline.iter().map(|t| (t.group, t.finish)).collect();
        let start: HashMap<usize, f64> =
            r.timeline.iter().map(|t| (t.group, t.start)).collect();
        for e in &g.edges {
            // singleton partition: group id == node id
            assert!(
                finish[&e.src] <= start[&e.dst] + 1e-9,
                "edge {}->{} violated",
                e.src,
                e.dst
            );
        }
    }

    #[test]
    fn fusion_beats_singletons_on_energy() {
        // fusing a conv-heavy chain must cut DRAM traffic hence energy
        let g = resnet18(1, 32, 10);
        let sing = Partition::singletons(&g);
        let r1 = schedule(&g, &sing, &edge(), &MappingConfig::edge_tpu_default());
        // greedy pairwise fusion: each node with its sole consumer when valid
        let mut groups: Vec<Vec<usize>> = vec![];
        let mut used = vec![false; g.len()];
        for n in g.topo_order() {
            if used[n] {
                continue;
            }
            let succs: Vec<_> = g.successors(n).collect();
            if succs.len() == 1 && !used[succs[0]] && g.in_degree(succs[0]) == 1 {
                groups.push(vec![n, succs[0]]);
                used[n] = true;
                used[succs[0]] = true;
            } else {
                groups.push(vec![n]);
                used[n] = true;
            }
        }
        let fused = Partition::from_groups(groups);
        fused.validate(&g).unwrap();
        let r2 = schedule(&g, &fused, &edge(), &MappingConfig::edge_tpu_default());
        assert!(r2.energy_pj < r1.energy_pj, "{} !< {}", r2.energy_pj, r1.energy_pj);
        // cross-group traffic rides the bus (not DRAM), so fusion shows up
        // as strictly lower energy; DRAM bytes must at least not grow
        assert!(r2.offchip_bytes <= r1.offchip_bytes);
    }

    #[test]
    fn bigger_accelerator_is_faster() {
        let g = resnet18(1, 32, 10);
        let p = Partition::singletons(&g);
        let small = EdgeTpuParams { u: 16, l: 1, ..EdgeTpuParams::baseline() }.build();
        let big = EdgeTpuParams { u: 128, l: 8, ..EdgeTpuParams::baseline() }.build();
        let cfg = MappingConfig::edge_tpu_default();
        let rs = schedule(&g, &p, &small, &cfg);
        let rb = schedule(&g, &p, &big, &cfg);
        assert!(rb.latency_cycles < rs.latency_cycles);
    }

    #[test]
    fn fusemax_runs_gpt2() {
        let g = gpt2(Gpt2Config::tiny());
        let p = Partition::singletons(&g);
        let a = FuseMaxParams::baseline().build();
        let r = schedule(&g, &p, &a, &MappingConfig::fusemax_default());
        assert!(r.latency_cycles > 0.0);
        assert!(r.peak_dram_bytes > 0);
    }

    #[test]
    fn tensor_parallel_helps_latency() {
        let g = resnet18(1, 32, 10);
        let p = Partition::singletons(&g);
        let a = edge();
        let r1 = schedule(&g, &p, &a, &MappingConfig { tensor_parallel: 1, intra_core_tiling: 4 });
        let r4 = schedule(&g, &p, &a, &MappingConfig { tensor_parallel: 4, intra_core_tiling: 4 });
        assert!(r4.latency_cycles <= r1.latency_cycles * 1.01);
    }

    #[test]
    fn phase_breakdown_sums_to_busy_and_orders_sanely() {
        use crate::autodiff::{build_training_graph, TrainOptions};
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        let p = Partition::singletons(&tg.graph);
        let r = schedule(&tg.graph, &p, &edge(), &MappingConfig::edge_tpu_default());
        let total: f64 = r.phase_busy.iter().sum();
        // phase time counts each group once; core_busy counts gang-wide
        // occupancy, so compare against the timeline durations
        let busy: f64 = r.timeline.iter().map(|t| t.finish - t.start).sum();
        assert!((total - busy).abs() / busy < 1e-6);
        // backward does ~2x the forward work
        assert!(r.phase_busy[1] > r.phase_busy[0]);
        // no recompute phase without checkpointing
        assert_eq!(r.phase_busy[3], 0.0);
        // inference graph has no backward/update time
        let ri = schedule(&fwd, &Partition::singletons(&fwd), &edge(), &MappingConfig::default());
        assert_eq!(ri.phase_busy[1], 0.0);
        assert_eq!(ri.phase_busy[2], 0.0);
    }

    #[test]
    fn cached_schedule_bit_identical_and_warm_hits() {
        let g = resnet18(1, 32, 10);
        let p = Partition::singletons(&g);
        let a = edge();
        let cfg = MappingConfig::edge_tpu_default();
        let cache = crate::eval::CostCache::new();
        let plain = schedule(&g, &p, &a, &cfg);
        let cold = schedule_with_cache(&g, &p, &a, &cfg, Some(&cache));
        let warm = schedule_with_cache(&g, &p, &a, &cfg, Some(&cache));
        for r in [&cold, &warm] {
            assert_eq!(plain.latency_cycles.to_bits(), r.latency_cycles.to_bits());
            assert_eq!(plain.energy_pj.to_bits(), r.energy_pj.to_bits());
            assert_eq!(plain.peak_dram_bytes, r.peak_dram_bytes);
            assert_eq!(plain.offchip_bytes.to_bits(), r.offchip_bytes.to_bits());
        }
        let s = cache.stats();
        // repeated layer shapes hit even within the cold run; the warm run
        // must be all hits (no new unique group costs)
        assert!(s.hits > s.misses, "hits {} misses {}", s.hits, s.misses);
        assert_eq!(s.entries as u64, s.misses);
    }

    #[test]
    fn sink_nodes_place_output_in_dram_not_on_the_bus() {
        use crate::workload::op::{EltwiseKind, OpKind, Phase};
        let relu = |elems: u64| OpKind::Eltwise { kind: EltwiseKind::Relu, elems, arity: 1 };
        let mut g = Graph::new();
        let a = g.add_node("a", relu(256), Phase::Forward);
        let b = g.add_node("b", relu(256), Phase::Forward);
        g.add_edge(a, b, 1024);
        let gof = vec![0usize, 1];
        for has_global in [false, true] {
            // b has no out-edges: its output must not pay bus / global-
            // buffer transfer (pre-fix, `any_out == false` forced
            // `all_internal == false` and set a transfer flag)
            let pb = group_placements(&g, &[b], &gof, 1, has_global);
            assert!(
                !pb[0].out_global && !pb[0].out_link && !pb[0].out_local,
                "sink output must go to DRAM (has_global={has_global}): {:?}",
                pb[0]
            );
            // while a real cross-group producer still ships its tensor out
            let pa = group_placements(&g, &[a], &gof, 0, has_global);
            assert_eq!(pa[0].out_global, has_global);
            assert_eq!(pa[0].out_link, !has_global);
        }
    }

    #[test]
    fn multi_consumer_tensor_is_one_dram_allocation() {
        use crate::workload::op::{EltwiseKind, OpKind, Phase};
        let relu = |elems: u64| OpKind::Eltwise { kind: EltwiseKind::Relu, elems, arity: 1 };
        let mut g = Graph::new();
        let a = g.add_node("a", relu(256), Phase::Forward);
        for i in 0..3 {
            let c = g.add_node(format!("c{i}"), relu(256), Phase::Forward);
            g.add_edge(a, c, 1000);
        }
        let p = Partition::singletons(&g);
        let r = schedule(&g, &p, &edge(), &MappingConfig::default());
        // a's single output tensor feeds 3 consumer groups: exactly one
        // 1000-byte allocation from a's finish to the last consumer's
        // finish (the pre-fix per-edge accounting peaked at 3000)
        assert_eq!(r.peak_dram_bytes, 1000);
    }

    #[test]
    fn peak_memory_positive_for_training_graph() {
        use crate::autodiff::{build_training_graph, TrainOptions};
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        let p = Partition::singletons(&tg.graph);
        let r = schedule(&tg.graph, &p, &edge(), &MappingConfig::edge_tpu_default());
        // training graph must hold activations live across fwd→bwd
        let rf = schedule(&fwd, &Partition::singletons(&fwd), &edge(), &MappingConfig::edge_tpu_default());
        assert!(r.peak_dram_bytes > rf.peak_dram_bytes);
    }
}
