//! Self-contained infrastructure: PRNG, JSON, statistics, property-test
//! helper. These replace non-vendored crates (rand, serde_json, proptest)
//! in this offline build environment — see DESIGN.md §Substitutions.

pub mod error;
pub mod fault;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
