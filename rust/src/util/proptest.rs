//! Micro property-testing harness (proptest is not vendored offline).
//!
//! `check(seed_count, gen, prop)` draws `seed_count` random cases from
//! `gen`, asserts `prop` on each, and on failure performs greedy input
//! shrinking via the generator's `shrink` hook before panicking with the
//! minimal counterexample. Deterministic: case i uses seed i.

use super::rng::Rng;
use std::fmt::Debug;

/// A generator of random test cases with an optional shrinker.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller inputs (tried in order during shrinking).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        vec![]
    }
}

/// Run a property over `cases` random inputs.
pub fn check<G: Gen>(cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::seed_from_u64(0xC0FFEE ^ seed);
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            // shrink greedily
            let mut cur = v;
            'outer: loop {
                for cand in gen.shrink(&cur) {
                    if !prop(&cand) {
                        cur = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!("property failed (seed {seed}), minimal counterexample: {cur:?}");
        }
    }
}

/// Generator: usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);
impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.0 + rng.usize(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = vec![];
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: vector of f64 in [lo, hi) with length in [min_len, max_len].
pub struct VecF64 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f64,
    pub hi: f64,
}
impl Gen for VecF64 {
    type Value = Vec<f64>;
    fn generate(&self, rng: &mut Rng) -> Vec<f64> {
        let n = self.min_len + rng.usize(self.max_len - self.min_len + 1);
        (0..n).map(|_| rng.range_f64(self.lo, self.hi)).collect()
    }
    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut out = vec![];
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut shorter = v.clone();
            shorter.pop();
            out.push(shorter);
        }
        out
    }
}

/// Generator: random bitmask of fixed width with given set-bit probability.
pub struct BitMask {
    pub width: usize,
    pub p: f64,
}
impl Gen for BitMask {
    type Value = Vec<bool>;
    fn generate(&self, rng: &mut Rng) -> Vec<bool> {
        (0..self.width).map(|_| rng.bool(self.p)).collect()
    }
    fn shrink(&self, v: &Vec<bool>) -> Vec<Vec<bool>> {
        // clearing bits shrinks towards the all-false mask
        let mut out = vec![];
        for i in 0..v.len() {
            if v[i] {
                let mut c = v.clone();
                c[i] = false;
                out.push(c);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, &UsizeIn(1, 100), |&n| n >= 1 && n <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        // fails for n >= 10; shrinker should find something small
        check(50, &UsizeIn(1, 100), |&n| n < 10);
    }

    #[test]
    fn vec_generator_respects_bounds() {
        check(30, &VecF64 { min_len: 1, max_len: 8, lo: -1.0, hi: 1.0 }, |v| {
            (1..=8).contains(&v.len()) && v.iter().all(|x| (-1.0..1.0).contains(x))
        });
    }

    #[test]
    fn bitmask_width() {
        check(30, &BitMask { width: 16, p: 0.3 }, |m| m.len() == 16);
    }
}
