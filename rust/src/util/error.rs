//! Minimal `anyhow`-compatible error type. The `anyhow` crate is not
//! vendored in this offline environment (DESIGN.md §Substitutions); the
//! runtime layer and the CLI only need string-context errors, `?` on any
//! `Display`-able error, and the `bail!`/`ensure!`/`anyhow!` macros — about
//! sixty lines, carried here.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `From<E: Display>` impl
//! coherent (an `Error` can absorb any displayable error via `?`).

use std::fmt;

/// A string-rendered error with accumulated context lines.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string() }
    }

    fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.msg = format!("{ctx}: {}", self.msg);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: the blanket conversion is bounded by `std::error::Error`
// (not `Display`) and `Error` itself stays outside that trait, so this
// does not overlap the reflexive `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` twin: attach context to `Result` errors and `None`s.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<u32> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_absorbs_std_errors() {
        fn inner() -> Result<u32> {
            let v = io_fail()?;
            Ok(v)
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let e = io_fail().context("reading meta").unwrap_err();
        assert_eq!(e.to_string(), "reading meta: gone");
        let e2 = None::<u32>.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e2.to_string(), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                crate::bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
    }
}
