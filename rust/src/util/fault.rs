//! Deterministic fault injection (the resilience PR's test harness).
//!
//! Production code calls the three hook points below; with no plan
//! installed every hook is a cheap atomic load and a no-op, so the
//! fault-free hot path pays nothing measurable. Tests install a seeded
//! [`FaultPlan`] to reproduce a specific disaster — a worker panic on one
//! design point, a failed filesystem write, a flipped snapshot byte —
//! then assert the engine degrades instead of aborting.
//!
//! The plan is process-global (hooks are reached from worker threads and
//! deep inside the persistence layer, where threading a handle through
//! would distort every signature). Tests that install plans must
//! serialize on a lock of their own — the CI fault-injection job runs the
//! recovery suite with `--test-threads=1` for the same reason.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// One reproducible disaster. All fields are optional and independent;
/// `Default` is the no-fault plan.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic inside the evaluation of this design-point index (caught by
    /// the engine's per-point isolation and surfaced as a failed row).
    pub panic_on_point: Option<usize>,
    /// Fail the n-th gated filesystem write (1-based count over every
    /// write that consults [`write_gate`]: snapshots and journal records).
    pub fail_write: Option<u64>,
    /// Flip one bit of the next gated buffer before it hits disk, at
    /// `offset % buf.len()` — a one-shot storage-corruption fault.
    pub flip_byte: Option<u64>,
}

impl FaultPlan {
    /// Derive a reproducible plan from a seed: one of the three fault
    /// kinds, aimed at a pseudo-random target within `n_points` design
    /// points / the first few writes. Equal seeds give equal plans — the
    /// CI matrix sweeps seeds, not hand-picked cases.
    pub fn seeded(seed: u64, n_points: usize) -> FaultPlan {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let mut plan = FaultPlan::default();
        match rng.usize(3) {
            0 => plan.panic_on_point = Some(rng.usize(n_points.max(1))),
            1 => plan.fail_write = Some(1 + rng.next_u64() % 4),
            _ => plan.flip_byte = Some(rng.next_u64() % 4096),
        }
        plan
    }
}

/// Fast-path gate: hooks return immediately while this is false.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Count of gated writes since the last [`install`].
static WRITES: AtomicU64 = AtomicU64::new(0);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

fn plan() -> Option<FaultPlan> {
    PLAN.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Arm a plan (resetting the write counter). Call [`clear`] when done.
pub fn install(plan: FaultPlan) {
    *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = Some(plan);
    WRITES.store(0, Ordering::SeqCst);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Disarm fault injection; every hook becomes a no-op again.
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
    *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = None;
}

/// Is a plan currently installed?
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Hook: called by the engine at the top of every per-point evaluation
/// (inside its `catch_unwind` fence). Panics when the armed plan targets
/// this index.
pub fn panic_point(index: usize) {
    if !active() {
        return;
    }
    if plan().and_then(|p| p.panic_on_point) == Some(index) {
        panic!("injected fault: panic on point {index}");
    }
}

/// Hook: gate one filesystem write. Returns `Err` on the plan's n-th
/// gated write, `Ok` otherwise.
pub fn write_gate(what: &str) -> std::io::Result<()> {
    if !active() {
        return Ok(());
    }
    let n = WRITES.fetch_add(1, Ordering::SeqCst) + 1;
    if plan().and_then(|p| p.fail_write) == Some(n) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("injected fault: failing write #{n} ({what})"),
        ));
    }
    Ok(())
}

/// Hook: corrupt `buf` in place if (and only if) a byte-flip fault is
/// armed. One-shot: the flip is consumed so only a single buffer is hit.
pub fn maybe_flip(buf: &mut [u8]) {
    if !active() || buf.is_empty() {
        return;
    }
    let mut guard = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(p) = guard.as_mut() {
        if let Some(off) = p.flip_byte.take() {
            let i = (off as usize) % buf.len();
            buf[i] ^= 0x40;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global plan is shared across the whole test binary; this lock
    // keeps the in-module tests from trampling each other (non-fault
    // tests elsewhere never install a plan, so they are unaffected).
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn inert_without_a_plan() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        clear();
        assert!(!active());
        panic_point(0);
        assert!(write_gate("x").is_ok());
        let mut b = vec![1u8, 2, 3];
        maybe_flip(&mut b);
        assert_eq!(b, vec![1, 2, 3]);
    }

    #[test]
    fn nth_write_fails_exactly_once_per_install() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        install(FaultPlan { fail_write: Some(2), ..Default::default() });
        assert!(write_gate("a").is_ok());
        assert!(write_gate("b").is_err());
        assert!(write_gate("c").is_ok());
        clear();
    }

    #[test]
    fn flip_is_one_shot() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        install(FaultPlan { flip_byte: Some(5), ..Default::default() });
        let mut a = vec![0u8; 4];
        maybe_flip(&mut a); // 5 % 4 == 1
        assert_eq!(a, vec![0, 0x40, 0, 0]);
        let mut b = vec![0u8; 4];
        maybe_flip(&mut b);
        assert_eq!(b, vec![0, 0, 0, 0], "flip must be consumed");
        clear();
    }

    #[test]
    fn seeded_plans_are_reproducible_and_single_fault() {
        for seed in 0..32u64 {
            let a = FaultPlan::seeded(seed, 100);
            let b = FaultPlan::seeded(seed, 100);
            assert_eq!(a, b);
            let armed = [
                a.panic_on_point.is_some(),
                a.fail_write.is_some(),
                a.flip_byte.is_some(),
            ];
            assert_eq!(armed.iter().filter(|&&x| x).count(), 1, "{a:?}");
        }
    }
}
