//! Tiny statistics helpers for benches and reports.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    // total_cmp: NaN sorts last instead of panicking the comparator
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize) - 1;
    v[rank.min(v.len() - 1)]
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
    }

    #[test]
    fn geomean_simple() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_tolerates_nan_inputs() {
        // regression: the comparator used to be partial_cmp().unwrap(),
        // which panics on the first NaN — total_cmp sorts NaN last
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn empty_inputs() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
