//! Minimal JSON parser and serializer — enough to read
//! `artifacts/meta.json` (objects, arrays, strings, numbers, booleans,
//! null) and to render `monet serve` responses. serde_json is not
//! vendored in this offline environment; the artifact metadata is small
//! and trusted, so a ~200-line recursive-descent parser is the right
//! tool.
//!
//! Serialization (`Display`) is **deterministic**: object keys are
//! emitted in sorted order (the in-memory representation is a
//! `HashMap`, whose iteration order must never leak into output) and
//! numbers use Rust's shortest-roundtrip `f64` formatting. Equal values
//! therefore always serialize to equal bytes — the property the
//! daemon-vs-one-shot bit-identity contract in `serve` rests on.

use std::collections::HashMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Build an object from key/value pairs (ergonomics for response
    /// construction; ordering is irrelevant — `Display` sorts keys).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

impl fmt::Display for Json {
    /// Compact, deterministic serialization: sorted object keys,
    /// shortest-roundtrip numbers, no insignificant whitespace.
    /// Non-finite numbers (unrepresentable in JSON) render as `null`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if !n.is_finite() => f.write_str("null"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                let mut keys: Vec<&String> = m.keys().collect();
                keys.sort();
                f.write_str("{")?;
                for (i, k) in keys.into_iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{}", m[k])?;
                }
                f.write_str("}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| self.err("bad \\u escape"))?,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d").unwrap().get("e").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn serializes_deterministically_with_sorted_keys() {
        let j = Json::obj(vec![
            ("zeta", Json::Num(2.0)),
            ("alpha", Json::Arr(vec![Json::Num(1.5), Json::Bool(true), Json::Null])),
            ("mid", Json::Str("a\n\"b\"\\".into())),
        ]);
        assert_eq!(
            j.to_string(),
            r#"{"alpha":[1.5,true,null],"mid":"a\n\"b\"\\","zeta":2}"#
        );
    }

    #[test]
    fn serialization_round_trips_through_the_parser() {
        let src = r#"{"a": [1, 2.25, {"b": "c d"}], "d": {"e": false, "f": null}}"#;
        let j = Json::parse(src).unwrap();
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
        // fixed point: serializing the reparse yields the same bytes
        assert_eq!(Json::parse(&s).unwrap().to_string(), s);
    }

    #[test]
    fn non_finite_numbers_render_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn control_chars_escape_as_unicode() {
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn parses_real_meta_shape() {
        let j = Json::parse(
            r#"{"gpt2_tiny": {"num_params": 437760,
                "param_names": ["tok_emb", "pos_emb"],
                "param_shapes": [[256, 128], [64, 128]]}}"#,
        )
        .unwrap();
        let g = j.get("gpt2_tiny").unwrap();
        assert_eq!(g.get("num_params").unwrap().as_usize(), Some(437760));
        let shapes = g.get("param_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].idx(0).unwrap().as_usize(), Some(256));
    }
}
