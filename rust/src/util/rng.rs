//! Small, fast, deterministic PRNG (xoshiro256**). The GA and the property
//! tests need reproducible streams; the crates.io `rand` stack is not
//! vendored in this offline environment, so we carry our own — ~60 lines,
//! same algorithm family rand's SmallRng uses.

/// splitmix64 finalizer: full-avalanche 64-bit mixer. Used for xoshiro
/// seed expansion here and for structural-hash finalization in
/// `eval::cost_cache`.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 expansion, the canonical seeding for xoshiro
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            splitmix64(x)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize(i + 1));
        }
    }

    /// Snapshot the full generator state. `from_state(state())` resumes
    /// the stream exactly — the hook GA run journals use to make a
    /// resumed search bit-identical to an uninterrupted one.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.usize(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::seed_from_u64(17);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn mean_approximately_half() {
        let mut r = Rng::seed_from_u64(11);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
