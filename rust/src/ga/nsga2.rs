//! NSGA-II (Deb et al. 2002) — the multi-objective GA the paper uses for
//! activation checkpointing (§V-B2): elitist survival via fast
//! non-dominated sorting, diversity via crowding distance, binary
//! tournament selection, and problem-supplied variation operators. All
//! objectives are minimized.
//!
//! §Generify (the deployment-genome PR): the core ([`nsga2_problem`]) is
//! generic over a [`GaProblem`] — anchors, seed fitting, random
//! initialization, crossover, mutation and deterministic feasibility
//! repair all come from the problem, while the core keeps the RNG
//! discipline, hash-keyed genome memoization, checkpointing and batch
//! evaluation. The original boolean-genome GA is the [`BitmaskProblem`]
//! instance (uniform crossover, per-bit flip mutation, all-false/all-true
//! anchors); [`nsga2`]/[`nsga2_with_memo`]/[`nsga2_resumable`] wrap it
//! with their historical signatures and are **bit-identical** to the
//! pre-refactor implementation — same RNG stream, same genomes, same
//! front (pinned by `reference_bitmask_ga_matches_the_generic_core`).
//!
//! §Perf (the memoized-evaluation PR): objective evaluation is the GA's
//! entire cost — each call runs the full checkpoint→fuse→schedule pipeline
//! — so (a) each generation's genomes are generated first and evaluated as
//! a batch fanned out over `cfg.workers` via the generic DSE pool
//! ([`crate::dse::engine::map_parallel`] — the same worker-pool core every
//! sweep family runs on), and (b) a genome→objectives memo skips
//! re-evaluating duplicate genomes, which dominate once the population
//! converges. Both are invisible in the results: `eval` must be pure
//! (`Fn + Sync`), genomes are produced by the same RNG stream as the
//! serial implementation, and results are assigned by index — the outcome
//! is bit-identical for any worker count.

use std::collections::{HashMap, HashSet};

use crate::util::rng::Rng;

/// The historical boolean genome (activation-checkpointing masks). The
/// type parameter of every generic item below defaults to this, so
/// pre-refactor call sites compile unchanged.
pub type Genome = Vec<bool>;
pub type Objectives = Vec<f64>;

#[derive(Debug, Clone)]
pub struct Individual<G = Genome> {
    pub genome: G,
    pub objectives: Objectives,
    pub rank: usize,
    pub crowding: f64,
}

/// A search problem NSGA-II can evolve: the genome representation plus
/// the variation operators over it. The core supplies selection,
/// survival, memoization, batching and checkpointing; the problem
/// supplies everything genome-shaped.
///
/// RNG discipline: every method receives the single GA RNG and must
/// consume draws deterministically (same genome in → same draws). The
/// exception is [`GaProblem::repair`], which must consume **no** RNG —
/// repair runs only on infeasible genomes, and an RNG draw there would
/// make the stream depend on feasibility, breaking resume bit-identity
/// whenever a checkpoint boundary splits a brood.
pub trait GaProblem: Sync {
    type Genome: Clone + Eq + std::hash::Hash + std::fmt::Debug + Send + Sync;

    /// Deterministic corner-case genomes that occupy the first population
    /// slots (the bitmask GA anchors all-false = "save everything" and
    /// all-true = "recompute everything"). Consumes no RNG.
    fn anchors(&self) -> Vec<Self::Genome>;

    /// Clip/pad an injected warm-start seed to this problem's shape.
    /// Consumes no RNG.
    fn fit_seed(&self, seed: &Self::Genome) -> Self::Genome;

    /// Draw a random genome for the initial population.
    fn random(&self, rng: &mut Rng) -> Self::Genome;

    /// Mix `other` into `child` in place (uniform crossover for bitmasks).
    fn crossover(&self, child: &mut Self::Genome, other: &Self::Genome, rng: &mut Rng);

    /// Mutate `genome` in place; `mutation_p` is the per-locus flip
    /// probability the config carries.
    fn mutate(&self, genome: &mut Self::Genome, rng: &mut Rng, mutation_p: f64);

    /// Deterministically repair an infeasible genome in place, consuming
    /// no RNG; returns whether anything changed. The default is a no-op
    /// for problems (like bitmasks) where every genome is feasible.
    fn repair(&self, _genome: &mut Self::Genome) -> bool {
        false
    }
}

/// The original fixed-width boolean-genome GA as a [`GaProblem`]. Its
/// operators replicate the pre-refactor hard-coded implementation draw
/// for draw, which is what makes [`nsga2_resumable`] bit-identical to
/// the historical behavior.
pub struct BitmaskProblem {
    pub width: usize,
}

impl GaProblem for BitmaskProblem {
    type Genome = Vec<bool>;

    fn anchors(&self) -> Vec<Vec<bool>> {
        vec![vec![false; self.width], vec![true; self.width]]
    }

    fn fit_seed(&self, seed: &Vec<bool>) -> Vec<bool> {
        let mut g = seed.clone();
        g.resize(self.width, false);
        g
    }

    fn random(&self, rng: &mut Rng) -> Vec<bool> {
        let p = rng.range_f64(0.05, 0.8);
        (0..self.width).map(|_| rng.bool(p)).collect()
    }

    fn crossover(&self, child: &mut Vec<bool>, other: &Vec<bool>, rng: &mut Rng) {
        for i in 0..self.width {
            if rng.bool(0.5) {
                child[i] = other[i];
            }
        }
    }

    fn mutate(&self, genome: &mut Vec<bool>, rng: &mut Rng, mutation_p: f64) {
        for bit in genome.iter_mut() {
            if rng.bool(mutation_p) {
                *bit = !*bit;
            }
        }
    }
}

/// Evaluation/search counters accumulated by [`nsga2_problem`] —
/// observability for tuning operators on new genome types (how much the
/// memo saves, how often repair fires) surfaced in end-of-run reports.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct GaStats {
    /// Genomes sent to the objective function (memo misses).
    pub evaluated: usize,
    /// Genome lookups satisfied by the memo (within-batch duplicates,
    /// converged-population repeats, and warm-start entries).
    pub memo_hits: usize,
    /// Generations actually run this call (0 when resumed at/past the
    /// configured end).
    pub generations: usize,
    /// Genomes produced by initialization + variation (the repair-rate
    /// denominator).
    pub produced: usize,
    /// Produced genomes the problem's repair hook had to fix.
    pub repaired: usize,
}

impl GaStats {
    /// Fraction of produced genomes that were infeasible before repair.
    pub fn repair_rate(&self) -> f64 {
        if self.produced == 0 {
            0.0
        } else {
            self.repaired as f64 / self.produced as f64
        }
    }
}

/// `a` Pareto-dominates `b` (all ≤, at least one <).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort; returns fronts (vectors of indices) and writes
/// ranks into the individuals.
pub fn non_dominated_sort<G>(pop: &mut [Individual<G>]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![vec![]; n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&pop[i].objectives, &pop[j].objectives) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&pop[j].objectives, &pop[i].objectives) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = vec![];
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    let mut rank = 0;
    while !current.is_empty() {
        for &i in &current {
            pop[i].rank = rank;
        }
        let mut next = vec![];
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(current);
        current = next;
        rank += 1;
    }
    fronts
}

/// Crowding distance within one front (writes into individuals).
pub fn crowding_distance<G>(pop: &mut [Individual<G>], front: &[usize]) {
    if front.is_empty() {
        return;
    }
    let m = pop[front[0]].objectives.len();
    for &i in front {
        pop[i].crowding = 0.0;
    }
    for obj in 0..m {
        let mut idx = front.to_vec();
        // total_cmp, not partial_cmp().unwrap(): one NaN objective from a
        // degenerate evaluation must not abort a multi-hour GA run (NaNs
        // order after +inf and the individual simply scores no diversity
        // bonus)
        idx.sort_by(|&a, &b| {
            pop[a].objectives[obj].total_cmp(&pop[b].objectives[obj])
        });
        let lo = pop[idx[0]].objectives[obj];
        let hi = pop[idx[idx.len() - 1]].objectives[obj];
        pop[idx[0]].crowding = f64::INFINITY;
        pop[idx[idx.len() - 1]].crowding = f64::INFINITY;
        if (hi - lo).abs() < 1e-30 {
            continue;
        }
        for w in 1..idx.len() - 1 {
            let d = (pop[idx[w + 1]].objectives[obj] - pop[idx[w - 1]].objectives[obj])
                / (hi - lo);
            pop[idx[w]].crowding += d;
        }
    }
}

/// Rank-0 (non-dominated) indices of a raw objective list — the NSGA-II
/// front machinery exposed for callers that *enumerate* rather than
/// evolve, like the cluster DSE's four-objective set (iteration latency,
/// energy, per-device memory, cluster size). All objectives are
/// minimized; rows with a NaN objective are excluded up front (a NaN can
/// neither dominate nor be dominated, which would smuggle every
/// degenerate row into the front — the same policy as
/// `dse::sweep::pareto_front`). Returned indices ascend.
pub fn pareto_rank0(objectives: &[Objectives]) -> Vec<usize> {
    let valid: Vec<usize> = (0..objectives.len())
        .filter(|&i| objectives[i].iter().all(|v| !v.is_nan()))
        .collect();
    let mut pop: Vec<Individual> = valid
        .iter()
        .map(|&i| Individual {
            genome: vec![],
            objectives: objectives[i].clone(),
            rank: 0,
            crowding: 0.0,
        })
        .collect();
    let fronts = non_dominated_sort(&mut pop);
    let mut out: Vec<usize> = fronts
        .first()
        .map(|f| f.iter().map(|&j| valid[j]).collect())
        .unwrap_or_default();
    out.sort_unstable();
    out
}

#[derive(Debug, Clone)]
pub struct GaConfig<G = Genome> {
    pub population: usize,
    pub generations: usize,
    pub crossover_p: f64,
    pub mutation_p: f64,
    pub seed: u64,
    /// Threads for objective evaluation (1 = serial). The returned front is
    /// identical for every value — parallelism only changes wall-clock.
    pub workers: usize,
    /// Genomes injected into the initial population — cross-restart
    /// warm-starts pass the previous run's Pareto front here. Each is
    /// fitted to the problem's shape via [`GaProblem::fit_seed`]; at most
    /// `population - anchors` are used (the problem's anchor genomes keep
    /// the first slots). Empty (the default) reproduces the unseeded
    /// population exactly.
    pub seeds: Vec<G>,
}

impl<G> Default for GaConfig<G> {
    fn default() -> Self {
        GaConfig {
            population: 32,
            generations: 30,
            crossover_p: 0.9,
            mutation_p: 0.02,
            seed: 0xACAC,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            seeds: vec![],
        }
    }
}

/// Everything needed to restart NSGA-II exactly where a previous run
/// stopped: the generation about to run, the RNG's raw state, and the
/// surviving population as `(genome, objectives)` pairs. Rank and crowding
/// are deliberately absent — the generation loop recomputes both before
/// using them, so a population resumed from a checkpoint walks the same
/// path as one that never stopped.
///
/// Checkpoints are emitted by [`nsga2_resumable`] after the initial
/// population is evaluated (`generation == 0`) and after every completed
/// generation (`generation == g + 1`); `dse::journal` gives them a
/// checksummed on-disk encoding.
#[derive(Debug, Clone, PartialEq)]
pub struct GaCheckpoint<G = Genome> {
    /// Index of the next generation to run (0 = none run yet).
    pub generation: usize,
    /// Raw xoshiro256** state at the checkpoint boundary.
    pub rng: [u64; 4],
    /// Surviving population, in truncation order.
    pub population: Vec<(G, Objectives)>,
}

fn checkpoint_of<G: Clone>(generation: usize, rng: &Rng, pop: &[Individual<G>]) -> GaCheckpoint<G> {
    GaCheckpoint {
        generation,
        rng: rng.state(),
        population: pop
            .iter()
            .map(|i| (i.genome.clone(), i.objectives.clone()))
            .collect(),
    }
}

/// Turn a batch of genomes into ranked-zero individuals, evaluating only
/// genomes absent from `memo` (first occurrence wins within the batch) and
/// fanning fresh evaluations over `workers` threads of the generic DSE
/// pool. Order of the returned individuals matches `genomes`; the memo
/// makes duplicate genomes — common once the population converges — cost
/// one lookup.
fn evaluate_batch<G: Clone + Eq + std::hash::Hash + Sync>(
    genomes: Vec<G>,
    eval: &(impl Fn(&G) -> Objectives + Sync),
    memo: &mut HashMap<G, Objectives>,
    workers: usize,
    stats: &mut GaStats,
) -> Vec<Individual<G>> {
    let mut need: Vec<G> = vec![];
    {
        let mut pending: HashSet<&G> = HashSet::new();
        for g in &genomes {
            if !memo.contains_key(g) && pending.insert(g) {
                need.push(g.clone());
            }
        }
    }
    stats.evaluated += need.len();
    stats.memo_hits += genomes.len() - need.len();

    // the generic engine's deterministic parallel map: fresh[i] ==
    // eval(&need[i]) for any worker count (serial when one suffices) —
    // the GA shares the DSE harness's pool core instead of forking it
    let fresh: Vec<Objectives> = crate::dse::engine::map_parallel(workers, &need, eval);
    for (g, o) in need.into_iter().zip(fresh) {
        memo.insert(g, o);
    }

    genomes
        .into_iter()
        .map(|genome| {
            let objectives = memo[&genome].clone();
            Individual { genome, objectives, rank: 0, crowding: 0.0 }
        })
        .collect()
}

/// Run NSGA-II over boolean genomes of width `width`; `eval` maps a genome
/// to its (minimized) objective vector and must be a *pure* function of the
/// genome (it is memoized and may run on worker threads). Returns the final
/// first front, deduplicated by genome.
pub fn nsga2(
    width: usize,
    cfg: &GaConfig,
    eval: impl Fn(&Genome) -> Objectives + Sync,
) -> Vec<Individual> {
    nsga2_with_memo(width, cfg, eval, &mut HashMap::new())
}

/// [`nsga2`] with a caller-owned genome→objectives memo: entries present
/// on entry are trusted (they must come from the *same* pure objective
/// function — cross-restart warm-starts persist and reload them), and the
/// map holds every evaluation made when the call returns, ready to be
/// persisted for the next restart.
pub fn nsga2_with_memo(
    width: usize,
    cfg: &GaConfig,
    eval: impl Fn(&Genome) -> Objectives + Sync,
    memo: &mut HashMap<Genome, Objectives>,
) -> Vec<Individual> {
    nsga2_resumable(width, cfg, eval, memo, None, |_| {})
}

/// [`nsga2_with_memo`] with crash-safe checkpointing: `on_generation` is
/// invoked with a [`GaCheckpoint`] after the initial population is
/// evaluated and again after every completed generation, and `resume`
/// restarts the search from a previously emitted checkpoint.
///
/// Determinism contract: the hook consumes no RNG and observes no shared
/// state, so a run with a no-op hook is bit-identical to [`nsga2_with_memo`],
/// and a run resumed from checkpoint `g` produces the same final front,
/// genome for genome, as one that ran `0..generations` uninterrupted —
/// the checkpoint restores the exact RNG state and surviving population,
/// and rank/crowding are recomputed before each use. A checkpoint whose
/// `generation` is at or past `cfg.generations` skips the loop entirely
/// and goes straight to front extraction.
///
/// Checkpointed `(genome, objectives)` pairs are trusted the same way
/// warm-memo entries are: they must come from the same pure `eval`. They
/// are inserted into `memo` on resume so surviving genomes are never
/// re-evaluated.
pub fn nsga2_resumable(
    width: usize,
    cfg: &GaConfig,
    eval: impl Fn(&Genome) -> Objectives + Sync,
    memo: &mut HashMap<Genome, Objectives>,
    resume: Option<GaCheckpoint>,
    on_generation: impl FnMut(&GaCheckpoint),
) -> Vec<Individual> {
    nsga2_problem(&BitmaskProblem { width }, cfg, eval, memo, resume, on_generation).0
}

/// The generic NSGA-II core: evolve any [`GaProblem`] genome type with
/// hash-keyed memoization, batched parallel evaluation, crash-safe
/// checkpointing and elitist (μ+λ) survival. Returns the deduplicated
/// first front plus the run's [`GaStats`].
///
/// Everything documented on [`nsga2_resumable`] (purity of `eval`, the
/// resume/worker-count determinism contracts, checkpoint cadence) holds
/// verbatim here for any problem whose operators are deterministic
/// functions of `(genome, rng)` and whose repair consumes no RNG.
///
/// "Pure" does not mean stateless: `eval` may keep interior-mutable memo
/// caches of pure sub-computations (the deployment GA recycles
/// `ClusterScratch` stage memos across genomes so a mutant re-costs only
/// its changed stages). The contract is on *results* — the objective
/// vector must be bit-identical whether the caches are cold or warm, for
/// any evaluation order.
pub fn nsga2_problem<P: GaProblem>(
    problem: &P,
    cfg: &GaConfig<P::Genome>,
    eval: impl Fn(&P::Genome) -> Objectives + Sync,
    memo: &mut HashMap<P::Genome, Objectives>,
    resume: Option<GaCheckpoint<P::Genome>>,
    mut on_generation: impl FnMut(&GaCheckpoint<P::Genome>),
) -> (Vec<Individual<P::Genome>>, GaStats) {
    let mut stats = GaStats::default();
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let start_gen;
    let mut pop;
    if let Some(cp) = resume {
        // restart exactly where the checkpoint was taken: RNG state and
        // the surviving population (rank/crowding are recomputed below)
        rng = Rng::from_state(cp.rng);
        start_gen = cp.generation.min(cfg.generations);
        pop = cp
            .population
            .into_iter()
            .map(|(genome, objectives)| {
                memo.insert(genome.clone(), objectives.clone());
                Individual { genome, objectives, rank: 0, crowding: 0.0 }
            })
            .collect::<Vec<_>>();
    } else {
        // initial population: the problem's anchor genomes, any injected
        // warm-start genomes (previous front), then random genomes.
        // Anchors and injected genomes consume no RNG, so an empty
        // `cfg.seeds` reproduces the unseeded stream.
        let anchors = problem.anchors();
        let injected: Vec<P::Genome> = cfg
            .seeds
            .iter()
            .take(cfg.population.saturating_sub(anchors.len()))
            .map(|s| problem.fit_seed(s))
            .collect();
        let seeds: Vec<P::Genome> = (0..cfg.population)
            .map(|i| {
                let mut g = if i < anchors.len() {
                    anchors[i].clone()
                } else if i - anchors.len() < injected.len() {
                    injected[i - anchors.len()].clone()
                } else {
                    problem.random(&mut rng)
                };
                stats.produced += 1;
                if problem.repair(&mut g) {
                    stats.repaired += 1;
                }
                g
            })
            .collect();
        start_gen = 0;
        pop = evaluate_batch(seeds, &eval, memo, cfg.workers, &mut stats);
        on_generation(&checkpoint_of(0, &rng, &pop));
    }

    for _gen in start_gen..cfg.generations {
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        // binary tournament by (rank, crowding)
        let better = |a: &Individual<P::Genome>, b: &Individual<P::Genome>| -> bool {
            a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding)
        };
        // generate the whole brood first (same RNG stream as the serial
        // implementation — eval never touched the RNG), then evaluate it
        // as one memoized, parallel batch
        let mut brood: Vec<P::Genome> = Vec::with_capacity(cfg.population);
        while brood.len() < cfg.population {
            let pick = |rng: &mut Rng, pop: &[Individual<P::Genome>]| -> P::Genome {
                let a = rng.usize(pop.len());
                let b = rng.usize(pop.len());
                if better(&pop[a], &pop[b]) { pop[a].genome.clone() } else { pop[b].genome.clone() }
            };
            let mut c1 = pick(&mut rng, &pop);
            let c2 = pick(&mut rng, &pop);
            if rng.bool(cfg.crossover_p) {
                problem.crossover(&mut c1, &c2, &mut rng);
            }
            problem.mutate(&mut c1, &mut rng, cfg.mutation_p);
            stats.produced += 1;
            if problem.repair(&mut c1) {
                stats.repaired += 1;
            }
            brood.push(c1);
        }
        let offspring = evaluate_batch(brood, &eval, memo, cfg.workers, &mut stats);
        // elitist survival: μ+λ, keep best `population` by (rank, crowding)
        pop.extend(offspring);
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        // total_cmp: crowding can be NaN when an objective is NaN, and a
        // panicking sort here would abort the whole run
        pop.sort_by(|a, b| {
            a.rank
                .cmp(&b.rank)
                .then(b.crowding.total_cmp(&a.crowding))
        });
        pop.truncate(cfg.population);
        stats.generations += 1;
        on_generation(&checkpoint_of(_gen + 1, &rng, &pop));
    }

    // return the deduplicated first front
    let fronts = non_dominated_sort(&mut pop);
    let mut out: Vec<Individual<P::Genome>> = vec![];
    if let Some(first) = fronts.first() {
        let mut seen = std::collections::HashSet::new();
        for &i in first {
            if seen.insert(pop[i].genome.clone()) {
                out.push(pop[i].clone());
            }
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_relation() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
    }

    fn mk(objs: &[(f64, f64)]) -> Vec<Individual> {
        objs.iter()
            .map(|&(a, b)| Individual {
                genome: vec![],
                objectives: vec![a, b],
                rank: 0,
                crowding: 0.0,
            })
            .collect()
    }

    #[test]
    fn sorting_produces_correct_fronts() {
        let mut pop = mk(&[(1.0, 4.0), (2.0, 3.0), (3.0, 2.0), (2.5, 3.5), (4.0, 4.0)]);
        let fronts = non_dominated_sort(&mut pop);
        let f0: std::collections::HashSet<_> = fronts[0].iter().copied().collect();
        assert_eq!(f0, [0usize, 1, 2].into_iter().collect());
        assert!(fronts[1].contains(&3));
        assert_eq!(pop[4].rank, 2);
    }

    #[test]
    fn pareto_rank0_matches_dominance_and_drops_nans() {
        let objs: Vec<Objectives> = vec![
            vec![1.0, 4.0, 1.0, 2.0],
            vec![2.0, 3.0, 1.0, 2.0],
            vec![2.0, 4.0, 1.0, 2.0],      // dominated by index 1
            vec![f64::NAN, 0.0, 0.0, 0.0], // NaN row never enters
            vec![1.0, 4.0, 1.0, 2.0],      // duplicate of 0: both survive
        ];
        assert_eq!(pareto_rank0(&objs), vec![0, 1, 4]);
        assert!(pareto_rank0(&[]).is_empty());
        // single valid row is trivially the whole front
        assert_eq!(pareto_rank0(&[vec![5.0]]), vec![0]);
    }

    #[test]
    fn crowding_boundary_is_infinite() {
        let mut pop = mk(&[(1.0, 4.0), (2.0, 3.0), (3.0, 2.0)]);
        let fronts = non_dominated_sort(&mut pop);
        crowding_distance(&mut pop, &fronts[0]);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[2].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    }

    #[test]
    fn optimizes_a_known_tradeoff() {
        // objectives: (#ones, #zeros) — the Pareto front is every mix; the
        // GA must return a non-dominated, diverse set
        let width = 24;
        let front = nsga2(
            width,
            &GaConfig { population: 24, generations: 20, ..Default::default() },
            |g| {
                let ones = g.iter().filter(|&&b| b).count() as f64;
                vec![ones, width as f64 - ones]
            },
        );
        assert!(!front.is_empty());
        // all returned points must be mutually non-dominated
        for a in &front {
            for b in &front {
                assert!(!dominates(&a.objectives, &b.objectives) || a.objectives == b.objectives);
            }
        }
        // diversity: at least 5 distinct trade-off points
        let distinct: std::collections::HashSet<u64> =
            front.iter().map(|i| i.objectives[0] as u64).collect();
        assert!(distinct.len() >= 5, "only {} distinct points", distinct.len());
    }

    #[test]
    fn converges_to_single_optimum_when_objectives_align() {
        // both objectives minimized by the all-false genome
        let front = nsga2(
            16,
            &GaConfig { population: 20, generations: 25, ..Default::default() },
            |g| {
                let ones = g.iter().filter(|&&b| b).count() as f64;
                vec![ones, ones * 2.0]
            },
        );
        assert!(front.iter().any(|i| i.objectives[0] == 0.0));
    }

    #[test]
    fn identical_results_across_worker_counts() {
        let run = |workers: usize| {
            nsga2(
                12,
                &GaConfig { population: 16, generations: 8, workers, ..Default::default() },
                |g| {
                    let ones = g.iter().filter(|&&b| b).count() as f64;
                    let runs = g.windows(2).filter(|p| p[0] != p[1]).count() as f64;
                    vec![ones, runs]
                },
            )
            .into_iter()
            .map(|i| (i.genome, i.objectives))
            .collect::<Vec<_>>()
        };
        let serial = run(1);
        assert_eq!(serial, run(4));
        assert_eq!(serial, run(8));
    }

    #[test]
    fn memo_skips_duplicate_genomes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let front = nsga2(
            6,
            &GaConfig { population: 16, generations: 10, workers: 1, ..Default::default() },
            |g| {
                calls.fetch_add(1, Ordering::Relaxed);
                vec![g.iter().filter(|&&b| b).count() as f64]
            },
        );
        assert!(!front.is_empty());
        // only 2^6 distinct genomes exist; without the memo the GA would
        // issue population × (generations + 1) = 176 evaluations
        assert!(calls.load(Ordering::Relaxed) <= 64, "memo failed: {} calls", calls.load(Ordering::Relaxed));
    }

    #[test]
    fn nan_objectives_do_not_panic_the_ga() {
        // one genome family poisons an objective with NaN: the sorts must
        // survive (total_cmp) and the GA must still return a front
        let front = nsga2(
            10,
            &GaConfig { population: 14, generations: 8, workers: 1, ..Default::default() },
            |g| {
                let ones = g.iter().filter(|&&b| b).count() as f64;
                let poisoned = if g[0] { f64::NAN } else { 10.0 - ones };
                vec![ones, poisoned]
            },
        );
        assert!(!front.is_empty());
        // the run completed: every survivor is a well-formed individual
        // (pre-fix, the crowding/elitist sorts panicked on the first NaN)
        for i in &front {
            assert_eq!(i.genome.len(), 10);
            assert_eq!(i.objectives.len(), 2);
        }
    }

    #[test]
    fn injected_seeds_enter_the_initial_population() {
        // a seeded optimum the random initializer is unlikely to produce:
        // minimize hamming distance to a fixed pattern
        let width = 16;
        let target: Genome = (0..width).map(|i| i % 3 == 0).collect();
        let t = target.clone();
        let eval = move |g: &Genome| -> Objectives {
            let d = g.iter().zip(&t).filter(|(a, b)| a != b).count() as f64;
            vec![d]
        };
        let cfg = GaConfig {
            population: 8,
            generations: 0, // initial population only: no search at all
            workers: 1,
            seeds: vec![target.clone()],
            ..Default::default()
        };
        let front = nsga2(width, &cfg, &eval);
        assert!(
            front.iter().any(|i| i.genome == target && i.objectives[0] == 0.0),
            "seeded genome missing from the zero-generation front"
        );
        // short/long seeds are padded/clipped to the problem width
        let cfg2 = GaConfig {
            seeds: vec![vec![true; 4], vec![false; 64]],
            population: 8,
            generations: 0,
            workers: 1,
            ..Default::default()
        };
        for i in nsga2(width, &cfg2, &eval) {
            assert_eq!(i.genome.len(), width);
        }
    }

    #[test]
    fn warm_memo_skips_known_genomes_and_is_returned() {
        use std::collections::HashMap;
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cfg = GaConfig { population: 12, generations: 4, workers: 1, ..Default::default() };
        let calls_cold = AtomicUsize::new(0);
        let mut memo: HashMap<Genome, Objectives> = HashMap::new();
        let cold = nsga2_with_memo(8, &cfg, |g| {
            calls_cold.fetch_add(1, Ordering::Relaxed);
            vec![g.iter().filter(|&&b| b).count() as f64]
        }, &mut memo);
        assert!(!cold.is_empty());
        assert_eq!(memo.len(), calls_cold.load(Ordering::Relaxed), "memo must hold every evaluation");

        // same config + warm memo: the identical genome stream re-runs
        // with zero fresh evaluations and an identical front
        let calls_warm = AtomicUsize::new(0);
        let mut warm_memo = memo.clone();
        let warm = nsga2_with_memo(8, &cfg, |g| {
            calls_warm.fetch_add(1, Ordering::Relaxed);
            vec![g.iter().filter(|&&b| b).count() as f64]
        }, &mut warm_memo);
        assert_eq!(calls_warm.load(Ordering::Relaxed), 0, "warm memo re-evaluated genomes");
        let key = |v: &[Individual]| {
            v.iter().map(|i| (i.genome.clone(), i.objectives.clone())).collect::<Vec<_>>()
        };
        assert_eq!(key(&cold), key(&warm));
    }

    #[test]
    fn resumed_run_matches_uninterrupted_at_every_checkpoint() {
        let cfg = GaConfig { population: 10, generations: 6, workers: 1, ..Default::default() };
        let eval = |g: &Genome| -> Objectives {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            let runs = g.windows(2).filter(|p| p[0] != p[1]).count() as f64;
            vec![ones, runs]
        };
        let key = |v: Vec<Individual>| {
            v.into_iter().map(|i| (i.genome, i.objectives)).collect::<Vec<_>>()
        };
        let mut cps: Vec<GaCheckpoint> = vec![];
        let full = key(nsga2_resumable(9, &cfg, eval, &mut HashMap::new(), None, |cp| {
            cps.push(cp.clone())
        }));
        // one checkpoint after init + one per generation
        assert_eq!(cps.len(), cfg.generations + 1);
        assert_eq!(cps[0].generation, 0);
        assert_eq!(cps.last().unwrap().generation, cfg.generations);
        // restarting from every boundary reproduces the uninterrupted front
        for cp in cps {
            let resumed =
                key(nsga2_resumable(9, &cfg, eval, &mut HashMap::new(), Some(cp), |_| {}));
            assert_eq!(resumed, full, "resume diverged from the uninterrupted run");
        }
    }

    #[test]
    fn resume_from_the_final_checkpoint_evaluates_nothing() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cfg = GaConfig { population: 8, generations: 3, workers: 1, ..Default::default() };
        let eval = |g: &Genome| vec![g.iter().filter(|&&b| b).count() as f64];
        let mut last: Option<GaCheckpoint> = None;
        let full = nsga2_resumable(6, &cfg, eval, &mut HashMap::new(), None, |cp| {
            last = Some(cp.clone())
        });
        let cp = last.expect("a checkpoint was emitted");
        let calls = AtomicUsize::new(0);
        let resumed = nsga2_resumable(
            6,
            &cfg,
            |g: &Genome| {
                calls.fetch_add(1, Ordering::Relaxed);
                vec![g.iter().filter(|&&b| b).count() as f64]
            },
            &mut HashMap::new(),
            Some(cp),
            |_| {},
        );
        assert_eq!(calls.load(Ordering::Relaxed), 0, "final checkpoint must skip the loop");
        let key = |v: &[Individual]| {
            v.iter().map(|i| (i.genome.clone(), i.objectives.clone())).collect::<Vec<_>>()
        };
        assert_eq!(key(&full), key(&resumed));
    }

    /// Line-for-line port of the pre-refactor hard-coded `Vec<bool>`
    /// NSGA-II (serial, memoized): the generic core behind the wrappers
    /// must reproduce it bit for bit — same RNG draws, same genomes,
    /// same survival order, same final front.
    fn reference_nsga2(
        width: usize,
        cfg: &GaConfig,
        eval: impl Fn(&Genome) -> Objectives,
    ) -> Vec<Individual> {
        let mut memo: HashMap<Genome, Objectives> = HashMap::new();
        let mut eval_all = move |genomes: Vec<Genome>| -> Vec<Individual> {
            genomes
                .into_iter()
                .map(|genome| {
                    let objectives =
                        memo.entry(genome.clone()).or_insert_with(|| eval(&genome)).clone();
                    Individual { genome, objectives, rank: 0, crowding: 0.0 }
                })
                .collect()
        };
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let seeds: Vec<Genome> = (0..cfg.population)
            .map(|i| match i {
                0 => vec![false; width],
                1 => vec![true; width],
                _ => {
                    let p = rng.range_f64(0.05, 0.8);
                    (0..width).map(|_| rng.bool(p)).collect()
                }
            })
            .collect();
        let mut pop = eval_all(seeds);
        for _gen in 0..cfg.generations {
            let fronts = non_dominated_sort(&mut pop);
            for f in &fronts {
                crowding_distance(&mut pop, f);
            }
            let mut brood: Vec<Genome> = vec![];
            while brood.len() < cfg.population {
                let pick = |rng: &mut Rng, pop: &[Individual]| -> Genome {
                    let a = rng.usize(pop.len());
                    let b = rng.usize(pop.len());
                    let better = pop[a].rank < pop[b].rank
                        || (pop[a].rank == pop[b].rank && pop[a].crowding > pop[b].crowding);
                    if better { pop[a].genome.clone() } else { pop[b].genome.clone() }
                };
                let mut c1 = pick(&mut rng, &pop);
                let c2 = pick(&mut rng, &pop);
                if rng.bool(cfg.crossover_p) {
                    for i in 0..width {
                        if rng.bool(0.5) {
                            c1[i] = c2[i];
                        }
                    }
                }
                for bit in c1.iter_mut() {
                    if rng.bool(cfg.mutation_p) {
                        *bit = !*bit;
                    }
                }
                brood.push(c1);
            }
            pop.extend(eval_all(brood));
            let fronts = non_dominated_sort(&mut pop);
            for f in &fronts {
                crowding_distance(&mut pop, f);
            }
            pop.sort_by(|a, b| a.rank.cmp(&b.rank).then(b.crowding.total_cmp(&a.crowding)));
            pop.truncate(cfg.population);
        }
        let fronts = non_dominated_sort(&mut pop);
        let mut out: Vec<Individual> = vec![];
        if let Some(first) = fronts.first() {
            let mut seen = std::collections::HashSet::new();
            for &i in first {
                if seen.insert(pop[i].genome.clone()) {
                    out.push(pop[i].clone());
                }
            }
        }
        out
    }

    #[test]
    fn reference_bitmask_ga_matches_the_generic_core() {
        let cfg = GaConfig { population: 14, generations: 7, workers: 1, ..Default::default() };
        let eval = |g: &Genome| -> Objectives {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            let runs = g.windows(2).filter(|p| p[0] != p[1]).count() as f64;
            vec![ones, runs]
        };
        let key = |v: Vec<Individual>| {
            v.into_iter().map(|i| (i.genome, i.objectives)).collect::<Vec<_>>()
        };
        let legacy = key(reference_nsga2(11, &cfg, eval));
        let generic = key(nsga2(11, &cfg, eval));
        assert_eq!(legacy, generic, "generic core diverged from the pre-refactor GA");
    }

    #[test]
    fn stats_count_evaluations_memo_hits_and_generations() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cfg = GaConfig { population: 12, generations: 5, workers: 1, ..Default::default() };
        let calls = AtomicUsize::new(0);
        let mut memo: HashMap<Genome, Objectives> = HashMap::new();
        let (front, stats) = nsga2_problem(
            &BitmaskProblem { width: 6 },
            &cfg,
            |g| {
                calls.fetch_add(1, Ordering::Relaxed);
                vec![g.iter().filter(|&&b| b).count() as f64]
            },
            &mut memo,
            None,
            |_| {},
        );
        assert!(!front.is_empty());
        assert_eq!(stats.evaluated, calls.load(Ordering::Relaxed));
        assert_eq!(stats.evaluated, memo.len());
        assert_eq!(stats.generations, cfg.generations);
        // init population + one brood per generation
        assert_eq!(stats.produced, cfg.population * (cfg.generations + 1));
        assert_eq!(stats.evaluated + stats.memo_hits, stats.produced);
        // bitmask genomes are always feasible: repair never fires
        assert_eq!(stats.repaired, 0);
        assert_eq!(stats.repair_rate(), 0.0);
        // width-6 search (64 possible genomes, 72 lookups) must repeat
        assert!(stats.memo_hits > 0, "no memo hits in a converging run");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            nsga2(8, &GaConfig::default(), |g| {
                vec![g.iter().filter(|&&b| b).count() as f64]
            })
            .into_iter()
            .map(|i| i.genome)
            .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
