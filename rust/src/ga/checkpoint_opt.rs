//! The activation-checkpointing optimization problem (paper §V-B2):
//! NSGA-II over the checkpoint/recompute bitvector, with objectives
//! (latency, energy, stored-activation memory) evaluated by the full
//! layer-fused scheduling pipeline — the non-linear evaluation the MILP
//! formulation cannot capture (§V-B1).

use crate::autodiff::{
    apply_checkpointing, checkpoint_candidates, stored_activation_bytes, CheckpointPlan,
    TrainingGraph,
};
use crate::fusion::{fuse_greedy, FusionConstraints};
use crate::ga::nsga2::{nsga2, GaConfig, Genome};
use crate::hardware::accelerator::Accelerator;
use crate::mapping::MappingConfig;
use crate::scheduler::schedule;
use crate::workload::graph::NodeId;

/// One point on the checkpointing Pareto front (Fig 12).
#[derive(Debug, Clone)]
pub struct CheckpointSolution {
    pub plan: CheckpointPlan,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Stored-activation bytes (FP16 accounting as in the paper).
    pub stored_bytes_fp16: u64,
    /// Fraction of baseline activation memory avoided.
    pub memory_saving: f64,
}

/// Problem instance.
pub struct CheckpointProblem<'a> {
    pub tg: &'a TrainingGraph,
    pub accel: &'a Accelerator,
    pub mapping: MappingConfig,
    pub fusion: FusionConstraints,
    pub candidates: Vec<NodeId>,
}

impl<'a> CheckpointProblem<'a> {
    pub fn new(
        tg: &'a TrainingGraph,
        accel: &'a Accelerator,
        mapping: MappingConfig,
        fusion: FusionConstraints,
    ) -> Self {
        let candidates = checkpoint_candidates(tg);
        CheckpointProblem { tg, accel, mapping, fusion, candidates }
    }

    pub fn genome_to_plan(&self, genome: &Genome) -> CheckpointPlan {
        CheckpointPlan {
            recompute: self
                .candidates
                .iter()
                .zip(genome)
                .filter(|(_, &bit)| bit)
                .map(|(&n, _)| n)
                .collect(),
        }
    }

    /// Evaluate one plan through the full pipeline: checkpoint transform →
    /// (greedy) fusion → layer-fused schedule. Returns (latency, energy,
    /// stored FP16 bytes).
    pub fn evaluate(&self, plan: &CheckpointPlan) -> (f64, f64, u64) {
        let g = apply_checkpointing(self.tg, plan);
        let partition = fuse_greedy(&g, &self.fusion);
        let r = schedule(&g, &partition, self.accel, &self.mapping);
        // paper §V-B2: memory metric assumes FP16 storage (half of our
        // FP32 graph bytes)
        let stored = stored_activation_bytes(self.tg, plan) / 2;
        (r.latency_cycles, r.energy_pj, stored)
    }

    /// Run the GA; returns the Pareto front sorted by memory saving.
    pub fn optimize(&self, ga: &GaConfig) -> Vec<CheckpointSolution> {
        let width = self.candidates.len();
        let baseline = stored_activation_bytes(self.tg, &CheckpointPlan::save_all()) / 2;
        let front = nsga2(width, ga, |genome| {
            let plan = self.genome_to_plan(genome);
            let (lat, en, mem) = self.evaluate(&plan);
            vec![lat, en, mem as f64]
        });
        let mut out: Vec<CheckpointSolution> = front
            .into_iter()
            .map(|ind| {
                let plan = self.genome_to_plan(&ind.genome);
                let stored = stored_activation_bytes(self.tg, &plan) / 2;
                CheckpointSolution {
                    plan,
                    latency_cycles: ind.objectives[0],
                    energy_pj: ind.objectives[1],
                    stored_bytes_fp16: stored,
                    memory_saving: if baseline > 0 {
                        1.0 - stored as f64 / baseline as f64
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        out.sort_by(|a, b| a.memory_saving.partial_cmp(&b.memory_saving).unwrap());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::hardware::presets::EdgeTpuParams;
    use crate::workload::models::mlp;
    use crate::workload::op::Optimizer;

    fn problem_parts() -> (TrainingGraph, Accelerator) {
        let tg = build_training_graph(
            &mlp(1, 32, 64, 3, 10),
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        );
        let accel = EdgeTpuParams::baseline().build();
        (tg, accel)
    }

    #[test]
    fn baseline_genome_matches_save_all() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let plan = p.genome_to_plan(&vec![false; p.candidates.len()]);
        assert_eq!(plan, CheckpointPlan::save_all());
    }

    #[test]
    fn recompute_all_saves_memory_costs_time() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let all_false = p.evaluate(&p.genome_to_plan(&vec![false; p.candidates.len()]));
        let all_true = p.evaluate(&p.genome_to_plan(&vec![true; p.candidates.len()]));
        assert!(all_true.2 < all_false.2, "memory must drop");
        assert!(all_true.0 >= all_false.0 * 0.99, "latency should not improve much");
    }

    #[test]
    fn ga_produces_nonempty_sorted_front() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let ga = GaConfig { population: 12, generations: 5, ..Default::default() };
        let front = p.optimize(&ga);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].memory_saving <= w[1].memory_saving + 1e-12);
        }
        // front must contain a high-memory-saving point
        assert!(front.last().unwrap().memory_saving > 0.2);
    }
}
