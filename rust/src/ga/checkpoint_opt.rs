//! The activation-checkpointing optimization problem (paper §V-B2):
//! NSGA-II over the checkpoint/recompute bitvector, with objectives
//! (latency, energy, stored-activation memory) evaluated by the full
//! layer-fused scheduling pipeline — the non-linear evaluation the MILP
//! formulation cannot capture (§V-B1).

use std::collections::HashMap;
use std::hash::Hash;
use std::path::Path;
use std::sync::{Arc, RwLock};

use crate::autodiff::{
    apply_checkpointing, checkpoint_candidates, stored_activation_bytes, CheckpointPlan,
    TrainingGraph,
};
use crate::eval::{persist, CacheStats, CostCache, StructuralHasher};
use crate::fusion::{fuse_greedy, FusionConstraints};
use crate::dse::journal;
use crate::ga::nsga2::{nsga2_resumable, nsga2_with_memo, GaConfig, Genome, Individual, Objectives};
use crate::hardware::accelerator::Accelerator;
use crate::mapping::MappingConfig;
use crate::scheduler::{schedule_with_cache, Partition};
use crate::workload::graph::{Graph, NodeId};

/// One point on the checkpointing Pareto front (Fig 12).
#[derive(Debug, Clone)]
pub struct CheckpointSolution {
    pub plan: CheckpointPlan,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Stored-activation bytes (FP16 accounting as in the paper).
    pub stored_bytes_fp16: u64,
    /// Fraction of baseline activation memory avoided.
    pub memory_saving: f64,
}

/// Problem instance. Carries two memo layers shared by every evaluation
/// (§Perf — NSGA-II revisits near-identical plans constantly):
///
/// * a *transform cache*: recompute-set hash → the checkpointed graph +
///   greedy-fused partition, skipping `apply_checkpointing`/`fuse_greedy`
///   for plans seen in earlier generations;
/// * a shared `eval::CostCache` threaded through `schedule_with_cache`, so
///   fused groups untouched by a plan (the vast majority — a plan rewires
///   a handful of activations) hit costs computed by previous plans.
///
/// Both are behind locks: `nsga2` fans evaluations over worker threads.
pub struct CheckpointProblem<'a> {
    pub tg: &'a TrainingGraph,
    pub accel: &'a Accelerator,
    pub mapping: MappingConfig,
    pub fusion: FusionConstraints,
    pub candidates: Vec<NodeId>,
    cost_cache: CostCache,
    transform_cache: RwLock<HashMap<u128, Arc<(Graph, Partition)>>>,
}

impl<'a> CheckpointProblem<'a> {
    pub fn new(
        tg: &'a TrainingGraph,
        accel: &'a Accelerator,
        mapping: MappingConfig,
        fusion: FusionConstraints,
    ) -> Self {
        Self::new_with_cache(tg, accel, mapping, fusion, CostCache::new())
    }

    /// [`CheckpointProblem::new`] with a caller-provided group-cost cache —
    /// the cross-restart lifecycle hook: pass a warm-loaded
    /// ([`persist::open_cost_cache`]) and/or bounded
    /// ([`CostCache::with_capacity`]) cache instead of a fresh unbounded
    /// one.
    pub fn new_with_cache(
        tg: &'a TrainingGraph,
        accel: &'a Accelerator,
        mapping: MappingConfig,
        fusion: FusionConstraints,
        cost_cache: CostCache,
    ) -> Self {
        let candidates = checkpoint_candidates(tg);
        CheckpointProblem {
            tg,
            accel,
            mapping,
            fusion,
            candidates,
            cost_cache,
            transform_cache: RwLock::new(HashMap::new()),
        }
    }

    /// The shared group-cost cache (for persisting after a run).
    pub fn cost_cache(&self) -> &CostCache {
        &self.cost_cache
    }

    /// Group-cost cache counters (hit rate of the shared `CostCache`).
    pub fn cache_stats(&self) -> CacheStats {
        self.cost_cache.stats()
    }

    /// Structural identity of this problem instance: everything the
    /// objective function reads beyond the genome (workload shape,
    /// candidate set, accelerator, mapping, fusion constraints). A
    /// persisted genome→objectives memo is only reusable when this key
    /// matches — a memo carries *final* objective values, so replaying it
    /// against a different problem would corrupt results silently.
    pub fn warm_key(&self) -> u128 {
        let mut h = StructuralHasher::new();
        self.tg.graph.len().hash(&mut h);
        self.tg.graph.edges.len().hash(&mut h);
        self.tg.graph.elem_bytes.hash(&mut h);
        // full graph structure, not just counts: two workloads with equal
        // topology but different layer shapes (e.g. the same model at two
        // resolutions) must never share a memo of final objective values
        for n in &self.tg.graph.nodes {
            n.kind.structural_hash(&mut h);
            crate::scheduler::phase_index(n.phase).hash(&mut h);
        }
        for e in &self.tg.graph.edges {
            e.src.hash(&mut h);
            e.dst.hash(&mut h);
            e.bytes.hash(&mut h);
            e.is_activation.hash(&mut h);
        }
        self.candidates.hash(&mut h);
        self.accel.cores.len().hash(&mut h);
        for c in &self.accel.cores {
            crate::eval::hash_core_class(&mut h, c);
        }
        self.accel.offchip_bw.to_bits().hash(&mut h);
        self.accel.global_buffer_bytes.hash(&mut h);
        self.accel.global_buffer_bw.to_bits().hash(&mut h);
        self.accel.interconnect.link_bw.to_bits().hash(&mut h);
        self.accel.interconnect.link_energy_pj.to_bits().hash(&mut h);
        self.mapping.tensor_parallel.hash(&mut h);
        self.mapping.intra_core_tiling.hash(&mut h);
        self.fusion.max_len.hash(&mut h);
        self.fusion.mem_budget.hash(&mut h);
        self.fusion.tiling.hash(&mut h);
        self.fusion.max_convs.hash(&mut h);
        self.fusion.max_gemms.hash(&mut h);
        self.fusion.op_type_constraint.hash(&mut h);
        self.fusion.per_seed_cap.hash(&mut h);
        h.finish128()
    }

    /// Structural key of a plan: the sorted recompute set. Plans with equal
    /// keys produce identical transformed graphs (`apply_checkpointing` is
    /// deterministic in its inputs).
    fn plan_key(plan: &CheckpointPlan) -> u128 {
        let mut nodes: Vec<NodeId> = plan.recompute.iter().copied().collect();
        nodes.sort_unstable();
        let mut h = StructuralHasher::new();
        nodes.hash(&mut h);
        h.finish128()
    }

    /// Upper bound on retained transforms. Unlike `CostCache` (small,
    /// fixed-size `NodeCost` entries), each entry here is a whole cloned
    /// graph + partition, so an unbounded map could reach GBs on a
    /// long-running GA over a large model. When full, the map is cleared
    /// and refilled — recent (converged, frequently-revisited) plans
    /// re-enter immediately; results are unaffected either way.
    const TRANSFORM_CACHE_CAP: usize = 1024;

    /// Checkpoint-transform + greedy fusion for `plan`, memoized.
    fn transformed(&self, plan: &CheckpointPlan) -> Arc<(Graph, Partition)> {
        let key = Self::plan_key(plan);
        if let Some(gp) = self.transform_cache.read().unwrap().get(&key) {
            return Arc::clone(gp);
        }
        // compute outside the write lock; a racing duplicate is identical
        // (the transform is deterministic) and first-insert wins
        let g = apply_checkpointing(self.tg, plan);
        let partition = fuse_greedy(&g, &self.fusion);
        let gp = Arc::new((g, partition));
        let mut cache = self.transform_cache.write().unwrap();
        if cache.len() >= Self::TRANSFORM_CACHE_CAP {
            cache.clear();
        }
        Arc::clone(cache.entry(key).or_insert(gp))
    }

    pub fn genome_to_plan(&self, genome: &Genome) -> CheckpointPlan {
        CheckpointPlan {
            recompute: self
                .candidates
                .iter()
                .zip(genome)
                .filter(|(_, &bit)| bit)
                .map(|(&n, _)| n)
                .collect(),
        }
    }

    /// Inverse of [`CheckpointProblem::genome_to_plan`] (used to persist a
    /// front as warm-start seeds).
    pub fn plan_to_genome(&self, plan: &CheckpointPlan) -> Genome {
        self.candidates.iter().map(|n| plan.recompute.contains(n)).collect()
    }

    /// Evaluate one plan through the full pipeline: checkpoint transform →
    /// (greedy) fusion → layer-fused schedule, with both memo layers
    /// engaged. Returns (latency, energy, stored FP16 bytes) — bit-exactly
    /// what the uncached pipeline returns.
    pub fn evaluate(&self, plan: &CheckpointPlan) -> (f64, f64, u64) {
        let gp = self.transformed(plan);
        let (g, partition) = (&gp.0, &gp.1);
        let r = schedule_with_cache(g, partition, self.accel, &self.mapping, Some(&self.cost_cache));
        // paper §V-B2: memory metric assumes FP16 storage (half of our
        // FP32 graph bytes)
        let stored = stored_activation_bytes(self.tg, plan) / 2;
        (r.latency_cycles, r.energy_pj, stored)
    }

    /// Run the GA; returns the Pareto front sorted by memory saving.
    pub fn optimize(&self, ga: &GaConfig) -> Vec<CheckpointSolution> {
        self.optimize_with_memo(ga, &mut HashMap::new())
    }

    /// [`CheckpointProblem::optimize`] with a caller-owned
    /// genome→objectives memo (see [`nsga2_with_memo`]): pre-loaded
    /// entries skip the full checkpoint→fuse→schedule pipeline, and the
    /// map holds every evaluation on return, ready to persist.
    pub fn optimize_with_memo(
        &self,
        ga: &GaConfig,
        memo: &mut HashMap<Genome, Objectives>,
    ) -> Vec<CheckpointSolution> {
        let width = self.candidates.len();
        let front = nsga2_with_memo(
            width,
            ga,
            |genome| {
                let plan = self.genome_to_plan(genome);
                let (lat, en, mem) = self.evaluate(&plan);
                vec![lat, en, mem as f64]
            },
            memo,
        );
        self.solutions_from(front)
    }

    /// The full cross-restart lifecycle: warm-start from `dir` when a
    /// matching snapshot exists (previous front injected as population
    /// seeds, genome memo reloaded), run the GA, and persist the new
    /// front + memo back — so a restarted checkpointing run resumes from
    /// the previous Pareto front instead of a random population. The
    /// group-cost cache is persisted separately (see
    /// [`persist::persist_cost_cache`]); callers owning a `--cache-dir`
    /// typically do both.
    pub fn optimize_persistent(&self, ga: &GaConfig, dir: &Path) -> Vec<CheckpointSolution> {
        let key = self.warm_key();
        let width = self.candidates.len();
        let mut cfg = ga.clone();
        let mut memo: HashMap<Genome, Objectives> = HashMap::new();
        if let Some(warm) = persist::load_ga_warmstart(dir, key, width) {
            if cfg.seeds.is_empty() {
                cfg.seeds = warm.seeds;
            }
            memo = warm.memo;
        }
        let front = self.optimize_with_memo(&cfg, &mut memo);
        let seeds: Vec<Genome> = front.iter().map(|s| self.plan_to_genome(&s.plan)).collect();
        if let Err(e) = persist::save_ga_warmstart(dir, key, width, &seeds, &memo) {
            eprintln!(
                "warning: failed to persist GA warm-start to {}: {e}",
                dir.display()
            );
        }
        front
    }

    /// Identity of one GA *run* for journal/resume purposes: the problem's
    /// [`warm_key`](CheckpointProblem::warm_key) plus every GA parameter
    /// that shapes the genome stream (population, generations, rates, seed,
    /// injected seeds). `workers` is deliberately excluded — the front is
    /// bit-identical for any worker count, so a journal written with 8
    /// workers resumes cleanly under 1.
    pub fn ga_run_digest(&self, ga: &GaConfig) -> u128 {
        let mut h = StructuralHasher::new();
        self.warm_key().hash(&mut h);
        self.candidates.len().hash(&mut h);
        ga.population.hash(&mut h);
        ga.generations.hash(&mut h);
        ga.crossover_p.to_bits().hash(&mut h);
        ga.mutation_p.to_bits().hash(&mut h);
        ga.seed.hash(&mut h);
        ga.seeds.hash(&mut h);
        h.finish128()
    }

    /// [`CheckpointProblem::optimize`] with crash-safe per-generation
    /// journaling: every completed generation appends a checksummed
    /// [`GaCheckpoint`](crate::ga::nsga2::GaCheckpoint) to
    /// `run_dir/ga_journal.bin`, and `resume` restarts the search from the
    /// last intact checkpoint whose run digest matches — so a GA killed
    /// mid-search loses at most one generation, and the resumed front is
    /// bit-identical to an uninterrupted run.
    ///
    /// Failure semantics: an unopenable journal (unwritable `run_dir`,
    /// quarantined mismatched file) degrades to a plain unjournaled
    /// [`optimize`](CheckpointProblem::optimize) with a warning; a write
    /// failure mid-run warns once and the search continues without further
    /// checkpoints. Neither path panics or changes the returned front.
    pub fn optimize_journaled(
        &self,
        ga: &GaConfig,
        run_dir: &Path,
        resume: bool,
    ) -> Vec<CheckpointSolution> {
        let digest = self.ga_run_digest(ga);
        let path = run_dir.join(journal::GA_JOURNAL_FILE);
        let (payloads, file) = match journal::open_journal(
            &path,
            journal::GA_JOURNAL_MAGIC,
            digest,
            resume,
        ) {
            Ok(v) => v,
            Err(e) => {
                eprintln!(
                    "warning: GA journal {} unavailable ({e}); running without crash-safety",
                    path.display()
                );
                return self.optimize(ga);
            }
        };
        let resume_cp =
            payloads.iter().rev().find_map(|p| journal::decode_ga_checkpoint::<Genome>(p));
        let mut file = file;
        let mut dead = false;
        let front = nsga2_resumable(
            self.candidates.len(),
            ga,
            |genome| {
                let plan = self.genome_to_plan(genome);
                let (lat, en, mem) = self.evaluate(&plan);
                vec![lat, en, mem as f64]
            },
            &mut HashMap::new(),
            resume_cp,
            |cp| {
                if dead {
                    return;
                }
                if let Err(e) = file.append_record(&journal::encode_ga_checkpoint(cp)) {
                    dead = true;
                    eprintln!(
                        "warning: GA journal write to {} failed ({e}); \
                         continuing without further checkpoints",
                        path.display()
                    );
                }
            },
        );
        self.solutions_from(front)
    }

    fn solutions_from(&self, front: Vec<Individual>) -> Vec<CheckpointSolution> {
        let baseline = stored_activation_bytes(self.tg, &CheckpointPlan::save_all()) / 2;
        let mut out: Vec<CheckpointSolution> = front
            .into_iter()
            .map(|ind| {
                let plan = self.genome_to_plan(&ind.genome);
                let stored = stored_activation_bytes(self.tg, &plan) / 2;
                CheckpointSolution {
                    plan,
                    latency_cycles: ind.objectives[0],
                    energy_pj: ind.objectives[1],
                    stored_bytes_fp16: stored,
                    memory_saving: if baseline > 0 {
                        1.0 - stored as f64 / baseline as f64
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        // total_cmp: a NaN objective must not abort the whole run here
        // either (the saving is derived from integer byte counts, but the
        // sort should never be the thing that panics)
        out.sort_by(|a, b| a.memory_saving.total_cmp(&b.memory_saving));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::hardware::presets::EdgeTpuParams;
    use crate::workload::models::mlp;
    use crate::workload::op::Optimizer;

    fn problem_parts() -> (TrainingGraph, Accelerator) {
        let tg = build_training_graph(
            &mlp(1, 32, 64, 3, 10),
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        );
        let accel = EdgeTpuParams::baseline().build();
        (tg, accel)
    }

    #[test]
    fn baseline_genome_matches_save_all() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let plan = p.genome_to_plan(&vec![false; p.candidates.len()]);
        assert_eq!(plan, CheckpointPlan::save_all());
    }

    #[test]
    fn recompute_all_saves_memory_costs_time() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let all_false = p.evaluate(&p.genome_to_plan(&vec![false; p.candidates.len()]));
        let all_true = p.evaluate(&p.genome_to_plan(&vec![true; p.candidates.len()]));
        assert!(all_true.2 < all_false.2, "memory must drop");
        assert!(all_true.0 >= all_false.0 * 0.99, "latency should not improve much");
    }

    #[test]
    fn evaluation_is_memoized_and_stable() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let genome: Vec<bool> = (0..p.candidates.len()).map(|i| i % 2 == 0).collect();
        let plan = p.genome_to_plan(&genome);
        let a = p.evaluate(&plan);
        let b = p.evaluate(&plan);
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1.to_bits(), b.1.to_bits());
        assert_eq!(a.2, b.2);
        let s = p.cache_stats();
        // the second evaluation reuses the transform and every group cost
        assert!(s.hits > 0, "cost cache never hit: {s:?}");
    }

    #[test]
    fn journaled_ga_matches_unjournaled_and_resumes_bit_identically() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let ga = GaConfig { population: 8, generations: 3, workers: 1, ..Default::default() };
        let dir = std::env::temp_dir()
            .join(format!("monet_ga_journal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let key = |v: &[CheckpointSolution]| {
            v.iter()
                .map(|s| {
                    (
                        s.plan.clone(),
                        s.latency_cycles.to_bits(),
                        s.energy_pj.to_bits(),
                        s.stored_bytes_fp16,
                    )
                })
                .collect::<Vec<_>>()
        };
        let plain = p.optimize(&ga);
        let journaled = p.optimize_journaled(&ga, &dir, false);
        assert_eq!(key(&plain), key(&journaled), "journaling changed the front");
        assert!(dir.join(journal::GA_JOURNAL_FILE).exists(), "no journal written");
        // resume from the completed journal: the final checkpoint replays
        // the front without re-running a single generation
        let resumed = p.optimize_journaled(&ga, &dir, true);
        assert_eq!(key(&plain), key(&resumed), "resume diverged");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unseeded_checkpointing_run_is_unchanged_on_the_generic_core() {
        // satellite of the generify PR: the warm-start machinery
        // (plan_to_genome round-trip, memoized optimize) now rides the
        // generic NSGA-II core through the BitmaskProblem instance — an
        // unseeded run must be bit-identical to driving the core directly.
        use crate::ga::nsga2::{nsga2_problem, BitmaskProblem};
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let ga = GaConfig { population: 8, generations: 3, workers: 1, ..Default::default() };
        let mut memo: HashMap<Genome, Objectives> = HashMap::new();
        let via_problem = p.optimize_with_memo(&ga, &mut memo);
        let mut direct_memo: HashMap<Genome, Objectives> = HashMap::new();
        let (front, stats) = nsga2_problem(
            &BitmaskProblem { width: p.candidates.len() },
            &ga,
            |genome| {
                let (lat, en, mem) = p.evaluate(&p.genome_to_plan(genome));
                vec![lat, en, mem as f64]
            },
            &mut direct_memo,
            None,
            |_| {},
        );
        let key = |v: &[CheckpointSolution]| {
            v.iter()
                .map(|s| (s.plan.clone(), s.latency_cycles.to_bits(), s.energy_pj.to_bits()))
                .collect::<Vec<_>>()
        };
        let direct = p.solutions_from(front);
        assert_eq!(key(&via_problem), key(&direct), "wrapper diverged from the generic core");
        // both paths evaluated the identical genome set
        assert_eq!(memo.len(), direct_memo.len());
        assert_eq!(stats.evaluated, direct_memo.len());
        assert_eq!(stats.repaired, 0, "bitmask genomes never need repair");
        // plan_to_genome inverts genome_to_plan for every front member, so
        // persisted warm-start seeds re-enter the search unchanged
        for s in &via_problem {
            let g = p.plan_to_genome(&s.plan);
            assert_eq!(p.genome_to_plan(&g), s.plan);
            assert_eq!(g.len(), p.candidates.len());
        }
    }

    #[test]
    fn ga_produces_nonempty_sorted_front() {
        let (tg, accel) = problem_parts();
        let p = CheckpointProblem::new(
            &tg,
            &accel,
            MappingConfig::default(),
            FusionConstraints::default(),
        );
        let ga = GaConfig { population: 12, generations: 5, ..Default::default() };
        let front = p.optimize(&ga);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].memory_saving <= w[1].memory_saving + 1e-12);
        }
        // front must contain a high-memory-saving point
        assert!(front.last().unwrap().memory_saving > 0.2);
    }
}
