//! The linear (MILP) activation-checkpointing baseline of paper §II-A
//! eq. (6) — the Checkmate/Dace-AD formulation MONET argues is inadequate
//! for fused-layer execution (§V-B1):
//!
//!   min Σ_a r_a·(1 − x_a)   s.t.  Σ_a m_a·x_a ≤ M,  x_a ∈ {0,1}
//!
//! where r_a is the *isolated* recompute cost (FLOPs) and m_a the storage
//! bytes of activation a. With one linear constraint this is exactly a 0/1
//! knapsack (checkpoint the activations with the best recompute-cost per
//! byte); we solve it optimally by dynamic programming over a bucketised
//! memory capacity.
//!
//! The point of carrying this baseline is the ablation
//! (`milp_vs_ga_ablation`): MILP plans, *re-evaluated under the true
//! non-linear fused-layer pipeline*, are dominated by the NSGA-II front —
//! quantifying the paper's central §V-B claim.

use crate::autodiff::{checkpoint_candidates, CheckpointPlan, TrainingGraph};
use crate::workload::graph::NodeId;

/// Per-activation linear coefficients: (node, m_a bytes, r_a MACs).
pub fn linear_coefficients(tg: &TrainingGraph) -> Vec<(NodeId, u64, u64)> {
    checkpoint_candidates(tg)
        .into_iter()
        .map(|n| {
            let m = tg.graph.out_bytes(n);
            // isolated recompute cost: the op itself (the linear model's
            // first-order approximation; the whole §V-B point is that the
            // true cost depends on which *other* activations are dropped)
            let r = tg.graph.node(n).kind.macs().max(1);
            (n, m, r)
        })
        .collect()
}

/// Solve eq. (6) optimally for a memory budget (bytes): returns the plan
/// (activations NOT checkpointed are recomputed) plus the objective value
/// (total recompute MACs).
pub fn solve_milp(tg: &TrainingGraph, budget_bytes: u64) -> (CheckpointPlan, u64) {
    const BUCKET: u64 = 4 << 10; // 4 KiB memory granularity
    let items = linear_coefficients(tg);
    // capacity beyond the total item weight is equivalent to "keep all"
    let total_weight: u64 = items.iter().map(|&(_, m, _)| m.div_ceil(BUCKET)).sum();
    let cap = (budget_bytes / BUCKET).min(total_weight) as usize;
    let n = items.len();

    // knapsack: maximise Σ r_a x_a (recompute avoided) under Σ m_a x_a ≤ M
    let weights: Vec<usize> =
        items.iter().map(|&(_, m, _)| (m.div_ceil(BUCKET)) as usize).collect();
    let values: Vec<u64> = items.iter().map(|&(_, _, r)| r).collect();

    let mut dp = vec![0u64; cap + 1];
    let mut take = vec![false; (cap + 1) * n];
    for i in 0..n {
        let w = weights[i];
        if w > cap {
            continue;
        }
        for c in (w..=cap).rev() {
            let cand = dp[c - w] + values[i];
            if cand > dp[c] {
                dp[c] = cand;
                take[c * n + i] = true;
            }
        }
    }
    // reconstruct
    let mut kept = vec![false; n];
    let mut c = cap;
    for i in (0..n).rev() {
        if c >= weights[i] && take[c * n + i] {
            kept[i] = true;
            c -= weights[i];
        }
    }
    let recompute: std::collections::HashSet<NodeId> = items
        .iter()
        .zip(&kept)
        .filter(|(_, &k)| !k)
        .map(|(&(node, _, _), _)| node)
        .collect();
    let objective: u64 = items
        .iter()
        .zip(&kept)
        .filter(|(_, &k)| !k)
        .map(|(&(_, _, r), _)| r)
        .sum();
    (CheckpointPlan { recompute }, objective)
}

/// Sweep eq. (6) over a range of budgets: the MILP "front" in the linear
/// model's own coordinates (budget, predicted recompute MACs, plan).
pub fn milp_budget_sweep(
    tg: &TrainingGraph,
    n_points: usize,
) -> Vec<(u64, u64, CheckpointPlan)> {
    let total: u64 = linear_coefficients(tg).iter().map(|&(_, m, _)| m).sum();
    (0..n_points)
        .map(|i| {
            let budget = total * (i as u64 + 1) / (n_points as u64 + 1);
            let (plan, obj) = solve_milp(tg, budget);
            (budget, obj, plan)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, stored_activation_bytes, TrainOptions};
    use crate::workload::models::{mlp, resnet18};

    fn tg() -> TrainingGraph {
        build_training_graph(&mlp(1, 32, 64, 3, 10), TrainOptions::default())
    }

    #[test]
    fn infinite_budget_checkpoints_everything() {
        let tg = tg();
        let (plan, obj) = solve_milp(&tg, u64::MAX / 2);
        assert!(plan.recompute.is_empty());
        assert_eq!(obj, 0);
    }

    #[test]
    fn zero_budget_recomputes_everything() {
        let tg = tg();
        let (plan, obj) = solve_milp(&tg, 0);
        assert_eq!(plan.recompute.len(), checkpoint_candidates(&tg).len());
        assert!(obj > 0);
    }

    #[test]
    fn plans_respect_budget() {
        let tg = build_training_graph(&resnet18(1, 32, 10), TrainOptions::default());
        let total = stored_activation_bytes(&tg, &CheckpointPlan::save_all());
        for (budget, _, plan) in milp_budget_sweep(&tg, 6) {
            let stored = stored_activation_bytes(&tg, &plan);
            // 4 KiB bucketisation slack
            assert!(
                stored <= budget + 4096 * checkpoint_candidates(&tg).len() as u64,
                "stored {stored} over budget {budget}"
            );
            assert!(stored <= total);
        }
    }

    #[test]
    fn objective_monotone_in_budget() {
        let tg = build_training_graph(&resnet18(1, 32, 10), TrainOptions::default());
        let sweep = milp_budget_sweep(&tg, 8);
        for w in sweep.windows(2) {
            assert!(w[1].1 <= w[0].1, "more budget must not increase recompute");
        }
    }

    #[test]
    fn knapsack_prefers_cheap_to_recompute_per_byte() {
        // with a budget fitting only part of the set, the kept activations
        // must have higher value density than the dropped ones on average
        let tg = tg();
        let items = linear_coefficients(&tg);
        let total: u64 = items.iter().map(|&(_, m, _)| m).sum();
        let (plan, _) = solve_milp(&tg, total / 3);
        let density = |n: &NodeId| {
            let &(_, m, r) = items.iter().find(|(x, _, _)| x == n).unwrap();
            r as f64 / m.max(1) as f64
        };
        let kept: Vec<f64> = items
            .iter()
            .filter(|(n, _, _)| !plan.recompute.contains(n))
            .map(|(n, _, _)| density(n))
            .collect();
        let dropped: Vec<f64> = plan.recompute.iter().map(density).collect();
        if !kept.is_empty() && !dropped.is_empty() {
            let mk = kept.iter().sum::<f64>() / kept.len() as f64;
            let md = dropped.iter().sum::<f64>() / dropped.len() as f64;
            assert!(mk >= md * 0.5, "kept density {mk} vs dropped {md}");
        }
    }
}
