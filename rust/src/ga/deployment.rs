//! The deployment-search genome: NSGA-II over heterogeneous cluster
//! deployments instead of exhaustive enumeration (ROADMAP item 2, walls
//! (b)/(c)). A [`DeploymentGenome`] encodes one `(dp, pp, m, tp)`
//! factorization plus the per-stage device-class placement — the same
//! information as a [`HeteroPoint`](crate::parallelism::HeteroPoint), see
//! [`crate::dse::ClusterSpace::genome_to_hetero`] — and
//! [`DeploymentProblem`] supplies the variation operators:
//!
//! * **mutation** moves one axis or one stage at a time (double/halve a
//!   gang axis, grow/shrink the pipeline by one stage, re-draw the
//!   microbatch count, reassign one stage's class), so consecutive
//!   genomes share almost all of their fused-group structure and the
//!   warm `CostCache`/`StageCutsMemo` keep re-evaluation cheap —
//!   `dse::search::ga_cluster_search` exploits this by recycling worker
//!   scratches (graph + cuts + per-stage `StageEval` memos) across
//!   genomes and generations, so a one-move mutant re-costs only the
//!   stage schedules it actually changed;
//! * **crossover** swaps whole axes between parents (the pipeline depth
//!   and its placement travel together);
//! * **repair** deterministically restores feasibility against the
//!   [`HeteroCluster`] capacity — shrink the `dp·tp` gang until some
//!   class can host a stage, clamp the pipeline depth to the available
//!   stage slots, and reassign over-capacity stages to the class with
//!   the most remaining room. Repair consumes no RNG (the
//!   [`GaProblem`] contract), so resume/worker bit-identity survives
//!   infeasible offspring.
//!
//! Out of scope (ROADMAP wall (a)): a genome's `dp` gang never spans
//! device classes — that needs the mixed-ring all-reduce model.

use crate::ga::nsga2::GaProblem;
use crate::parallelism::HeteroCluster;
use crate::util::rng::Rng;

/// One deployment candidate: `dp·tp`-device gangs per stage, `pp` stages,
/// `microbatches` pipeline microbatches, and the device class hosting
/// each stage (indices into [`HeteroCluster::classes`]). `Ord` is derived
/// so genome collections have a canonical order independent of hash/
/// evaluation order — the GA's archive front is sorted by it.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeploymentGenome {
    pub dp: usize,
    pub pp: usize,
    pub microbatches: usize,
    pub tp: usize,
    /// Class index per pipeline stage; length `pp`.
    pub placement: Vec<usize>,
}

/// The [`GaProblem`] instance evolving [`DeploymentGenome`]s against one
/// device pool.
pub struct DeploymentProblem<'a> {
    pub hc: &'a HeteroCluster,
    /// Microbatch counts the search may assign to pipelined genomes
    /// (`m = 1` is always available — the minimum-energy corner).
    pub microbatches: Vec<usize>,
}

impl<'a> DeploymentProblem<'a> {
    /// The deduplicated microbatch menu, `1` first (mirrors the
    /// enumeration's `ms` list).
    pub fn menu(&self) -> Vec<usize> {
        let mut ms = vec![1usize];
        for &m in &self.microbatches {
            if !ms.contains(&m) {
                ms.push(m);
            }
        }
        ms
    }

    /// Class with the most remaining capacity (lowest index wins ties) —
    /// the deterministic target for repairing over-capacity stages.
    fn roomiest(left: &[usize]) -> usize {
        (0..left.len())
            .max_by_key(|&j| (left[j], std::cmp::Reverse(j)))
            .expect("a HeteroCluster always has at least one class")
    }
}

impl<'a> GaProblem for DeploymentProblem<'a> {
    type Genome = DeploymentGenome;

    /// Deterministic corners: per class, the single-device deployment and
    /// the all-of-class data-parallel deployment; plus the two contiguous
    /// class-block pipelines over the whole pool (the best the fallback
    /// enumeration can do at full depth).
    fn anchors(&self) -> Vec<DeploymentGenome> {
        let mut out: Vec<DeploymentGenome> = vec![];
        for c in 0..self.hc.classes.len() {
            for g in [
                DeploymentGenome { dp: 1, pp: 1, microbatches: 1, tp: 1, placement: vec![c] },
                DeploymentGenome {
                    dp: self.hc.counts[c],
                    pp: 1,
                    microbatches: 1,
                    tp: 1,
                    placement: vec![c],
                },
            ] {
                if !out.contains(&g) {
                    out.push(g);
                }
            }
        }
        let total = self.hc.total_devices();
        for rev in [false, true] {
            let order: Vec<usize> = if rev {
                (0..self.hc.classes.len()).rev().collect()
            } else {
                (0..self.hc.classes.len()).collect()
            };
            let mut placement = Vec::with_capacity(total);
            for &c in &order {
                for _ in 0..self.hc.counts[c] {
                    placement.push(c);
                }
            }
            let g = DeploymentGenome { dp: 1, pp: total, microbatches: 1, tp: 1, placement };
            if !out.contains(&g) {
                out.push(g);
            }
        }
        out
    }

    fn fit_seed(&self, seed: &DeploymentGenome) -> DeploymentGenome {
        let mut g = seed.clone();
        self.repair(&mut g);
        g
    }

    fn random(&self, rng: &mut Rng) -> DeploymentGenome {
        let total = self.hc.total_devices();
        let k = self.hc.classes.len();
        let bits = total.max(1).ilog2() as usize;
        let dp = 1usize << rng.usize(bits + 1);
        let tp = 1usize << rng.usize(bits + 1);
        let pp = 1 + rng.usize(total);
        let menu = self.menu();
        let microbatches = menu[rng.usize(menu.len())];
        let placement: Vec<usize> = (0..pp).map(|_| rng.usize(k)).collect();
        let mut g = DeploymentGenome { dp, pp, microbatches, tp, placement };
        self.repair(&mut g);
        g
    }

    /// Axis-wise uniform crossover: each of dp, tp, m, and the pipeline
    /// (depth + placement, swapped as a unit) comes from either parent.
    fn crossover(&self, child: &mut DeploymentGenome, other: &DeploymentGenome, rng: &mut Rng) {
        if rng.bool(0.5) {
            child.dp = other.dp;
        }
        if rng.bool(0.5) {
            child.tp = other.tp;
        }
        if rng.bool(0.5) {
            child.microbatches = other.microbatches;
        }
        if rng.bool(0.5) {
            child.pp = other.pp;
            child.placement = other.placement.clone();
        }
    }

    /// One move at a time: double/halve `dp` or `tp`, grow/shrink the
    /// pipeline by one stage, re-draw the microbatch count, or reassign
    /// one stage's class — then another move with probability
    /// `mutation_p`, geometrically. Small steps keep consecutive
    /// evaluations close in the cost caches.
    fn mutate(&self, g: &mut DeploymentGenome, rng: &mut Rng, mutation_p: f64) {
        let k = self.hc.classes.len();
        let menu = self.menu();
        loop {
            match rng.usize(5) {
                0 => g.dp = if rng.bool(0.5) { g.dp * 2 } else { (g.dp / 2).max(1) },
                1 => g.tp = if rng.bool(0.5) { g.tp * 2 } else { (g.tp / 2).max(1) },
                2 => {
                    if rng.bool(0.5) {
                        g.pp += 1;
                        g.placement.push(rng.usize(k));
                    } else if g.pp > 1 {
                        g.pp -= 1;
                        g.placement.pop();
                    }
                }
                3 => g.microbatches = menu[rng.usize(menu.len())],
                _ => {
                    if !g.placement.is_empty() {
                        let i = rng.usize(g.placement.len());
                        g.placement[i] = rng.usize(k);
                    }
                }
            }
            if !rng.bool(mutation_p) {
                break;
            }
        }
    }

    /// Deterministic, RNG-free feasibility repair against the pool:
    ///
    /// 1. clamp every axis to ≥ 1;
    /// 2. halve the `dp·tp` gang (tp first) until some class can host at
    ///    least one stage;
    /// 3. clamp `pp` to the total stage slots and sync the placement
    ///    length;
    /// 4. walk the placement, re-homing invalid/over-capacity stages to
    ///    the class with the most remaining room (lowest index on ties);
    /// 5. canonicalize `m = 1` for non-pipelined genomes.
    ///
    /// Returns whether anything changed. The result always satisfies
    /// [`HeteroPoint::feasible`](crate::parallelism::HeteroPoint::feasible).
    fn repair(&self, g: &mut DeploymentGenome) -> bool {
        let orig = g.clone();
        let counts = &self.hc.counts;
        g.dp = g.dp.max(1);
        g.tp = g.tp.max(1);
        g.pp = g.pp.max(1);
        let mut gang = g.dp * g.tp;
        while gang > 1 && counts.iter().all(|&c| c / gang == 0) {
            if g.tp > 1 {
                g.tp /= 2;
            } else {
                g.dp /= 2;
            }
            gang = g.dp * g.tp;
        }
        let caps: Vec<usize> = counts.iter().map(|&c| c / gang).collect();
        let slots: usize = caps.iter().sum();
        g.pp = g.pp.min(slots).max(1);
        g.placement.truncate(g.pp);
        let mut left = caps;
        for i in 0..g.placement.len() {
            let c = g.placement[i];
            if c < left.len() && left[c] > 0 {
                left[c] -= 1;
            } else {
                let best = Self::roomiest(&left);
                g.placement[i] = best;
                left[best] -= 1;
            }
        }
        while g.placement.len() < g.pp {
            let best = Self::roomiest(&left);
            g.placement.push(best);
            left[best] -= 1;
        }
        if g.pp <= 1 {
            g.microbatches = 1;
        } else {
            g.microbatches = g.microbatches.max(1);
        }
        *g != orig
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::ClusterSpace;
    use crate::parallelism::DeviceClass;
    use crate::util::proptest::{check, UsizeIn};

    fn pool() -> HeteroCluster {
        HeteroCluster::new(vec![
            (DeviceClass::edge(), 6),
            (DeviceClass::server(), 3),
            (DeviceClass::datacenter(), 2),
        ])
    }

    #[test]
    fn anchors_are_feasible_and_cover_the_per_class_extremes() {
        let hc = pool();
        let problem = DeploymentProblem { hc: &hc, microbatches: vec![2, 4] };
        let anchors = problem.anchors();
        assert!(!anchors.is_empty());
        let set: std::collections::HashSet<&DeploymentGenome> = anchors.iter().collect();
        assert_eq!(set.len(), anchors.len(), "duplicate anchors");
        for g in &anchors {
            assert!(ClusterSpace::genome_to_hetero(g).feasible(&hc), "infeasible anchor {g:?}");
        }
        for c in 0..hc.classes.len() {
            assert!(anchors.iter().any(|g| g.placement == vec![c] && g.dp == 1));
            assert!(anchors.iter().any(|g| g.placement == vec![c] && g.dp == hc.counts[c]));
        }
        // the two full-depth contiguous block pipelines
        assert!(anchors.iter().any(|g| g.pp == hc.total_devices()));
    }

    #[test]
    fn repair_always_restores_feasibility_without_rng() {
        let hc = pool();
        let problem = DeploymentProblem { hc: &hc, microbatches: vec![2, 4] };
        check(80, &UsizeIn(0, u32::MAX as usize), |&seed| {
            let mut rng = Rng::seed_from_u64(seed as u64);
            // raw, deliberately out-of-range genome
            let pp = rng.usize(20);
            let mut g = DeploymentGenome {
                dp: rng.usize(40),
                pp,
                microbatches: rng.usize(9),
                tp: rng.usize(40),
                placement: (0..rng.usize(pp + 4)).map(|_| rng.usize(6)).collect(),
            };
            let mut again = g.clone();
            problem.repair(&mut g);
            problem.repair(&mut again);
            // deterministic (no RNG): repairing the same input twice agrees,
            // and re-repairing a repaired genome is a no-op
            let mut fixed = g.clone();
            let changed = problem.repair(&mut fixed);
            g == again
                && !changed
                && fixed == g
                && ClusterSpace::genome_to_hetero(&g).feasible(&hc)
                && (g.pp > 1 || g.microbatches == 1)
        });
    }

    #[test]
    fn operators_are_deterministic_and_stay_feasible_after_repair() {
        let hc = pool();
        let problem = DeploymentProblem { hc: &hc, microbatches: vec![2, 4] };
        check(40, &UsizeIn(0, u32::MAX as usize), |&seed| {
            let mut a = Rng::seed_from_u64(seed as u64);
            let mut b = Rng::seed_from_u64(seed as u64);
            let ga = problem.random(&mut a);
            let gb = problem.random(&mut b);
            if ga != gb || !ClusterSpace::genome_to_hetero(&ga).feasible(&hc) {
                return false;
            }
            let other = problem.random(&mut a);
            let mut ca = ga.clone();
            let mut cb = gb.clone();
            let mut a2 = Rng::seed_from_u64(seed as u64 ^ 0x5EED);
            let mut b2 = Rng::seed_from_u64(seed as u64 ^ 0x5EED);
            problem.crossover(&mut ca, &other, &mut a2);
            problem.crossover(&mut cb, &other, &mut b2);
            problem.mutate(&mut ca, &mut a2, 0.1);
            problem.mutate(&mut cb, &mut b2, 0.1);
            problem.repair(&mut ca);
            problem.repair(&mut cb);
            ca == cb && ClusterSpace::genome_to_hetero(&ca).feasible(&hc)
        });
    }

    #[test]
    fn mutation_moves_one_axis_at_a_time() {
        let hc = pool();
        let problem = DeploymentProblem { hc: &hc, microbatches: vec![2, 4] };
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..200 {
            let g = problem.random(&mut rng);
            let mut m = g.clone();
            // mutation_p = 0: exactly one move
            problem.mutate(&mut m, &mut rng, 0.0);
            let mut diffs = 0;
            diffs += (m.dp != g.dp) as usize;
            diffs += (m.tp != g.tp) as usize;
            diffs += (m.microbatches != g.microbatches) as usize;
            // the pipeline (depth + placement) counts as one axis
            diffs += (m.pp != g.pp || m.placement != g.placement) as usize;
            assert!(diffs <= 1, "one move changed {diffs} axes: {g:?} -> {m:?}");
        }
    }

    #[test]
    fn genome_hetero_round_trip_is_lossless() {
        let hc = pool();
        let problem = DeploymentProblem { hc: &hc, microbatches: vec![2] };
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..50 {
            let g = problem.random(&mut rng);
            let p = ClusterSpace::genome_to_hetero(&g);
            assert_eq!(ClusterSpace::hetero_to_genome(&p), g);
            assert_eq!(p.devices(), g.dp * g.tp * g.pp);
        }
    }
}
