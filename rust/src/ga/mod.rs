//! Multi-objective genetic optimization (DESIGN.md S10): NSGA-II and the
//! activation-checkpointing problem encoding (paper §V-B).

pub mod checkpoint_opt;
pub mod milp;
pub mod nsga2;

pub use checkpoint_opt::{CheckpointProblem, CheckpointSolution};
pub use nsga2::{
    dominates, nsga2, nsga2_with_memo, pareto_rank0, GaConfig, Genome, Individual, Objectives,
};
