//! Multi-objective genetic optimization (DESIGN.md S10): NSGA-II and the
//! problem encodings it evolves — activation checkpointing (paper §V-B)
//! and heterogeneous cluster deployment.
//!
//! [`nsga2`] hosts the generic parallel NSGA-II core
//! ([`nsga2::nsga2_problem`]), generic over a [`nsga2::GaProblem`] genome
//! type: `Fn + Sync` evaluation fanned over `GaConfig::workers` threads
//! of the generic DSE pool ([`crate::dse::engine::map_parallel`]) with a
//! hash-keyed genome→objectives memo, bit-identical for any worker
//! count, plus `pareto_rank0` — the N-objective rank-0 dominance set the
//! cluster DSE reuses for its 4-objective fronts. Two problem instances
//! exist: [`checkpoint_opt`] encodes the checkpointing problem through
//! the historical boolean genome (bit = recompute this activation),
//! evaluates through the shared [`crate::eval::CostCache`], and
//! warm-starts across process restarts via persisted front + memo
//! snapshots (see `CheckpointProblem::optimize_persistent`);
//! [`deployment`] encodes a heterogeneous cluster deployment —
//! `(dp, pp, m, tp)` + per-stage class placement — with feasibility
//! repair against the pool, the search behind `ga-cluster`. [`milp`] is
//! the linear Checkmate-style formulation (eq. 6) kept as the ablation
//! baseline the GA is measured against.

pub mod checkpoint_opt;
pub mod deployment;
pub mod milp;
pub mod nsga2;

pub use checkpoint_opt::{CheckpointProblem, CheckpointSolution};
pub use deployment::{DeploymentGenome, DeploymentProblem};
pub use nsga2::{
    dominates, nsga2, nsga2_problem, nsga2_resumable, nsga2_with_memo, pareto_rank0,
    BitmaskProblem, GaCheckpoint, GaConfig, GaProblem, GaStats, Genome, Individual, Objectives,
};
