//! Multi-objective genetic optimization (DESIGN.md S10): NSGA-II and the
//! activation-checkpointing problem encoding (paper §V-B).
//!
//! [`nsga2`] is a generic parallel NSGA-II over bit-genomes: `Fn + Sync`
//! evaluation fanned over `GaConfig::workers` threads of the generic DSE
//! pool ([`crate::dse::engine::map_parallel`]) with a
//! genome→objectives memo, bit-identical for any worker count, plus
//! `pareto_rank0` — the N-objective rank-0 dominance set the cluster DSE
//! reuses for its 4-objective fronts. [`checkpoint_opt`] encodes the
//! checkpointing problem (genome bit = recompute this activation),
//! evaluates through the shared [`crate::eval::CostCache`], and
//! warm-starts across process restarts via persisted front + memo
//! snapshots (see `CheckpointProblem::optimize_persistent`). [`milp`] is
//! the linear Checkmate-style formulation (eq. 6) kept as the ablation
//! baseline the GA is measured against.

pub mod checkpoint_opt;
pub mod milp;
pub mod nsga2;

pub use checkpoint_opt::{CheckpointProblem, CheckpointSolution};
pub use nsga2::{
    dominates, nsga2, nsga2_resumable, nsga2_with_memo, pareto_rank0, GaCheckpoint, GaConfig,
    Genome, Individual, Objectives,
};
