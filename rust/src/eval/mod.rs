//! Memoized, parallel evaluation engine (DESIGN.md §Perf): the shared
//! fast path under every high-volume caller of `schedule()` — DSE sweeps
//! (`dse::sweep`), the staged search (`dse::search`) and the NSGA-II
//! checkpointing GA (`ga::checkpoint_opt`).
//!
//! The observation (TRIM, arXiv 2105.08239; TBD, arXiv 1803.06905): these
//! workloads evaluate thousands of design points / genomes, but training
//! graphs are dominated by a small set of repeated layer shapes and the
//! searched spaces share core classes — so the expensive inner quantity,
//! the cost of one fused group on one core class at one gang width, is
//! recomputed orders of magnitude more often than it changes. This module
//! memoizes it.
//!
//! ## The soundness contract
//!
//! Caching `group_cost` is sound because `group_cost`/`node_cost` are pure
//! functions of: the group's op structures + tensor placements, the
//! core-class representative's cost-relevant fields, the gang width, and
//! the schedule-wide memory environment (all hashed into the key — see
//! [`cost_cache`] for the exact list).
//!
//! What `group_cost` may **NOT** read (and anyone extending the cost model
//! must keep it that way, or widen the key in `scheduler::engine`):
//!
//! * schedule-time mutable state: core free times, group ready times,
//!   accumulated energy/traffic, the event timeline;
//! * identity rather than structure: node ids, group ids, node names,
//!   core ids/names, partition layout beyond the group's own placements;
//! * graph topology beyond what `group_placements` already folded into
//!   the per-node `TensorPlacement`s;
//! * training phase (`Phase` drives reporting attribution in the
//!   scheduler, never cost);
//! * global mutable configuration of any kind.
//!
//! Violating the contract shows up as cached-vs-uncached divergence; the
//! `eval_cache` integration tests pin bit-identity across ResNet-18 and
//! GPT-2 training graphs to catch exactly that.
//!
//! ## Lifecycle: bounded capacity and persistence
//!
//! The cache is no longer tied to one process: [`persist`] serializes it
//! to a versioned binary snapshot (`--cache-dir`) and [`evict`] bounds it
//! to a configured entry count (`--cache-cap`) with a sharded
//! second-chance/CLOCK policy. Neither affects results — eviction only
//! re-misses a pure computation, and a warm-loaded snapshot replays the
//! exact bits a cold run would compute.
//!
//! **The snapshot-header rule:** every snapshot carries (format version,
//! structural fingerprint of the hashing scheme, soundness-contract
//! version) and is rejected wholesale on any mismatch. The fingerprint
//! updates itself (it is a digest of a probe through
//! [`StructuralHasher`]); [`CACHE_CONTRACT_VERSION`] must be bumped **by
//! hand** whenever the key is widened or its meaning changes — the same
//! events that require widening the key in `scheduler::engine` — so
//! snapshots written under the old contract self-invalidate instead of
//! serving stale costs. The authoritative statement of the rule — which
//! changes force a bump, and why in-process bit-identity tests cannot
//! substitute for it — lives in `ROADMAP.md` ("Snapshot-header rule");
//! the per-version rationale is the History list on
//! [`CACHE_CONTRACT_VERSION`] below.

pub mod cost_cache;
pub mod evict;
pub mod persist;

pub use cost_cache::{CacheStats, CostCache, StructuralHasher};
pub use persist::{load_cost_cache, open_cost_cache, persist_cost_cache, save_cost_cache};

/// Version of the cache-key soundness contract (see module docs and
/// [`persist`]). Bump on **any** change that alters what a persisted
/// entry means:
///
/// * key-widening — a new input hashed into the group-cost key, or a
///   changed field set in [`hash_env`], [`hash_group_node`] or
///   [`hash_core_class`];
/// * **value changes** — any edit to the `group_cost`/`node_cost`
///   formulas (`cost/mod.rs`, the fused-rider rule in
///   `scheduler::engine::group_cost`, energy constants). The in-process
///   bit-identity tests compare warm-vs-cold *within one build* and
///   cannot catch a snapshot carrying the previous build's numbers —
///   only this version bump invalidates it;
/// * **scheduler-behavior changes** — anything that alters `schedule()`
///   outputs at all (list-scheduler tie-breaks, transfer latency/energy
///   rules, memory-lifetime accounting). The cost-cache keys don't read
///   these, but the persisted GA warm-start memo stores whole-schedule
///   objective values (latency/energy), so its entries go stale under any
///   such change even though every key still matches.
///
/// Stale snapshots written under an older contract are rejected at load
/// time.
///
/// History:
/// * **3** — heterogeneous clusters with stage placement (PR 4): the
///   pipeline splitter became latency-balancing (`split_stages_balanced`
///   re-schedules candidate cuts, changing every pipeline stage shape a
///   snapshot may hold), and stage placement now selects the accelerator
///   a stage's entries are keyed under (per-class `DeviceClass` core
///   configurations enter the key via `hash_core_class`/`hash_env`).
///   Entries from a v2 snapshot are structurally keyed and would still be
///   *sound*, but they describe stage shapes the new splitter never
///   produces — dead weight that defeats `--cache-cap` sizing — and the
///   snapshot-header rule is deliberately conservative: the cost of a
///   false bump is one cold run.
/// * **2** — the cluster-scale parallelism DSE (PR 3): persisted snapshot
///   directories are now shared by single-device sweeps *and* cluster
///   sweeps whose entries come from pipeline-stage subgraph schedules;
///   the version line guarantees no pre-cluster snapshot (written before
///   stage-subgraph keys and their cross-factorization sharing existed)
///   is ever replayed into the widened workload mix. Conservative by
///   design: the cost of a false bump is one cold run.
/// * **1** — initial persisted-snapshot contract (PR 2).
pub const CACHE_CONTRACT_VERSION: u32 = 3;

use std::hash::Hash;

use crate::cost::{MemEnv, TensorPlacement};
use crate::hardware::core::Core;
use crate::workload::op::OpKind;

/// Hash the schedule-wide environment: every `MemEnv` field plus the
/// graph's element width. Computed once per `schedule()` call.
pub fn hash_env(h: &mut StructuralHasher, env: &MemEnv, elem_bytes: u64) {
    env.offchip_bw.to_bits().hash(h);
    env.global_bw.to_bits().hash(h);
    env.global_energy_pj.to_bits().hash(h);
    env.link_bw.to_bits().hash(h);
    env.link_energy_pj.to_bits().hash(h);
    elem_bytes.hash(h);
}

/// Hash one group member: op structure + operand placement.
pub fn hash_group_node(h: &mut StructuralHasher, kind: &OpKind, place: &TensorPlacement) {
    kind.structural_hash(h);
    place.hash(h);
}

/// Hash the cost-relevant fields of a core-class representative. Name and
/// id are cosmetic; `regfile_bytes` is not read by the cost model. This is
/// deliberately the same field set `core_classes` keys interchangeability
/// on, so two identical PEs share cache entries.
pub fn hash_core_class(h: &mut StructuralHasher, core: &Core) {
    core.dataflow.hash(h);
    core.local_mem_bytes.hash(h);
    core.onchip_bw.to_bits().hash(h);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::core::Dataflow;
    use crate::workload::op::{EltwiseKind, GemmSpec};

    fn env() -> MemEnv {
        MemEnv {
            offchip_bw: 64.0,
            global_bw: 0.0,
            global_energy_pj: 2.0,
            link_bw: 256.0,
            link_energy_pj: 1.8,
        }
    }

    fn key_of(f: impl FnOnce(&mut StructuralHasher)) -> u128 {
        let mut h = StructuralHasher::new();
        f(&mut h);
        h.finish128()
    }

    #[test]
    fn env_hash_separates_bandwidths() {
        let a = key_of(|h| hash_env(h, &env(), 4));
        let b = key_of(|h| hash_env(h, &MemEnv { offchip_bw: 65.0, ..env() }, 4));
        let c = key_of(|h| hash_env(h, &env(), 2));
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, key_of(|h| hash_env(h, &env(), 4)));
    }

    #[test]
    fn node_hash_separates_structure_and_placement() {
        let g1 = OpKind::Gemm(GemmSpec { batch: 1, m: 8, n: 16, k: 32, weight_b: true });
        let g2 = OpKind::Gemm(GemmSpec { batch: 1, m: 8, n: 16, k: 64, weight_b: true });
        let e = OpKind::Eltwise { kind: EltwiseKind::Relu, elems: 128, arity: 1 };
        let p0 = TensorPlacement::default();
        let p1 = TensorPlacement { in_offchip: 64, ..Default::default() };
        let k = |op: &OpKind, p: &TensorPlacement| key_of(|h| hash_group_node(h, op, p));
        assert_ne!(k(&g1, &p0), k(&g2, &p0));
        assert_ne!(k(&g1, &p0), k(&g1, &p1));
        assert_ne!(k(&g1, &p0), k(&e, &p0));
        assert_eq!(k(&g1, &p1), k(&g1, &p1.clone()));
    }

    #[test]
    fn core_class_hash_ignores_identity_fields() {
        let mk = |id: usize, name: &str, regfile: u64| Core {
            id,
            name: name.into(),
            dataflow: Dataflow::WeightStationary { rows: 64, cols: 4 },
            local_mem_bytes: 2 << 20,
            regfile_bytes: regfile,
            onchip_bw: 128.0,
        };
        let a = key_of(|h| hash_core_class(h, &mk(0, "pe0", 32 << 10)));
        let b = key_of(|h| hash_core_class(h, &mk(7, "pe7", 64 << 10)));
        assert_eq!(a, b, "identity/regfile fields must not affect the key");
        let mut c = mk(0, "pe0", 32 << 10);
        c.dataflow = Dataflow::Simd { lanes: 256 };
        assert_ne!(a, key_of(|h| hash_core_class(h, &c)));
    }
}
