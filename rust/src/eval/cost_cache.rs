//! The shared group-cost cache: a sharded, thread-safe map from the
//! 128-bit structural key of a `group_cost` invocation to its `NodeCost`.
//!
//! ## Key soundness
//!
//! A cache entry may be reused wherever a fresh `group_cost` call would
//! return the same value, so the key must cover *every* input the
//! computation reads — and `group_cost`/`node_cost` are deliberately kept
//! pure over exactly these (see `eval` module docs for what they may NOT
//! read):
//!
//! * per node of the group: the op's structural identity
//!   ([`crate::workload::op::OpKind::structural_hash`]) and its
//!   [`crate::cost::TensorPlacement`] (operand byte counts + output
//!   placement flags, which also encode whether the HDA has a global
//!   buffer);
//! * the executing core's cost-relevant fields: dataflow geometry, local
//!   memory size, on-chip bandwidth (name/id are cosmetic; the register
//!   file is not read by the cost model) — i.e. the core-*class*
//!   representative, the same equivalence `core_classes` uses;
//! * the gang width (tensor parallelism);
//! * the schedule-wide [`crate::cost::MemEnv`] bandwidths/energies and the
//!   graph's element width.
//!
//! Keys are 128-bit structural hashes (two independently-seeded SipHash
//! streams); at the ~1e6-entry scale of a full Table II sweep the
//! collision probability is ~1e-26, far below any bit-level concern.
//!
//! ## Concurrency
//!
//! The map is sharded 16 ways under `std::sync::RwLock` (std-only — no
//! external concurrent-map dependency). Readers proceed in parallel;
//! a miss computes outside any lock and races at worst duplicate the
//! (pure) computation, never corrupt it.
//!
//! ## Lifecycle
//!
//! Two orthogonal extensions keep the cache usable at multi-million-point
//! sweep scale (see the sibling modules):
//!
//! * **bounded capacity** ([`CostCache::with_capacity`]): each shard runs
//!   a second-chance/CLOCK ring ([`super::evict::ClockShard`]) so the memo
//!   tops out at a configured entry count, with evictions counted in
//!   [`CacheStats::evictions`];
//! * **persistence** ([`super::persist`]): the whole cache serializes to a
//!   versioned binary snapshot and reloads across process runs, rejected
//!   wholesale when the header (format / hashing scheme / soundness
//!   contract) no longer matches.

use std::hash::Hasher;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::evict::ClockShard;
use crate::cost::NodeCost;
use crate::util::rng::splitmix64 as mix64;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Two differently-seeded, differently-mixed hash streams receiving
/// identical input, yielding a 128-bit structural key (self-contained —
/// std's `DefaultHasher` is both slower and process-seeded). The byte loop
/// is FNV-1a; `finish128` applies a splitmix64 finalizer per stream for
/// avalanche. `Clone` lets callers checkpoint a key prefix (the
/// schedule-wide environment, then per-group content) and fork it cheaply
/// for each (core class × gang width) candidate.
#[derive(Clone)]
pub struct StructuralHasher {
    lo: u64,
    hi: u64,
}

impl StructuralHasher {
    pub fn new() -> Self {
        // distinct stream seeds — everything written afterwards is shared
        StructuralHasher { lo: FNV_OFFSET, hi: 0x9E37_79B9_7F4A_7C15 }
    }

    /// The 128-bit key accumulated so far (does not consume the hasher).
    pub fn finish128(&self) -> u128 {
        ((mix64(self.hi) as u128) << 64) | mix64(self.lo) as u128
    }
}

impl Default for StructuralHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher for StructuralHasher {
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.lo = (self.lo ^ b as u64).wrapping_mul(FNV_PRIME);
            // second stream: same input, different seed AND a per-byte
            // rotation, so the two 64-bit digests fail independently
            self.hi = ((self.hi ^ b as u64).wrapping_mul(FNV_PRIME)).rotate_left(29);
        }
    }

    fn finish(&self) -> u64 {
        mix64(self.lo)
    }
}

const N_SHARDS: usize = 16;

/// Aggregate counters, readable at any time (e.g. after a sweep).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Entries displaced by the CLOCK policy to admit new ones (always 0
    /// for an unbounded cache).
    pub evictions: u64,
    /// Snapshot files present but refused at open time (stale contract,
    /// foreign hasher, truncation, bit rot). Without this counter a lost
    /// snapshot is indistinguishable from a first run.
    pub snapshots_rejected: u64,
    /// Rejected snapshots successfully moved to their `.corrupt` sidecar.
    pub snapshots_quarantined: u64,
    /// Transient snapshot-write failures absorbed by the bounded
    /// retry-with-backoff in [`super::persist::persist_cost_cache`].
    pub io_retries: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sharded memo table for group costs. One instance is shared across a
/// whole sweep / GA run; dropping it discards the memory (or persist it
/// first via [`super::persist::save_cost_cache`]).
pub struct CostCache {
    shards: [RwLock<ClockShard>; N_SHARDS],
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    snapshots_rejected: AtomicU64,
    snapshots_quarantined: AtomicU64,
    io_retries: AtomicU64,
}

impl CostCache {
    /// Unbounded cache (the PR-1 behaviour): never evicts.
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Cache bounded to ~`capacity` entries total (0 = unbounded). The
    /// bound is enforced per shard (`capacity / 16`, rounded up), so the
    /// live entry count never exceeds `capacity` rounded up to a multiple
    /// of the shard count.
    pub fn with_capacity(capacity: usize) -> Self {
        let per_shard = if capacity == 0 { 0 } else { capacity.div_ceil(N_SHARDS).max(1) };
        CostCache {
            shards: std::array::from_fn(|_| RwLock::new(ClockShard::new(per_shard))),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            snapshots_rejected: AtomicU64::new(0),
            snapshots_quarantined: AtomicU64::new(0),
            io_retries: AtomicU64::new(0),
        }
    }

    /// Configured total capacity (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    fn shard(&self, key: u128) -> &RwLock<ClockShard> {
        // low bits feed the in-shard HashMap; take shard bits from the top
        &self.shards[(key >> 124) as usize % N_SHARDS]
    }

    /// Return the memoized cost for `key`, computing (and storing) it via
    /// `compute` on a miss. `compute` must be a pure function of the data
    /// hashed into `key` — see the module docs.
    pub fn get_or_compute(&self, key: u128, compute: impl FnOnce() -> NodeCost) -> NodeCost {
        let shard = self.shard(key);
        if let Some(c) = shard.read().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c;
        }
        // compute outside the lock: concurrent misses on one key duplicate
        // a pure computation instead of serializing every worker
        let cost = compute();
        self.misses.fetch_add(1, Ordering::Relaxed);
        let evicted = shard.write().unwrap().insert(key, cost);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        cost
    }

    /// Admit an entry restored from a snapshot: counts neither as a hit
    /// nor a miss (it was computed in a previous process), but bounded
    /// caches may evict to make room.
    pub fn insert_loaded(&self, key: u128, cost: NodeCost) {
        let evicted = self.shard(key).write().unwrap().insert(key, cost);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Snapshot of every live entry, sorted by key — the deterministic
    /// order the persistence codec writes.
    pub fn export_entries(&self) -> Vec<(u128, NodeCost)> {
        let mut out: Vec<(u128, NodeCost)> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().iter().collect::<Vec<_>>())
            .collect();
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Record a snapshot file that failed verification at open time.
    pub fn note_snapshot_rejected(&self) {
        self.snapshots_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a rejected snapshot successfully moved to its `.corrupt`
    /// sidecar for post-mortem inspection.
    pub fn note_snapshot_quarantined(&self) {
        self.snapshots_quarantined.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one transient snapshot-write failure that was retried.
    pub fn note_io_retry(&self) {
        self.io_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.shards.iter().map(|s| s.read().unwrap().len()).sum(),
            evictions: self.evictions.load(Ordering::Relaxed),
            snapshots_rejected: self.snapshots_rejected.load(Ordering::Relaxed),
            snapshots_quarantined: self.snapshots_quarantined.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
        }
    }

    /// Reset counters (entries stay). Benches use this to separate the
    /// cold-fill phase from warm-path measurement.
    pub fn reset_counters(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
        self.snapshots_rejected.store(0, Ordering::Relaxed);
        self.snapshots_quarantined.store(0, Ordering::Relaxed);
        self.io_retries.store(0, Ordering::Relaxed);
    }
}

impl Default for CostCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    #[test]
    fn hasher_prefix_forking_is_consistent() {
        let mut base = StructuralHasher::new();
        42u64.hash(&mut base);
        let mut a = base.clone();
        let mut b = base.clone();
        7u64.hash(&mut a);
        7u64.hash(&mut b);
        assert_eq!(a.finish128(), b.finish128());
        let mut c = base.clone();
        8u64.hash(&mut c);
        assert_ne!(a.finish128(), c.finish128());
    }

    #[test]
    fn lo_and_hi_streams_differ() {
        let mut h = StructuralHasher::new();
        1234u64.hash(&mut h);
        let k = h.finish128();
        assert_ne!((k >> 64) as u64, k as u64);
    }

    #[test]
    fn cache_hits_after_first_compute() {
        let cache = CostCache::new();
        let mut calls = 0;
        for _ in 0..3 {
            let c = cache.get_or_compute(99, || {
                calls += 1;
                NodeCost { cycles: 5.0, ..Default::default() }
            });
            assert_eq!(c.cycles, 5.0);
        }
        assert_eq!(calls, 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 1));
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn distinct_keys_distinct_entries() {
        let cache = CostCache::new();
        for k in 0..100u128 {
            // spread keys across shards, including the top bits
            cache.get_or_compute(k << 120 | k, || NodeCost {
                cycles: k as f64,
                ..Default::default()
            });
        }
        assert_eq!(cache.stats().entries, 100);
        let c = cache.get_or_compute(5u128 << 120 | 5, || unreachable!());
        assert_eq!(c.cycles, 5.0);
    }

    #[test]
    fn bounded_cache_evicts_and_recomputes_identically() {
        let cache = CostCache::with_capacity(32); // 2 per shard
        let make = |k: u128| NodeCost { cycles: k as f64, ..Default::default() };
        for k in 0..500u128 {
            // spread across shards via the top bits
            let key = (k % 16) << 124 | k;
            assert_eq!(cache.get_or_compute(key, || make(k)).cycles, k as f64);
        }
        let s = cache.stats();
        assert!(s.entries <= 32, "capacity exceeded: {s:?}");
        assert!(s.evictions > 0, "bounded cache never evicted: {s:?}");
        assert_eq!(s.misses - s.evictions, s.entries as u64);
        // a re-miss after eviction recomputes the same pure value
        let key = 0u128; // shard 0, first inserted, certainly evicted
        assert_eq!(cache.get_or_compute(key, || make(0)).cycles, 0.0);
    }

    #[test]
    fn lifecycle_counters_accumulate_and_reset() {
        let cache = CostCache::new();
        cache.note_snapshot_rejected();
        cache.note_snapshot_quarantined();
        cache.note_io_retry();
        cache.note_io_retry();
        let s = cache.stats();
        assert_eq!(s.snapshots_rejected, 1);
        assert_eq!(s.snapshots_quarantined, 1);
        assert_eq!(s.io_retries, 2);
        cache.reset_counters();
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn concurrent_access_is_safe_and_consistent() {
        let cache = CostCache::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for k in 0..256u128 {
                        let c = cache.get_or_compute(k, || NodeCost {
                            cycles: k as f64,
                            ..Default::default()
                        });
                        assert_eq!(c.cycles, k as f64);
                    }
                });
            }
        });
        assert_eq!(cache.stats().entries, 256);
    }
}
