//! Bounded-capacity admission/eviction for the group-cost cache: a
//! second-chance/CLOCK ring per shard (Corbató 1968 — the classic
//! one-bit LRU approximation), sized so a multi-million-point sweep
//! cannot grow the memo without bound.
//!
//! ## Why CLOCK, and why per shard
//!
//! The cache's hot path is a read-locked lookup fanned over a worker
//! pool; a true LRU would need to reorder a recency list on every hit,
//! which either takes the write lock (serializing all readers) or a
//! global lock-free deque (not std). CLOCK needs only a *reference bit*
//! per entry, and a bit can be an `AtomicBool` flipped through the shard's
//! read guard — hits stay read-locked and contention-free. Each of the 16
//! shards runs its own hand over its own ring, so eviction work never
//! crosses a shard boundary and there is no global LRU lock anywhere.
//!
//! ## Soundness under eviction
//!
//! Evicting an entry can never change a result, only its cost: the cache
//! stores pure-function outputs keyed by their full input (see the `eval`
//! module docs), so a re-miss recomputes bit-identical bytes. The
//! `eval_cache` integration tests pin exactly this: a capacity so small it
//! evicts constantly must still reproduce the uncached schedule bit for
//! bit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::cost::NodeCost;

/// One ring slot: a cached group cost plus its second-chance bit.
struct Slot {
    key: u128,
    cost: NodeCost,
    referenced: AtomicBool,
}

/// One shard of the cost cache: a key→slot index plus the CLOCK ring.
/// Readers call [`ClockShard::get`] under a shared lock; inserts and
/// evictions happen under the exclusive lock.
pub struct ClockShard {
    index: HashMap<u128, usize>,
    slots: Vec<Slot>,
    hand: usize,
    /// Maximum slots in this shard; 0 = unbounded (never evicts).
    cap: usize,
}

impl ClockShard {
    pub fn new(cap: usize) -> Self {
        ClockShard { index: HashMap::new(), slots: Vec::new(), hand: 0, cap }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Per-shard capacity (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lookup under the shard's *read* lock. A hit marks the slot
    /// recently-used via its atomic reference bit — no write lock on the
    /// hot path.
    pub fn get(&self, key: u128) -> Option<NodeCost> {
        let &i = self.index.get(&key)?;
        let slot = &self.slots[i];
        slot.referenced.store(true, Ordering::Relaxed);
        Some(slot.cost)
    }

    /// Insert under the shard's write lock; returns the number of entries
    /// evicted to admit this one (0 or 1). A key already present is a
    /// racing duplicate of a pure computation and is left untouched.
    pub fn insert(&mut self, key: u128, cost: NodeCost) -> u64 {
        if self.index.contains_key(&key) {
            return 0;
        }
        if self.cap == 0 || self.slots.len() < self.cap {
            self.index.insert(key, self.slots.len());
            self.slots.push(Slot { key, cost, referenced: AtomicBool::new(false) });
            return 0;
        }
        // CLOCK sweep: referenced slots get their second chance (bit
        // cleared, hand moves on); the first un-referenced slot is the
        // victim. Terminates within two laps — the first lap clears
        // every bit it passes.
        let n = self.slots.len();
        loop {
            if self.slots[self.hand].referenced.swap(false, Ordering::Relaxed) {
                self.hand = (self.hand + 1) % n;
                continue;
            }
            let victim = self.hand;
            let old_key = self.slots[victim].key;
            self.index.remove(&old_key);
            self.index.insert(key, victim);
            self.slots[victim] = Slot { key, cost, referenced: AtomicBool::new(false) };
            self.hand = (victim + 1) % n;
            return 1;
        }
    }

    /// Iterate the shard's entries in slot order (insertion order between
    /// evictions).
    pub fn iter(&self) -> impl Iterator<Item = (u128, NodeCost)> + '_ {
        self.slots.iter().map(|s| (s.key, s.cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(cycles: f64) -> NodeCost {
        NodeCost { cycles, ..Default::default() }
    }

    #[test]
    fn unbounded_shard_never_evicts() {
        let mut s = ClockShard::new(0);
        for k in 0..1000u128 {
            assert_eq!(s.insert(k, cost(k as f64)), 0);
        }
        assert_eq!(s.len(), 1000);
        assert_eq!(s.get(999).unwrap().cycles, 999.0);
    }

    #[test]
    fn bounded_shard_respects_capacity_and_counts_evictions() {
        let mut s = ClockShard::new(4);
        let mut evicted = 0;
        for k in 0..10u128 {
            evicted += s.insert(k, cost(k as f64));
        }
        assert_eq!(s.len(), 4);
        assert_eq!(evicted, 6);
    }

    #[test]
    fn referenced_entries_survive_one_sweep() {
        let mut s = ClockShard::new(4);
        for k in 0..4u128 {
            s.insert(k, cost(k as f64));
        }
        // touch key 2: its reference bit protects it from the next victim
        // selection (keys 0 and 1 go first — hand order with second
        // chances)
        s.get(2).unwrap();
        s.insert(100, cost(100.0));
        s.insert(101, cost(101.0));
        assert!(s.get(2).is_some(), "recently-used entry was evicted");
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn duplicate_insert_is_a_noop() {
        let mut s = ClockShard::new(2);
        s.insert(7, cost(7.0));
        assert_eq!(s.insert(7, cost(999.0)), 0);
        assert_eq!(s.get(7).unwrap().cycles, 7.0, "first insert wins");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn iter_reports_live_entries() {
        let mut s = ClockShard::new(3);
        for k in [10u128, 20, 30] {
            s.insert(k, cost(k as f64));
        }
        let mut got: Vec<u128> = s.iter().map(|(k, _)| k).collect();
        got.sort_unstable();
        assert_eq!(got, vec![10, 20, 30]);
    }
}
