//! Cross-run persistence for the evaluation memos: a std-only binary
//! snapshot codec (no serde in this offline build) for (a) the group-cost
//! cache and (b) the NSGA-II warm-start state (previous Pareto-front
//! genomes + the genome→objectives memo).
//!
//! ## The snapshot-header rule
//!
//! A snapshot is only as sound as the key scheme that produced it, so
//! every file opens with a header of three independent guards and is
//! rejected *wholesale* when any of them mismatches:
//!
//! 1. **format version** ([`SNAPSHOT_FORMAT_VERSION`]) — the byte layout
//!    of this codec;
//! 2. **hasher fingerprint** ([`hasher_fingerprint`]) — the digest of a
//!    fixed probe sequence pushed through [`StructuralHasher`]; any change
//!    to the hash streams (seeds, mixing, finalizer) silently remaps every
//!    key, and this catches it structurally rather than by convention;
//! 3. **soundness-contract version** ([`super::CACHE_CONTRACT_VERSION`])
//!    — bumped by hand whenever the *meaning* of an entry changes: a key
//!    widening (a new input hashed into the group-cost key, a widened
//!    field set in `hash_env`/`hash_group_node`/`hash_core_class`), **a
//!    cost-formula change** (`node_cost`/`group_cost` math, energy
//!    constants) that alters the values a key maps to, or **any
//!    scheduler-behavior change** that alters `schedule()` outputs — the
//!    GA warm-start memo below stores whole-schedule objectives, whose
//!    dependencies are strictly wider than the cost-cache keys. In every
//!    case, snapshots written under the old contract self-invalidate
//!    instead of serving stale numbers. The bump-by-bump rationale
//!    (currently v3: the latency-balancing stage splitter + per-class
//!    stage placement of the heterogeneous cluster DSE) is the History
//!    list on [`super::CACHE_CONTRACT_VERSION`]; the rule itself is also
//!    recorded in `ROADMAP.md`.
//!
//! A checksum trailer (FNV-1a over the whole file body) additionally
//! rejects truncated or bit-rotted files. Rejection is always total: a
//! loader returns `None` and the caller starts cold — a half-loaded
//! snapshot could violate the bit-identity contract the `eval_cache`
//! tests pin.
//!
//! Writes go to a temp file in the target directory and are `rename`d
//! into place, so a crashed run never leaves a torn snapshot behind.

use std::collections::HashMap;
use std::fs;
use std::hash::Hash;
use std::io;
use std::path::{Path, PathBuf};

use super::cost_cache::{CostCache, StructuralHasher};
use crate::cost::NodeCost;

/// Byte-layout version of this codec.
pub const SNAPSHOT_FORMAT_VERSION: u32 = 1;

/// File name of the cost-cache snapshot inside a `--cache-dir`.
pub const COST_SNAPSHOT_FILE: &str = "cost_cache.bin";

/// File name of the GA warm-start snapshot inside a `--cache-dir`.
pub const GA_WARMSTART_FILE: &str = "ga_warmstart.bin";

const COST_MAGIC: &[u8; 8] = b"MONETCC\0";
const GA_MAGIC: &[u8; 8] = b"MONETGA\0";

/// Digest of a fixed probe sequence through [`StructuralHasher`]: 256
/// single bytes, a multi-byte write, and a `u64` via `Hash`. Equal across
/// processes iff the hashing scheme (both stream seeds, the per-byte
/// mixing, the splitmix64 finalizer) is unchanged — the self-describing
/// half of the snapshot-header rule.
pub fn hasher_fingerprint() -> u128 {
    use std::hash::Hasher as _;
    let mut h = StructuralHasher::new();
    for b in 0u8..=255 {
        h.write(&[b]);
    }
    h.write(b"monet-cache-snapshot-probe");
    0x00C0_FFEE_D15C_0B1Au64.hash(&mut h);
    h.finish128()
}

// ---------------------------------------------------------------------------
// codec primitives — shared with `dse::journal`, which writes its records
// with the same little-endian layout and FNV-1a checksums
// ---------------------------------------------------------------------------

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}
pub fn put_u128(buf: &mut Vec<u8>, v: u128) {
    buf.extend_from_slice(&v.to_le_bytes());
}
pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}
/// Length-prefixed UTF-8 string.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// FNV-1a over the file body — corruption detection only (the structural
/// guards live in the header).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Bounds-checked little-endian cursor over a snapshot/journal payload.
/// Every accessor returns `None` past the end — decoding never panics on
/// torn or corrupt input.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Some(out)
    }
    pub fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }
    pub fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }
    pub fn u128(&mut self) -> Option<u128> {
        Some(u128::from_le_bytes(self.take(16)?.try_into().ok()?))
    }
    pub fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }
    /// Inverse of [`put_str`].
    pub fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }
    pub fn exhausted(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Header written after the magic; identical for both snapshot kinds.
fn put_header(buf: &mut Vec<u8>, magic: &[u8; 8]) {
    buf.extend_from_slice(magic);
    put_u32(buf, SNAPSHOT_FORMAT_VERSION);
    put_u32(buf, super::CACHE_CONTRACT_VERSION);
    put_u128(buf, hasher_fingerprint());
}

/// Verify checksum + magic + header guards; returns a reader positioned
/// at the first payload byte, or `None` for any stale/incompatible/corrupt
/// snapshot.
fn verified_reader<'a>(buf: &'a [u8], magic: &[u8; 8]) -> Option<Reader<'a>> {
    // magic(8) + format(4) + contract(4) + fingerprint(16) + checksum(8)
    if buf.len() < 40 {
        return None;
    }
    let (body, sum_bytes) = buf.split_at(buf.len() - 8);
    if fnv64(body) != u64::from_le_bytes(sum_bytes.try_into().ok()?) {
        return None;
    }
    let mut r = Reader { buf: body, pos: 0 };
    if r.take(8)? != magic {
        return None;
    }
    if r.u32()? != SNAPSHOT_FORMAT_VERSION {
        return None;
    }
    if r.u32()? != super::CACHE_CONTRACT_VERSION {
        return None;
    }
    if r.u128()? != hasher_fingerprint() {
        return None;
    }
    Some(r)
}

/// Per-process sequence number for snapshot temp files. A pid-only
/// suffix is unique across processes but NOT across threads of one
/// process: two concurrent in-process persists (the daemon's periodic
/// checkpoint racing a shutdown persist) would share one temp path, and
/// a rename could then publish a half-written file. The (pid, seq) pair
/// makes every in-flight write its own temp file, keeping the
/// rename-into-place atomic for any number of concurrent writers
/// (pinned in `tests/fault_injection.rs`).
static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Checksum, then write-to-temp + rename (atomic on POSIX within one
/// filesystem). Safe under concurrent in-process writers: each write
/// gets a unique temp file, so the published snapshot is always exactly
/// one writer's complete buffer. Consults the fault-injection hooks
/// ([`crate::util::fault`]) so tests can fail or corrupt exactly the n-th
/// snapshot write; with no plan armed both hooks are no-ops.
fn write_snapshot(dir: &Path, file: &str, mut buf: Vec<u8>) -> io::Result<PathBuf> {
    fs::create_dir_all(dir)?;
    let sum = fnv64(&buf);
    put_u64(&mut buf, sum);
    crate::util::fault::write_gate(file)?;
    crate::util::fault::maybe_flip(&mut buf);
    let path = dir.join(file);
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = dir.join(format!("{file}.tmp.{}.{seq}", std::process::id()));
    fs::write(&tmp, &buf)?;
    if let Err(e) = fs::rename(&tmp, &path) {
        fs::remove_file(&tmp).ok();
        return Err(e);
    }
    Ok(path)
}

// ---------------------------------------------------------------------------
// cost-cache snapshots
// ---------------------------------------------------------------------------

/// Serialize every live entry of `cache` to `dir/cost_cache.bin`. Entries
/// are written sorted by key, so equal caches produce byte-equal files.
pub fn save_cost_cache(cache: &CostCache, dir: &Path) -> io::Result<PathBuf> {
    let entries = cache.export_entries();
    let mut buf = Vec::with_capacity(40 + entries.len() * 64);
    put_header(&mut buf, COST_MAGIC);
    put_u64(&mut buf, entries.len() as u64);
    for (key, c) in &entries {
        put_u128(&mut buf, *key);
        for v in [c.cycles, c.energy_pj, c.offchip_bytes, c.global_bytes, c.onchip_bytes, c.utilization] {
            put_f64(&mut buf, v);
        }
    }
    write_snapshot(dir, COST_SNAPSHOT_FILE, buf)
}

/// Load `dir/cost_cache.bin` into a fresh cache of the given `capacity`
/// (0 = unbounded). Returns `None` — load nothing, start cold — when the
/// file is absent, truncated, corrupt, or written under a different
/// format/hasher/contract. If the snapshot holds more entries than
/// `capacity`, admission happens in key order and the CLOCK policy keeps
/// the bound.
pub fn load_cost_cache(dir: &Path, capacity: usize) -> Option<CostCache> {
    let buf = fs::read(dir.join(COST_SNAPSHOT_FILE)).ok()?;
    let mut r = verified_reader(&buf, COST_MAGIC)?;
    let n = r.u64()?;
    let cache = CostCache::with_capacity(capacity);
    for _ in 0..n {
        let key = r.u128()?;
        let cost = NodeCost {
            cycles: r.f64()?,
            energy_pj: r.f64()?,
            offchip_bytes: r.f64()?,
            global_bytes: r.f64()?,
            onchip_bytes: r.f64()?,
            utilization: r.f64()?,
        };
        cache.insert_loaded(key, cost);
    }
    if !r.exhausted() {
        return None; // trailing garbage — reject rather than guess
    }
    Some(cache)
}

/// Load-or-new: warm-load the snapshot under `dir` when one is present
/// and valid, else start a fresh cache of `capacity` entries.
///
/// A snapshot file that exists but is **rejected** (stale contract,
/// foreign hasher, truncation, bit rot) is not silently discarded: it is
/// quarantined to a `cost_cache.bin.corrupt` sidecar, a warning names the
/// file and the fallback, and the returned cold cache carries the event
/// in its [`CacheStats`] (`snapshots_rejected`/`snapshots_quarantined`) so
/// the end-of-run report can distinguish "first run" from "snapshot lost".
/// [`load_cost_cache`] itself stays pure — it never touches the file.
pub fn open_cost_cache(dir: Option<&Path>, capacity: usize) -> CostCache {
    if let Some(d) = dir {
        if let Some(cache) = load_cost_cache(d, capacity) {
            return cache;
        }
        let path = d.join(COST_SNAPSHOT_FILE);
        if path.exists() {
            let cache = CostCache::with_capacity(capacity);
            cache.note_snapshot_rejected();
            let quarantine = d.join(format!("{COST_SNAPSHOT_FILE}.corrupt"));
            match fs::rename(&path, &quarantine) {
                Ok(()) => {
                    cache.note_snapshot_quarantined();
                    eprintln!(
                        "warning: rejected cost-cache snapshot {} (stale, truncated or corrupt); \
                         quarantined to {} and starting cold",
                        path.display(),
                        quarantine.display()
                    );
                }
                Err(e) => eprintln!(
                    "warning: rejected cost-cache snapshot {} (stale, truncated or corrupt) \
                     and could not quarantine it ({e}); starting cold",
                    path.display()
                ),
            }
            return cache;
        }
    }
    CostCache::with_capacity(capacity)
}

/// Best-effort save for end-of-run hooks: a persistence failure must not
/// fail the sweep that produced the results. Transient IO errors get a
/// bounded retry with exponential backoff (counted in
/// [`CacheStats::io_retries`]); only after the final attempt fails does a
/// warning — never a panic, never silence — report the loss.
pub fn persist_cost_cache(cache: &CostCache, dir: Option<&Path>) {
    const ATTEMPTS: u32 = 3;
    if let Some(d) = dir {
        let mut delay = std::time::Duration::from_millis(10);
        for attempt in 1..=ATTEMPTS {
            match save_cost_cache(cache, d) {
                Ok(_) => return,
                Err(e) if attempt < ATTEMPTS => {
                    cache.note_io_retry();
                    std::thread::sleep(delay);
                    delay *= 2;
                    let _ = e;
                }
                Err(e) => eprintln!(
                    "warning: failed to persist cost cache to {} after {ATTEMPTS} attempts: {e}",
                    d.display()
                ),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// GA warm-start snapshots
// ---------------------------------------------------------------------------

/// Cross-restart NSGA-II state: the previous run's front genomes (injected
/// as seeds) and its genome→objectives memo.
pub struct GaWarmStart {
    pub seeds: Vec<Vec<bool>>,
    pub memo: HashMap<Vec<bool>, Vec<f64>>,
}

fn put_genome(buf: &mut Vec<u8>, genome: &[bool], width: usize) {
    debug_assert_eq!(genome.len(), width);
    buf.extend(genome.iter().map(|&b| b as u8));
}

fn read_genome(r: &mut Reader, width: usize) -> Option<Vec<bool>> {
    Some(r.take(width)?.iter().map(|&b| b != 0).collect())
}

/// Cap on persisted memo entries: without one, every restart reloads the
/// previous union and rewrites a strictly larger file, growing without
/// bound over a long-lived `--cache-dir`. Seed (front) genomes are always
/// kept; the remainder is a deterministic (genome-sorted) prefix. A
/// dropped entry only costs one re-evaluation, exactly like cost-cache
/// eviction.
pub const GA_MEMO_CAP: usize = 100_000;

/// Serialize GA warm-start state to `dir/ga_warmstart.bin`. `problem_key`
/// must capture every input the objective function reads beyond the
/// genome (workload, accelerator, mapping, fusion constraints) — a memo
/// is only reusable against the identical problem.
pub fn save_ga_warmstart(
    dir: &Path,
    problem_key: u128,
    width: usize,
    seeds: &[Vec<bool>],
    memo: &HashMap<Vec<bool>, Vec<f64>>,
) -> io::Result<PathBuf> {
    let mut buf = Vec::new();
    put_header(&mut buf, GA_MAGIC);
    put_u128(&mut buf, problem_key);
    put_u32(&mut buf, width as u32);
    put_u32(&mut buf, seeds.len() as u32);
    for g in seeds {
        put_genome(&mut buf, g, width);
    }
    // deterministic memo order: sort by genome
    let mut entries: Vec<(&Vec<bool>, &Vec<f64>)> = memo.iter().collect();
    entries.sort_unstable_by(|a, b| a.0.cmp(b.0));
    if entries.len() > GA_MEMO_CAP {
        // keep every seed genome's entry, then a deterministic prefix
        let seed_set: std::collections::HashSet<&Vec<bool>> = seeds.iter().collect();
        entries.sort_by(|a, b| {
            seed_set
                .contains(b.0)
                .cmp(&seed_set.contains(a.0))
                .then(a.0.cmp(b.0))
        });
        entries.truncate(GA_MEMO_CAP);
        entries.sort_unstable_by(|a, b| a.0.cmp(b.0));
    }
    put_u64(&mut buf, entries.len() as u64);
    for (g, objs) in entries {
        put_genome(&mut buf, g, width);
        put_u32(&mut buf, objs.len() as u32);
        for &o in objs {
            put_f64(&mut buf, o);
        }
    }
    write_snapshot(dir, GA_WARMSTART_FILE, buf)
}

/// Load `dir/ga_warmstart.bin`; `None` when absent/corrupt/stale or when
/// `problem_key`/`width` do not match the file (a different problem's
/// memo must never be injected).
pub fn load_ga_warmstart(dir: &Path, problem_key: u128, width: usize) -> Option<GaWarmStart> {
    let buf = fs::read(dir.join(GA_WARMSTART_FILE)).ok()?;
    let mut r = verified_reader(&buf, GA_MAGIC)?;
    if r.u128()? != problem_key {
        return None;
    }
    if r.u32()? as usize != width {
        return None;
    }
    let n_seeds = r.u32()?;
    let mut seeds = Vec::with_capacity(n_seeds as usize);
    for _ in 0..n_seeds {
        seeds.push(read_genome(&mut r, width)?);
    }
    let n_memo = r.u64()?;
    let mut memo = HashMap::with_capacity(n_memo as usize);
    for _ in 0..n_memo {
        let g = read_genome(&mut r, width)?;
        let n_obj = r.u32()?;
        let mut objs = Vec::with_capacity(n_obj as usize);
        for _ in 0..n_obj {
            objs.push(r.f64()?);
        }
        memo.insert(g, objs);
    }
    if !r.exhausted() {
        return None;
    }
    Some(GaWarmStart { seeds, memo })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("monet_persist_{tag}_{}", std::process::id()));
        fs::remove_dir_all(&d).ok(); // leftovers from a crashed prior run
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn cost(seed: u64) -> NodeCost {
        NodeCost {
            cycles: seed as f64 * 1.5,
            energy_pj: seed as f64 * 2.5,
            offchip_bytes: seed as f64,
            global_bytes: 0.25,
            onchip_bytes: seed as f64 * 3.0,
            utilization: 0.5,
        }
    }

    #[test]
    fn cost_cache_round_trip_preserves_every_bit() {
        let dir = tmp_dir("roundtrip");
        let cache = CostCache::new();
        for k in 0..200u128 {
            cache.insert_loaded(k << 100 | k, cost(k as u64));
        }
        save_cost_cache(&cache, &dir).unwrap();
        let loaded = load_cost_cache(&dir, 0).expect("valid snapshot");
        let a = cache.export_entries();
        let b = loaded.export_entries();
        assert_eq!(a.len(), b.len());
        for ((ka, ca), (kb, cb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            assert_eq!(ca.cycles.to_bits(), cb.cycles.to_bits());
            assert_eq!(ca.energy_pj.to_bits(), cb.energy_pj.to_bits());
            assert_eq!(ca.utilization.to_bits(), cb.utilization.to_bits());
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_corrupt_and_stale_snapshots_are_rejected() {
        let dir = tmp_dir("reject");
        assert!(load_cost_cache(&dir, 0).is_none(), "missing file");

        let cache = CostCache::new();
        cache.insert_loaded(42, cost(7));
        let path = save_cost_cache(&cache, &dir).unwrap();

        // bit-rot: flip one payload byte → checksum rejects
        let orig = fs::read(&path).unwrap();
        let mut bad = orig.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        fs::write(&path, &bad).unwrap();
        assert!(load_cost_cache(&dir, 0).is_none(), "corrupt payload");

        // truncation
        fs::write(&path, &orig[..orig.len() - 3]).unwrap();
        assert!(load_cost_cache(&dir, 0).is_none(), "truncated file");

        // stale contract version: byte 8..12 is the format version,
        // 12..16 the contract version — bump it and re-checksum so only
        // the header guard (not the checksum) can reject
        let mut stale = orig.clone();
        stale.truncate(stale.len() - 8);
        let v = u32::from_le_bytes(stale[12..16].try_into().unwrap()) + 1;
        stale[12..16].copy_from_slice(&v.to_le_bytes());
        let sum = fnv64(&stale);
        stale.extend_from_slice(&sum.to_le_bytes());
        fs::write(&path, &stale).unwrap();
        assert!(load_cost_cache(&dir, 0).is_none(), "stale contract version");

        // intact file loads again
        fs::write(&path, &orig).unwrap();
        assert!(load_cost_cache(&dir, 0).is_some());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshots_from_every_older_contract_are_rejected_wholesale() {
        // a snapshot whose header carries any *previous* contract version
        // (e.g. one written by a pre-cluster-DSE build) must be refused in
        // full — nothing loaded, loader falls back to cold — even though
        // its checksum and payload are perfectly intact
        let dir = tmp_dir("old_contract");
        let cache = CostCache::new();
        for k in 0..10u128 {
            cache.insert_loaded(k, cost(k as u64));
        }
        let path = save_cost_cache(&cache, &dir).unwrap();
        let orig = fs::read(&path).unwrap();
        // CACHE_CONTRACT_VERSION is ≥2 since the cluster-DSE bump, so this
        // loop always exercises at least versions 0 and 1
        for old in 0..super::super::CACHE_CONTRACT_VERSION {
            // bytes 12..16 hold the contract version; rewrite it to the
            // old value and re-checksum so only the header guard decides
            let mut stale = orig.clone();
            stale.truncate(stale.len() - 8);
            stale[12..16].copy_from_slice(&old.to_le_bytes());
            let sum = fnv64(&stale);
            stale.extend_from_slice(&sum.to_le_bytes());
            fs::write(&path, &stale).unwrap();
            assert!(
                load_cost_cache(&dir, 0).is_none(),
                "contract-v{old} snapshot must be rejected wholesale"
            );
        }
        // unmodified current-version snapshot still loads completely
        fs::write(&path, &orig).unwrap();
        let loaded = load_cost_cache(&dir, 0).expect("current snapshot loads");
        assert_eq!(loaded.stats().entries, cache.stats().entries);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bounded_load_respects_capacity() {
        let dir = tmp_dir("bounded");
        let cache = CostCache::new();
        for k in 0..500u128 {
            cache.insert_loaded((k % 16) << 124 | k, cost(k as u64));
        }
        save_cost_cache(&cache, &dir).unwrap();
        let loaded = load_cost_cache(&dir, 64).unwrap();
        assert!(loaded.stats().entries <= 64);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ga_warmstart_round_trip_and_key_guards() {
        let dir = tmp_dir("ga");
        let width = 9usize;
        let seeds = vec![vec![true; width], vec![false; width]];
        let mut memo = HashMap::new();
        memo.insert(
            (0..width).map(|i| i % 2 == 0).collect::<Vec<bool>>(),
            vec![1.0, 2.0, f64::from_bits(0x400921FB54442D18)],
        );
        memo.insert(vec![true; width], vec![0.5, 0.25, 0.125]);
        save_ga_warmstart(&dir, 0xABCD, width, &seeds, &memo).unwrap();

        let w = load_ga_warmstart(&dir, 0xABCD, width).expect("valid warm start");
        assert_eq!(w.seeds, seeds);
        assert_eq!(w.memo.len(), memo.len());
        // audit:allow(DT02): per-key equality assertions — each iteration is independent, order cannot change the verdict
        for (g, objs) in &memo {
            let got = &w.memo[g];
            assert_eq!(objs.len(), got.len());
            for (a, b) in objs.iter().zip(got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // a different problem or width must never warm-start from this file
        assert!(load_ga_warmstart(&dir, 0xABCE, width).is_none());
        assert!(load_ga_warmstart(&dir, 0xABCD, width + 1).is_none());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejected_snapshot_is_quarantined_with_counters() {
        let dir = tmp_dir("quarantine");
        let cache = CostCache::new();
        cache.insert_loaded(1, cost(1));
        let path = save_cost_cache(&cache, &dir).unwrap();
        let mut bad = fs::read(&path).unwrap();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        fs::write(&path, &bad).unwrap();

        let cold = open_cost_cache(Some(&dir), 0);
        let s = cold.stats();
        assert_eq!(s.entries, 0, "nothing from a corrupt snapshot may load");
        assert_eq!(s.snapshots_rejected, 1);
        assert_eq!(s.snapshots_quarantined, 1);
        assert!(!path.exists(), "rejected snapshot must be moved aside");
        let sidecar = dir.join(format!("{COST_SNAPSHOT_FILE}.corrupt"));
        assert!(sidecar.exists(), "quarantine sidecar missing");
        assert_eq!(fs::read(&sidecar).unwrap(), bad, "sidecar must hold the evidence");

        // with the corpse moved aside, the next open is a plain first run
        let again = open_cost_cache(Some(&dir), 0);
        assert_eq!(again.stats().snapshots_rejected, 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reader_str_round_trips_and_rejects_torn_input() {
        let mut buf = Vec::new();
        put_str(&mut buf, "hello monet");
        let mut r = Reader::new(&buf);
        assert_eq!(r.str().as_deref(), Some("hello monet"));
        assert!(r.exhausted());
        let mut torn = Reader::new(&buf[..buf.len() - 1]);
        assert!(torn.str().is_none(), "short payload must not decode");
    }

    #[test]
    fn fingerprint_is_stable_within_a_build() {
        assert_eq!(hasher_fingerprint(), hasher_fingerprint());
        assert_ne!(hasher_fingerprint(), 0);
    }
}
