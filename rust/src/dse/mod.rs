//! Design-space exploration (DESIGN.md S11): the end-user search layer
//! over everything the lower layers can model.
//!
//! The load-bearing piece is [`engine`] — **one** generic worker-pool
//! harness ([`Engine::run`]) owning chunking, per-worker scratch, the
//! shared cost-cache lifecycle (`--no-cache`/`--cache-dir`/
//! `--cache-cap`), stat aggregation and deterministic result ordering.
//! Every experiment is a [`DesignSpace`] (deterministic point
//! enumeration + stable ids) paired with an [`Evaluate`] instance;
//! adding a search dimension means writing one such pair, not forking a
//! harness.
//!
//! Three spaces are searchable today:
//!
//! * **accelerator points** ([`DesignPoint`], Tables II/III) — swept by
//!   [`run_sweep`]/[`search()`] (via [`sweep::SweepEval`]) with the
//!   Pallas-kernel pre-filter ([`prefilter`]) pruning hopeless
//!   configurations before detailed scheduling;
//! * **homogeneous deployments** ([`ClusterPoint`]) — device counts ×
//!   link tiers × DP/PP/TP factorizations ([`run_cluster_sweep`] via
//!   [`sweep::ClusterEval`], ranked by [`cluster_search`]);
//! * **heterogeneous deployments** ([`crate::parallelism::HeteroPoint`])
//!   — a mixed edge/server/datacenter device pool with a stage-placement
//!   dimension ([`ClusterSpace::enumerate_hetero`], [`hetero_search`]
//!   via [`sweep::HeteroEval`] over a [`HeteroSpace`]).
//!
//! Past the exhaustive-enumeration wall (256+-device pools, where the
//! placement dimension is `k^pp`-bounded), [`ga_cluster_search`] evolves
//! [`crate::ga::DeploymentGenome`]s over the generic NSGA-II core
//! instead: the contiguous-block fallback enumeration
//! ([`ClusterSpace::enumerate_hetero_fallback`]) is evaluated as a
//! journaled backbone and baseline, and the returned rank-0 front weakly
//! dominates every fallback front row while visiting a small fraction of
//! [`ClusterSpace::count_hetero`] points.
//!
//! The NSGA-II GA's per-generation genome batches ride the same pool
//! core through [`engine::map_parallel`]. All families share one
//! [`crate::eval::CostCache`] across their workers and are bit-identical
//! across worker counts and cache settings (pinned in
//! `tests/dse_engine.rs`); cluster outcomes are ranked with the typed
//! four-objective [`Objectives`] set (iteration latency, energy,
//! per-device memory, cluster size) through NSGA-II rank-0 dominance.

pub mod engine;
pub mod journal;
pub mod prefilter;
pub mod search;
pub mod space;
pub mod sweep;

pub use engine::{
    map_parallel, try_map_parallel, DesignSpace, Engine, EngineConfig, EngineError, Evaluate,
    HeteroSpace, Objectives, PointFailure, RunOutcome, SharedCache,
};
pub use journal::{journal_record_bounds, JournalRow, PointRecord};
pub use prefilter::{accel_to_cfg, graph_to_layers, prefilter_scores, select_survivors};
pub use search::{
    best_latency_factorization, cluster_search, front_factorizations, front_recall,
    ga_cluster_search, hetero_search, mixed_domination_witness, mixed_placement, placed_only_on,
    search, ClusterSearchOutcome, GaClusterOutcome, SearchOutcome,
};
pub use space::{ClusterPoint, ClusterSpace, DesignPoint};
pub use sweep::{
    evaluate_point, evaluate_point_cached, evaluate_point_prepared, pareto_front,
    run_cluster_sweep, run_cluster_sweep_outcome, run_hetero_sweep, run_hetero_sweep_outcome,
    run_sweep, run_sweep_outcome, run_sweep_stats, ClusterEval, ClusterRow, ClusterScratch,
    FusionStrategy, HeteroEval, Mode, SweepConfig, SweepEval, SweepPartitions, SweepRow,
};
