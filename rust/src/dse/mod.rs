//! Design-space exploration (DESIGN.md S11): the sweep orchestrator, the
//! Table II/III spaces, and the Pallas-kernel pre-filter.

pub mod prefilter;
pub mod search;
pub mod space;
pub mod sweep;

pub use prefilter::{accel_to_cfg, graph_to_layers, prefilter_scores, select_survivors};
pub use search::{
    best_latency_factorization, cluster_search, front_factorizations, front_recall, search,
    ClusterSearchOutcome, SearchOutcome,
};
pub use space::{ClusterPoint, ClusterSpace, DesignPoint};
pub use sweep::{
    evaluate_point_cached, evaluate_point_prepared, SweepPartitions,
    evaluate_point, pareto_front, run_cluster_sweep, run_sweep, run_sweep_stats, ClusterRow,
    FusionStrategy, Mode, SweepConfig, SweepRow,
};
