//! Design-space exploration (DESIGN.md S11): the end-user search layer
//! over everything the lower layers can model.
//!
//! Three spaces are searchable:
//!
//! * **accelerator points** ([`DesignPoint`], Tables II/III) — swept by
//!   [`run_sweep`]/[`search()`] with the Pallas-kernel pre-filter
//!   ([`prefilter`]) pruning hopeless configurations before detailed
//!   scheduling;
//! * **homogeneous deployments** ([`ClusterPoint`]) — device counts ×
//!   link tiers × DP/PP/TP factorizations ([`run_cluster_sweep`],
//!   [`cluster_search`]);
//! * **heterogeneous deployments** ([`crate::parallelism::HeteroPoint`])
//!   — a mixed edge/server/datacenter device pool with a stage-placement
//!   dimension ([`ClusterSpace::enumerate_hetero`], [`hetero_search`]).
//!
//! All sweeps share one [`crate::eval::CostCache`] across their worker
//! pools and are bit-identical across worker counts and cache settings;
//! cluster outcomes are ranked with the four-objective NSGA-II dominance
//! set (iteration latency, energy, per-device memory, cluster size).

pub mod prefilter;
pub mod search;
pub mod space;
pub mod sweep;

pub use prefilter::{accel_to_cfg, graph_to_layers, prefilter_scores, select_survivors};
pub use search::{
    best_latency_factorization, cluster_search, front_factorizations, front_recall,
    hetero_search, mixed_domination_witness, mixed_placement, placed_only_on, search,
    ClusterSearchOutcome, SearchOutcome,
};
pub use space::{ClusterPoint, ClusterSpace, DesignPoint};
pub use sweep::{
    evaluate_point_cached, evaluate_point_prepared, SweepPartitions,
    evaluate_point, pareto_front, run_cluster_sweep, run_hetero_sweep, run_sweep,
    run_sweep_stats, ClusterRow, FusionStrategy, Mode, SweepConfig, SweepRow,
};
