//! The design-space axes swept in the paper's §IV: Edge TPU (Table II) and
//! FuseMax (Table III) points, unified behind one `DesignPoint` type —
//! plus the cluster-scale deployment space ([`ClusterSpace`]): device
//! counts × link tiers × DP/PP/TP factorizations, the searchable
//! dimension behind the Fig 5 edge→datacenter Pareto front. The
//! heterogeneous variant ([`ClusterSpace::enumerate_hetero`]) adds the
//! **stage-placement** dimension: which device class of a mixed pool
//! hosts which pipeline stage.

use crate::hardware::accelerator::Accelerator;
use crate::hardware::presets::{EdgeTpuParams, FuseMaxParams};
use crate::parallelism::{Cluster, HeteroCluster, HeteroPoint, LinkTier, Strategy};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DesignPoint {
    EdgeTpu(EdgeTpuParams),
    FuseMax(FuseMaxParams),
}

impl DesignPoint {
    pub fn build(&self) -> Accelerator {
        match self {
            DesignPoint::EdgeTpu(p) => p.build(),
            DesignPoint::FuseMax(p) => p.build(),
        }
    }

    /// Total compute resource (x-axis of Fig 8).
    pub fn total_macs(&self) -> u64 {
        match self {
            DesignPoint::EdgeTpu(p) => p.total_macs(),
            DesignPoint::FuseMax(p) => p.total_macs(),
        }
    }

    /// Per-PE compute resource U·L (colour axis of Fig 8) or the buffer
    /// bandwidth (colour axis of Fig 9).
    pub fn color_axis(&self) -> f64 {
        match self {
            DesignPoint::EdgeTpu(p) => p.per_pe_macs() as f64,
            DesignPoint::FuseMax(p) => p.buffer_bw as f64,
        }
    }

    pub fn label(&self) -> String {
        match self {
            DesignPoint::EdgeTpu(p) => format!(
                "edge,{},{},{},{},{},{}",
                p.x_pes, p.y_pes, p.u, p.l, p.local_mem, p.regfile
            ),
            DesignPoint::FuseMax(p) => format!(
                "fusemax,{},{},{},{},{},{}",
                p.x_pes, p.y_pes, p.vector_pes, p.buffer_bw, p.buffer_size, p.offchip_bw
            ),
        }
    }

    pub fn edge_space(stride: usize) -> Vec<DesignPoint> {
        EdgeTpuParams::space_strided(stride)
            .into_iter()
            .map(DesignPoint::EdgeTpu)
            .collect()
    }

    pub fn fusemax_space(stride: usize) -> Vec<DesignPoint> {
        FuseMaxParams::space_strided(stride)
            .into_iter()
            .map(DesignPoint::FuseMax)
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Cluster-scale deployment space (paper §II-C1 / Fig 5 made searchable)
// ---------------------------------------------------------------------------

/// One deployment point: a device count on a fabric tier running one
/// hybrid DP/PP/TP factorization (`dp · pp · tp == devices`). The pure
/// strategies are the degenerate factorizations — `(n,1,1)` is data
/// parallelism, `(1,n,1)` pipeline, `(1,1,n)` tensor parallelism — so
/// enumerating hybrids covers everything (see the `parallelism`
/// degeneracy contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ClusterPoint {
    pub devices: usize,
    pub tier: LinkTier,
    pub dp: usize,
    pub pp: usize,
    /// Pipeline microbatches (1 whenever `pp == 1`).
    pub microbatches: usize,
    pub tp: usize,
}

impl ClusterPoint {
    pub fn strategy(&self) -> Strategy {
        Strategy::Hybrid {
            dp: self.dp,
            pp_stages: self.pp,
            microbatches: self.microbatches,
            tp: self.tp,
        }
    }

    pub fn cluster(&self) -> Cluster {
        self.tier.cluster(self.devices)
    }

    /// Stable row label, e.g. `edge,n4,dp2,pp2,m4,tp1`.
    pub fn label(&self) -> String {
        format!(
            "{},n{},dp{},pp{},m{},tp{}",
            self.tier.as_str(),
            self.devices,
            self.dp,
            self.pp,
            self.microbatches,
            self.tp
        )
    }
}

/// The enumerable cluster space: device counts × link tiers ×
/// factorizations (× microbatch options for pipelined points).
#[derive(Debug, Clone)]
pub struct ClusterSpace {
    pub device_counts: Vec<usize>,
    pub tiers: Vec<LinkTier>,
    /// Microbatch counts tried for every factorization with `pp > 1`.
    pub microbatches: Vec<usize>,
}

impl ClusterSpace {
    /// Powers of two from 1 to `max_devices`, all three link tiers,
    /// microbatch options {4, 8}.
    pub fn default_space(max_devices: usize) -> Self {
        let mut device_counts = vec![];
        let mut d = 1usize;
        while d <= max_devices.max(1) {
            device_counts.push(d);
            d *= 2;
        }
        ClusterSpace {
            device_counts,
            tiers: LinkTier::all().to_vec(),
            microbatches: vec![4, 8],
        }
    }

    /// All ordered triples `(dp, pp, tp)` with `dp·pp·tp == n`.
    pub fn factorizations(n: usize) -> Vec<(usize, usize, usize)> {
        let n = n.max(1);
        let mut out = vec![];
        for dp in 1..=n {
            if n % dp != 0 {
                continue;
            }
            let rest = n / dp;
            for pp in 1..=rest {
                if rest % pp != 0 {
                    continue;
                }
                out.push((dp, pp, rest / pp));
            }
        }
        out
    }

    /// Pipelines up to this deep get their stage placements enumerated
    /// exhaustively; deeper ones fall back to contiguous class blocks
    /// (ascending and descending class order) — the sequence count at
    /// depth `pp` over `k` classes is `k^pp`-bounded and would swamp the
    /// sweep beyond this.
    pub const MAX_EXHAUSTIVE_PLACEMENT: usize = 8;

    /// Enumerate every heterogeneous deployment point of a device pool:
    /// factorizations `dp·pp·tp ≤ total devices` × stage placements
    /// feasible under the per-class device counts (each stage occupies
    /// `dp·tp` devices of its class) × microbatch options. `m = 1` (no
    /// microbatching) is always tried for pipelined points — it is the
    /// minimum-energy pipeline corner (no per-microbatch weight
    /// re-streaming). Symmetry pruning: [`HeteroCluster::new`] merges
    /// identically-named pool entries, so no two enumerated placements
    /// are permutations of indistinguishable classes; the `seen` set
    /// drops exact duplicates (e.g. repeated `m = 1`). Deterministic
    /// order: devices, factorization, placement (lexicographic class
    /// order), microbatches.
    pub fn enumerate_hetero(hc: &HeteroCluster, microbatches: &[usize]) -> Vec<HeteroPoint> {
        let total = hc.total_devices();
        let mut out: Vec<HeteroPoint> = vec![];
        let mut seen: std::collections::HashSet<HeteroPoint> = std::collections::HashSet::new();
        for n in 1..=total {
            for (dp, pp, tp) in Self::factorizations(n) {
                let gang = dp * tp;
                let caps: Vec<usize> = hc.counts.iter().map(|&c| c / gang).collect();
                if caps.iter().sum::<usize>() < pp {
                    continue; // not enough stage slots anywhere
                }
                let placements = if pp <= Self::MAX_EXHAUSTIVE_PLACEMENT {
                    class_sequences(pp, &caps)
                } else {
                    class_block_sequences(pp, &caps)
                };
                for placement in placements {
                    let mut ms: Vec<usize> = vec![1];
                    if pp > 1 {
                        ms.extend(microbatches.iter().copied());
                    }
                    for &m in &ms {
                        let p = HeteroPoint {
                            dp,
                            pp,
                            microbatches: m,
                            tp,
                            placement: placement.clone(),
                        };
                        debug_assert!(p.feasible(hc));
                        if seen.insert(p.clone()) {
                            out.push(p);
                        }
                    }
                }
            }
        }
        out
    }

    /// Enumerate every deployment point of the space, deterministically
    /// ordered (devices, tier order, factorization, microbatches).
    pub fn enumerate(&self) -> Vec<ClusterPoint> {
        let mut out = vec![];
        for &devices in &self.device_counts {
            for &tier in &self.tiers {
                for (dp, pp, tp) in Self::factorizations(devices) {
                    if pp > 1 {
                        for &m in &self.microbatches {
                            out.push(ClusterPoint { devices, tier, dp, pp, microbatches: m, tp });
                        }
                    } else {
                        out.push(ClusterPoint { devices, tier, dp, pp, microbatches: 1, tp });
                    }
                }
            }
        }
        out
    }
}

/// All class-index sequences of length `len` under per-class multiplicity
/// caps, in lexicographic class order.
fn class_sequences(len: usize, caps: &[usize]) -> Vec<Vec<usize>> {
    fn rec(len: usize, cur: &mut Vec<usize>, left: &mut [usize], out: &mut Vec<Vec<usize>>) {
        if cur.len() == len {
            out.push(cur.clone());
            return;
        }
        for c in 0..left.len() {
            if left[c] == 0 {
                continue;
            }
            left[c] -= 1;
            cur.push(c);
            rec(len, cur, left, out);
            cur.pop();
            left[c] += 1;
        }
    }
    let mut out = vec![];
    let mut left = caps.to_vec();
    rec(len, &mut Vec::with_capacity(len), &mut left, &mut out);
    out
}

/// Contiguous class-block placements (each class's stages adjacent), in
/// ascending and descending class order — the fallback beyond
/// [`ClusterSpace::MAX_EXHAUSTIVE_PLACEMENT`].
fn class_block_sequences(len: usize, caps: &[usize]) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![];
    for rev in [false, true] {
        let order: Vec<usize> = if rev {
            (0..caps.len()).rev().collect()
        } else {
            (0..caps.len()).collect()
        };
        let mut seq = Vec::with_capacity(len);
        for &c in &order {
            for _ in 0..caps[c] {
                if seq.len() < len {
                    seq.push(c);
                }
            }
        }
        if seq.len() == len && !out.contains(&seq) {
            out.push(seq);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spaces_build() {
        let e = DesignPoint::edge_space(500);
        let f = DesignPoint::fusemax_space(200);
        assert!(!e.is_empty() && !f.is_empty());
        for p in e.iter().chain(&f) {
            let a = p.build();
            assert!(a.total_macs() > 0);
            // the built HDA adds auxiliary vector cores, so its MAC count
            // is at least the point's headline U·L·nPEs resource
            assert!(a.total_macs() >= p.total_macs());
        }
    }

    #[test]
    fn labels_unique_within_space() {
        let pts = DesignPoint::edge_space(100);
        let labels: std::collections::HashSet<String> =
            pts.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), pts.len());
    }

    #[test]
    fn factorizations_cover_and_multiply_back() {
        for n in [1usize, 2, 4, 6, 8, 16] {
            let fs = ClusterSpace::factorizations(n);
            assert!(!fs.is_empty());
            for &(dp, pp, tp) in &fs {
                assert_eq!(dp * pp * tp, n);
            }
            // the three pure strategies are always present
            assert!(fs.contains(&(n, 1, 1)));
            assert!(fs.contains(&(1, n, 1)));
            assert!(fs.contains(&(1, 1, n)));
            // no duplicates
            let set: std::collections::HashSet<_> = fs.iter().collect();
            assert_eq!(set.len(), fs.len());
        }
        assert_eq!(ClusterSpace::factorizations(4).len(), 6);
    }

    #[test]
    fn hetero_enumeration_is_feasible_unique_and_covers_the_extremes() {
        use crate::parallelism::DeviceClass;

        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let pts = ClusterSpace::enumerate_hetero(&hc, &[2, 4]);
        assert!(!pts.is_empty());
        let set: std::collections::HashSet<&HeteroPoint> = pts.iter().collect();
        assert_eq!(set.len(), pts.len(), "duplicate deployment points");
        let labels: std::collections::HashSet<String> = pts.iter().map(|p| p.label(&hc)).collect();
        assert_eq!(labels.len(), pts.len(), "labels must be unique");
        for p in &pts {
            assert!(p.feasible(&hc), "infeasible point enumerated: {p:?}");
            assert!(p.devices() <= hc.total_devices());
            assert!(p.pp > 1 || p.microbatches == 1);
        }
        // the uniform extremes and genuinely mixed placements all appear
        assert!(pts.iter().any(|p| !p.is_mixed() && p.placement == vec![0]));
        assert!(pts.iter().any(|p| !p.is_mixed() && p.placement == vec![1]));
        assert!(pts.iter().any(|p| p.is_mixed()));
        // m = 1 is always tried for pipelined points
        assert!(pts.iter().any(|p| p.pp > 1 && p.microbatches == 1));
        // symmetry pruning: a split pool of identical classes enumerates
        // exactly the same points as the merged pool
        let split = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::edge(), 2)]);
        let merged = HeteroCluster::new(vec![(DeviceClass::edge(), 4)]);
        assert_eq!(
            ClusterSpace::enumerate_hetero(&split, &[2]),
            ClusterSpace::enumerate_hetero(&merged, &[2])
        );
    }

    #[test]
    fn class_sequences_respect_caps() {
        let seqs = class_sequences(2, &[2, 1]);
        assert_eq!(seqs, vec![vec![0, 0], vec![0, 1], vec![1, 0]]);
        assert!(class_sequences(4, &[1, 1]).is_empty());
        // the deep-pipeline fallback keeps only contiguous class blocks
        let blocks = class_block_sequences(4, &[2, 2]);
        assert_eq!(blocks, vec![vec![0, 0, 1, 1], vec![1, 1, 0, 0]]);
    }

    #[test]
    fn cluster_space_enumerates_unique_labelled_points() {
        let space = ClusterSpace::default_space(8);
        assert_eq!(space.device_counts, vec![1, 2, 4, 8]);
        let pts = space.enumerate();
        assert!(!pts.is_empty());
        let labels: std::collections::HashSet<String> =
            pts.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), pts.len(), "labels must be unique");
        for p in &pts {
            assert_eq!(p.dp * p.pp * p.tp, p.devices);
            assert!(p.pp > 1 || p.microbatches == 1);
            assert_eq!(p.cluster().devices, p.devices);
        }
        // every tier appears at every device count
        for &d in &space.device_counts {
            for &t in &space.tiers {
                assert!(pts.iter().any(|p| p.devices == d && p.tier == t));
            }
        }
    }
}
